"""Consolidated cross-backend property harness for the serving engine.

Every backend (exact / PQ / tiered / disk — the tiered backend over the
block-aligned on-disk slow tier — ooc — the out-of-core backend walking a
block-aware packed store with only PQ codes in device memory — disk_hot /
ooc_hot — the same two with the frequency-aware hot tier promoting and
demoting asynchronously underneath the matrix — and
distributed whenever the process has a mesh, i.e. the CI multi-device
matrix job) is pinned to the same
scheduling-transparency properties from shared fixtures
(``tests/_backend_fixtures.py``); the disk variant's reference paths are
the *in-memory* tiered ones, so the matrix also pins storage-tier
bit-identity (plus the explicit bitwise tests below):

* **staged vs monolithic** — the engine's staged probe/bucket/continue
  path returns the single-program adaptive path's results (bitwise for the
  distributed step, whose staged split runs the same mesh kernels; up to
  distance ties for the single-host backends, whose monolithic jit is a
  differently-fused program);
* **bucketed vs unbucketed** — host bucket scheduling (engine and the
  historical core ``num_buckets=`` entry points) never changes math;
* **pipelined vs eager** — ``search_batches`` is bit-identical to
  per-batch ``search``, including ragged final batches and the
  single-batch stream;
* **permutation invariance** — bucket membership is a per-query property,
  never a batch-order artifact (pinned LID center);
* **coalescing** — merged micro-batches split back into per-input-batch
  results bit-identical to serving each batch alone (pinned center).

Consolidates the duplicated identity properties that previously lived in
``test_bucketed_search.py`` and ``test_serving_pipeline.py``; the new
distributed staged path is covered by the same matrix for free.
"""
import numpy as np
import pytest

from tests import _backend_fixtures as fx
from tests._hypothesis_compat import given, settings, st


def _queries(variant):
    if variant == "dist":
        _, _, _, q, _ = fx.built_dist()
        return q
    _, q, _, _, _ = fx.built()
    return q


# ------------------------------------------------------- pipelined vs eager

@settings(max_examples=3, deadline=None)
@given(batch=st.integers(7, 40))
def test_pipelined_bit_identical_to_eager(batch):
    """search_batches == per-batch search, bitwise, on every backend — for
    every batching, including ragged final batches (40 % batch != 0 for most
    draws) and the single-batch stream (batch >= 40: no prefetch partner)."""
    for variant in fx.backends():
        q = _queries(variant)
        batches = fx.split(q, batch)
        eng = fx.engine(variant)
        piped = list(eng.search_batches(batches))
        assert len(piped) == len(batches)
        for res_p, qb in zip(piped, batches):
            fx.assert_bit_identical(res_p, eng.search(qb))


def test_single_batch_stream_degrades_to_search():
    """No prefetch partner: a one-batch stream is exactly search()."""
    for variant in fx.backends():
        q = _queries(variant)
        eng = fx.engine(variant)
        (res,) = list(eng.search_batches([q]))
        fx.assert_bit_identical(res, eng.search(q))


def test_ragged_final_batch_shapes():
    """A ragged tail yields its own full result (one per input batch)."""
    for variant in fx.backends():
        q = _queries(variant)
        batches = [q[:16], q[16:32], q[32:39]]  # 7-lane tail
        for res, qb in zip(fx.engine(variant).search_batches(batches),
                           batches):
            assert res.ids.shape == (qb.shape[0], 10)
            assert res.d2.shape == (qb.shape[0], 10)


# ---------------------------------------------------- staged vs monolithic

@settings(max_examples=3, deadline=None)
@given(batch=st.integers(10, 40))
def test_staged_matches_monolithic(batch):
    """The engine's staged path returns the monolithic single-program
    adaptive path's results — bitwise for the distributed backend (same
    mesh kernels, split at the probe horizon; batch sizes on the chunk
    grid, which is all the monolithic step accepts), up to distance ties
    for the single-host backends."""
    for variant in fx.backends():
        q = _queries(variant)
        # The monolithic distributed step accepts only chunk-divisible
        # batches; pin its size (16 + the 8-lane tail) so the mesh compiles
        # a bounded shape family across examples.
        batch_v = 16 if variant == "dist" else batch
        eng = fx.engine(variant)
        for qb in fx.split(q, batch_v):
            if variant == "dist" and qb.shape[0] % fx.DIST_CHUNK:
                continue
            res = eng.search(qb)
            ids_m, d_m, stats_m, astats_m = fx.monolithic(variant, qb)
            if variant == "dist":
                np.testing.assert_array_equal(res.ids, np.asarray(ids_m))
                np.testing.assert_array_equal(res.d2, np.asarray(d_m))
            else:
                fx.assert_same_up_to_ties(res.ids, res.d2, ids_m, d_m)
                np.testing.assert_array_equal(np.asarray(res.stats.hops),
                                              np.asarray(stats_m.hops))
                np.testing.assert_array_equal(
                    np.asarray(res.astats.budget),
                    np.asarray(astats_m.budget))


# --------------------------------------------------- bucketed vs unbucketed

@settings(max_examples=3, deadline=None)
@given(num_buckets=st.integers(2, 6))
def test_engine_bucketed_matches_unbucketed(num_buckets):
    """Any fixed bucket family, the auto family, and no bucketing at all
    serve the same results on every backend (scheduling changes, math
    doesn't); work accounting (hops, granted budgets) is preserved
    exactly."""
    for variant in fx.backends():
        q = _queries(variant)
        res_u = fx.engine(variant, num_buckets=None).search(q)
        for nb in (num_buckets, "auto"):
            res_b = fx.engine(variant, num_buckets=nb).search(q)
            fx.assert_same_up_to_ties(res_u.ids, res_u.d2,
                                      res_b.ids, res_b.d2)
            np.testing.assert_array_equal(np.asarray(res_u.stats.hops),
                                          np.asarray(res_b.stats.hops))
            np.testing.assert_array_equal(np.asarray(res_u.astats.budget),
                                          np.asarray(res_b.astats.budget))


@settings(max_examples=3, deadline=None)
@given(num_buckets=st.integers(2, 6))
def test_core_bucketed_matches_unbucketed(num_buckets):
    """The historical core ``num_buckets=`` entry points (eager per-bucket
    gathers) stay pinned to the single-program path too."""
    for variant in fx.SINGLE_HOST:
        q = _queries(variant)
        ids_u, d_u, stats_u, astats_u = fx.monolithic(variant, q)
        ids_b, d_b, stats_b, astats_b = fx.core_bucketed(
            variant, q, num_buckets)
        fx.assert_same_up_to_ties(ids_u, d_u, ids_b, d_b)
        np.testing.assert_array_equal(np.asarray(stats_u.hops),
                                      np.asarray(stats_b.hops))
        np.testing.assert_array_equal(np.asarray(astats_u.budget),
                                      np.asarray(astats_b.budget))


# ------------------------------------------------------------- permutation

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_permutation_invariant(seed):
    """Shuffling the query batch must not change any query's result: bucket
    membership (and, distributed, the per-shard budget grant) is a
    per-query property, not a batch-order artifact. Pinned LID center —
    batch-mean centering is the reducer's order sensitivity, not the
    scheduler's."""
    for variant in fx.backends():
        q = _queries(variant)
        perm = np.random.default_rng(seed).permutation(q.shape[0])
        inv = np.argsort(perm)
        eng = fx.engine(variant)
        res_o = eng.search(q)
        res_p = eng.search(np.asarray(q)[perm])
        fx.assert_same_up_to_ties(res_o.ids, res_o.d2,
                                  np.asarray(res_p.ids)[inv],
                                  np.asarray(res_p.d2)[inv])
        np.testing.assert_array_equal(np.asarray(res_o.stats.hops),
                                      np.asarray(res_p.stats.hops)[inv])


# -------------------------------------------------------------- coalescing

@pytest.mark.parametrize("lanes,threshold", [(4, 16), (7, 24)])
def test_coalescing_preserves_per_batch_results(lanes, threshold):
    """Admission coalescing merges micro-batches before dispatch and splits
    the results back: one result per *input* batch, bit-identical per query
    to serving that batch alone (pinned center), order preserved."""
    for variant in fx.backends():
        q = _queries(variant)
        micro = fx.split(q, lanes)
        eng = fx.engine(variant)
        engc = fx.engine(variant, coalesce_lanes=threshold)
        res_c = list(engc.search_batches(micro))
        assert len(res_c) == len(micro)
        for res, qb in zip(res_c, micro):
            ref = eng.search(qb)
            np.testing.assert_array_equal(res.ids, ref.ids)
            np.testing.assert_array_equal(res.d2, ref.d2)
            np.testing.assert_array_equal(np.asarray(res.stats.hops),
                                          np.asarray(ref.stats.hops))
            np.testing.assert_array_equal(np.asarray(res.astats.budget),
                                          np.asarray(ref.astats.budget))


def test_coalescing_monolithic_backend():
    """Coalescing also composes with monolithic dispatch (fixed-beam): the
    merged program's results split back per input batch."""
    x, q, _, idx, _ = fx.built()
    from repro import serving

    eng = serving.SearchEngine(
        serving.ExactBackend(x, idx.adj, idx.entry), None, k=10,
        beam_width=32, coalesce_lanes=32)
    ref = serving.SearchEngine(
        serving.ExactBackend(x, idx.adj, idx.entry), None, k=10,
        beam_width=32)
    micro = fx.split(q, 10)
    res_c = list(eng.search_batches(micro))
    assert len(res_c) == len(micro)
    merged = ref.search(q)
    np.testing.assert_array_equal(
        np.concatenate([r.ids for r in res_c]), merged.ids)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(r.stats.hops) for r in res_c]),
        np.asarray(merged.stats.hops))


# ----------------------------------------------- disk slow tier bit-identity

@pytest.mark.parametrize("num_buckets", [None, 3, "auto"])
def test_disk_slow_tier_bit_identical_to_memory(num_buckets):
    """The block-store-backed slow tier serves *bit-identical* results to
    the in-memory rows — ids, distances, hops and granted budgets — for
    every bucket family, eager and pipelined (ragged final batch included),
    and coalesced micro-batches.  The walk never touches the slow tier;
    only the rerank fetch moves from an in-graph gather to checksummed
    block reads, and the rerank arithmetic is the same jitted program."""
    _, q, _, _, _ = fx.built()
    eng_m = fx.engine("tiered", num_buckets=num_buckets)
    eng_d = fx.engine("disk", num_buckets=num_buckets)
    fx.assert_bit_identical(eng_d.search(q), eng_m.search(q))
    batches = fx.split(q, 9)                     # 40 % 9 != 0: ragged tail
    for res_d, res_m in zip(eng_d.search_batches(batches),
                            eng_m.search_batches(batches)):
        fx.assert_bit_identical(res_d, res_m)
    for res_d, res_m in zip(
            fx.engine("disk", num_buckets=num_buckets,
                      coalesce_lanes=16).search_batches(fx.split(q, 5)),
            fx.engine("tiered", num_buckets=num_buckets,
                      coalesce_lanes=16).search_batches(fx.split(q, 5))):
        fx.assert_bit_identical(res_d, res_m)


def test_disk_fixed_beam_bit_identical_to_memory():
    """Fixed-beam (monolithic dispatch) disk serving matches the in-memory
    fused walk+rerank program bitwise too."""
    from repro import serving

    _, q, _, _, tiered = fx.built()
    eng_m = serving.SearchEngine(serving.TieredBackend(tiered), None, k=10,
                                 beam_width=24)
    eng_d = serving.SearchEngine(
        serving.TieredBackend(tiered, slow_tier=fx.built_disk_tier()), None,
        k=10, beam_width=24)
    res_m, res_d = eng_m.search(q), eng_d.search(q)
    np.testing.assert_array_equal(res_d.ids, res_m.ids)
    np.testing.assert_array_equal(res_d.d2, res_m.d2)
    np.testing.assert_array_equal(np.asarray(res_d.stats.hops),
                                  np.asarray(res_m.stats.hops))
    assert "slow_tier" in res_d.extras   # observability contract holds here too


def test_in_memory_slow_tier_honoured():
    """An explicitly passed InMemorySlowTier is served (not silently
    shadowed by index.vectors) and matches the default in-memory path
    bitwise — the other concrete SlowTier implementation stays live."""
    from repro import serving
    from repro.index import InMemorySlowTier

    _, q, _, _, tiered = fx.built()
    eng_t = serving.SearchEngine(
        serving.TieredBackend(tiered,
                              slow_tier=InMemorySlowTier(tiered.vectors)),
        fx.BUDGET, k=10)
    fx.assert_bit_identical(eng_t.search(q), fx.engine("tiered").search(q))


def _fresh_tier():
    """A private BlockSlowTier over the shared fixture store file — for
    tests that close tiers (the shared fixture tier must stay open)."""
    from repro.index import BlockSlowTier, BlockStore

    return BlockSlowTier(BlockStore(fx.built_disk_tier().store.path))


def test_disk_backend_refresh_requires_explicit_slow_tier():
    """Online-MCGI refresh on a disk backend must re-state the slow tier:
    the old store holds the old vectors, so a bare update() would either
    serve stale reranks or silently fall back to memory.  A replaced disk
    tier's worker thread is shut down (no leak across refreshes)."""
    from repro import serving

    _, _, _, _, tiered = fx.built()
    t1, t2 = _fresh_tier(), _fresh_tier()
    backend = serving.TieredBackend(tiered, slow_tier=t1)
    with pytest.raises(ValueError, match="slow_tier"):
        backend.update(tiered)
    assert not t1.closed                  # failed refresh keeps the old tier
    backend.update(tiered, slow_tier=t2)  # explicit: fine
    assert t1.closed and not t2.closed    # replaced tier torn down
    backend.update(tiered, slow_tier=t2)  # same tier re-stated: not closed
    assert not t2.closed
    backend.update(tiered, slow_tier=None)                  # back to memory
    assert backend.slow_tier is None and t2.closed
    mem = serving.TieredBackend(tiered)
    mem.update(tiered)                                      # memory: as before


def test_backend_close_shuts_down_tier():
    """TieredBackend.close / SearchEngine.close shut the disk tier's worker
    down (idempotently); the engine's close reaches any backend's."""
    from repro import serving

    _, q, _, idx, tiered = fx.built()
    t = _fresh_tier()
    eng = serving.SearchEngine(serving.TieredBackend(tiered, slow_tier=t),
                               fx.BUDGET, k=10)
    eng.search(q[:4])
    eng.close()
    assert t.closed
    eng.close()                                   # idempotent
    # Post-close prefetches degrade to synchronous completed futures (an
    # in-flight stream racing close must still complete with real data).
    assert t.prefetch(np.zeros((1, 2), np.int32)).done()
    # Backends without resources are a no-op close.
    serving.SearchEngine(serving.ExactBackend(
        np.asarray(fx.built()[0]), idx.adj, idx.entry), fx.BUDGET).close()


# --------------------------------------------- out-of-core walk bit-identity

@pytest.mark.parametrize("num_buckets", [None, 3, "auto"])
def test_out_of_core_bit_identical_to_memory(num_buckets):
    """An index whose adjacency + vectors live *only* in the block store
    (out-of-core walk: the device holds just PQ codes) serves bit-identical
    results to the in-memory tiered backend — ids, distances, hops, granted
    budgets and bucket families — for every bucket family, eager and
    pipelined (ragged tail included), and coalesced micro-batches.  The
    fixture store is block-aware packed (nodes_per_block=8, greedy layout),
    so the packed read path is pinned to the same bits too."""
    _, q, _, _, _ = fx.built()
    assert fx.built_ooc_tier().store.nodes_per_block == 8
    eng_m = fx.engine("tiered", num_buckets=num_buckets)
    eng_o = fx.engine("ooc", num_buckets=num_buckets)
    fx.assert_bit_identical(eng_o.search(q), eng_m.search(q))
    batches = fx.split(q, 9)                     # 40 % 9 != 0: ragged tail
    for res_o, res_m in zip(eng_o.search_batches(batches),
                            eng_m.search_batches(batches)):
        fx.assert_bit_identical(res_o, res_m)
    for res_o, res_m in zip(
            fx.engine("ooc", num_buckets=num_buckets,
                      coalesce_lanes=16).search_batches(fx.split(q, 5)),
            fx.engine("tiered", num_buckets=num_buckets,
                      coalesce_lanes=16).search_batches(fx.split(q, 5))):
        fx.assert_bit_identical(res_o, res_m)


def test_out_of_core_io_group_invariance():
    """io_groups is a pure I/O/compute-overlap knob: any grouping of lanes
    round-robined through the walk returns the same bits."""
    from repro import serving

    _, q, _, idx, tiered = fx.built()
    res = []
    for iog in (1, 3):
        be = serving.OutOfCoreBackend(tiered.codes, tiered.codebook,
                                      idx.entry, fx.built_ooc_tier(),
                                      io_groups=iog)
        res.append(serving.SearchEngine(be, fx.BUDGET, k=10).search(q))
    fx.assert_bit_identical(res[0], res[1])
    fx.assert_bit_identical(res[0], fx.engine("ooc").search(q))


def test_out_of_core_fixed_beam_bit_identical_to_memory():
    """Fixed-beam out-of-core serving matches the in-memory tiered
    walk+rerank bitwise (monolithic dispatch, no budget law)."""
    from repro import serving

    _, q, _, idx, tiered = fx.built()
    eng_m = serving.SearchEngine(serving.TieredBackend(tiered), None, k=10,
                                 beam_width=24, max_hops=96)
    eng_o = serving.SearchEngine(
        serving.OutOfCoreBackend(tiered.codes, tiered.codebook, idx.entry,
                                 fx.built_ooc_tier()),
        None, k=10, beam_width=24, max_hops=96)
    res_m, res_o = eng_m.search(q), eng_o.search(q)
    np.testing.assert_array_equal(res_o.ids, res_m.ids)
    np.testing.assert_array_equal(res_o.d2, res_m.d2)
    np.testing.assert_array_equal(np.asarray(res_o.stats.hops),
                                  np.asarray(res_m.stats.hops))
    assert "slow_tier" in res_o.extras


def test_out_of_core_walk_prefetch_stage_engaged():
    """The ooc engine's pipeline runs the walk-prefetch stage (first in the
    stage list) and it only warms the cache — serving with io_depth=0-ish
    tiny depth vs the default returns the same bits."""
    from repro import serving

    _, q, _, idx, tiered = fx.built()
    eng = fx.engine("ooc")
    assert eng._walk_prefetching()
    assert not fx.engine("disk")._walk_prefetching()
    be = serving.OutOfCoreBackend(tiered.codes, tiered.codebook, idx.entry,
                                  fx.built_ooc_tier(), io_depth=1)
    fx.assert_bit_identical(
        serving.SearchEngine(be, fx.BUDGET, k=10).search(q),
        eng.search(q))


def test_out_of_core_refresh_and_zero_query():
    """OOC refresh must name the slow tier explicitly (the store *is* the
    graph here), a replaced tier is closed; zero-query batches serve empty
    typed results through the staged path."""
    from repro import serving
    from repro.index import BlockSlowTier, BlockStore

    _, q, _, idx, tiered = fx.built()
    path = fx.built_ooc_tier().store.path
    t1, t2 = BlockSlowTier(BlockStore(path)), BlockSlowTier(BlockStore(path))
    be = serving.OutOfCoreBackend(tiered.codes, tiered.codebook, idx.entry,
                                  t1)
    with pytest.raises(TypeError):
        be.update(tiered.codes, tiered.codebook, idx.entry)
    with pytest.raises(ValueError, match="BlockSlowTier"):
        be.update(tiered.codes, tiered.codebook, idx.entry, slow_tier=None)
    be.update(tiered.codes, tiered.codebook, idx.entry, slow_tier=t2)
    assert t1.closed and not t2.closed
    eng = serving.SearchEngine(be, fx.BUDGET, k=10)
    r0 = eng.search(np.asarray(q)[:0])
    assert r0.ids.shape == (0, 10) and r0.d2.shape == (0, 10)
    eng.close()
    assert t2.closed


def test_disk_engine_surfaces_cache_stats():
    """Every disk-backed BatchResult carries the slow tier's cumulative
    cache/I-O counters in extras (the observability contract)."""
    _, q, _, _, _ = fx.built()
    res = fx.engine("disk").search(q)
    st = res.extras["slow_tier"]
    assert st["cache_hits"] + st["cache_misses"] > 0
    assert 0.0 <= st["hit_rate"] <= 1.0
    assert st["blocks_read"] >= 0 and st["measured_read_us"] >= 0.0


# ------------------------------------------- frequency-aware hot-tier axis

@pytest.mark.parametrize("variant", ["disk_hot", "ooc_hot"])
def test_hot_tier_bit_identical_to_memory(variant):
    """With the frequency-aware hot tier enabled (small LRU, so promotions
    and demotions actually churn mid-stream), both storage backends stay
    bit-identical to the in-memory tiered reference — eager, pipelined
    (ragged tail) and coalesced micro-batches.  Each pass drains the
    in-flight promotion tick so the next one runs against migrated
    residency, and the counters in ``extras`` prove the tier was live, not
    idle: this is the axis that pins 'promotion changes where a record is
    read, never its bytes'."""
    _, q, _, _, _ = fx.built()
    tier = (fx.built_disk_hot_tier() if variant == "disk_hot"
            else fx.built_ooc_hot_tier())
    eng_m, eng_h = fx.engine("tiered"), fx.engine(variant)
    fx.assert_bit_identical(eng_h.search(q), eng_m.search(q))       # eager
    tier.drain_promotions()
    for res_h, res_m in zip(eng_h.search_batches(fx.split(q, 9)),
                            eng_m.search_batches(fx.split(q, 9))):
        fx.assert_bit_identical(res_h, res_m)   # pipelined, ragged tail
        tier.drain_promotions()                 # next batch sees new residency
    for res_h, res_m in zip(
            fx.engine(variant,
                      coalesce_lanes=16).search_batches(fx.split(q, 5)),
            fx.engine("tiered",
                      coalesce_lanes=16).search_batches(fx.split(q, 5))):
        fx.assert_bit_identical(res_h, res_m)   # coalesced micro-batches
    tier.drain_promotions()
    st = fx.engine(variant).search(q[:4]).extras["slow_tier"]
    assert st["promotion_ticks"] >= 1 and st["promotions"] > 0
    assert 0 < st["hot_nodes"] <= st["hot_capacity"]
    assert st["hot_hits"] > 0          # migrated residency actually served
    assert st["pinned_nodes"] == 64    # pins excluded, still the fast probe


# ------------------------------------------------------- step-kernel axis

def test_step_kernel_staged_bit_identity():
    """The fused Pallas beam step (interpret mode off-TPU) serves
    *bit-identical* results to the reference hop chain on every backend's
    staged adaptive path — ids, distances, hops, granted budgets and the
    chosen bucket family (identical probes grant identical budgets)."""
    for variant in fx.backends():
        q = _queries(variant)
        fx.assert_bit_identical(
            fx.engine(variant, step_kernel="pallas").search(q),
            fx.engine(variant).search(q))


def test_step_kernel_bucketed_and_pipelined_bit_identity():
    """The kernel axis composes with host bucket scheduling and the
    double-buffered pipeline: fixed bucket family, pipelined stream with a
    ragged tail — still bitwise equal to the reference kernel."""
    for variant in fx.backends():
        q = _queries(variant)
        fx.assert_bit_identical(
            fx.engine(variant, num_buckets=3, step_kernel="pallas").search(q),
            fx.engine(variant, num_buckets=3).search(q))
        batches = fx.split(q, 9)                 # 40 % 9 != 0: ragged tail
        for res_p, res_r in zip(
                fx.engine(variant,
                          step_kernel="pallas").search_batches(batches),
                fx.engine(variant).search_batches(batches)):
            fx.assert_bit_identical(res_p, res_r)


def test_step_kernel_coalesced_bit_identity():
    """Admission coalescing over the fused kernel splits back to the same
    per-input-batch results as the reference kernel's coalesced path."""
    for variant in fx.backends():
        q = _queries(variant)
        micro = fx.split(q, 5)
        res_p = list(fx.engine(variant, coalesce_lanes=16,
                               step_kernel="pallas").search_batches(micro))
        res_r = list(fx.engine(variant,
                               coalesce_lanes=16).search_batches(micro))
        assert len(res_p) == len(res_r) == len(micro)
        for a, b in zip(res_p, res_r):
            fx.assert_bit_identical(a, b)


def test_step_kernel_fixed_beam_bit_identity():
    """Fixed-beam serving (monolithic dispatch, disk rerank included) is on
    the kernel axis too: the fused step's walk == the reference walk."""
    from repro import serving

    x, q, _, idx, tiered = fx.built()
    pairs = [
        (serving.ExactBackend(x, idx.adj, idx.entry),
         serving.ExactBackend(x, idx.adj, idx.entry, step_kernel="pallas")),
        (serving.TieredBackend(tiered),
         serving.TieredBackend(tiered, step_kernel="pallas")),
        (serving.TieredBackend(tiered, slow_tier=fx.built_disk_tier()),
         serving.TieredBackend(tiered, slow_tier=fx.built_disk_tier(),
                               step_kernel="pallas")),
    ]
    for b_ref, b_pal in pairs:
        eng_r = serving.SearchEngine(b_ref, None, k=10, beam_width=24)
        eng_p = serving.SearchEngine(b_pal, None, k=10, beam_width=24)
        res_r, res_p = eng_r.search(q), eng_p.search(q)
        np.testing.assert_array_equal(res_p.ids, res_r.ids)
        np.testing.assert_array_equal(res_p.d2, res_r.d2)
        np.testing.assert_array_equal(np.asarray(res_p.stats.hops),
                                      np.asarray(res_r.stats.hops))


def test_step_kernel_knob_resolution():
    """The engine-level knob reaches the backend, and "auto" follows the
    ops-layer dispatch policy (reference on this CPU container, the fused
    step under REPRO_PALLAS_INTERPRET=1)."""
    import os

    from repro import serving
    from repro.core import search
    from repro.kernels import ops

    x, _, _, idx, _ = fx.built()
    backend = serving.ExactBackend(x, idx.adj, idx.entry)
    serving.SearchEngine(backend, fx.BUDGET, k=10, step_kernel="pallas")
    assert backend.step_kernel == "pallas"
    expected_auto = (search.PALLAS_STEP if ops.resolve_impl() != "ref"
                     else search.REFERENCE_STEP)
    assert search.resolve_step_kernel("auto") is expected_auto
    assert search.resolve_step_kernel(None) is search.REFERENCE_STEP
    assert search.resolve_step_kernel("reference") is search.REFERENCE_STEP
    assert search.resolve_step_kernel("pallas") is search.PALLAS_STEP
    with pytest.raises(ValueError, match="step_kernel"):
        search.resolve_step_kernel("vectorised")


# ------------------------------------------- distributed-only extra checks

def test_distributed_per_shard_laws_identity_broadcast():
    """Broadcasting the global (lam, l_min) as per-shard arrays serves
    bit-identical results to the scalar law — the arrays are pure plumbing
    until a per-shard calibration writes real values into them."""
    if not fx.has_mesh():
        pytest.skip("needs >= 8 devices (CI multi-device matrix)")
    from repro import serving

    mesh, arrays, _per, q, _gt = fx.built_dist()
    budget = fx.BUDGET_DIST
    laws = (np.full(8, budget.lam, np.float32),
            np.full(8, budget.l_min, np.int32))
    eng = fx.engine("dist")
    eng_laws = serving.SearchEngine(
        fx._make_backend("dist", budget, shard_laws=laws), budget, k=10)
    res, res_l = eng.search(q), eng_laws.search(q)
    np.testing.assert_array_equal(res.ids, res_l.ids)
    np.testing.assert_array_equal(res.d2, res_l.d2)


def test_distributed_fault_injection_between_batches():
    """set_shard_ok flipped between batches of a pipelined stream: later
    batches exclude the dead shard (graceful, recall loss bounded by its
    data fraction) and nothing recompiles (the mask is a runtime input)."""
    if not fx.has_mesh():
        pytest.skip("needs >= 8 devices (CI multi-device matrix)")
    import jax.numpy as jnp

    from repro import serving
    from repro.core import distance

    mesh, arrays, _per, q, gt_i = fx.built_dist()
    budget = fx.BUDGET_DIST
    backend = fx._make_backend("dist", budget)
    eng = serving.SearchEngine(backend, budget, k=10, num_buckets=None)
    batches = [q[:20]] * 6
    list(eng.search_batches(batches))  # warm every program
    caches = (backend._probe_step._cache_size(),
              backend._continue_step._cache_size())
    dead = jnp.ones((8,), jnp.bool_).at[3].set(False)
    results = []
    for i, res in enumerate(eng.search_batches(batches)):
        results.append(res)
        if i == 1:
            backend.set_shard_ok(dead)
    backend.set_shard_ok(jnp.ones((8,), jnp.bool_))
    r_full = float(distance.recall_at_k(jnp.asarray(results[0].ids),
                                        jnp.asarray(gt_i[:20])))
    r_dead = float(distance.recall_at_k(jnp.asarray(results[-1].ids),
                                        jnp.asarray(gt_i[:20])))
    assert (results[-1].extras["shard_ids"] != 3).all()
    assert np.isfinite(results[-1].d2).all()   # best-so-far under deadlines
    assert r_dead >= r_full - 0.2, (r_full, r_dead)
    assert (backend._probe_step._cache_size(),
            backend._continue_step._cache_size()) == caches
