"""Per-architecture smoke tests (deliverable f): every assigned arch runs a
reduced-config forward/train step on CPU — output shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(0)

LM_ARCHS = [
    "qwen3-moe-30b-a3b", "deepseek-v2-lite-16b", "deepseek-coder-33b",
    "qwen2-7b", "minicpm-2b",
]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    spec = cfg_base.get(arch)
    cfg: tfm.TransformerConfig = spec.smoke_config
    params = tfm.init_lm(cfg, KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    loss, metrics = tfm.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: tfm.lm_loss(cfg, p, batch)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    spec = cfg_base.get(arch)
    cfg: tfm.TransformerConfig = spec.smoke_config
    params = tfm.init_lm(cfg, KEY)
    B = 2
    cache = tfm.init_cache(cfg, B, 16, dtype=jnp.float32)
    logits, cache2 = tfm.decode_step(
        cfg, params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B,), jnp.int32),
    )
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_lm_full_config_param_counts():
    """Full configs must hit their published parameter budgets (shape-only,
    via eval_shape — nothing is allocated)."""
    expected = {
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "deepseek-coder-33b": (31e9, 35e9),
        "qwen2-7b": (7e9, 8.2e9),
        "minicpm-2b": (2.3e9, 3.1e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = cfg_base.get(arch).config
        n = cfg.n_params()
        assert lo <= n <= hi, (arch, n)


def test_qwen3_moe_active_params():
    cfg = cfg_base.get("qwen3-moe-30b-a3b").config
    active = cfg.n_active_params()
    assert 2.5e9 <= active <= 4e9, active  # "A3B"


def test_gnn_smoke_all_regimes():
    spec = cfg_base.get("gat-cora")
    arch_cfg = spec.smoke_config
    rng = np.random.default_rng(0)
    for cell in spec.shapes:
        meta = cell.meta
        cfg = arch_cfg.for_regime(d_in=16, n_classes=meta["n_classes"])
        n, e = 100, 300
        p = gnn_mod.gat_init(KEY, cfg)
        feats = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
        ei = jnp.asarray(gnn_mod.pad_edges(
            rng.integers(0, n, e), rng.integers(0, n, e), 384, n))
        if meta["level"] == "graph":
            batch = {
                "features": feats, "edge_index": ei,
                "graph_ids": jnp.asarray((np.arange(n) % 4).astype(np.int32)),
                "labels": jnp.asarray(rng.integers(0, meta["n_classes"], 4)
                                      .astype(np.int32)),
            }
            loss, _ = gnn_mod.gat_graph_loss(cfg, p, batch)
        else:
            batch = {
                "features": feats, "edge_index": ei,
                "labels": jnp.asarray(rng.integers(0, meta["n_classes"], n)
                                      .astype(np.int32)),
                "mask": jnp.ones((n,), bool),
            }
            loss, _ = gnn_mod.gat_loss(cfg, p, batch)
        assert np.isfinite(float(loss)), cell.name


RECSYS = ["dlrm-mlperf", "deepfm", "mind", "bert4rec"]


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_smoke_full_cycle(arch):
    """Train loss + serve scores + retrieval scores on the smoke config."""
    spec = cfg_base.get(arch)
    cfg = spec.smoke_config
    B, C = 16, 64
    if arch == "dlrm-mlperf":
        p = recsys_mod.dlrm_init(KEY, cfg)
        batch = {"dense": jax.random.normal(KEY, (B, cfg.n_dense)),
                 "sparse": jax.random.randint(KEY, (B, cfg.n_sparse), 0, 5),
                 "labels": jax.random.bernoulli(KEY, 0.3, (B,))}
        loss, _ = recsys_mod.dlrm_loss(cfg, p, batch)
        scores = recsys_mod.dlrm_retrieval(cfg, p, {
            "dense": batch["dense"][:1], "sparse": batch["sparse"][:1],
            "candidates": jnp.arange(C)})
        assert scores.shape == (C,)
    elif arch == "deepfm":
        p = recsys_mod.deepfm_init(KEY, cfg)
        batch = {"sparse": jax.random.randint(KEY, (B, cfg.n_fields), 0, 50),
                 "labels": jax.random.bernoulli(KEY, 0.3, (B,))}
        loss, _ = recsys_mod.deepfm_loss(cfg, p, batch)
        scores = recsys_mod.deepfm_retrieval(cfg, p, {
            "sparse": batch["sparse"][:1], "candidates": jnp.arange(C)})
        assert scores.shape == (C,)
    elif arch == "mind":
        p = recsys_mod.mind_init(KEY, cfg)
        batch = {"hist": jax.random.randint(KEY, (B, cfg.hist_len), 0, 100),
                 "hist_mask": jnp.ones((B, cfg.hist_len), bool),
                 "target": jax.random.randint(KEY, (B,), 0, 100)}
        loss, _ = recsys_mod.mind_loss(cfg, p, batch)
        scores = recsys_mod.mind_retrieval(cfg, p, {
            "hist": batch["hist"][:1], "hist_mask": batch["hist_mask"][:1],
            "candidates": jnp.arange(C)})
        assert scores.shape == (1, C)
    else:
        p = recsys_mod.bert4rec_init(KEY, cfg)
        batch = {"seq": jax.random.randint(KEY, (B, cfg.seq_len), 0, 100),
                 "seq_mask": jnp.ones((B, cfg.seq_len), bool),
                 "mlm_positions": jax.random.randint(KEY, (B, 4), 0, cfg.seq_len),
                 "mlm_labels": jax.random.randint(KEY, (B, 4), 0, 100)}
        loss, _ = recsys_mod.bert4rec_loss(cfg, p, batch)
        scores = recsys_mod.bert4rec_retrieval(cfg, p, {
            "seq": batch["seq"][:1], "seq_mask": batch["seq_mask"][:1],
            "candidates": jnp.arange(C)})
        assert scores.shape == (1, C)
    assert np.isfinite(float(loss)), arch


def test_mcgi_shard_budget_laws():
    """McgiDatasetConfig.shard_budget_laws broadcasts the dataset's budget
    law per shard (the serve cells' runtime-array plumbing): stored
    per-shard fits pass through verbatim and must match the shard count;
    with none stored the global (lam, l_min) broadcasts."""
    import dataclasses

    from repro.configs.mcgi_datasets import McgiDatasetConfig

    cfg = McgiDatasetConfig("t", 1000, 32, 16, 32, None, "float32",
                            l_search=64, lam=0.3, l_min=8)
    lam, l_min = cfg.shard_budget_laws(4)
    assert lam.shape == (4,) and lam.dtype == np.float32
    assert l_min.shape == (4,) and l_min.dtype == np.int32
    assert (lam == np.float32(0.3)).all() and (l_min == 8).all()

    fitted = dataclasses.replace(cfg, shard_lam=(0.1, 0.5),
                                 shard_l_min=(2, 16))
    lam2, l_min2 = fitted.shard_budget_laws(2)
    np.testing.assert_allclose(lam2, np.asarray([0.1, 0.5], np.float32))
    np.testing.assert_array_equal(l_min2, [2, 16])
    with pytest.raises(AssertionError):
        fitted.shard_budget_laws(4)  # stored fits must match the mesh


def test_registry_complete():
    """All 10 assigned archs + 5 paper-dataset archs registered; 40 assigned
    cells present."""
    archs = cfg_base.all_archs()
    assigned = [a for a, s in archs.items() if s.family in ("lm", "gnn", "recsys")]
    assert len(assigned) == 10, sorted(assigned)
    cells = sum(len(archs[a].shapes) for a in assigned)
    assert cells == 40, cells
    mcgi = [a for a, s in archs.items() if s.family == "mcgi"]
    assert len(mcgi) == 5
