"""Round-trip coverage for the index serializer: a built TieredIndex
(adjacency, PQ codebook, medoid entry, geometric profile, disk-tier model)
must survive serialize/deserialize with bit-identical search behaviour."""
import numpy as np
import pytest

from repro.core import build, search
from repro.index import (build_tiered_index, load_disk_model, load_index,
                         load_shard_laws, save_index)
from repro.index.disk import (DiskTierModel, search_tiered,
                              search_tiered_adaptive)

CFG = build.BuildConfig(degree=16, beam_width=32, iters=1, batch=256,
                        max_hops=64)


@pytest.fixture(scope="module")
def built(tiny_dataset):
    x, q = tiny_dataset
    x, q = x[:1000], q[:24]
    idx = build.build_mcgi(x, CFG)
    return build_tiered_index(x, idx, m_pq=8), q


def test_round_trip_bit_identical_arrays(built, tmp_path):
    index, _ = built
    p = tmp_path / "idx.npz"
    save_index(p, index)
    loaded = load_index(p)
    for name, a, b in (
        ("adj", index.graph.adj, loaded.graph.adj),
        ("entry", index.graph.entry, loaded.graph.entry),
        ("alpha", index.graph.alpha, loaded.graph.alpha),
        ("lid", index.graph.lid, loaded.graph.lid),
        ("mu", index.graph.mu, loaded.graph.mu),
        ("sigma", index.graph.sigma, loaded.graph.sigma),
        ("centroids", index.codebook.centroids, loaded.codebook.centroids),
        ("codes", index.codes, loaded.codes),
        ("vectors", index.vectors, loaded.vectors),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
        assert np.asarray(a).dtype == np.asarray(b).dtype, name
    assert loaded.n == index.n
    assert loaded.fast_tier_bytes() == index.fast_tier_bytes()


def test_round_trip_search_bit_identical(built, tmp_path):
    """The loaded index serves *exactly* what the in-memory one serves —
    fixed-beam and adaptive (bucketed) paths both, ids and distances."""
    index, q = built
    p = tmp_path / "idx.npz"
    save_index(p, index)
    loaded = load_index(p)

    ids_a, d2_a, _ = search_tiered(index, q, beam_width=24, k=10)
    ids_b, d2_b, _ = search_tiered(loaded, q, beam_width=24, k=10)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d2_a), np.asarray(d2_b))

    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=24, lam=0.3)
    for num_buckets in (None, 3):
        ia, da, sa, aa = search_tiered_adaptive(
            index, q, cfg, k=10, num_buckets=num_buckets)
        ib, db, sb, ab = search_tiered_adaptive(
            loaded, q, cfg, k=10, num_buckets=num_buckets)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
        np.testing.assert_array_equal(np.asarray(sa.hops), np.asarray(sb.hops))
        np.testing.assert_array_equal(np.asarray(aa.budget),
                                      np.asarray(ab.budget))


def test_round_trip_disk_model(built, tmp_path):
    index, _ = built
    model = DiskTierModel(read_latency_us=20.0, queue_depth=16)
    p = tmp_path / "with_model.npz"
    save_index(p, index, disk_model=model)
    loaded = load_disk_model(p)
    assert loaded == model
    # The reloaded model prices work identically.
    import jax.numpy as jnp
    assert float(loaded.latency_us(jnp.float32(10), rerank_reads=32)) == \
        float(model.latency_us(jnp.float32(10), rerank_reads=32))
    # Indexes saved without a model stay loadable and report None.
    p2 = tmp_path / "without_model.npz"
    save_index(p2, index)
    assert load_disk_model(p2) is None
    assert load_index(p2).n == index.n


def test_round_trip_shard_laws(built, tmp_path):
    """Per-shard calibrated (lam, l_min) budget-law arrays survive the
    round trip bit-exactly (float32 -> json double -> float32 is lossless)
    and stay optional — indexes without them report None."""
    index, _ = built
    lam = np.asarray([0.188, 0.0, 0.5, 1.0], np.float32)
    l_min = np.asarray([2, 8, 4, 16], np.int32)
    p = tmp_path / "with_laws.npz"
    save_index(p, index, shard_laws=(lam, l_min))
    out = load_shard_laws(p)
    assert out is not None
    np.testing.assert_array_equal(out[0], lam)
    np.testing.assert_array_equal(out[1], l_min)
    assert out[0].dtype == np.float32 and out[1].dtype == np.int32
    # Composes with the disk model in the same manifest.
    p2 = tmp_path / "laws_and_model.npz"
    save_index(p2, index, disk_model=DiskTierModel(),
               shard_laws=(lam, l_min))
    assert load_disk_model(p2) is not None
    np.testing.assert_array_equal(load_shard_laws(p2)[0], lam)
    # Absent by default; the index itself still loads.
    p3 = tmp_path / "without_laws.npz"
    save_index(p3, index)
    assert load_shard_laws(p3) is None
    assert load_index(p3).n == index.n
