"""Round-trip coverage for the index serializer: a built TieredIndex
(adjacency, PQ codebook, medoid entry, geometric profile, disk-tier model)
must survive serialize/deserialize with bit-identical search behaviour —
in both on-disk formats (v1 single-npz, v2 npz + block-store sidecar), and
migrating between them."""
import json
import pathlib

import numpy as np
import pytest

from repro.core import build, search
from repro.index import (build_tiered_index, load_disk_model, load_index,
                         load_shard_laws, load_slow_tier, open_block_store,
                         save_index)
from repro.index.disk import (DiskTierModel, search_tiered,
                              search_tiered_adaptive)
from repro.index.serializer import FORMAT_V1, FORMAT_V2, blocks_path


def _manifest(path) -> dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["manifest"]))

CFG = build.BuildConfig(degree=16, beam_width=32, iters=1, batch=256,
                        max_hops=64)


@pytest.fixture(scope="module")
def built(tiny_dataset):
    x, q = tiny_dataset
    x, q = x[:1000], q[:24]
    idx = build.build_mcgi(x, CFG)
    return build_tiered_index(x, idx, m_pq=8), q


def test_round_trip_bit_identical_arrays(built, tmp_path):
    index, _ = built
    p = tmp_path / "idx.npz"
    save_index(p, index)
    loaded = load_index(p)
    for name, a, b in (
        ("adj", index.graph.adj, loaded.graph.adj),
        ("entry", index.graph.entry, loaded.graph.entry),
        ("alpha", index.graph.alpha, loaded.graph.alpha),
        ("lid", index.graph.lid, loaded.graph.lid),
        ("mu", index.graph.mu, loaded.graph.mu),
        ("sigma", index.graph.sigma, loaded.graph.sigma),
        ("centroids", index.codebook.centroids, loaded.codebook.centroids),
        ("codes", index.codes, loaded.codes),
        ("vectors", index.vectors, loaded.vectors),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
        assert np.asarray(a).dtype == np.asarray(b).dtype, name
    assert loaded.n == index.n
    assert loaded.fast_tier_bytes() == index.fast_tier_bytes()


def test_round_trip_search_bit_identical(built, tmp_path):
    """The loaded index serves *exactly* what the in-memory one serves —
    fixed-beam and adaptive (bucketed) paths both, ids and distances."""
    index, q = built
    p = tmp_path / "idx.npz"
    save_index(p, index)
    loaded = load_index(p)

    ids_a, d2_a, _ = search_tiered(index, q, beam_width=24, k=10)
    ids_b, d2_b, _ = search_tiered(loaded, q, beam_width=24, k=10)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d2_a), np.asarray(d2_b))

    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=24, lam=0.3)
    for num_buckets in (None, 3):
        ia, da, sa, aa = search_tiered_adaptive(
            index, q, cfg, k=10, num_buckets=num_buckets)
        ib, db, sb, ab = search_tiered_adaptive(
            loaded, q, cfg, k=10, num_buckets=num_buckets)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
        np.testing.assert_array_equal(np.asarray(sa.hops), np.asarray(sb.hops))
        np.testing.assert_array_equal(np.asarray(aa.budget),
                                      np.asarray(ab.budget))


def test_round_trip_disk_model(built, tmp_path):
    index, _ = built
    model = DiskTierModel(read_latency_us=20.0, queue_depth=16)
    p = tmp_path / "with_model.npz"
    save_index(p, index, disk_model=model)
    loaded = load_disk_model(p)
    assert loaded == model
    # The reloaded model prices work identically.
    import jax.numpy as jnp
    assert float(loaded.latency_us(jnp.float32(10), rerank_reads=32)) == \
        float(model.latency_us(jnp.float32(10), rerank_reads=32))
    # Indexes saved without a model stay loadable and report None.
    p2 = tmp_path / "without_model.npz"
    save_index(p2, index)
    assert load_disk_model(p2) is None
    assert load_index(p2).n == index.n


def _assert_same_index(a, b):
    for name, x, y in (
        ("adj", a.graph.adj, b.graph.adj),
        ("entry", a.graph.entry, b.graph.entry),
        ("alpha", a.graph.alpha, b.graph.alpha),
        ("lid", a.graph.lid, b.graph.lid),
        ("mu", a.graph.mu, b.graph.mu),
        ("sigma", a.graph.sigma, b.graph.sigma),
        ("centroids", a.codebook.centroids, b.codebook.centroids),
        ("codes", a.codes, b.codes),
        ("vectors", a.vectors, b.vectors),
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)
        assert np.asarray(x).dtype == np.asarray(y).dtype, name


def test_v1_loads_under_v2_code_path_and_migrates(built, tmp_path):
    """Migration both directions: a v1 file (what every pre-v2 deployment
    has on disk) loads bit-identically under the v2-aware loader; re-saving
    the loaded index as v2 and loading *that* is still bit-identical; and a
    v2 index re-saved as v1 closes the loop.  Riders (disk_model,
    shard_laws) survive every leg."""
    index, q = built
    model = DiskTierModel(read_latency_us=20.0, queue_depth=16)
    laws = (np.asarray([0.25, 0.5], np.float32), np.asarray([4, 8], np.int32))

    p1 = tmp_path / "v1.npz"
    save_index(p1, index, disk_model=model, shard_laws=laws)  # v1 default
    assert _manifest(p1)["format"] == FORMAT_V1
    assert not blocks_path(p1).exists()
    loaded1 = load_index(p1)
    _assert_same_index(index, loaded1)

    p2 = tmp_path / "v2.npz"
    save_index(p2, loaded1, disk_model=model, shard_laws=laws, version=2)
    assert _manifest(p2)["format"] == FORMAT_V2
    assert blocks_path(p2).exists()
    loaded2 = load_index(p2)
    _assert_same_index(index, loaded2)

    p1b = tmp_path / "back_to_v1.npz"
    save_index(p1b, loaded2, disk_model=model, shard_laws=laws, version=1)
    _assert_same_index(index, load_index(p1b))

    for p in (p1, p2, p1b):
        assert load_disk_model(p) == model
        out = load_shard_laws(p)
        np.testing.assert_array_equal(out[0], laws[0])
        np.testing.assert_array_equal(out[1], laws[1])

    # Both formats serve bit-identically (the loaded arrays are identical,
    # but pin the end-to-end claim on the deployed tiered path too).
    ids_a, d2_a, _ = search_tiered(loaded1, q, beam_width=24, k=10)
    ids_b, d2_b, _ = search_tiered(loaded2, q, beam_width=24, k=10)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(d2_a), np.asarray(d2_b))


def test_v2_sidecar_serves_the_slow_tier(built, tmp_path):
    """The v2 sidecar is a live slow tier: ``load_slow_tier`` opens it with
    entry-proximal pins and fetches exactly the saved vectors; the block
    adjacency matches the npz fast-tier adjacency row for row."""
    index, _ = built
    p = tmp_path / "v2.npz"
    save_index(p, index, version=2)
    store = open_block_store(p)
    vecs, adj = store.read_many(np.arange(store.n))
    np.testing.assert_array_equal(vecs, np.asarray(index.vectors))
    np.testing.assert_array_equal(adj, np.asarray(index.graph.adj))
    tier = load_slow_tier(p, cache_nodes=64, pin_nodes=16)
    assert tier.stats()["pinned_nodes"] == 16
    beams = np.asarray([[0, 5, -1], [7, 7, 2]])
    want = np.zeros((*beams.shape, store.d), np.float32)
    want[beams >= 0] = np.asarray(index.vectors)[beams[beams >= 0]]
    np.testing.assert_array_equal(tier.fetch_beams(beams), want)
    # v1 files have no sidecar to serve from — a typed error says so.
    from repro.index import BlockStoreFormatError

    p1 = tmp_path / "v1.npz"
    save_index(p1, index)
    with pytest.raises(BlockStoreFormatError, match="version=2"):
        load_slow_tier(p1)


def test_unknown_version_rejected(built, tmp_path):
    index, _ = built
    with pytest.raises(ValueError, match="unknown index format version"):
        save_index(tmp_path / "v3.npz", index, version=3)
    # Unknown format string on load is a clear error too.
    p = tmp_path / "weird.npz"
    save_index(p, index)
    with np.load(p, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files if k != "manifest"}
        manifest = json.loads(str(z["manifest"]))
    manifest["format"] = "repro.tiered_index.v99"
    np.savez_compressed(p, manifest=json.dumps(manifest), **arrays)
    with pytest.raises(ValueError, match="v99"):
        load_index(p)


def test_round_trip_shard_laws(built, tmp_path):
    """Per-shard calibrated (lam, l_min) budget-law arrays survive the
    round trip bit-exactly (float32 -> json double -> float32 is lossless)
    and stay optional — indexes without them report None."""
    index, _ = built
    lam = np.asarray([0.188, 0.0, 0.5, 1.0], np.float32)
    l_min = np.asarray([2, 8, 4, 16], np.int32)
    p = tmp_path / "with_laws.npz"
    save_index(p, index, shard_laws=(lam, l_min))
    out = load_shard_laws(p)
    assert out is not None
    np.testing.assert_array_equal(out[0], lam)
    np.testing.assert_array_equal(out[1], l_min)
    assert out[0].dtype == np.float32 and out[1].dtype == np.int32
    # Composes with the disk model in the same manifest.
    p2 = tmp_path / "laws_and_model.npz"
    save_index(p2, index, disk_model=DiskTierModel(),
               shard_laws=(lam, l_min))
    assert load_disk_model(p2) is not None
    np.testing.assert_array_equal(load_shard_laws(p2)[0], lam)
    # Absent by default; the index itself still loads.
    p3 = tmp_path / "without_laws.npz"
    save_index(p3, index)
    assert load_shard_laws(p3) is None
    assert load_index(p3).n == index.n


def test_v2_packed_sidecar_round_trips_and_pins_layout(built, tmp_path):
    """A block-aware (packed) v2 sidecar: the layout rider rides in the
    manifest, loading stays bit-identical to the node-order layout, the
    slow tier serves from it, and a sidecar swapped for a differently-laid
    -out rebuild of the *same content* is refused."""
    from repro.core import block_layout
    from repro.index import BlockStoreFormatError, write_block_store

    index, _ = built
    p = tmp_path / "packed.npz"
    save_index(p, index, version=2, nodes_per_block=8,
               slot_of=block_layout(index.graph, 8))
    blk = _manifest(p)["blocks"]
    assert blk["nodes_per_block"] == 8 and blk["layout"] == "packed"
    assert blk["slot_table_crc32"] is not None
    store = open_block_store(p)
    assert store.nodes_per_block == 8 and store.layout == "packed"
    _assert_same_index(index, load_index(p))     # layout-agnostic arrays
    tier = load_slow_tier(p, cache_nodes=64, pin_nodes=8)
    np.testing.assert_array_equal(
        tier.fetch_beams(np.asarray([[0, 5, -1]]))[0, :2],
        np.asarray(index.vectors)[[0, 5]])
    tier.close()
    # Same content, node-order layout: only the layout rider can tell.
    write_block_store(blocks_path(p), np.asarray(index.vectors),
                      np.asarray(index.graph.adj))
    with pytest.raises(BlockStoreFormatError, match="stale or swapped"):
        open_block_store(p)
    # Default-layout saves keep the historical manifest (no layout keys).
    p1 = tmp_path / "plain.npz"
    save_index(p1, index, version=2)
    assert "nodes_per_block" not in _manifest(p1)["blocks"]
