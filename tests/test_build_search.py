"""End-to-end index quality: MCGI vs Vamana vs Online-MCGI, recall + I/O."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, distance, online, search
from repro.core.ivf import build_ivf, search_ivf
from repro.core.hnsw import build_hnsw, search_hnsw

CFG = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                        max_hops=96)


@pytest.fixture(scope="module")
def built(tiny_dataset):
    x, q = tiny_dataset
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build.build_mcgi(x, CFG)
    return x, q, gt_i, idx


def test_mcgi_recall(built):
    x, q, gt_i, idx = built
    ids, _, stats = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=48, k=10
    )
    r = float(distance.recall_at_k(ids, gt_i))
    assert r >= 0.95, r
    assert float(stats.hops.mean()) < 96


def test_alpha_tracks_lid(built):
    """The paper's core mechanism: high-LID nodes get small alpha."""
    _, _, _, idx = built
    lid = np.asarray(idx.lid)
    alpha = np.asarray(idx.alpha)
    corr = np.corrcoef(lid, alpha)[0, 1]
    assert corr < -0.9, corr  # logistic of z-score: strongly anti-monotone
    assert alpha.min() >= 1.0 and alpha.max() <= 1.5


def test_recall_increases_with_beam(built):
    """Fig. 2b trend: recall(L) monotone-ish in L."""
    x, q, gt_i, idx = built
    recalls = []
    for L in (8, 24, 64):
        ids, _, _ = search.beam_search_exact(
            x, idx.adj, q, idx.entry, beam_width=L, k=10
        )
        recalls.append(float(distance.recall_at_k(ids, gt_i)))
    assert recalls[0] <= recalls[1] + 0.02
    assert recalls[1] <= recalls[2] + 0.02
    assert recalls[-1] > 0.9


def test_vamana_baseline_recall(tiny_dataset):
    x, q = tiny_dataset
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build.build_vamana(x, alpha=1.2, cfg=CFG)
    ids, _, _ = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=48, k=10
    )
    assert float(distance.recall_at_k(ids, gt_i)) >= 0.9
    assert float(idx.alpha[0]) == pytest.approx(1.2)


def test_online_mcgi_recall(tiny_dataset):
    x, q = tiny_dataset
    x = x[:1000]
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = online.build_online_mcgi(
        x, dataclasses.replace(CFG, iters=2), sample=256
    )
    ids, _, _ = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=48, k=10
    )
    assert float(distance.recall_at_k(ids, gt_i)) >= 0.9
    # Online alpha must actually vary across nodes (adaptivity happened).
    assert float(jnp.std(idx.alpha)) > 1e-3


def test_ivf_baseline(tiny_dataset):
    x, q = tiny_dataset
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build_ivf(x, nlist=32, iters=5)
    ids, _, scanned = search_ivf(idx, x, q, nprobe=8, k=10)
    r = float(distance.recall_at_k(ids, gt_i))
    assert r >= 0.9, r
    assert float(scanned.mean()) < x.shape[0]  # sub-linear scan


def test_hnsw_baseline(tiny_dataset):
    x, q = tiny_dataset
    x, q = x[:800], q[:20]
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build_hnsw(x, m=12, ef_construction=64)
    ids, _, _ = search_hnsw(idx, x, q, ef=48, k=10)
    r = float(distance.recall_at_k(ids, gt_i))
    assert r >= 0.9, r


def test_search_stats_io_accounting(built):
    """Hops == slow-tier reads: bounded by max_hops, > 0, and dist_evals
    <= hops * degree."""
    x, q, _, idx = built
    _, _, stats = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=16, max_hops=50, k=10
    )
    hops = np.asarray(stats.hops)
    evals = np.asarray(stats.dist_evals)
    assert (hops > 0).all() and (hops <= 50).all()
    assert (evals <= hops * idx.degree_cap).all()
