"""Training substrate: optimizer, schedules, compression, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import compression as comp
from repro.training import optimizer as opt
from repro.training import train_step as ts

KEY = jax.random.PRNGKey(0)


def _quadratic_problem():
    w_true = jax.random.normal(KEY, (16,))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 16))
    y = x @ w_true

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {}

    params = {"w": jnp.zeros((16,))}
    return loss_fn, params, {"x": x, "y": y}


def test_adamw_converges():
    loss_fn, params, batch = _quadratic_problem()
    cfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0, schedule="const",
                          grad_clip=10.0)
    step = jax.jit(ts.make_train_step(loss_fn, cfg))
    state = ts.init_train_state(params)
    for _ in range(200):
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < 1e-2


def test_schedules_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt.cosine_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=1e-3)
    assert lrs[-1] < 1e-3
    wsd = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="wsd", decay_frac=0.2)
    stable = float(opt.wsd_schedule(wsd, jnp.int32(50)))
    late = float(opt.wsd_schedule(wsd, jnp.int32(99)))
    assert stable == pytest.approx(1.0, abs=1e-3)  # flat plateau
    assert late < 0.05  # sharp final decay


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_compression_error_feedback_roundtrip():
    g = {"w": jax.random.normal(KEY, (128,))}
    err = comp.init_error_feedback(g)
    deq, err2 = comp.compress_grads_with_feedback(g, err)
    # First-step quantisation error bounded by scale/2 per element.
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale
    # Error feedback carries the residual exactly.
    np.testing.assert_allclose(
        np.asarray(err2["w"]), np.asarray(g["w"] - deq["w"]), rtol=1e-6
    )


def test_compressed_training_converges():
    loss_fn, params, batch = _quadratic_problem()
    cfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0, schedule="const")
    step = jax.jit(ts.make_train_step(loss_fn, cfg, compress_grads=True))
    state = ts.init_train_state(params, compress_grads=True)
    for _ in range(300):
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < 5e-2  # int8 grads + EF still converge


def test_compression_payload_accounting():
    params = {"w": jnp.zeros((1000,)), "b": jnp.zeros((10,))}
    full, compressed = comp.compressed_allreduce_bytes(params)
    assert full == 4 * 1010
    assert compressed < full / 3.5


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.int32(7)}
    ckpt.save_checkpoint(tmp_path, 3, tree)
    restored, step = ckpt.restore_checkpoint(tmp_path, tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_latest_and_prune(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(tmp_path, s, tree)
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.prune_old(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert len(list(tmp_path.glob("step_*"))) == 2


def test_async_checkpointer(tmp_path):
    tree = {"a": jnp.arange(5, dtype=jnp.float32)}
    ac = ckpt.AsyncCheckpointer()
    ac.save(tmp_path, 1, tree)
    ac.wait()
    restored, _ = ckpt.restore_checkpoint(tmp_path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_train_state_metrics():
    loss_fn, params, batch = _quadratic_problem()
    cfg = opt.AdamWConfig(lr=0.01)
    step = ts.make_train_step(loss_fn, cfg)
    state = ts.init_train_state(params)
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert {"loss", "lr", "grad_norm"} <= set(metrics)
