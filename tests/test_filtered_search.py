"""In-graph per-query filtered search: enforcement + parity matrix.

The filter is a boolean *allowed* mask packed into exclusion bitset words
(:func:`repro.core.search.pack_filter`) that pre-seed the walk's visited
bitset — excluded nodes are never expanded, never ranked, never returned.
Pinned here:

* packing layout (bit j of word w is node w*32+j, the walk's own packing);
* an all-True filter is *bit-identical* to no filter on every single-host
  backend (the packed words are all zero, so every traced value matches);
* zero out-of-filter ids across the engine-parity matrix (staged adaptive
  and fixed-beam), under shared-(n,) and per-query-(Q,n) masks;
* shared mask vs its tiled per-query form: bit-identical;
* the batch stream (``search_batches(filter=)``) with ragged per-batch
  masks (including None members) matches the per-batch ``search`` calls;
* filtered recall against the *restricted* brute force (the correctness
  anchor: filtering is semantics, not just masking);
* the distributed backend refuses filters loudly (no global-id view).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import search
from tests import _backend_fixtures as fx

K = 10


def _tenant_masks(n: int, nq: int, tenants: int = 3, seed: int = 7):
    """A per-query namespace workload: node -> tenant, query -> tenant,
    allowed = same tenant."""
    rng = np.random.default_rng(seed)
    node_t = rng.integers(0, tenants, size=n)
    q_t = rng.integers(0, tenants, size=nq)
    return node_t[None, :] == q_t[:, None]        # (Q, n) bool


def _assert_in_filter(ids: np.ndarray, allowed: np.ndarray):
    ids = np.asarray(ids)
    ok = allowed[np.arange(ids.shape[0])[:, None], np.maximum(ids, 0)]
    ok |= ids < 0
    assert ok.all(), f"{int((~ok).sum())} out-of-filter ids returned"


def test_pack_filter_bit_layout():
    n = 70                                         # spans 3 words, ragged
    allowed = np.ones((2, n), dtype=bool)
    allowed[0, 0] = False                          # word 0, bit 0
    allowed[0, 33] = False                         # word 1, bit 1
    allowed[1, 69] = False                         # word 2, bit 5
    words = np.asarray(search.pack_filter(allowed, n))
    assert words.shape == (2, 3) and words.dtype == np.uint32
    assert words[0, 0] == 1 and words[0, 1] == 2 and words[0, 2] == 0
    assert words[1, 2] == 1 << 5 and words[1, 0] == 0
    # Shared (n,) mask packs to one row.
    shared = np.asarray(search.pack_filter(allowed[0], n))
    np.testing.assert_array_equal(shared, words[:1])


@pytest.mark.parametrize("variant", fx.SINGLE_HOST)
def test_all_true_filter_bit_identical(variant):
    """Filter that excludes nothing must not perturb a single bit — the
    packed words are zero, so the filtered programs compute the exact same
    values as the unfiltered ones."""
    _x, q, _gt, _idx, _t = fx.built()
    eng = fx.engine(variant)
    plain = eng.search(q)
    ones = eng.search(q, filter=np.ones(eng.backend.num_nodes(), bool))
    fx.assert_bit_identical(plain, ones)


@pytest.mark.parametrize("variant", fx.SINGLE_HOST)
def test_zero_out_of_filter_adaptive(variant):
    x, q, _gt, _idx, _t = fx.built()
    allowed = _tenant_masks(x.shape[0], q.shape[0])
    res = fx.engine(variant).search(q, filter=allowed)
    _assert_in_filter(res.ids, allowed)
    assert (np.asarray(res.ids) >= 0).any(), "filtered search returned nothing"


@pytest.mark.parametrize("variant", ("exact", "tiered", "disk"))
def test_zero_out_of_filter_fixed_beam(variant):
    """The monolithic fixed-beam path (budget_cfg=None) enforces the same
    mask through ``backend.fixed``."""
    from repro import serving

    x, q, _gt, _idx, _t = fx.built()
    allowed = _tenant_masks(x.shape[0], q.shape[0])
    eng = serving.SearchEngine(fx._make_backend(variant, fx.BUDGET), None,
                               k=K, beam_width=48)
    res = eng.search(q, filter=allowed)
    _assert_in_filter(res.ids, allowed)
    assert (np.asarray(res.ids) >= 0).any()


def test_shared_mask_matches_tiled():
    x, q, _gt, _idx, _t = fx.built()
    rng = np.random.default_rng(3)
    shared = rng.random(x.shape[0]) < 0.5          # one namespace for all
    eng = fx.engine("tiered")
    a = eng.search(q, filter=shared)
    b = eng.search(q, filter=np.broadcast_to(shared, (q.shape[0],
                                                      x.shape[0])))
    fx.assert_bit_identical(a, b)
    _assert_in_filter(a.ids, np.broadcast_to(shared,
                                             (q.shape[0], x.shape[0])))


def test_filtered_recall_vs_restricted_brute_force():
    """Semantics anchor: with a roomy namespace the filtered walk finds the
    *within-namespace* nearest neighbours, not merely in-namespace ids."""
    x, q, _gt, _idx, _t = fx.built()
    rng = np.random.default_rng(11)
    shared = rng.random(x.shape[0]) < 0.5
    res = fx.engine("exact").search(q, filter=shared)
    xn, qn = np.asarray(x), np.asarray(q)
    d2 = np.einsum("qnd,qnd->qn", qn[:, None] - xn[None],
                   qn[:, None] - xn[None], dtype=np.float32)
    d2[:, ~shared] = np.inf
    gt = np.argsort(d2, axis=1)[:, :K]
    hits = np.mean([np.isin(np.asarray(res.ids)[i], gt[i]).mean()
                    for i in range(qn.shape[0])])
    assert hits >= 0.8, f"filtered recall {hits:.3f} below floor"


def test_search_batches_per_batch_masks():
    """The stream path with ragged per-batch masks — (n,), (Q,n) and a None
    member — matches the per-batch ``search`` results bit for bit."""
    x, q, _gt, _idx, _t = fx.built()
    eng = fx.engine("tiered")
    batches = fx.split(q, 16)
    rng = np.random.default_rng(5)
    masks = [rng.random(x.shape[0]) < 0.6,
             _tenant_masks(x.shape[0], batches[1].shape[0], seed=9),
             None][: len(batches)]
    streamed = list(eng.search_batches(batches, filter=masks))
    assert len(streamed) == len(batches)
    for qb, m, res in zip(batches, masks, streamed):
        fx.assert_bit_identical(res, eng.search(qb, filter=m))
        if m is not None:
            am = np.broadcast_to(m, (qb.shape[0], x.shape[0]))
            _assert_in_filter(res.ids, am)


def test_search_batches_filtered_coalescing():
    """Sub-quantum batches coalesce into one dispatch with their per-query
    masks concatenated (a None member expands to all-allowed rows); results
    still match the uncoalesced per-batch reference."""
    x, q, _gt, _idx, _t = fx.built()
    eng = fx.engine("tiered", coalesce_lanes=32)
    ref = fx.engine("tiered")
    batches = [q[:8], q[8:16], q[16:24]]
    masks = [_tenant_masks(x.shape[0], 8, seed=13), None,
             _tenant_masks(x.shape[0], 8, seed=17)]
    out = list(eng.search_batches(batches, filter=masks))
    for qb, m, res in zip(batches, masks, out):
        # Per-query bit-identity (pinned budget center); ceilings are a
        # batch-composition property, so the merged dispatch may pick a
        # different bucket family — same discipline as the unfiltered
        # coalescing parity test.
        r = ref.search(qb, filter=m)
        np.testing.assert_array_equal(res.ids, r.ids)
        np.testing.assert_array_equal(res.d2, r.d2)
        np.testing.assert_array_equal(np.asarray(res.stats.hops),
                                      np.asarray(r.stats.hops))
        np.testing.assert_array_equal(np.asarray(res.astats.budget),
                                      np.asarray(r.astats.budget))


@pytest.mark.skipif(not fx.has_mesh(), reason="needs >= 8 devices")
def test_distributed_rejects_filter():
    _mesh, _arrays, _per, q, _gt = fx.built_dist()
    eng = fx.engine("dist")
    with pytest.raises(NotImplementedError, match="node-id view"):
        eng.search(q[:8], filter=np.ones(8, bool))


def test_engine_filter_shape_checks():
    _x, q, _gt, _idx, _t = fx.built()
    eng = fx.engine("tiered")
    with pytest.raises(ValueError):
        eng.search(q, filter=np.ones((q.shape[0], 7), bool))
