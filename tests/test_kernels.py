"""Pallas kernels vs jnp oracles — interpret-mode shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.l2_distance import l2_distance
from repro.kernels.lid_kernel import lid_estimate
from repro.kernels.pq_scan import pq_scan
from repro.kernels.topk import topk


@pytest.mark.parametrize("q_n,x_n,d", [(8, 64, 32), (130, 300, 96), (1, 129, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_distance_sweep(q_n, x_n, d, dtype):
    key = jax.random.PRNGKey(q_n + x_n + d)
    q = jax.random.normal(key, (q_n, d), dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (x_n, d), dtype)
    out = l2_distance(q, x, interpret=True)
    expect = ref.l2_distance_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("n,m,k,q", [(200, 8, 16, 2), (513, 16, 256, 3), (64, 4, 64, 1)])
def test_pq_scan_sweep(n, m, k, q):
    key = jax.random.PRNGKey(n)
    codes = jax.random.randint(key, (n, m), 0, k).astype(jnp.uint8)
    luts = jax.random.uniform(jax.random.fold_in(key, 1), (q, m, k))
    out = pq_scan(luts, codes, interpret=True)
    expect = jax.vmap(lambda l: ref.pq_scan_ref(l, codes))(luts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k,q", [(1500, 10, 4), (5000, 32, 2), (1000, 1, 1)])
def test_topk_sweep(n, k, q):
    key = jax.random.PRNGKey(k)
    d = jax.random.uniform(key, (q, n))
    vals, ids = topk(d, k, interpret=True)
    evals, eids = ref.topk_ref(d, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(evals), rtol=1e-6)
    assert (np.asarray(ids) == np.asarray(eids)).all()


@pytest.mark.parametrize("b,k", [(100, 8), (700, 16), (512, 32)])
def test_lid_kernel_sweep(b, k):
    key = jax.random.PRNGKey(b)
    d2 = jnp.sort(jax.random.uniform(key, (b, k)) + 0.01, axis=1)
    out = lid_estimate(d2, interpret=True)
    expect = ref.lid_ref(d2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 8, 2, 700, 64), (1, 4, 4, 512, 32),
                                          (3, 6, 1, 130, 16)])
def test_decode_attention_sweep(b, hq, hkv, s, d):
    key = jax.random.PRNGKey(s)
    q = jax.random.normal(key, (b, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    lens = jax.random.randint(jax.random.fold_in(key, 3), (b,), 1, s + 1)
    out = decode_attention(q, k, v, lens, interpret=True)
    g = hq // hkv
    expect = ref.decode_attention_ref(
        q, jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2), lens
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)


def test_ops_dispatch_cpu_fallback():
    """On CPU the ops layer must route to the oracle and stay numerically
    identical to it."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (4, 16))
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    np.testing.assert_allclose(
        np.asarray(ops.bulk_l2(q, x)), np.asarray(ref.l2_distance_ref(q, x)),
        rtol=1e-6,
    )
