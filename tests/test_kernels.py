"""Pallas kernels vs jnp oracles — interpret-mode shape/dtype sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.beam_step import beam_step
from repro.kernels.decode_attention import decode_attention
from repro.kernels.l2_distance import l2_distance
from repro.kernels.lid_kernel import lid_estimate
from repro.kernels.pq_scan import pq_scan
from repro.kernels.topk import topk


@pytest.mark.parametrize("q_n,x_n,d", [(8, 64, 32), (130, 300, 96), (1, 129, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_distance_sweep(q_n, x_n, d, dtype):
    key = jax.random.PRNGKey(q_n + x_n + d)
    q = jax.random.normal(key, (q_n, d), dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (x_n, d), dtype)
    out = l2_distance(q, x, interpret=True)
    expect = ref.l2_distance_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("n,m,k,q", [(200, 8, 16, 2), (513, 16, 256, 3), (64, 4, 64, 1)])
def test_pq_scan_sweep(n, m, k, q):
    key = jax.random.PRNGKey(n)
    codes = jax.random.randint(key, (n, m), 0, k).astype(jnp.uint8)
    luts = jax.random.uniform(jax.random.fold_in(key, 1), (q, m, k))
    out = pq_scan(luts, codes, interpret=True)
    expect = jax.vmap(lambda l: ref.pq_scan_ref(l, codes))(luts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k,q", [(1500, 10, 4), (5000, 32, 2), (1000, 1, 1)])
def test_topk_sweep(n, k, q):
    key = jax.random.PRNGKey(k)
    d = jax.random.uniform(key, (q, n))
    vals, ids = topk(d, k, interpret=True)
    evals, eids = ref.topk_ref(d, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(evals), rtol=1e-6)
    assert (np.asarray(ids) == np.asarray(eids)).all()


@pytest.mark.parametrize("b,k", [(100, 8), (700, 16), (512, 32)])
def test_lid_kernel_sweep(b, k):
    key = jax.random.PRNGKey(b)
    d2 = jnp.sort(jax.random.uniform(key, (b, k)) + 0.01, axis=1)
    out = lid_estimate(d2, interpret=True)
    expect = ref.lid_ref(d2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 8, 2, 700, 64), (1, 4, 4, 512, 32),
                                          (3, 6, 1, 130, 16)])
def test_decode_attention_sweep(b, hq, hkv, s, d):
    key = jax.random.PRNGKey(s)
    q = jax.random.normal(key, (b, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    lens = jax.random.randint(jax.random.fold_in(key, 3), (b,), 1, s + 1)
    out = decode_attention(q, k, v, lens, interpret=True)
    g = hq // hkv
    expect = ref.decode_attention_ref(
        q, jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2), lens
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)


def test_ops_dispatch_cpu_fallback():
    """On CPU the ops layer must route to the oracle and stay numerically
    identical to it."""
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (4, 16))
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    np.testing.assert_allclose(
        np.asarray(ops.bulk_l2(q, x)), np.asarray(ref.l2_distance_ref(q, x)),
        rtol=1e-6,
    )


def _walk_problem(kind, n, r, beam, q, seed):
    """A random fused-walk problem: dup-free adjacency, per-query entry
    seeded in beam slot 0 (visited bit set), ragged budgets/hop limits."""
    rng = np.random.default_rng(seed)
    adj = jnp.asarray(np.stack(
        [rng.choice(n, size=r, replace=False) for _ in range(n)]
    ).astype(np.int32))
    if kind == "pq":
        m, k = 8, 16
        table = jnp.asarray(rng.integers(0, k, (n, m)).astype(np.uint8))
        ctxs = jnp.asarray(rng.random((q, m, k), dtype=np.float32))
        d0 = np.asarray(ctxs)[
            np.arange(q)[:, None], np.arange(m), np.asarray(table)[:q].astype(int)
        ].sum(axis=1)
    else:
        d = 24
        table = jnp.asarray(rng.standard_normal((n, d), dtype=np.float32))
        ctxs = jnp.asarray(rng.standard_normal((q, d), dtype=np.float32))
        d0 = ((np.asarray(table)[:q] - np.asarray(ctxs)) ** 2).sum(axis=1)
    entries = np.arange(q, dtype=np.int32)       # query i enters at node i
    beam_ids = np.full((q, beam), -1, np.int32)
    beam_d = np.full((q, beam), np.inf, np.float32)
    beam_ids[:, 0], beam_d[:, 0] = entries, d0
    visited = np.zeros((q, (n + 31) // 32), np.uint32)
    visited[np.arange(q), entries // 32] = np.uint32(1) << (entries % 32)
    state = (jnp.asarray(beam_ids), jnp.asarray(beam_d),
             jnp.zeros((q, beam), bool), jnp.asarray(visited),
             jnp.zeros((q,), jnp.int32), jnp.ones((q,), jnp.int32))
    budgets = jnp.asarray(
        rng.integers(max(2, beam // 2), beam + 1, q).astype(np.int32))
    hop_limits = jnp.asarray(rng.integers(2, 7, q).astype(np.int32))
    return state, ctxs, adj, table, budgets, hop_limits


@pytest.mark.parametrize("kind", ["exact", "pq"])
@pytest.mark.parametrize("n,r,beam,q", [(200, 8, 16, 3), (64, 4, 8, 1),
                                        (130, 6, 12, 2)])
def test_beam_step_sweep(kind, n, r, beam, q):
    """Multi-hop fused walk (interpret) vs the jitted oracle, bit-identical
    at every hop — ids, distances, visited words, hop/eval counters.  The
    oracle is jitted so both sides share XLA's reduction order; that is the
    same discipline the step-kernel layer relies on for engine parity."""
    st_k, ctxs, adj, table, budgets, hop_limits = _walk_problem(
        kind, n, r, beam, q, seed=n + beam)
    st_r = st_k
    step_r = jax.jit(functools.partial(ref.beam_step_ref, kind=kind))
    for _ in range(6):
        st_k = beam_step(st_k, ctxs, adj, table, budgets, hop_limits,
                         kind=kind, interpret=True)
        st_r = step_r(st_r, ctxs, adj, table, budgets, hop_limits)
        for got, want in zip(st_k, st_r):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # hop_limit <= 6 for every lane, so every lane is terminal (limit hit
    # or frontier exhausted): one more step must be the identity.
    assert (np.asarray(st_k[4]) <= np.asarray(hop_limits)).all()
    st_fix = beam_step(st_k, ctxs, adj, table, budgets, hop_limits,
                       kind=kind, interpret=True)
    for got, want in zip(st_fix, st_k):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_beam_step_respects_budget():
    """The per-lane budget gates frontier selection: budget=1 is the greedy
    walk, it diverges from the full-beam walk on the same problem, and it
    stays bit-identical to the jitted oracle at the same budget."""
    st0, ctxs, adj, table, _, _ = _walk_problem("exact", 200, 8, 16, 4, seed=7)
    hop_limits = jnp.full((4,), jnp.int32(6))
    step_r = jax.jit(functools.partial(ref.beam_step_ref, kind="exact"))
    runs = {}
    for b in (1, 16):
        budgets = jnp.full((4,), jnp.int32(b))
        st = st0
        for _ in range(6):
            st = beam_step(st, ctxs, adj, table, budgets, hop_limits,
                           kind="exact", interpret=True)
        runs[b] = st
        want = st0
        for _ in range(6):
            want = step_r(want, ctxs, adj, table, budgets, hop_limits)
        for got, exp in zip(st, want):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    assert not np.array_equal(np.asarray(runs[1][1]), np.asarray(runs[16][1]))


def test_resolve_impl_policy(monkeypatch):
    """interpret-env > TPU > oracle — and the env var must win *on* TPU."""
    from repro.kernels import ops

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert ops.resolve_impl() == "ref"
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert ops.resolve_impl() == "pallas"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.resolve_impl() == "interpret"      # env wins over TPU
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert ops.resolve_impl() == "interpret"
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.resolve_impl() == "ref"            # "0" is not opted in


def test_ops_beam_step_request_routing(monkeypatch):
    """``request="pallas"`` upgrades the CPU fallback to interpret mode —
    never the oracle — while ``request="auto"`` takes the resolved impl."""
    from repro.kernels import ops

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    calls = []
    monkeypatch.setattr(
        ops._beam, "beam_step",
        lambda *a, **kw: calls.append(("kernel", kw["interpret"])))
    monkeypatch.setattr(
        ops._ref, "beam_step_ref", lambda *a, **kw: calls.append(("oracle",)))
    args = (None,) * 6
    ops.beam_step(*args, kind="exact", request="pallas")
    ops.beam_step(*args, kind="exact", request="auto")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    ops.beam_step(*args, kind="exact", request="auto")
    assert calls == [("kernel", True), ("oracle",), ("kernel", True)]
