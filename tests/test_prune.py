"""Adaptive robust prune — the dynamic occlusion criterion."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import prune

INVALID = prune.INVALID


def _prune_complete(x: np.ndarray, u: int, alpha: float, degree: int):
    xj = jnp.asarray(x, jnp.float32)
    cand = jnp.arange(x.shape[0], dtype=jnp.int32)[None, :]
    rows, d2 = prune.robust_prune_batch(
        xj, jnp.asarray([u], jnp.int32), cand,
        jnp.asarray([alpha], jnp.float32), degree,
    )
    return np.asarray(rows[0]), np.asarray(d2[0])


def test_degree_cap():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(50, 4)).astype(np.float32)
    rows, _ = _prune_complete(x, 0, 1.0, degree=5)
    assert (rows != INVALID).sum() <= 5


def test_nearest_always_selected():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(30, 3)).astype(np.float32)
    d = ((x - x[7]) ** 2).sum(1)
    d[7] = np.inf
    nearest = int(np.argmin(d))
    rows, _ = _prune_complete(x, 7, 1.3, degree=8)
    assert nearest in rows.tolist()


def test_occlusion_rule_manual():
    """Three colinear points: with alpha=1 the far point is occluded by the
    middle one; with huge alpha the middle no longer occludes."""
    x = np.array([[0.0], [1.0], [2.1]], dtype=np.float32)
    rows_strict, _ = _prune_complete(x, 0, 1.0, degree=3)
    kept = set(rows_strict[rows_strict != INVALID].tolist())
    assert kept == {1}  # node 2 pruned: 1.0*d(1,2) <= d(0,2)
    # alpha large enough that alpha*d(1,2) > d(0,2): 2 survives.
    # (alpha on true distances: need alpha*1.1 > 2.1 -> alpha > 1.909)
    rows_loose, _ = _prune_complete(x, 0, 2.0, degree=3)
    kept = set(rows_loose[rows_loose != INVALID].tolist())
    assert kept == {1, 2}


def test_monotone_in_alpha():
    """Larger alpha prunes less aggressively => at least as many neighbours
    (up to the degree cap)."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(60, 6)).astype(np.float32)
    n1 = (_prune_complete(x, 3, 1.0, degree=59)[0] != INVALID).sum()
    n2 = (_prune_complete(x, 3, 1.5, degree=59)[0] != INVALID).sum()
    assert n2 >= n1


def test_duplicates_and_self_removed():
    x = np.array([[0.0], [1.0], [3.0]], dtype=np.float32)
    cand = jnp.asarray([[0, 1, 1, 2, INVALID]], jnp.int32)
    rows, _ = prune.robust_prune_batch(
        jnp.asarray(x), jnp.asarray([0], jnp.int32), cand,
        jnp.asarray([2.0], jnp.float32), 5,
    )
    vals = rows[0][rows[0] != INVALID].tolist()
    assert 0 not in vals
    assert len(vals) == len(set(vals))


@given(
    seed=st.integers(0, 10_000),
    n=st.integers(5, 40),
    alpha=st.floats(min_value=1.0, max_value=2.0),
)
@settings(max_examples=50, deadline=None)
def test_always_selects_at_least_one(seed, n, alpha):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3)).astype(np.float32)
    rows, _ = _prune_complete(x, 0, alpha, degree=max(4, n // 4))
    assert (rows != INVALID).sum() >= 1


def test_greedy_block_pack_co_locates_entry_neighbourhood():
    """The block-aware layout packs each seed with its nearest unassigned
    out-neighbours into consecutive slots of one I/O block (adjacency rows
    are distance-ascending out of the prune), BFS order from the entry;
    unreached nodes follow in id order."""
    adj = np.asarray([[5, 3, -1], [-1] * 3, [-1] * 3, [1, -1, -1],
                      [-1] * 3, [2, -1, -1]], np.int32)
    slot_of = prune.greedy_block_pack(adj, entry=0, nodes_per_block=4)
    # Group {0, 5, 3} fills slots 0-2; node 2 lands in the block's last
    # slot; node 1 opens the next block; unreached node 4 is appended.
    np.testing.assert_array_equal(slot_of, [0, 4, 3, 2, 5, 1])
    # The entry's whole out-neighbourhood shares its I/O block.
    assert {slot_of[v] // 4 for v in (0, 5, 3)} == {0}


@given(seed=st.integers(0, 1000), npb=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=25, deadline=None)
def test_greedy_block_pack_is_a_permutation(seed, npb):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 50))
    adj = rng.integers(-1, n, size=(n, 4)).astype(np.int32)
    entry = int(rng.integers(0, n))
    slot_of = prune.greedy_block_pack(adj, entry, npb)
    assert slot_of.dtype == np.int64
    assert sorted(slot_of.tolist()) == list(range(n))
    if npb == 1:
        np.testing.assert_array_equal(slot_of, np.arange(n))
    else:
        assert slot_of[entry] == 0       # the entry seeds the first block
