"""Shared fixtures + assertions for the cross-backend engine-parity tests.

One module owns the build caches, the per-backend engine constructors and
the two result-comparison disciplines (bitwise / up-to-distance-ties) that
used to be duplicated across ``test_bucketed_search.py``,
``test_serving_pipeline.py`` and ``test_adaptive_serving.py``.  The
consolidated property matrix itself lives in ``test_engine_parity.py``;
the distributed backend joins it whenever the process has >= 8 devices
(the CI multi-device matrix job; single-device tier-1 runs cover the same
properties via the ``staged_engine`` scenario of ``_distributed_worker``).

``@given``-wrapped tests can't take pytest fixtures (the hypothesis shim
erases the signature), so everything here is module-level ``lru_cache``.
"""
from __future__ import annotations

import atexit
import functools
import pathlib
import shutil
import tempfile

import jax
import numpy as np

from repro import serving
from repro.core import build, distance, search
from repro.index import build_tiered_index
from repro.index.disk import search_tiered_adaptive

CFG = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                        max_hops=96)
# Pinned LID center: batch-mean centering makes budgets depend on which
# queries share a batch/chunk, which is the *reducer's* property; pinning
# isolates the scheduling properties under test.
BUDGET = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3, center=8.0)
# Distributed variant: in-graph bucket deadlines need a (l_min, l_max)
# range matching the example-scale shard graphs.
BUDGET_DIST = search.AdaptiveBeamBudget(l_min=8, l_max=32, lam=0.35,
                                        center=8.0)
DIST_CHUNK = 8          # query_chunk of the distributed fixtures
# "disk" = the tiered backend with its slow tier served from the
# block-aligned on-disk store — same walk, host-side rerank fetch; its
# reference paths (monolithic / core-bucketed) are the *in-memory* tiered
# ones, which is exactly the bit-identity under test.  "ooc" = the
# out-of-core backend: adjacency + vectors live *only* in a block-aware
# packed store (nodes_per_block=8, greedy build-time layout) and are read
# at walk time — same in-memory tiered reference paths, so the matrix pins
# the out-of-core walk's bit-identity too.  "disk_hot"/"ooc_hot" = the same
# two storage backends with the frequency-aware hot tier enabled over a
# deliberately small LRU: promotions and demotions run asynchronously
# *during* the matrix, so every scheduling property is also pinned while
# residency migrates between tiers (the hot tier may change where a record
# is read, never its bytes).
SINGLE_HOST = ("exact", "pq", "tiered", "disk", "ooc", "disk_hot",
               "ooc_hot")


def has_mesh() -> bool:
    """Whether this process can host the distributed backend (the CI
    multi-device matrix sets --xla_force_host_platform_device_count=8)."""
    return jax.device_count() >= 8


def backends() -> tuple[str, ...]:
    return SINGLE_HOST + (("dist",) if has_mesh() else ())


@functools.lru_cache(maxsize=1)
def built():
    from repro.data import make_dataset

    x, q = make_dataset("tiny-mixture", seed=0)
    x, q = x[:1500], q[:40]
    idx = build.build_mcgi(x, CFG)
    tiered = build_tiered_index(x, idx, m_pq=8)
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    return x, np.asarray(q), gt_i, idx, tiered


@functools.lru_cache(maxsize=1)
def built_dist():
    """Sharded fixture over a (2, 4) mesh: shard-major sub-graphs, PQ
    codes, per-shard medoids — plus ground truth over the truncated rows."""
    import jax.numpy as jnp

    from repro import compat
    from repro.distributed import sharded_search as ss

    assert has_mesh(), "distributed fixtures need >= 8 devices"
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    from repro.data import make_dataset

    x, q = make_dataset("tiny-mixture", seed=0)
    q = np.asarray(q[:40])
    cfg = build.BuildConfig(degree=16, beam_width=32, iters=1, batch=256,
                            max_hops=64)
    arrays, per = ss.build_sharded_arrays(x, mesh, build_cfg=cfg, m_pq=8)
    n = per * mesh.devices.size
    gt_d, gt_i = distance.brute_force_topk(
        jnp.asarray(q), jnp.asarray(np.asarray(x)[:n]), k=10)
    return mesh, arrays, per, q, np.asarray(gt_i)


@functools.lru_cache(maxsize=1)
def built_disk_tier():
    """One shared BlockSlowTier over a block store written from the tiered
    fixture (cache state never affects results, so sharing is safe)."""
    from repro.index import BlockSlowTier, BlockStore, write_block_store
    from repro.index.disk import entry_proximal_ids

    _x, _q, _gt, idx, tiered = built()
    tmp = tempfile.mkdtemp(prefix="mcgi-blockstore-")
    atexit.register(shutil.rmtree, tmp, ignore_errors=True)
    p = pathlib.Path(tmp) / "fixture.blocks"
    write_block_store(p, np.asarray(tiered.vectors), np.asarray(idx.adj))
    tier = BlockSlowTier(
        BlockStore(p), cache_nodes=1024,
        pinned_ids=entry_proximal_ids(idx.adj, idx.entry, limit=64))
    atexit.register(tier.close)    # don't leak the worker thread
    return tier


@functools.lru_cache(maxsize=1)
def built_ooc_tier():
    """Shared BlockSlowTier for the out-of-core backend: a *packed* store
    (nodes_per_block=8, greedy block-aware slot assignment from the built
    graph), so the parity matrix exercises the block-granular read path and
    the build-time layout together."""
    from repro.core.build import block_layout
    from repro.index import BlockSlowTier, BlockStore, write_block_store
    from repro.index.disk import entry_proximal_ids

    _x, _q, _gt, idx, tiered = built()
    tmp = tempfile.mkdtemp(prefix="mcgi-packedstore-")
    atexit.register(shutil.rmtree, tmp, ignore_errors=True)
    p = pathlib.Path(tmp) / "fixture-packed.blocks"
    write_block_store(p, np.asarray(tiered.vectors), np.asarray(idx.adj),
                      nodes_per_block=8, slot_of=block_layout(idx, 8))
    tier = BlockSlowTier(
        BlockStore(p), cache_nodes=1024,
        pinned_ids=entry_proximal_ids(idx.adj, idx.entry, limit=64))
    atexit.register(tier.close)
    return tier


def _hot_tier(store_path):
    """A frequency-aware tier over an existing fixture store: the LRU is
    kept small (128 nodes over a 1500-node graph) so real misses feed the
    EMA scores and promotion/demotion actually churn under the matrix's
    traffic; entry-proximal pins stay excluded from promotion."""
    from repro.index import BlockSlowTier, BlockStore
    from repro.index.disk import entry_proximal_ids

    _x, _q, _gt, idx, _tiered = built()
    tier = BlockSlowTier(
        BlockStore(store_path), cache_nodes=128,
        pinned_ids=entry_proximal_ids(idx.adj, idx.entry, limit=64),
        hot_nodes=256, hot_chunk=64, freq_decay=0.5)
    atexit.register(tier.close)
    return tier


@functools.lru_cache(maxsize=1)
def built_disk_hot_tier():
    return _hot_tier(built_disk_tier().store.path)


@functools.lru_cache(maxsize=1)
def built_ooc_hot_tier():
    return _hot_tier(built_ooc_tier().store.path)


def _make_backend(variant: str, budget, shard_laws=None, step_kernel=None):
    if variant == "dist":
        mesh, arrays, _per, _q, _gt = built_dist()
        return serving.DistributedBackend(
            mesh, arrays, beam_width=budget.l_max, max_hops=96, k=10,
            query_chunk=DIST_CHUNK, beam_budget=budget, budget_buckets=4,
            shard_laws=shard_laws, step_kernel=step_kernel)
    x, _, _, idx, tiered = built()
    if variant == "exact":
        return serving.ExactBackend(x, idx.adj, idx.entry,
                                    step_kernel=step_kernel)
    if variant == "pq":
        return serving.TieredBackend(tiered, rerank=False,
                                     step_kernel=step_kernel)
    if variant == "disk":
        return serving.TieredBackend(tiered, slow_tier=built_disk_tier(),
                                     step_kernel=step_kernel)
    if variant == "disk_hot":
        return serving.TieredBackend(tiered, slow_tier=built_disk_hot_tier(),
                                     step_kernel=step_kernel)
    if variant == "ooc":
        return serving.OutOfCoreBackend(
            tiered.codes, tiered.codebook, idx.entry, built_ooc_tier(),
            step_kernel=step_kernel)
    if variant == "ooc_hot":
        return serving.OutOfCoreBackend(
            tiered.codes, tiered.codebook, idx.entry, built_ooc_hot_tier(),
            step_kernel=step_kernel)
    assert variant == "tiered", variant
    return serving.TieredBackend(tiered, step_kernel=step_kernel)


@functools.lru_cache(maxsize=128)
def engine(variant: str, num_buckets="auto", budget=BUDGET,
           coalesce_lanes=None, staged: bool = True, step_kernel=None):
    """A cached engine per configuration (jit caches live on the backend's
    compiled programs, so reuse matters for test wall-clock).  ``staged``
    only matters for the distributed backend: False serves the monolithic
    one-program step through the same engine API.  ``step_kernel`` selects
    the walk's hop implementation (the engine-parity kernel axis)."""
    if variant == "dist" and budget is BUDGET:
        budget = BUDGET_DIST
    backend = _make_backend(variant, budget, step_kernel=step_kernel)
    return serving.SearchEngine(backend, budget if staged else None, k=10,
                                num_buckets=num_buckets,
                                coalesce_lanes=coalesce_lanes)


def monolithic(variant: str, q, budget=BUDGET):
    """The single-program adaptive reference for each backend: one compiled
    call, no staging, no host scheduling."""
    if variant == "dist":
        res = engine("dist", staged=False).search(q)
        return res.ids, res.d2, None, None
    x, _, _, idx, tiered = built()
    if variant == "exact":
        return search.beam_search_exact_adaptive(
            x, idx.adj, q, idx.entry, budget, k=10)
    if variant == "pq":
        return search_tiered_adaptive(tiered, q, budget, k=10, rerank=False)
    # The disk / out-of-core variants (hot tier on or off) share the
    # in-memory tiered reference: storage must reproduce the in-memory bits.
    assert variant in ("tiered", "disk", "ooc", "disk_hot", "ooc_hot"), (
        variant)
    return search_tiered_adaptive(tiered, q, budget, k=10)


def core_bucketed(variant: str, q, num_buckets, budget=BUDGET):
    """The historical ``num_buckets=`` entry points of the core kernels
    (eager per-bucket gathers) — kept under test next to the engine so the
    convenience path stays pinned to the same properties."""
    x, _, _, idx, tiered = built()
    if variant == "exact":
        return search.beam_search_exact_adaptive(
            x, idx.adj, q, idx.entry, budget, k=10, num_buckets=num_buckets)
    if variant == "pq":
        return search_tiered_adaptive(
            tiered, q, budget, k=10, rerank=False, num_buckets=num_buckets)
    assert variant in ("tiered", "disk", "ooc", "disk_hot", "ooc_hot"), (
        variant)
    return search_tiered_adaptive(
        tiered, q, budget, k=10, num_buckets=num_buckets)


def split(q, batch: int):
    return [q[i:i + batch] for i in range(0, q.shape[0], batch)]


def assert_bit_identical(a: serving.BatchResult, b: serving.BatchResult):
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.d2, b.d2)
    if a.stats is not None or b.stats is not None:
        np.testing.assert_array_equal(np.asarray(a.stats.hops),
                                      np.asarray(b.stats.hops))
    if a.astats is not None or b.astats is not None:
        np.testing.assert_array_equal(np.asarray(a.astats.budget),
                                      np.asarray(b.astats.budget))
    assert a.ceilings == b.ceilings


def assert_same_up_to_ties(ids_a, d_a, ids_b, d_b, tol=1e-5):
    """Result equality modulo distance ties: distances must match, and any
    id mismatch must sit on a tie (equal distances at that rank)."""
    ids_a, d_a = np.asarray(ids_a), np.asarray(d_a)
    ids_b, d_b = np.asarray(ids_b), np.asarray(d_b)
    both_inf = np.isinf(d_a) & np.isinf(d_b)
    np.testing.assert_allclose(
        np.where(both_inf, 0.0, d_a), np.where(both_inf, 0.0, d_b),
        rtol=tol, atol=tol)
    mism = ids_a != ids_b
    assert np.allclose(d_a[mism], d_b[mism], rtol=tol, atol=tol), (
        "id mismatch without a distance tie")
