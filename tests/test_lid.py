"""LID estimator (paper Eq. 5) — quantitative validation on known-dim data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distance, lid
from repro.data.synthetic import gaussian_subspace_clusters, uniform_hypercube
from repro.kernels import ops as kops


@pytest.mark.parametrize("d_intrinsic", [2, 8])
def test_lid_recovers_intrinsic_dim(d_intrinsic):
    key = jax.random.PRNGKey(0)
    x = gaussian_subspace_clusters(
        key, 4000, d_ambient=64, d_intrinsic=d_intrinsic, n_clusters=1,
        noise=0.0,
    )
    prof = lid.estimate_dataset_lid(x, k=20)
    med = float(jnp.median(prof.lid))
    # MLE LID is biased at finite k; generous band around the true dim.
    assert 0.5 * d_intrinsic <= med <= 2.0 * d_intrinsic, med


def test_lid_orders_by_complexity():
    """Higher-dimensional data must get higher LID estimates (the signal
    the mapping function consumes)."""
    key = jax.random.PRNGKey(1)
    x_lo = gaussian_subspace_clusters(key, 2000, 32, d_intrinsic=2,
                                      n_clusters=1, noise=0.0)
    x_hi = uniform_hypercube(jax.random.fold_in(key, 1), 2000, 32)
    lo = float(lid.estimate_dataset_lid(x_lo, k=16).mu)
    hi = float(lid.estimate_dataset_lid(x_hi, k=16).mu)
    assert lo < hi, (lo, hi)


def test_lid_from_dists_matches_definition():
    """Eq. 5 literal check on a hand-built neighbourhood."""
    r = jnp.array([1.0, 2.0, 4.0, 8.0])
    expected = -1.0 / np.mean(np.log(np.array([1, 2, 4, 8]) / 8.0))
    got = float(lid.lid_from_dists(r[None, :] ** 2, squared=True)[0])
    np.testing.assert_allclose(got, expected, rtol=1e-5)


def test_lid_degenerate_duplicates():
    d = jnp.ones((3, 8))  # all neighbours equidistant -> ln ratios all 0
    out = lid.lid_from_dists(d)
    assert bool(jnp.isfinite(out).all())
    assert float(out.min()) > 100.0  # treated as maximally complex


def test_online_lid_handles_padding():
    d = jnp.array([[1.0, 2.0, 3.0, jnp.inf, jnp.inf]])
    out = lid.online_lid(d, k=5)
    assert bool(jnp.isfinite(out).all())


def test_bootstrap_matches_full_estimate(tiny_dataset):
    x, _ = tiny_dataset
    prof = lid.estimate_dataset_lid(x, k=16)
    mu_b, sigma_b = lid.bootstrap_stats(x, jax.random.PRNGKey(2),
                                        sample=600, k=16)
    assert abs(float(mu_b) - float(prof.mu)) < 0.35 * float(prof.mu)


def test_lid_kernel_matches_module(tiny_dataset):
    x, _ = tiny_dataset
    d2, _ = distance.knn_graph(x[:512], k=16)
    d2 = jnp.sort(d2, axis=1)
    via_kernel = kops.lid_estimate(d2)
    via_module = lid.lid_from_dists(d2, squared=True)
    np.testing.assert_allclose(
        np.asarray(via_kernel), np.asarray(via_module), rtol=1e-4
    )
