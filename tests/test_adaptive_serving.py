"""Per-query adaptive-beam serving engine + regression tests for the fixes
that shipped with it (online-LID recording, disk-model queue depth, per-shard
entry points)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, distance, mapping, online, search
from repro.distributed import sharded_search as ss
from repro.index import build_tiered_index
from repro.index.disk import DiskTierModel, search_tiered_adaptive

CFG = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                        max_hops=96)


@pytest.fixture(scope="module")
def built(tiny_dataset):
    x, q = tiny_dataset
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build.build_mcgi(x, CFG)
    return x, q, gt_i, idx


# ------------------------------------------------------- adaptive beam engine

def test_budgets_monotone_in_query_lid(built):
    """Prop. 4.2 in the engine: harder queries (higher LID) get larger beam
    budgets; the law's bounds are respected."""
    x, q, _, idx = built
    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3)
    _, _, _, astats = search.beam_search_exact_adaptive(
        x, idx.adj, q, idx.entry, cfg, k=10)
    lid = np.asarray(astats.q_lid)
    budget = np.asarray(astats.budget)
    assert (budget >= 8).all() and (budget <= 48).all()
    order = np.argsort(lid)
    assert (np.diff(budget[order]) >= 0).all()
    # Adaptivity actually happened: the batch isn't all one budget.
    assert budget.min() < budget.max()


def test_adaptive_matches_fixed_recall_at_equal_mean_budget(built):
    """Iso-recall: adaptive at mean budget ~L matches fixed-L recall - eps on
    tiny-mixture."""
    x, q, gt_i, idx = built
    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3)
    ids_a, _, stats_a, astats = search.beam_search_exact_adaptive(
        x, idx.adj, q, idx.entry, cfg, k=10)
    r_adapt = float(distance.recall_at_k(ids_a, gt_i))

    mean_budget = int(round(float(astats.budget.mean())))
    ids_f, _, stats_f = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=mean_budget,
        max_hops=4 * mean_budget, k=10)
    r_fixed = float(distance.recall_at_k(ids_f, gt_i))
    assert r_adapt >= r_fixed - 0.05, (r_adapt, r_fixed, mean_budget)


def test_adaptive_retires_easy_queries_early(built):
    """Per-query early exit: hop counts vary with the granted budget, and
    small-budget queries pay fewer hops than the fixed-l_max baseline."""
    x, q, _, idx = built
    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3)
    _, _, stats_a, astats = search.beam_search_exact_adaptive(
        x, idx.adj, q, idx.entry, cfg, k=10)
    _, _, stats_f = search.beam_search_exact(
        x, idx.adj, q, idx.entry, beam_width=48, max_hops=192, k=10)
    assert float(stats_a.hops.mean()) < float(stats_f.hops.mean())
    hops = np.asarray(stats_a.hops)
    budget = np.asarray(astats.budget)
    lo, hi = budget <= np.median(budget), budget > np.median(budget)
    if lo.any() and hi.any():
        assert hops[lo].mean() <= hops[hi].mean()


def test_adaptive_tiered_path(built):
    """The deployed two-tier path: PQ-routed adaptive walk + slow-tier
    rerank returns sane results and diagnostics."""
    x, q, gt_i, idx = built
    tiered = build_tiered_index(x, idx, m_pq=8)
    cfg = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3)
    ids, d2, stats, astats = search_tiered_adaptive(tiered, q, cfg, k=10)
    r = float(distance.recall_at_k(ids, gt_i))
    assert r >= 0.85, r
    assert astats.budget.shape == (q.shape[0],)
    assert (np.asarray(d2)[:, :-1] <= np.asarray(d2)[:, 1:] + 1e-6).all()


# ------------------------------------------------------------- satellite fixes

def test_online_mcgi_records_lid(tiny_dataset):
    """build_online_mcgi returns the per-node online-LID estimates its alphas
    were computed from (regression: it used to return zeros)."""
    x, _ = tiny_dataset
    x = x[:1000]
    idx = online.build_online_mcgi(
        x, dataclasses.replace(CFG, iters=1), sample=256)
    lid = np.asarray(idx.lid)
    assert float(lid.std()) > 1e-3  # non-constant
    # Consistent with the returned alphas: alpha == Phi(lid) exactly.
    expect = np.asarray(mapping.phi(idx.lid, idx.mu, idx.sigma))
    np.testing.assert_allclose(np.asarray(idx.alpha), expect, atol=1e-5)


def test_disk_model_queue_depth():
    """Rerank batch is issued queue_depth-parallel (regression: queue_depth
    was ignored)."""
    m = DiskTierModel(read_latency_us=100.0, queue_depth=8)
    # 10 serial reads + ceil(48/8)=6 rounds of rerank.
    lat = float(m.latency_us(jnp.float32(10), rerank_reads=48))
    assert lat == pytest.approx((10 + 6) * 100.0)
    # Deeper queue, fewer rounds — strictly faster for the same work.
    deeper = DiskTierModel(read_latency_us=100.0, queue_depth=16)
    assert float(deeper.latency_us(jnp.float32(10), rerank_reads=48)) < lat
    # No rerank term when there is no rerank batch.
    assert float(m.latency_us(jnp.float32(10))) == pytest.approx(1000.0)


def test_disk_model_overlapped_pipeline_mode():
    """overlapped=True models the double-buffered engine: per-batch cost is
    max(dependent chain, rerank rounds), not their sum — and degrades to the
    serial model when either stage is absent."""
    m = DiskTierModel(read_latency_us=100.0, queue_depth=8)
    # Chain 10 reads (1000us) vs 48-read rerank = 6 rounds (600us): the
    # rerank hides behind the next batch's chain entirely.
    assert float(m.latency_us(jnp.float32(10), rerank_reads=48,
                              overlapped=True)) == pytest.approx(1000.0)
    # Rerank-bound regime: 2-read chain (200us) under a 600us rerank.
    assert float(m.latency_us(jnp.float32(2), rerank_reads=48,
                              overlapped=True)) == pytest.approx(600.0)
    # Overlap never exceeds the serial model, and equals it with no rerank.
    serial = float(m.latency_us(jnp.float32(10), rerank_reads=48))
    assert float(m.latency_us(jnp.float32(10), rerank_reads=48,
                              overlapped=True)) < serial
    assert float(m.latency_us(jnp.float32(10), overlapped=True)) == \
        pytest.approx(float(m.latency_us(jnp.float32(10))))


def test_local_search_uses_given_entry():
    """_local_search starts at the supplied per-shard entry (regression: it
    hardcoded local row 0). A disconnected graph makes the entry decisive:
    with no out-edges the walk can only ever see its entry point."""
    n, d = 16, 4
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)), jnp.float32)
    adj = jnp.full((n, 4), -1, jnp.int32)  # no edges at all
    q = x[:4]
    for entry in (0, 7):
        d2, ids = ss._local_search(
            adj, None, x, None, q, jnp.int32(entry),
            beam_width=4, max_hops=8, k=1, query_chunk=4, use_pq=False)
        assert (np.asarray(ids) == entry).all()


def test_shard_medoids_matches_per_block_medoid():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 8)), jnp.float32)
    ents = ss.shard_medoids(x, 4)
    assert ents.shape == (4,)
    for s in range(4):
        block = x[s * 16:(s + 1) * 16]
        assert int(ents[s]) == int(search.medoid(block))
