"""Model-zoo correctness: decode==forward, MLA absorption, MoE, GNN, recsys."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_mod
from repro.models import gnn, moe as moe_mod, recsys
from repro.models import transformer as tfm
from repro.models.blockwise import blockwise_attention

KEY = jax.random.PRNGKey(0)


def _dense_cfg(**kw):
    base = dict(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, dtype=jnp.float32, attn_chunk_q=8, attn_chunk_k=8,
    )
    base.update(kw)
    return tfm.TransformerConfig(**base)


def test_blockwise_matches_dense_reference():
    q = jax.random.normal(KEY, (2, 32, 2, 3, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 2, 16))
    out = blockwise_attention(q, k, v, chunk_q=8, chunk_k=8)
    # dense reference
    logits = jnp.einsum("bshgd,bthd->bhgst", q, k) * (16 ** -0.5)
    mask = jnp.tril(jnp.ones((32, 32), bool))
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, -1)
    expect = jnp.einsum("bhgst,bthd->bshgd", w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_impl_equivalence():
    q = jax.random.normal(KEY, (1, 64, 2, 2, 8))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 64, 2, 8))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 64, 2, 12))
    a = blockwise_attention(q, k, v, chunk_q=16, chunk_k=8,
                            skip_masked_blocks=False)
    b = blockwise_attention(q, k, v, chunk_q=16, chunk_k=8,
                            skip_masked_blocks=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_decode_matches_forward_gqa():
    cfg = _dense_cfg(qkv_bias=True, qk_norm=True)
    params = tfm.init_lm(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    cache = tfm.init_cache(cfg, B, 32, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = tfm.decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                    jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    x, _ = tfm.forward(cfg, params, tokens)
    full = tfm.logits_from_hidden(cfg, params, x, None)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_mla_decode_absorbed_equals_naive():
    mla = attn_mod.MlaConfig(d_model=64, n_heads=4, kv_lora_rank=32,
                             qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                             attn_chunk_q=8, attn_chunk_k=8)
    cfg = _dense_cfg(attention="mla", mla=mla, n_kv_heads=4)
    params = tfm.init_lm(cfg, KEY)
    B = 2
    tokens = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
    cache = tfm.init_cache(cfg, B, 8, dtype=jnp.float32)
    kv = jnp.zeros((B,), jnp.int32)
    la, _ = tfm.decode_step(cfg, params, cache, tokens, kv, mla_absorbed=True)
    ln, _ = tfm.decode_step(cfg, params, cache, tokens, kv, mla_absorbed=False)
    np.testing.assert_allclose(np.asarray(la), np.asarray(ln),
                               rtol=1e-5, atol=1e-5)


def test_mla_decode_matches_forward():
    mla = attn_mod.MlaConfig(d_model=64, n_heads=4, kv_lora_rank=32,
                             qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                             attn_chunk_q=8, attn_chunk_k=8)
    cfg = _dense_cfg(attention="mla", mla=mla, n_kv_heads=4)
    params = tfm.init_lm(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    cache = tfm.init_cache(cfg, B, 32, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = tfm.decode_step(cfg, params, cache, tokens[:, t:t + 1],
                                    jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    x, _ = tfm.forward(cfg, params, tokens)
    full = tfm.logits_from_hidden(cfg, params, x, None)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_and_combine():
    cfg = moe_mod.MoeConfig(d_model=16, n_experts=4, top_k=2, d_expert=8)
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 16))
    out, aux = moe_mod.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound at balance


def test_moe_grads_flow_to_experts():
    cfg = moe_mod.MoeConfig(d_model=16, n_experts=4, top_k=2, d_expert=8)
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 16))
    g = jax.grad(lambda pp: moe_mod.moe_apply(pp, cfg, x)[0].sum())(p)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_unroll_flag_is_numerically_neutral():
    cfg = _dense_cfg()
    params = tfm.init_lm(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l1, _ = tfm.lm_loss(cfg, params, batch)
    cfg2 = dataclasses.replace(cfg, unroll_layers=True, attn_unroll=True)
    l2, _ = tfm.lm_loss(cfg2, params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_gat_learns_on_homophilous_graph():
    from repro.training.data import random_graph_data

    feats, ei, labels, mask = random_graph_data(300, 2000, 16, 4, seed=0)
    cfg = gnn.GatConfig(d_in=16, d_hidden=8, n_heads=4, n_classes=4)
    p = gnn.gat_init(KEY, cfg)
    batch = {
        "features": jnp.asarray(feats),
        "edge_index": jnp.asarray(gnn.pad_edges(ei[0], ei[1], 2048, 300)),
        "labels": jnp.asarray(labels),
        "mask": jnp.asarray(mask),
    }
    from repro.training import optimizer as opt_mod
    from repro.training import train_step as ts_mod

    step = ts_mod.make_train_step(
        lambda pp, b: gnn.gat_loss(cfg, pp, b),
        opt_mod.AdamWConfig(lr=1e-2, weight_decay=0.0, schedule="const"),
    )
    state = ts_mod.init_train_state(p)
    step = jax.jit(step)
    first = None
    for i in range(30):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < 0.7 * first
    assert float(metrics["acc"]) > 0.5


def test_gat_graph_level():
    cfg = gnn.GatConfig(d_in=8, d_hidden=4, n_heads=2, n_classes=2)
    p = gnn.gat_init(KEY, cfg)
    n, e, g = 64, 128, 8
    rng = np.random.default_rng(0)
    batch = {
        "features": jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32)),
        "edge_index": jnp.asarray(
            gnn.pad_edges(rng.integers(0, n, e), rng.integers(0, n, e), 160, n)
        ),
        "graph_ids": jnp.asarray(np.repeat(np.arange(g), n // g).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, g).astype(np.int32)),
    }
    loss, m = gnn.gat_graph_loss(cfg, p, batch)
    assert bool(jnp.isfinite(loss))


def test_neighbor_sampler_shapes_and_validity():
    rng = np.random.default_rng(1)
    src = rng.integers(0, 500, 4000)
    dst = rng.integers(0, 500, 4000)
    s = gnn.NeighborSampler(np.stack([src, dst]), 500, seed=0)
    nodes, es, ed = s.sample_block(np.arange(32), (5, 3))
    assert (nodes[:32] == np.arange(32)).all()  # seeds first
    assert es.max() < len(nodes) and ed.max() < len(nodes)
    # Every sampled edge must exist in the original graph.
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for a, b in zip(nodes[es[:50]], nodes[ed[:50]]):
        assert (int(a), int(b)) in edge_set


@pytest.mark.parametrize("model", ["dlrm", "deepfm", "mind", "bert4rec"])
def test_recsys_losses_and_grads(model):
    B = 32
    if model == "dlrm":
        cfg = recsys.DlrmConfig(vocab_sizes=(100, 50, 30), embed_dim=8,
                                bot_mlp=(16, 8), top_mlp=(16, 1))
        p = recsys.dlrm_init(KEY, cfg)
        batch = {"dense": jax.random.normal(KEY, (B, 13)),
                 "sparse": jax.random.randint(KEY, (B, 3), 0, 30),
                 "labels": jax.random.bernoulli(KEY, 0.3, (B,))}
        loss_fn = lambda pp: recsys.dlrm_loss(cfg, pp, batch)[0]
    elif model == "deepfm":
        cfg = recsys.DeepFmConfig(n_fields=5, vocab_per_field=50, embed_dim=8,
                                  mlp=(16,))
        p = recsys.deepfm_init(KEY, cfg)
        batch = {"sparse": jax.random.randint(KEY, (B, 5), 0, 50),
                 "labels": jax.random.bernoulli(KEY, 0.3, (B,))}
        loss_fn = lambda pp: recsys.deepfm_loss(cfg, pp, batch)[0]
    elif model == "mind":
        cfg = recsys.MindConfig(n_items=200, embed_dim=8, hist_len=12)
        p = recsys.mind_init(KEY, cfg)
        batch = {"hist": jax.random.randint(KEY, (B, 12), 0, 200),
                 "hist_mask": jnp.ones((B, 12), bool),
                 "target": jax.random.randint(KEY, (B,), 0, 200)}
        loss_fn = lambda pp: recsys.mind_loss(cfg, pp, batch)[0]
    else:
        cfg = recsys.Bert4RecConfig(n_items=200, embed_dim=16, n_blocks=1,
                                    n_heads=2, seq_len=12)
        p = recsys.bert4rec_init(KEY, cfg)
        batch = {"seq": jax.random.randint(KEY, (B, 12), 0, 200),
                 "seq_mask": jnp.ones((B, 12), bool),
                 "mlm_positions": jax.random.randint(KEY, (B, 2), 0, 12),
                 "mlm_labels": jax.random.randint(KEY, (B, 2), 0, 200)}
        loss_fn = lambda pp: recsys.bert4rec_loss(cfg, pp, batch)[0]
    loss = loss_fn(p)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(loss_fn)(p)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


def test_mind_capsule_interests_distinct():
    cfg = recsys.MindConfig(n_items=500, embed_dim=16, n_interests=4,
                            hist_len=20)
    p = recsys.mind_init(KEY, cfg)
    hist = jax.random.randint(KEY, (4, 20), 0, 500)
    mask = jnp.ones((4, 20), bool)
    u = recsys.mind_interests(cfg, p, hist, mask)
    assert u.shape == (4, 4, 16)
    # Interests should not all collapse to one vector.
    pd = jnp.sum((u[:, :, None, :] - u[:, None, :, :]) ** 2, -1)
    assert float(pd.max()) > 1e-4
