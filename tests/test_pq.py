"""PQ substrate + two-tier index."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, distance
from repro.index import build_tiered_index, load_index, save_index
from repro.index.disk import DiskTierModel, search_tiered
from repro.pq import adc_distances, build_lut, pq_decode, pq_encode, train_pq
from repro.pq.adc import adc_topk


@pytest.fixture(scope="module")
def pq_setup(tiny_dataset):
    x, q = tiny_dataset
    book = train_pq(x, m=8, iters=5)
    codes = pq_encode(x, book)
    return x, q, book, codes


def test_reconstruction_error(pq_setup):
    x, _, book, codes = pq_setup
    rec = pq_decode(codes, book)
    rel = float(jnp.mean(jnp.sum((rec - x) ** 2, -1))
                / jnp.mean(jnp.sum(x * x, -1)))
    assert rel < 0.05, rel


def test_adc_correlates_with_exact(pq_setup):
    x, q, book, codes = pq_setup
    luts = build_lut(q, book.centroids)
    d_hat = adc_distances(luts, codes)
    d_true = distance.squared_l2(q, x)
    corr = float(jnp.corrcoef(d_hat.ravel(), d_true.ravel())[0, 1])
    assert corr > 0.99, corr


def test_adc_topk_near_exact(pq_setup):
    x, q, book, codes = pq_setup
    luts = build_lut(q, book.centroids)
    _, ids = adc_topk(luts, codes, k=10)
    _, gt = distance.brute_force_topk(q, x, k=10)
    r = float(distance.recall_at_k(ids, gt))
    assert r > 0.7, r  # pure-ADC recall before rerank


def test_tiered_search_and_roundtrip(tiny_dataset, tmp_path):
    x, q = tiny_dataset
    x, q = x[:1000], q[:30]
    cfg = build.BuildConfig(degree=24, beam_width=48, iters=1, batch=256,
                            max_hops=96)
    graph = build.build_mcgi(x, cfg)
    tiered = build_tiered_index(x, graph, m_pq=8)
    _, gt = distance.brute_force_topk(q, x, k=10)
    ids, _, stats = search_tiered(tiered, q, beam_width=48, k=10)
    r = float(distance.recall_at_k(ids, gt))
    assert r >= 0.9, r
    # Fast tier strictly smaller than slow tier (the disk-resident premise).
    assert tiered.fast_tier_bytes() < tiered.slow_tier_bytes()

    p = tmp_path / "idx.npz"
    save_index(p, tiered)
    t2 = load_index(p)
    ids2, _, _ = search_tiered(t2, q, beam_width=48, k=10)
    assert (np.asarray(ids2) == np.asarray(ids)).all()


def test_disk_model_latency_monotone():
    m = DiskTierModel()
    lat = m.latency_us(jnp.array([1, 10, 100]))
    assert float(lat[0]) < float(lat[1]) < float(lat[2])
