"""Frequency-aware hot tier: promotion policy, hysteresis, off-path I/O.

The engine-parity matrix pins the one invariant that matters for results
(the hot tier changes *where* a record is read, never its bytes); this file
pins the *policy* and the *asynchrony*: EMA decay lets a shifted hot set
overtake the old one, ties never thrash residency, promotion I/O runs on
its own thread and never blocks (or is counted against) the serving
stream, and the prefetch-pool sizing knob follows its adoption rules.
"""
import threading

import numpy as np
import pytest

from repro.index import BlockSlowTier, BlockStore, write_block_store

N, D, R = 64, 12, 6


@pytest.fixture()
def store_path(tmp_path):
    rng = np.random.default_rng(5)
    vectors = rng.normal(size=(N, D)).astype(np.float32)
    adj = rng.integers(-1, N, size=(N, R)).astype(np.int32)
    p = write_block_store(tmp_path / "h.blocks", vectors, adj)
    return p, vectors, adj


def _tier(p, **kw):
    return BlockSlowTier(BlockStore(p), **kw)


def test_promotion_serves_bit_exact_records_without_serving_io(store_path):
    """A promoted node's next fetch is served from the hot arrays: same
    bytes, zero serving block reads, counted as a hit + a hot hit.  With
    cache_nodes=0 the LRU cannot shadow the property."""
    p, vectors, adj = store_path
    tier = _tier(p, cache_nodes=0, hot_nodes=4, hot_chunk=4)
    try:
        ids = np.asarray([3, 9, 3, 21])
        v1, a1 = tier.fetch_records(ids)
        np.testing.assert_array_equal(v1, vectors[ids])
        np.testing.assert_array_equal(a1, adj[ids])
        tier.promotion_tick().result()
        st = tier.stats()
        assert st["hot_nodes"] == 3 and st["promotions"] == 3
        assert st["promotion_io_blocks"] > 0
        before = st["blocks_read"]
        v2, a2 = tier.fetch_records(ids)
        np.testing.assert_array_equal(v2, vectors[ids])
        np.testing.assert_array_equal(a2, adj[ids])
        st = tier.stats()
        assert st["blocks_read"] == before     # hot hits: no serving I/O
        assert st["hot_hits"] == 3
        assert st["cache_hits"] == 3 and st["cache_misses"] == 3
    finally:
        tier.close()


def test_decay_lets_shifted_hot_set_overtake(store_path):
    """The EMA decay is what makes the tier *traffic-following*: after the
    hot set shifts, the new nodes' fresh scores beat the old residents'
    decayed ones and a tick demotes the stale set in one chunk."""
    p, _, _ = store_path
    tier = _tier(p, cache_nodes=0, hot_nodes=2, hot_chunk=2, freq_decay=0.5)
    try:
        for _ in range(4):
            tier.fetch_records(np.asarray([1, 2]))
        tier.promotion_tick().result()
        st = tier.stats()
        assert st["hot_nodes"] == 2 and st["demotions"] == 0
        assert set(tier._hot.node_of.tolist()) == {1, 2}
        for _ in range(4):
            tier.fetch_records(np.asarray([3, 4]))
        tier.promotion_tick().result()
        st = tier.stats()
        assert st["demotions"] == 2 and st["hot_nodes"] == 2
        assert set(tier._hot.node_of.tolist()) == {3, 4}
    finally:
        tier.close()


def test_hysteresis_never_demotes_on_ties(store_path):
    """A resident is only displaced by a *strictly* hotter candidate —
    equal scores keep the incumbent, so alternating traffic between two
    equally-warm nodes cannot thrash one hot slot."""
    p, _, _ = store_path
    tier = _tier(p, cache_nodes=0, hot_nodes=1, hot_chunk=1, freq_decay=1.0)
    try:
        tier.fetch_records(np.asarray([5]))
        tier.promotion_tick().result()
        assert tier.stats()["hot_nodes"] == 1
        tier.fetch_records(np.asarray([6]))    # freq: both exactly 1.0 now
        tier.promotion_tick().result()
        st = tier.stats()
        assert st["demotions"] == 0
        assert tier._hot.node_of.tolist() == [5]
    finally:
        tier.close()


def test_promotion_never_blocks_serving(store_path):
    """The tentpole's serving contract, made observable: gate the promoter
    thread's block read on an Event and show that while promotion I/O is
    stuck mid-flight, (a) promotion_tick() keeps returning the same
    in-flight future instead of piling up ticks, (b) stats() returns, (c) a
    serving fetch completes with correct bytes, and (d) the promotion read
    never appears in the serving stream's I/O counters."""
    p, vectors, adj = store_path
    tier = _tier(p, cache_nodes=8, hot_nodes=4, hot_chunk=4)
    gate, entered = threading.Event(), threading.Event()
    try:
        tier.fetch_records(np.asarray([1, 2, 3]))
        real = tier._hot.store.read_many

        def gated(ids):
            entered.set()
            assert gate.wait(30.0)
            return real(ids)

        tier._hot.store.read_many = gated
        fut = tier.promotion_tick()
        assert entered.wait(30.0)              # promotion I/O now in flight
        assert tier.promotion_tick() is fut    # at most one tick in flight
        before = tier.stats()                  # doesn't block on the gate
        ids = np.asarray([7, 8])
        v, a = tier.fetch_records(ids)         # serving doesn't block either
        np.testing.assert_array_equal(v, vectors[ids])
        np.testing.assert_array_equal(a, adj[ids])
        st = tier.stats()
        assert st["blocks_read"] == before["blocks_read"] + 2
        assert st["promotion_io_blocks"] == before["promotion_io_blocks"]
        gate.set()
        fut.result()
        assert tier.stats()["promotions"] == 3
        assert tier.promotion_tick() is not fut   # done tick -> next starts
        tier.drain_promotions()
    finally:
        gate.set()
        tier.close()


def test_promotion_tick_lifecycle(store_path):
    """No hot tier -> no tick; closed tier -> no tick; close() joins the
    promoter thread so nothing named hot-tier-promoter leaks."""
    p, _, _ = store_path
    with _tier(p) as plain:
        assert plain.promotion_tick() is None
    tier = _tier(p, hot_nodes=4)
    tier.fetch_records(np.asarray([1, 2]))
    tier.promotion_tick()
    promoters = set(tier._hot._pool._threads)   # this tier's, not global:
    tier.close()                                # other fixtures' tiers live
    assert tier.promotion_tick() is None
    assert promoters and not any(t.is_alive() for t in promoters)
    # Residency stays probe-able after close: synchronous fetches still work.
    tier.fetch_records(np.asarray([1, 2]))


def test_default_io_workers_adoption_rules(store_path):
    """default_io_workers is a *default*, not an override: an explicit
    constructor count wins, the first adoption sticks, and once the pool
    exists the knob is frozen."""
    p, _, _ = store_path
    with _tier(p, io_workers=3) as t:
        t.default_io_workers(8)
        assert t.io_workers == 3               # explicit ctor value wins
    with _tier(p) as t:
        t.default_io_workers(4)
        assert t.io_workers == 4               # adopted
        t.default_io_workers(9)
        assert t.io_workers == 4               # first adoption sticks
    with _tier(p) as t:
        t.prefetch(np.asarray([[1]])).result() # pool spins up at width 1
        t.default_io_workers(6)
        assert t.io_workers is None            # too late: pool exists


def test_fetch_latency_window(store_path):
    """Per-call fetch latency percentiles come from a bounded window that
    reset_stats() clears; the empty window reports zeros, not NaNs."""
    p, _, _ = store_path
    with _tier(p) as tier:
        assert tier.fetch_latency_us()["fetch_samples"] == 0
        assert tier.fetch_latency_us()["fetch_p99_us"] == 0.0
        for _ in range(5):
            tier.fetch_records(np.asarray([1, 2, 3]))
        lat = tier.fetch_latency_us()
        assert lat["fetch_samples"] == 5
        assert 0.0 < lat["fetch_p50_us"] <= lat["fetch_p99_us"]
        tier.reset_stats()
        assert tier.fetch_latency_us()["fetch_samples"] == 0


def test_engine_integration_adopts_and_ticks():
    """Through the serving engine: the OOC backend sizes the tier's
    prefetch pool to its io_groups, every gather fires a non-blocking
    promotion tick, the counters ride BatchResult.extras, results stay
    bit-identical to the in-memory reference while residency migrates, and
    engine.close() tears the promoter down."""
    from repro import serving
    from tests import _backend_fixtures as fx

    _x, q, _gt, idx, tiered = fx.built()
    tier = BlockSlowTier(BlockStore(fx.built_ooc_tier().store.path),
                         cache_nodes=64, hot_nodes=128, hot_chunk=32)
    assert tier.io_workers is None
    be = serving.OutOfCoreBackend(tiered.codes, tiered.codebook, idx.entry,
                                  tier, io_groups=2)
    assert tier.io_workers == 2                # backend adopted io_groups
    eng = serving.SearchEngine(be, fx.BUDGET, k=10)
    ref = fx.engine("tiered")
    fx.assert_bit_identical(eng.search(q), ref.search(q))
    tier.drain_promotions()                    # first gather's tick lands
    res = eng.search(q)                        # now served against hot set
    fx.assert_bit_identical(res, ref.search(q))
    st = res.extras["slow_tier"]
    assert st["promotion_ticks"] >= 1 and st["promotions"] > 0
    assert st["hot_hits"] > 0
    promoters = set(tier._hot._pool._threads)
    eng.close()
    assert tier.closed
    assert promoters and not any(t.is_alive() for t in promoters)
