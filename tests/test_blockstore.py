"""Block store fault injection + cache accounting.

A disk tier that can return garbage is worse than no disk tier: every
corruption mode here (truncation, bit rot, wrong/stale format) must surface
as a *typed* error naming the problem, never as silently wrong neighbours.
The cache counters are pinned exactly — they are the serving observability
signal, so "roughly right" is not a property.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import build
from repro.index import (BlockChecksumError, BlockSlowTier, BlockStore,
                         BlockStoreFormatError, BlockStoreTruncatedError,
                         build_tiered_index, entry_proximal_ids,
                         open_block_store, save_index, write_block_store)
from repro.index import blockstore as bs
from tests._hypothesis_compat import given, settings, st

N, D, R = 64, 12, 6


@pytest.fixture()
def store_path(tmp_path):
    rng = np.random.default_rng(0)
    vectors = rng.normal(size=(N, D)).astype(np.float32)
    adj = rng.integers(-1, N, size=(N, R)).astype(np.int32)
    p = write_block_store(tmp_path / "t.blocks", vectors, adj)
    return p, vectors, adj


def test_round_trip_and_alignment(store_path):
    p, vectors, adj = store_path
    store = BlockStore(p)
    assert store.n == N and store.d == D and store.r == R
    assert store.block_size % bs.SECTOR == 0
    assert p.stat().st_size == (N + 1) * store.block_size
    ids = np.asarray([0, 3, 63, 3])          # duplicates allowed
    vecs, adjs = store.read_many(ids)
    np.testing.assert_array_equal(vecs, vectors[ids])
    np.testing.assert_array_equal(adjs, adj[ids])
    assert store.stats.blocks_read == 4
    with pytest.raises(IndexError):
        store.read_many(np.asarray([N]))


def test_truncated_file_raises_typed_error(store_path):
    p, _, _ = store_path
    data = p.read_bytes()
    p.write_bytes(data[: len(data) - bs.SECTOR])     # lose the last node
    with pytest.raises(BlockStoreTruncatedError, match="bytes on disk"):
        BlockStore(p)


def test_corrupted_block_raises_checksum_error(store_path):
    p, _, _ = store_path
    store = BlockStore(p)
    raw = bytearray(p.read_bytes())
    node = 7
    raw[(1 + node) * store.block_size + 2] ^= 0xFF   # flip one payload byte
    p.write_bytes(bytes(raw))
    corrupt = BlockStore(p)
    with pytest.raises(BlockChecksumError, match="node 7"):
        corrupt.read_many(np.asarray([3, 7, 11]))
    # Untouched nodes still read fine.
    corrupt.read_many(np.asarray([3, 11]))


def test_wrong_format_raises_format_error(store_path, tmp_path):
    p, _, _ = store_path
    # Bad magic.
    raw = bytearray(p.read_bytes())
    raw[0] ^= 0xFF
    bad = tmp_path / "bad_magic.blocks"
    bad.write_bytes(bytes(raw))
    with pytest.raises(BlockStoreFormatError, match="bad magic"):
        BlockStore(bad)
    # Right magic, wrong format string in the manifest.
    raw = bytearray(p.read_bytes())
    store = BlockStore(p)
    manifest = json.dumps({"format": "repro.blockstore.v999", "n": N,
                           "d": D, "r": R,
                           "block_size": store.block_size}).encode()
    raw[len(bs.MAGIC): len(bs.MAGIC) + 4] = (
        np.uint32(len(manifest)).astype("<u4").tobytes())
    end = len(bs.MAGIC) + 4 + len(manifest)
    raw[len(bs.MAGIC) + 4: end] = manifest
    raw[end: store.block_size] = b"\0" * (store.block_size - end)
    wrong = tmp_path / "wrong_format.blocks"
    wrong.write_bytes(bytes(raw))
    with pytest.raises(BlockStoreFormatError, match="v999"):
        BlockStore(wrong)
    # Not a block store at all.
    not_store = tmp_path / "noise.blocks"
    not_store.write_bytes(b"\x01" * 2048)
    with pytest.raises(BlockStoreFormatError):
        BlockStore(not_store)
    with pytest.raises(BlockStoreFormatError):
        BlockStore(tmp_path / "missing.blocks")


def test_stale_sidecar_is_a_format_error(tmp_path):
    """A v2 index whose sidecar geometry disagrees with its manifest (stale
    or swapped .blocks file) must refuse to open, not serve wrong vectors."""
    from repro.data import make_dataset

    x, _ = make_dataset("tiny-mixture", seed=0)
    x = x[:300]
    cfg = build.BuildConfig(degree=8, beam_width=16, iters=1, batch=128,
                            max_hops=32)
    index = build_tiered_index(x, build.build_mcgi(x, cfg), m_pq=8)
    p = tmp_path / "idx.npz"
    save_index(p, index, version=2)
    sidecar = pathlib.Path(str(p) + ".blocks")
    rng = np.random.default_rng(1)
    write_block_store(sidecar,                       # overwrite: wrong shape
                      rng.normal(size=(10, 4)).astype(np.float32),
                      np.zeros((10, 2), np.int32))
    with pytest.raises(BlockStoreFormatError, match="stale or swapped"):
        open_block_store(p)
    # Same geometry, different content: only the fingerprint can tell.
    vec2 = np.asarray(index.vectors).copy()
    vec2[0, 0] += 1.0
    write_block_store(sidecar, vec2, np.asarray(index.graph.adj))
    with pytest.raises(BlockStoreFormatError, match="vectors_crc32"):
        open_block_store(p)


def test_ensure_block_store_reuses_recovers_and_rewrites(tmp_path):
    """The shared bootstrap: reuse on fingerprint match, rewrite on
    anything else — absent, unreadable junk (must not crash), or a
    same-shaped store for different content."""
    from repro.index import ensure_block_store
    from repro.index.blockstore import vectors_crc32

    rng = np.random.default_rng(2)
    vectors = rng.normal(size=(16, 8)).astype(np.float32)
    adj = rng.integers(-1, 16, size=(16, 4)).astype(np.int32)
    p = tmp_path / "e.blocks"
    msgs = []
    s1 = ensure_block_store(p, vectors, adj, log=msgs.append)
    assert any("wrote" in m for m in msgs)
    mtime = p.stat().st_mtime_ns
    s2 = ensure_block_store(p, vectors, adj)          # match: reused as-is
    assert p.stat().st_mtime_ns == mtime
    assert s2.vectors_crc32 == s1.vectors_crc32
    p.write_bytes(b"not a store")                     # junk: recovered
    msgs.clear()
    s3 = ensure_block_store(p, vectors, adj, log=msgs.append)
    assert any("unreadable" in m for m in msgs)
    np.testing.assert_array_equal(s3.read_many(np.asarray([5]))[0],
                                  vectors[[5]])
    v2 = vectors.copy()                               # same shape, new content
    v2[0, 0] += 1.0
    msgs.clear()
    s4 = ensure_block_store(p, v2, adj, log=msgs.append)
    assert any("stale" in m for m in msgs)
    assert s4.vectors_crc32 == vectors_crc32(v2)


@settings(max_examples=10, deadline=None)
@given(frac=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_crash_recovery_never_opens_a_torn_store(frac, seed):
    """Crash-recovery property for the atomic tmp-rename publish: simulate
    a crash at an *arbitrary* byte cut — a partial ``.tmp`` that was never
    renamed, a torn header, a truncated store — and assert a torn store is
    never opened (typed error) while ``ensure_block_store`` always recovers
    by rewriting."""
    import shutil
    import tempfile

    from repro.index import ensure_block_store

    rng = np.random.default_rng(seed)
    vectors = rng.normal(size=(16, 8)).astype(np.float32)
    adj = rng.integers(-1, 16, size=(16, 4)).astype(np.int32)
    tmpdir = pathlib.Path(tempfile.mkdtemp(prefix="mcgi-crash-"))
    try:
        p = tmpdir / "c.blocks"
        write_block_store(p, vectors, adj)
        full = p.read_bytes()
        cut = int(frac * (len(full) - 1))     # always strictly truncated

        # Crash BEFORE the rename: only a partial .tmp exists, the target
        # is absent.  The partial write must be invisible to readers and
        # the rewrite path must recover (and re-publish over the stray tmp).
        p.unlink()
        tmp = p.with_name(p.name + ".tmp")
        tmp.write_bytes(full[:cut])
        with pytest.raises(bs.BlockStoreFormatError):
            BlockStore(p)                     # the target was never published
        store = ensure_block_store(p, vectors, adj)
        np.testing.assert_array_equal(store.read_many(np.arange(16))[0],
                                      vectors)
        assert not tmp.exists()               # publish consumed the tmp name

        # Crash that tore the published file itself (torn header when the
        # cut lands in block 0, truncated records otherwise): never opens.
        p.write_bytes(full[:cut])
        with pytest.raises(bs.BlockStoreError):
            BlockStore(p)
        msgs = []
        store = ensure_block_store(p, vectors, adj, log=msgs.append)
        assert any("unreadable" in m for m in msgs)
        vr, ar = store.read_many(np.arange(16))
        np.testing.assert_array_equal(vr, vectors)
        np.testing.assert_array_equal(ar, adj)
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)


def test_cache_counters_exact_on_replayed_stream(store_path):
    p, vectors, adj = store_path
    pinned = np.asarray([0, 1, 2, 3])
    tier = BlockSlowTier(BlockStore(p), cache_nodes=N, pinned_ids=pinned)
    # Counters start clean: the pinned-set load is construction, not traffic.
    assert tier.stats()["blocks_read"] == 0
    assert tier.stats()["pinned_nodes"] == 4

    # INVALID (-1) padding lanes are masked out of counting and I/O — they
    # must not clamp to node 0 and inflate its hit/miss counters (node 0 is
    # pinned here, so the old clamping would fake an extra pinned hit).
    stream = [np.asarray([[5, 9, -1], [5, 17, -1]]),
              np.asarray([[9, 17, 33]])]
    # First pass: per batch, each *distinct valid* id counts once.
    tier.fetch_beams(stream[0])   # distinct valid {5,9,17}: 0 hits, 3 miss
    tier.fetch_beams(stream[1])   # distinct {9,17,33}: 2 hits, 1 miss
    st = tier.stats()
    assert (st["cache_hits"], st["cache_misses"]) == (2, 4)
    assert st["blocks_read"] == 4                 # reads == misses
    # Replay: everything is cached now — all hits, zero block reads.
    tier.reset_stats()
    for beams in stream:
        out = tier.fetch_beams(beams)
        valid = beams >= 0
        np.testing.assert_array_equal(out[valid], vectors[beams[valid]])
        assert (out[~valid] == 0.0).all()         # INVALID rows zero-filled
    st2 = tier.stats()
    assert (st2["cache_hits"], st2["cache_misses"]) == (6, 0)
    assert st2["hit_rate"] == 1.0 and st2["blocks_read"] == 0


def test_lru_eviction_bounds_cache_and_keeps_pins(store_path):
    p, vectors, _ = store_path
    tier = BlockSlowTier(BlockStore(p), cache_nodes=4,
                         pinned_ids=np.asarray([60]))
    tier.fetch(np.arange(10))                     # 10 misses through a 4-LRU
    st = tier.stats()
    assert st["cached_nodes"] == 4 and st["pinned_nodes"] == 1
    assert st["cache_misses"] == 10
    # Pinned node hits without a read even after heavy eviction traffic.
    tier.reset_stats()
    np.testing.assert_array_equal(tier.fetch(np.asarray([60]))[0],
                                  vectors[60])
    assert tier.stats()["cache_hits"] == 1
    assert tier.stats()["blocks_read"] == 0


def test_prefetch_future_matches_direct_fetch(store_path):
    p, vectors, adj = store_path
    tier = BlockSlowTier(BlockStore(p), cache_nodes=N)
    beams = np.asarray([[1, 4, -1], [44, 2, 9]])
    want = np.zeros((*beams.shape, D), np.float32)
    want[beams >= 0] = vectors[beams[beams >= 0]]
    fut = tier.prefetch(beams)
    np.testing.assert_array_equal(fut.result(), want)
    # Walk-frontier prefetch: adjacency rows, INVALID lanes all-INVALID.
    u = np.asarray([3, -1, 44])
    rows = tier.prefetch_adj(u).result()
    np.testing.assert_array_equal(rows[[0, 2]], adj[[3, 44]])
    assert (rows[1] == -1).all()
    tier.close()


def test_close_shuts_down_worker_and_is_idempotent(store_path):
    """The tier owns its prefetch thread: close() (or the context manager)
    tears it down, later prefetches degrade to synchronous completed
    futures (teardown may race an in-flight stream, which must still see
    correct data), synchronous fetches still work, double-close is fine."""
    import threading

    def n_workers():
        return sum("slow-tier-prefetch" in t.name
                   for t in threading.enumerate())

    p, vectors, _ = store_path
    base = n_workers()               # other fixtures may own live tiers
    with BlockSlowTier(BlockStore(p), cache_nodes=8) as tier:
        tier.prefetch(np.asarray([[1, 2]])).result()
        assert n_workers() == base + 1
    assert tier.closed
    assert n_workers() == base       # close() joins the worker
    fut = tier.prefetch(np.asarray([[1, 2]]))      # degraded: no new worker
    assert fut.done() and n_workers() == base
    np.testing.assert_array_equal(fut.result()[0, 0], vectors[1])
    np.testing.assert_array_equal(tier.fetch(np.asarray([5]))[0], vectors[5])
    tier.close()                                   # idempotent


@pytest.fixture()
def packed_path(tmp_path):
    """A packed store: 8 records per I/O block, random slot permutation
    (content round-trip must be layout-agnostic; the greedy layout is a
    build-time concern tested in test_prune)."""
    rng = np.random.default_rng(3)
    vectors = rng.normal(size=(N, D)).astype(np.float32)
    adj = rng.integers(-1, N, size=(N, R)).astype(np.int32)
    slot_of = rng.permutation(N).astype(np.int64)
    p = write_block_store(tmp_path / "p.blocks", vectors, adj,
                          nodes_per_block=8, slot_of=slot_of)
    return p, vectors, adj, slot_of


def test_packed_layout_round_trips_by_node_id(store_path, packed_path):
    p, vectors, adj, slot_of = packed_path
    store = BlockStore(p)
    assert store.nodes_per_block == 8 and store.layout == "packed"
    assert store.slot_table_crc32 is not None
    np.testing.assert_array_equal(store.slot_of, slot_of)
    ids = np.asarray([0, 9, 63, 9])              # node ids, not slots
    vecs, adjs = store.read_many(ids)
    np.testing.assert_array_equal(vecs, vectors[ids])
    np.testing.assert_array_equal(adjs, adj[ids])
    # Default-layout files keep the historical attribute values (and the
    # historical byte format: no layout keys, no slot table).
    default = BlockStore(store_path[0])
    assert default.nodes_per_block == 1 and default.layout == "node-order"
    assert default.slot_of is None and default.slot_table_crc32 is None


def test_read_blocks_returns_every_co_located_record(packed_path):
    p, vectors, adj, _ = packed_path
    store = BlockStore(p)
    bid = store.io_block_of(np.asarray([5]))
    assert bid.shape == (1,)
    node_ids, vecs, adjs = store.read_blocks(bid)
    assert node_ids.size == 8 and 5 in node_ids.tolist()
    np.testing.assert_array_equal(vecs, vectors[node_ids])
    np.testing.assert_array_equal(adjs, adj[node_ids])
    assert store.stats.io_blocks == 1            # one I/O block touched...
    assert store.stats.blocks_read == 8          # ...eight records read
    # read_many's io_blocks counter is distinct-blocks, so reading all 8
    # co-located nodes record-wise still counts a single I/O block.
    store.reset_stats()
    store.read_many(node_ids)
    assert store.stats.io_blocks == 1


def test_packed_tier_turns_co_location_into_cache_hits(packed_path):
    p, vectors, _, _ = packed_path
    peers = BlockStore(p)                        # discovery copy: own stats
    node_ids, _, _ = peers.read_blocks(peers.io_block_of(np.asarray([5])))
    others = np.asarray([i for i in node_ids.tolist() if i != 5][:3])
    with BlockSlowTier(BlockStore(p), cache_nodes=N) as tier:
        np.testing.assert_array_equal(tier.fetch(np.asarray([5]))[0],
                                      vectors[5])
        st1 = tier.stats()
        # One miss — but the whole-block read cached the co-located peers.
        assert (st1["cache_hits"], st1["cache_misses"]) == (0, 1)
        assert st1["io_blocks"] == 1
        np.testing.assert_array_equal(tier.fetch(others), vectors[others])
        st2 = tier.stats()
        assert (st2["cache_hits"], st2["cache_misses"]) == (3, 1)
        assert st2["io_blocks"] == 1             # no further I/O


def test_ensure_block_store_rewrites_on_layout_change(tmp_path):
    from repro.index import ensure_block_store

    rng = np.random.default_rng(4)
    vectors = rng.normal(size=(16, 8)).astype(np.float32)
    adj = rng.integers(-1, 16, size=(16, 4)).astype(np.int32)
    slot_of = rng.permutation(16).astype(np.int64)
    p = tmp_path / "l.blocks"
    ensure_block_store(p, vectors, adj)          # default layout first
    msgs = []
    s = ensure_block_store(p, vectors, adj, nodes_per_block=8,
                           slot_of=slot_of, log=msgs.append)
    assert any("laid out differently" in m for m in msgs)
    assert s.nodes_per_block == 8 and s.layout == "packed"
    mtime = p.stat().st_mtime_ns
    s2 = ensure_block_store(p, vectors, adj, nodes_per_block=8,
                            slot_of=slot_of)     # same layout: reused as-is
    assert p.stat().st_mtime_ns == mtime
    np.testing.assert_array_equal(s2.read_many(np.arange(16))[0], vectors)


def test_concurrent_fetches_bit_exact_with_exact_counter_totals(store_path):
    """Stress the lock-split design (``_lock`` for cache+counters, never
    held across I/O; ``_io_lock`` for store reads) the way serving actually
    drives it: ``fetch_beams`` / ``prefetch`` / ``prefetch_adj`` racing from
    many threads over a multi-worker prefetch pool.  Every returned record
    must be bit-exact, and the hit+miss *total* must be exact — each call
    counts its distinct valid ids once, wherever they are found, so the
    total is deterministic even when the hit/miss split races.  A replay
    with everything cached then pins the split itself: all hits, zero
    reads."""
    import concurrent.futures as cf

    p, vectors, adj = store_path
    rng = np.random.default_rng(11)
    beams = [rng.integers(-1, N, size=(4, 5)) for _ in range(10)]
    frontiers = [rng.integers(-1, N, size=(7,)) for _ in range(10)]
    expected_total = sum(
        np.unique(a[a >= 0]).size for a in beams + frontiers)

    def check_beams(tier, b):
        out = tier.fetch_beams(b)
        valid = b >= 0
        np.testing.assert_array_equal(out[valid], vectors[b[valid]])
        assert (out[~valid] == 0.0).all()

    def check_adj(tier, u):
        rows = tier.prefetch_adj(u).result()     # worker-pool path
        valid = u >= 0
        np.testing.assert_array_equal(rows[valid], adj[u[valid]])
        assert (rows[~valid] == -1).all()

    def check_prefetch(tier, b):
        out = tier.prefetch(b).result()          # future == direct fetch
        valid = b >= 0
        np.testing.assert_array_equal(out[valid], vectors[b[valid]])

    def race(tier):
        with cf.ThreadPoolExecutor(max_workers=8) as pool:
            futs = []
            for b, u in zip(beams, frontiers):
                futs.append(pool.submit(check_beams, tier, b))
                futs.append(pool.submit(check_adj, tier, u))
                futs.append(pool.submit(check_prefetch, tier, b))
            for f in futs:
                f.result()                       # re-raises thread asserts

    with BlockSlowTier(BlockStore(p), cache_nodes=N,
                       io_workers=4) as tier:
        assert tier.io_workers == 4
        race(tier)
        st = tier.stats()
        # prefetch repeats each beam batch, so its distinct ids count twice.
        beams_total = sum(np.unique(b[b >= 0]).size for b in beams)
        assert (st["cache_hits"] + st["cache_misses"]
                == expected_total + beams_total)
        # Replay: the LRU holds every node now (cache_nodes=N, nothing
        # evicted) — the split itself is deterministic: all hits, no I/O.
        tier.reset_stats()
        race(tier)
        st2 = tier.stats()
        assert st2["cache_misses"] == 0 and st2["blocks_read"] == 0
        assert st2["cache_hits"] == expected_total + beams_total


def test_entry_proximal_pins_bfs_neighbourhood():
    adj = np.asarray([[1, 2, -1], [3, -1, -1], [3, 4, -1],
                      [-1] * 3, [-1] * 3, [-1] * 3], np.int32)
    ids = entry_proximal_ids(adj, 0, limit=4)
    assert ids[0] == 0
    assert set(ids.tolist()) == {0, 1, 2, 3}      # BFS order, truncated
    assert entry_proximal_ids(adj, 5, limit=4).tolist() == [5]
