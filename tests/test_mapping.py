"""Mapping function Phi (Eq. 8) — Props 3.5/3.6 as executable properties."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import mapping

finite_f = st.floats(
    min_value=-1e4, max_value=1e4, allow_nan=False, allow_infinity=False
)


@given(
    lid=st.lists(finite_f, min_size=2, max_size=32),
    mu=finite_f,
    sigma=st.floats(min_value=1e-3, max_value=1e3),
)
@settings(max_examples=200, deadline=None)
def test_boundedness(lid, mu, sigma):
    """Prop 3.6: alpha strictly inside (alpha_min, alpha_max) for finite LID."""
    a = mapping.phi(jnp.asarray(lid), jnp.float32(mu), jnp.float32(sigma))
    assert bool((a >= mapping.ALPHA_MIN).all())
    assert bool((a <= mapping.ALPHA_MAX).all())


@given(
    l1=finite_f, l2=finite_f, mu=finite_f,
    sigma=st.floats(min_value=1e-3, max_value=1e3),
)
@settings(max_examples=200, deadline=None)
def test_monotonicity(l1, l2, mu, sigma):
    """Prop 3.5: Phi strictly decreasing in LID (weakly under f32/clipping)."""
    lo, hi = min(l1, l2), max(l1, l2)
    a_lo = float(mapping.phi(jnp.float32(lo), jnp.float32(mu), jnp.float32(sigma)))
    a_hi = float(mapping.phi(jnp.float32(hi), jnp.float32(mu), jnp.float32(sigma)))
    assert a_hi <= a_lo + 1e-6


def test_midpoint():
    """z = 0 maps to the midpoint alpha ~= 1.25 (paper §3.2)."""
    a = float(mapping.phi(jnp.float32(5.0), jnp.float32(5.0), jnp.float32(1.0)))
    np.testing.assert_allclose(a, 1.25, atol=1e-6)


def test_constant_alpha_is_vamana():
    a = mapping.constant_alpha(10, 1.2)
    assert a.shape == (10,)
    np.testing.assert_allclose(float(a[0]), 1.2, rtol=1e-6)


@given(
    lam=st.floats(min_value=0.0, max_value=1.0),
    lids=st.lists(st.floats(min_value=0.5, max_value=64.0), min_size=2,
                  max_size=16),
)
@settings(max_examples=100, deadline=None)
def test_adaptive_budget_bounds_and_monotone(lam, lids):
    l = mapping.adaptive_beam_budget(jnp.asarray(lids), lam, 8, 128)
    assert bool((l >= 8).all()) and bool((l <= 128).all())
    order = np.argsort(np.asarray(lids))
    budgets = np.asarray(l)[order]
    assert (np.diff(budgets) >= 0).all()
