"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — unit/smoke tests run on the single real
CPU device. Multi-device behaviour is tested via subprocesses that set
--xla_force_host_platform_device_count themselves (tests/test_distributed.py).
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data import make_dataset

    x, q = make_dataset("tiny-mixture", seed=0)
    return x[:1500], q[:40]
