"""Property-test shim: real hypothesis when installed, a deterministic
sampler otherwise.

The tier-1 suite must collect and run on a clean environment (no
``pip install``), so the property tests in test_mapping.py / test_prune.py
import ``given``/``settings``/``st`` from here. With hypothesis present they
are the real thing (shrinking, example database, the works); without it, a
small deterministic fallback draws a fixed number of seeded examples from
the same strategy expressions — weaker, but the properties still execute.

Only the strategy surface the test files use is implemented: ``floats``,
``integers``, ``lists``, ``sampled_from``.
"""
from __future__ import annotations

import functools

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 50

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # Hit the boundaries sometimes — that's where clipping and
                # degenerate-variance behaviour lives.
                r = rng.random()
                if r < 0.05:
                    return lo
                if r < 0.10:
                    return hi
                return float(rng.uniform(lo, hi))

            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            choices = list(elements)
            return _Strategy(
                lambda rng: choices[int(rng.integers(len(choices)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(size)]

            return _Strategy(draw)

    st = _St()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = getattr(wrapper, "_fallback_max_examples",
                            _FALLBACK_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (wraps copies __wrapped__, which inspect follows).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(max_examples=None, **_kw):
        """Honour max_examples in the fallback (apply @settings *above*
        @given, the usual hypothesis stacking); other knobs are ignored."""

        def deco(fn):
            if max_examples is not None:
                fn._fallback_max_examples = max_examples
            return fn

        return deco
