"""Regression tests for the budget-law calibration pass: the fitted ``lam``
must hit its recall target (within tolerance) on synthetic data across two
intrinsic-dimensionality regimes, the fit must find a non-trivial (interior)
lam when the target bites, and the whole pass must be deterministic under a
fixed seed."""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.core import build, calibrate, distance, search
from repro.data import synthetic

CFG = build.BuildConfig(degree=16, beam_width=32, iters=1, batch=256,
                        max_hops=64)
# Tight budget floor (l_min=2) + modest hop budget: raising lam genuinely
# costs recall on the easy lanes, so the target is binding and the fitted
# lam is an interior point, not a range endpoint.
BASE = search.AdaptiveBeamBudget(l_min=2, l_max=48, lam=0.0, probe_hops=4,
                                 hop_factor=2)
TARGET = 0.97
TOL = 0.02
# Two heterogeneous-LID regimes: mostly-flat (2/8) vs mostly-complex (8/32).
DIM_REGIMES = ((2, 8), (8, 32))


@functools.lru_cache(maxsize=4)
def _built(intrinsic_dims):
    """Synthetic mixture of known intrinsic dimensionalities + MCGI graph."""
    key = jax.random.PRNGKey(7)
    pool = synthetic.mixture_of_manifolds(
        key, 1300, 48, intrinsic_dims=intrinsic_dims)
    x, q = pool[:1200], pool[1200:]
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    idx = build.build_mcgi(x, CFG)
    return x, q, gt_i, idx


def _fit(intrinsic_dims):
    x, q, gt_i, idx = _built(intrinsic_dims)
    return calibrate.calibrate_budget_law(
        calibrate.exact_recall_eval(x, idx.adj, idx.entry, q, gt_i,
                                    sample=64, seed=0),
        BASE, TARGET, max_iters=6)


@pytest.mark.parametrize("intrinsic_dims", DIM_REGIMES)
def test_calibrated_lam_hits_recall_target(intrinsic_dims):
    """On both LID regimes the fitted config's measured recall meets the
    target within tolerance, and the fit is an interior lam (the budget law
    is actually being used, not parked at an endpoint)."""
    result = _fit(intrinsic_dims)
    assert result.achieved, result
    assert result.recall >= TARGET - TOL, (intrinsic_dims, result)
    assert 0.0 < result.lam < 1.0, (intrinsic_dims, result.lam)
    # The recorded curve brackets the target: some evaluated lam missed it
    # (the constraint bites), the returned one meets it.
    recalls = [r for _, _, r in result.history]
    assert min(recalls) < TARGET <= result.recall


@pytest.mark.parametrize("intrinsic_dims", DIM_REGIMES)
def test_calibration_deterministic_under_fixed_seed(intrinsic_dims):
    """Same data + seed -> bit-identical fit: same lam, same hop_factor,
    same measured recall, same bisection path."""
    a, b = _fit(intrinsic_dims), _fit(intrinsic_dims)
    assert a.lam == b.lam
    assert a.hop_factor == b.hop_factor
    assert a.recall == b.recall
    assert a.history == b.history


def test_bisect_lam_finds_largest_feasible_knob():
    """Pure bisection logic on a synthetic monotone-decreasing recall curve:
    recall 1.0 - 0.25*lam crosses the 0.9 target at lam = 0.4."""
    curve = lambda lam: 1.0 - 0.25 * lam
    lam, recall, hist = calibrate.bisect_lam(
        curve, 0.9, 0.0, 1.0, tol=0.01, max_iters=12)
    assert recall >= 0.9
    assert abs(lam - 0.4) <= 0.02, lam
    assert hist[0] == (0.0, 1.0)  # feasibility check at lam_lo runs first


def test_bisect_lam_endpoints():
    # Even lam_lo misses: report infeasible at lam_lo (caller escalates).
    lam, recall, hist = calibrate.bisect_lam(
        lambda _lam: 0.3, 0.9, 0.0, 1.0, tol=0.01)
    assert lam == 0.0 and recall == 0.3 and len(hist) == 1
    # The whole range is feasible: take the max-savings endpoint.
    lam, recall, _ = calibrate.bisect_lam(
        lambda _lam: 0.99, 0.9, 0.0, 1.0, tol=0.01)
    assert lam == 1.0 and recall == 0.99


def test_calibrate_escalates_hop_factor():
    """When no lam reaches the target, hop_factor doubles until it does (or
    tops out, reported as not-achieved)."""
    def eval_recall(cfg):
        # Recall saturates at 0.8 until the hop budget doubles once.
        return 0.95 if cfg.hop_factor >= 8 else 0.8

    result = calibrate.calibrate_budget_law(
        eval_recall, search.AdaptiveBeamBudget(l_min=4, l_max=32, lam=0.2,
                                               hop_factor=4),
        0.9, max_hop_factor=16)
    assert result.achieved and result.hop_factor == 8
    assert result.recall == 0.95

    capped = calibrate.calibrate_budget_law(
        lambda cfg: 0.5, search.AdaptiveBeamBudget(l_min=4, l_max=32,
                                                   lam=0.2, hop_factor=4),
        0.9, max_hop_factor=8)
    assert not capped.achieved and capped.recall == 0.5


def test_dataset_config_calibration_uses_its_own_target():
    """McgiDatasetConfig.calibrated_beam_budget threads the config's
    recall_target into the fit and returns a ready-to-serve budget."""
    from repro.configs.mcgi_datasets import McgiDatasetConfig

    cfg = McgiDatasetConfig("t", 1000, 32, 16, 32, None, "float32",
                            l_search=64, lam=0.3, recall_target=0.9)
    seen = []

    def eval_recall(candidate):
        seen.append(candidate.lam)
        # Feasible only below lam=0.5: recall crosses the 0.9 target there.
        return 1.0 - candidate.lam * 0.2

    fitted = cfg.calibrated_beam_budget(eval_recall)
    assert fitted.l_max == 64 and fitted.l_min == 8
    assert 0.0 < fitted.lam <= 0.5
    assert 1.0 - fitted.lam * 0.2 >= cfg.recall_target
    assert len(seen) >= 2  # the bisection actually probed the curve


def test_joint_fit_picks_smallest_feasible_floor():
    """The joint (lam, l_min) fit scans floors ascending and returns the
    smallest one whose lam bisection meets the target; the fitted floor is
    substituted into budget_cfg()."""
    base = search.AdaptiveBeamBudget(l_min=16, l_max=64, lam=0.2)
    assert calibrate.joint_l_min_candidates(base) == (2, 4, 8, 16)

    def make_eval(cfg_lm):
        # Feasible iff l_min >= 8 (below, recall collapses regardless of
        # lam); above the floor, recall degrades gently in lam.
        def eval_recall(cfg):
            if cfg.l_min < 8:
                return 0.5
            return 1.0 - 0.2 * cfg.lam
        return eval_recall

    result = calibrate.calibrate_budget_law_joint(make_eval, base, 0.9)
    assert result.achieved and result.l_min == 8
    assert result.recall >= 0.9
    # The infeasible smaller floors were tried first and recorded.
    assert [lm for lm, *_ in result.joint_history] == [2, 4, 8]
    assert not result.joint_history[0][4] and result.joint_history[-1][4]
    fitted = result.budget_cfg(base)
    assert fitted.l_min == 8 and fitted.lam == result.lam

    # Deterministic: same inputs, same fit.
    again = calibrate.calibrate_budget_law_joint(make_eval, base, 0.9)
    assert again == result


def test_joint_fit_reports_infeasible_at_largest_floor():
    base = search.AdaptiveBeamBudget(l_min=8, l_max=32, lam=0.2,
                                     hop_factor=4)
    result = calibrate.calibrate_budget_law_joint(
        lambda cfg_lm: (lambda cfg: 0.5), base, 0.9, max_hop_factor=8)
    assert not result.achieved and result.l_min == 8
    assert result.recall == 0.5


def test_joint_fit_on_engine_hits_target():
    """End-to-end joint fit over the real exact-distance engine: the fitted
    (lam, l_min) meets the target on the held-out sample, and the floor
    never exceeds the base config's."""
    x, q, gt_i, idx = _built(DIM_REGIMES[1])
    base = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.0,
                                     probe_hops=4, hop_factor=2)
    result = calibrate.calibrate_budget_law_joint(
        lambda cfg: calibrate.exact_recall_eval(
            x, idx.adj, idx.entry, q, gt_i, sample=64, seed=0,
            base_cfg=cfg),
        base, 0.95, max_iters=4)
    assert result.achieved, result
    assert result.recall >= 0.95
    assert result.l_min in calibrate.joint_l_min_candidates(base)


@functools.lru_cache(maxsize=1)
def _two_regime_mesh():
    """A 2-shard distributed layout whose shards have *different* intrinsic
    dimensionality (shard 0 mostly-flat, shard 1 mostly-complex) — the
    geometry per-shard calibration exists for. Shard-major concatenated
    arrays + per-shard entries, plus a shared query pool drawn from both
    regimes."""
    import jax.numpy as jnp

    from repro.core import search as search_mod

    key = jax.random.PRNGKey(11)
    per = 600
    shards, queries = [], []
    for s, dims in enumerate(DIM_REGIMES):
        pool = synthetic.mixture_of_manifolds(
            jax.random.fold_in(key, s), per + 24, 48, intrinsic_dims=dims)
        shards.append(pool[:per])
        queries.append(pool[per:])
    adj = jnp.concatenate([build.build_mcgi(xs, CFG).adj for xs in shards])
    x = jnp.concatenate(shards)
    entries = jnp.stack([search_mod.medoid(xs) for xs in shards])
    q = jnp.concatenate(queries)
    return np.asarray(x), np.asarray(adj), np.asarray(entries), np.asarray(q)


# Per-shard fits need floor candidates to scan (joint_l_min_candidates
# halves down from the base floor) and a target the hard shard can only
# meet above the smallest floor — that separation is what per-shard
# calibration exists to exploit.
BASE_SHARD = dataclasses.replace(BASE, l_min=8)
TARGET_SHARD = 0.97


def _per_shard_fit():
    x, adj, entries, q = _two_regime_mesh()
    return calibrate.calibrate_budget_law_per_shard(
        calibrate.shard_exact_recall_evals(x, adj, entries, q, 2, k=10,
                                           sample=48, seed=0),
        BASE_SHARD, TARGET_SHARD, n_shards=2, max_iters=4)


def test_per_shard_calibration_deterministic():
    """Same data + seed -> identical per-shard fits, shard by shard (laws,
    hop factors, full bisection histories)."""
    a, b = _per_shard_fit(), _per_shard_fit()
    assert a == b
    lam, l_min = a.law_arrays()
    assert lam.shape == (2,) and lam.dtype == np.float32
    assert l_min.shape == (2,) and l_min.dtype == np.int32


def test_per_shard_fits_at_least_as_tight_as_global():
    """On the two-regime mesh, every shard's own (lam, l_min) fit meets the
    target on that shard, and the per-shard laws are at least as tight as
    one global law that must hold the target on *every* shard (min-pooled
    recall — a global SLO is only met when its worst shard meets it):
    shard floors never exceed the global floor, and the flat shard runs
    strictly below it — the easy shard stops subsidising the hard one."""
    import dataclasses as dc

    import jax.numpy as jnp

    x, adj, entries, q = _two_regime_mesh()
    make_shard_eval = calibrate.shard_exact_recall_evals(
        x, adj, entries, q, 2, k=10, sample=48, seed=0)
    fit = _per_shard_fit()
    assert fit.achieved, fit

    def make_pooled(cfg):
        evals = [make_shard_eval(s)(cfg) for s in range(2)]

        def pooled(c):
            return float(min(e(c) for e in evals))

        return pooled

    global_fit = calibrate.calibrate_budget_law_joint(
        make_pooled, BASE_SHARD, TARGET_SHARD, max_iters=4)
    assert global_fit.achieved, global_fit
    cfg_g = global_fit.budget_cfg(BASE_SHARD)

    # The hard shard's floor requirement binds the global law; per-shard
    # floors are never above it, and the regimes actually separate (the
    # flat shard sustains a strictly lower floor than the complex one).
    assert all(lm <= cfg_g.l_min for lm in fit.l_min), (fit, cfg_g)
    assert fit.l_min[0] < fit.l_min[1], fit

    def mean_budget(shard, cfg):
        per = adj.shape[0] // 2
        _, _, _, astats = search.beam_search_exact_adaptive(
            jnp.asarray(x[shard * per:(shard + 1) * per]),
            jnp.asarray(adj[shard * per:(shard + 1) * per]),
            jnp.asarray(q), jnp.asarray(entries)[shard], cfg, k=10)
        return float(np.mean(np.asarray(astats.budget)))

    # On the flat shard, serving its own law is strictly cheaper than
    # serving the global law the hard shard forced.
    own = mean_budget(0, dc.replace(BASE_SHARD, lam=fit.lam[0],
                                    l_min=fit.l_min[0],
                                    hop_factor=fit.hop_factor[0]))
    forced = mean_budget(0, cfg_g)
    assert own < forced, (own, forced, fit, global_fit)


def test_per_shard_serving_budget_escalates_hop_factor():
    """hop_factor is global in the distributed step: a fit that escalated
    it on any shard must raise the serving config's value to the per-shard
    max, or that shard serves under a tighter deadline than it was
    calibrated to (hop limits are caps — the max is safe everywhere)."""
    base = search.AdaptiveBeamBudget(l_min=4, l_max=32, lam=0.2,
                                     hop_factor=4)

    def make_shard_eval(s):
        def factory(cfg):
            def eval_recall(c):
                # Shard 1's hop budget binds until hop_factor doubles.
                if s == 1 and c.hop_factor < 8:
                    return 0.8
                return 0.95

            return eval_recall

        return factory

    fit = calibrate.calibrate_budget_law_per_shard(
        make_shard_eval, base, 0.9, n_shards=2)
    assert fit.achieved
    assert fit.hop_factor[0] == 4 and fit.hop_factor[1] == 8, fit
    srv = fit.serving_budget(base)
    assert srv.hop_factor == 8
    assert (srv.l_min, srv.l_max, srv.lam) == (base.l_min, base.l_max,
                                               base.lam)


def test_holdout_sample_deterministic_and_sorted():
    a = calibrate.holdout_sample(100, 32, seed=3)
    b = calibrate.holdout_sample(100, 32, seed=3)
    np.testing.assert_array_equal(a, b)
    assert len(np.unique(a)) == 32
    assert (np.diff(a) > 0).all()  # sorted, no repeats
    assert calibrate.holdout_sample(10, 32).shape == (10,)
