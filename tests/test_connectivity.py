"""Prop 4.3 — topological fidelity: E_EMST ⊆ E_RNG ⊆ E_MCGI (alpha >= 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, mapping, theory
from repro.core.search import medoid


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_inclusion_chain_complete_pool(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(40, 3)).astype(np.float32)
    emst = theory.emst_edges(x)
    rngg = theory.rng_edges(x)
    alpha = np.full((40,), 1.0, np.float32)
    mcgi = theory.mcgi_complete_pool_edges(x, alpha, degree=None)
    assert emst <= rngg, "Toussaint inclusion violated"
    assert rngg <= mcgi, f"RNG ⊄ MCGI: missing {rngg - mcgi}"
    assert theory.is_connected(40, mcgi)


def test_inclusion_with_heterogeneous_alpha():
    """Per-node alpha(u) >= 1 (the MCGI regime) preserves the chain."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(30, 4)).astype(np.float32)
    alpha = rng.uniform(1.0, 1.5, size=30).astype(np.float32)
    rngg = theory.rng_edges(x)
    mcgi = theory.mcgi_complete_pool_edges(x, alpha, degree=None)
    assert rngg <= mcgi
    assert theory.is_connected(30, mcgi)


def test_built_index_navigable(tiny_dataset):
    """Every node reachable from the medoid on a built MCGI graph — the
    operational consequence Prop 4.3 exists to guarantee."""
    x, _ = tiny_dataset
    x = x[:800]
    cfg = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                            max_hops=96)
    idx = build.build_mcgi(x, cfg)
    reach = theory.reachable_from(np.asarray(idx.adj), int(idx.entry))
    assert reach.mean() > 0.999, reach.mean()


def test_alpha_below_one_can_break_rng():
    """Sanity of the test oracle: alpha < 1 (disallowed) breaks inclusion,
    demonstrating the alpha >= 1 hypothesis is load-bearing."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(25, 3)).astype(np.float32)
    rngg = theory.rng_edges(x)
    mcgi = theory.mcgi_complete_pool_edges(
        x, np.full((25,), 0.5, np.float32), degree=None
    )
    # Not asserting strict violation (it's distribution-dependent), but the
    # pruned graph must be no larger and typically loses RNG edges.
    assert len(mcgi) <= len(theory.mcgi_complete_pool_edges(
        x, np.ones((25,), np.float32), degree=None))
