"""Property tests pinning budget-bucketed execution (via the hypothesis
shim): bucket scheduling must be a pure wall-clock optimisation —
permutation-invariant and identical to the unbucketed adaptive path, up to
distance ties, for the exact, PQ, and tiered variants."""
import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro.core import build, distance, search
from repro.distributed import sharded_search as ss
from repro.index import build_tiered_index
from repro.index.disk import search_tiered_adaptive
from tests._hypothesis_compat import given, settings, st

CFG = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                        max_hops=96)
BUDGET = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3)


@functools.lru_cache(maxsize=1)
def _built():
    """Module-level build cache: @given-wrapped tests can't take fixtures
    (the shim erases the signature), so the shared index lives here."""
    from repro.data import make_dataset

    x, q = make_dataset("tiny-mixture", seed=0)
    x, q = x[:1500], q[:40]
    idx = build.build_mcgi(x, CFG)
    tiered = build_tiered_index(x, idx, m_pq=8)
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    return x, q, gt_i, idx, tiered


def _run_variant(variant, q, num_buckets, budget=BUDGET):
    x, _, _, idx, tiered = _built()
    if variant == "exact":
        return search.beam_search_exact_adaptive(
            x, idx.adj, q, idx.entry, budget, k=10, num_buckets=num_buckets)
    if variant == "pq":
        return search_tiered_adaptive(
            tiered, q, budget, k=10, rerank=False, num_buckets=num_buckets)
    assert variant == "tiered"
    return search_tiered_adaptive(
        tiered, q, budget, k=10, num_buckets=num_buckets)


def _assert_same_up_to_ties(ids_a, d_a, ids_b, d_b, tol=1e-5):
    """Result equality modulo distance ties: distances must match, and any
    id mismatch must sit on a tie (equal distances at that rank)."""
    ids_a, d_a = np.asarray(ids_a), np.asarray(d_a)
    ids_b, d_b = np.asarray(ids_b), np.asarray(d_b)
    both_inf = np.isinf(d_a) & np.isinf(d_b)
    np.testing.assert_allclose(
        np.where(both_inf, 0.0, d_a), np.where(both_inf, 0.0, d_b),
        rtol=tol, atol=tol)
    mism = ids_a != ids_b
    assert np.allclose(d_a[mism], d_b[mism], rtol=tol, atol=tol), (
        "id mismatch without a distance tie")


VARIANTS = ("exact", "pq", "tiered")


@functools.lru_cache(maxsize=8)
def _unbucketed(variant):
    _, q, _, _, _ = _built()
    return _run_variant(variant, q, None)


@settings(max_examples=5, deadline=None)
@given(num_buckets=st.integers(2, 6))
def test_bucketed_matches_unbucketed(num_buckets):
    """Bucketed execution returns the unbucketed adaptive path's results
    (scheduling changes, math doesn't) for every bucket count, on the exact,
    PQ, and tiered variants."""
    _, q, _, _, _ = _built()
    for variant in VARIANTS:
        ids_u, d_u, stats_u, astats_u = _unbucketed(variant)
        ids_b, d_b, stats_b, astats_b = _run_variant(variant, q, num_buckets)
        _assert_same_up_to_ties(ids_u, d_u, ids_b, d_b)
        # Work accounting is preserved exactly: same hops, same budgets.
        np.testing.assert_array_equal(np.asarray(stats_u.hops),
                                      np.asarray(stats_b.hops))
        np.testing.assert_array_equal(np.asarray(astats_u.budget),
                                      np.asarray(astats_b.budget))


# Pinned LID center: the default (batch-mean) centering is itself
# order-sensitive at the float-ulp level (a permuted sum rounds differently),
# which is the *reducer's* property, not the bucket scheduler's. Pinning the
# center isolates the property under test: scheduling must not depend on
# batch order.
BUDGET_PINNED = dataclasses.replace(BUDGET, center=8.0)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), num_buckets=st.integers(2, 5))
def test_bucketed_permutation_invariant(seed, num_buckets):
    """Shuffling the query batch must not change any query's result: bucket
    membership is a per-query property, not a batch-order artifact."""
    _, q, _, _, _ = _built()
    perm = np.random.default_rng(seed).permutation(q.shape[0])
    inv = np.argsort(perm)
    q_perm = jnp.asarray(np.asarray(q)[perm])
    for variant in VARIANTS:
        ids_o, d_o, stats_o, _ = _run_variant(
            variant, q, num_buckets, budget=BUDGET_PINNED)
        ids_p, d_p, stats_p, _ = _run_variant(
            variant, q_perm, num_buckets, budget=BUDGET_PINNED)
        _assert_same_up_to_ties(ids_o, d_o,
                                np.asarray(ids_p)[inv],
                                np.asarray(d_p)[inv])
        np.testing.assert_array_equal(np.asarray(stats_o.hops),
                                      np.asarray(stats_p.hops)[inv])


@settings(max_examples=8, deadline=None)
@given(l_min=st.integers(1, 64), span=st.integers(0, 512),
       max_buckets=st.integers(1, 8))
def test_bucket_ceilings_cover_budget_range(l_min, span, max_buckets):
    """Ceilings are ascending, bounded by [l_min, l_max], end at l_max, and
    quantization rounds every in-range budget up to a valid ceiling."""
    l_max = l_min + span
    cs = search.budget_bucket_ceilings(l_min, l_max, max_buckets)
    assert list(cs) == sorted(set(cs))
    assert 1 <= len(cs) <= max_buckets
    assert cs[-1] == l_max and cs[0] >= l_min
    budgets = jnp.asarray(
        np.linspace(l_min, l_max, num=16).round().astype(np.int32))
    idx, quant = search.quantize_budgets(budgets, cs)
    q_np, b_np = np.asarray(quant), np.asarray(budgets)
    assert (q_np >= b_np).all() and (q_np <= l_max).all()
    assert all(int(c) in cs for c in q_np)
    # Round-up is tight: no ceiling between the budget and its bucket.
    for b, c in zip(b_np, q_np):
        lower = [cc for cc in cs if cc >= b]
        assert c == lower[0]


def test_distributed_bucket_deadline_caps_hops():
    """The in-graph quantized path (hedged per-shard deadlines): budgets are
    rounded up to bucket ceilings and the walk still returns its best-so-far
    candidates under the ceiling-derived hop deadline."""
    x, q, _, idx, _ = _built()
    ceilings = search.budget_bucket_ceilings(BUDGET.l_min, BUDGET.l_max, 4)
    d2, ids = ss._local_search(
        idx.adj, None, x, None, q, idx.entry,
        beam_width=BUDGET.l_max, max_hops=96, k=5, query_chunk=q.shape[0],
        use_pq=False, beam_budget=BUDGET, bucket_ceilings=ceilings)
    assert d2.shape == (q.shape[0], 5) and ids.shape == (q.shape[0], 5)
    assert bool(jnp.isfinite(d2).all())
    # Quantized budgets can only widen the frontier: recall of the hedged
    # path is no worse than the raw adaptive path on the same shard.
    d2_raw, _ = ss._local_search(
        idx.adj, None, x, None, q, idx.entry,
        beam_width=BUDGET.l_max, max_hops=96, k=5, query_chunk=q.shape[0],
        use_pq=False, beam_budget=BUDGET, bucket_ceilings=None)
    assert float(jnp.mean(d2)) <= float(jnp.mean(d2_raw)) + 1e-5
