"""In-graph budget-bucket properties: the static ceiling family itself and
the distributed step's hedged per-shard hop deadlines.  The host-side
bucketed==unbucketed / permutation-invariance identity properties formerly
here are consolidated in ``tests/test_engine_parity.py`` (shared fixtures:
``tests/_backend_fixtures.py``), parametrized over every backend including
the staged distributed path."""
import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.distributed import sharded_search as ss
from tests._backend_fixtures import BUDGET, built
from tests._hypothesis_compat import given, settings, st


@settings(max_examples=8, deadline=None)
@given(l_min=st.integers(1, 64), span=st.integers(0, 512),
       max_buckets=st.integers(1, 8))
def test_bucket_ceilings_cover_budget_range(l_min, span, max_buckets):
    """Ceilings are ascending, bounded by [l_min, l_max], end at l_max, and
    quantization rounds every in-range budget up to a valid ceiling."""
    l_max = l_min + span
    cs = search.budget_bucket_ceilings(l_min, l_max, max_buckets)
    assert list(cs) == sorted(set(cs))
    assert 1 <= len(cs) <= max_buckets
    assert cs[-1] == l_max and cs[0] >= l_min
    budgets = jnp.asarray(
        np.linspace(l_min, l_max, num=16).round().astype(np.int32))
    idx, quant = search.quantize_budgets(budgets, cs)
    q_np, b_np = np.asarray(quant), np.asarray(budgets)
    assert (q_np >= b_np).all() and (q_np <= l_max).all()
    assert all(int(c) in cs for c in q_np)
    # Round-up is tight: no ceiling between the budget and its bucket.
    for b, c in zip(b_np, q_np):
        lower = [cc for cc in cs if cc >= b]
        assert c == lower[0]


def test_distributed_bucket_deadline_caps_hops():
    """The in-graph quantized path (hedged per-shard deadlines): budgets are
    rounded up to bucket ceilings and the walk still returns its best-so-far
    candidates under the ceiling-derived hop deadline."""
    x, q, _, idx, _ = built()
    ceilings = search.budget_bucket_ceilings(BUDGET.l_min, BUDGET.l_max, 4)
    d2, ids = ss._local_search(
        idx.adj, None, x, None, q, idx.entry,
        beam_width=BUDGET.l_max, max_hops=96, k=5, query_chunk=q.shape[0],
        use_pq=False, beam_budget=BUDGET, bucket_ceilings=ceilings)
    assert d2.shape == (q.shape[0], 5) and ids.shape == (q.shape[0], 5)
    assert bool(jnp.isfinite(d2).all())
    # Quantized budgets can only widen the frontier: recall of the hedged
    # path is no worse than the raw adaptive path on the same shard.
    d2_raw, _ = ss._local_search(
        idx.adj, None, x, None, q, idx.entry,
        beam_width=BUDGET.l_max, max_hops=96, k=5, query_chunk=q.shape[0],
        use_pq=False, beam_budget=BUDGET, bucket_ceilings=None)
    assert float(jnp.mean(d2)) <= float(jnp.mean(d2_raw)) + 1e-5


def test_local_search_per_shard_law_overrides():
    """Traced (lam, l_min) overrides reproduce the config's own law exactly
    (identity broadcast) and actually move the granted budgets when they
    differ — the per-shard calibration contract."""
    x, q, _, idx, _ = built()
    base = dict(beam_width=BUDGET.l_max, max_hops=96, k=5,
                query_chunk=q.shape[0], use_pq=False, beam_budget=BUDGET)
    d2_cfg, ids_cfg = ss._local_search(
        idx.adj, None, x, None, q, idx.entry, **base)
    d2_ovr, ids_ovr = ss._local_search(
        idx.adj, None, x, None, q, idx.entry, **base,
        lam=jnp.float32(BUDGET.lam), l_min=jnp.int32(BUDGET.l_min))
    np.testing.assert_array_equal(np.asarray(ids_cfg), np.asarray(ids_ovr))
    np.testing.assert_array_equal(np.asarray(d2_cfg), np.asarray(d2_ovr))
    # A different law changes the grant: lam=0 collapses every budget to the
    # geometric mid, which must differ from the spread law's grants on a
    # heterogeneous batch (the walk's top-k may coincide on a tiny graph —
    # the budgets are the contract).
    eval_dists = ss._shard_eval(None, x, use_pq=False)
    _, b_cfg, _, _ = search.adaptive_probe_batch(
        q, idx.adj, idx.entry, eval_dists, x.shape[0], BUDGET)
    _, b_flat, _, _ = search.adaptive_probe_batch(
        q, idx.adj, idx.entry, eval_dists, x.shape[0], BUDGET,
        lam=jnp.float32(0.0))
    assert np.asarray(b_cfg).min() < np.asarray(b_cfg).max()
    assert np.asarray(b_flat).min() == np.asarray(b_flat).max()
    assert not np.array_equal(np.asarray(b_flat), np.asarray(b_cfg))
