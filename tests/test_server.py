"""The async serving front door, tested entirely under the virtual clock.

Every test drives :class:`repro.serving.server.VirtualClock` /
:class:`VirtualDispatcher` — no ``time.sleep`` anywhere, every interleaving
(randomized arrivals, class mixes, coalescing boundaries, deadline expiry
mid-continue, overload shed, drain on shutdown) replayable bit-exactly.
The core property: for every *admitted* request the served lane is
bit-identical to a direct engine call on the same queries — admission,
coalescing and scheduling never change the math (the front-door extension
of the pipeline's result-transparency invariant).

Engines are the shared parity fixtures (``tests/_backend_fixtures.py``,
pinned LID center so per-lane results are dispatch-composition-independent);
the admission/lifecycle mechanics run against a deterministic fake engine
so queue/deadline/shed behaviour is tested without device math in the way.
"""
import dataclasses
import functools
import math
import threading

import numpy as np

from repro.core import search
from repro.serving import server
from repro.serving.engine import BatchResult, SearchEngine, TieredBackend
from tests._backend_fixtures import BUDGET, built, engine
from tests._hypothesis_compat import given, settings, st


@functools.lru_cache(maxsize=1)
def ref_rows():
    """Per-lane reference results over the fixture queries: under the pinned
    center, row i of the all-queries batch == any dispatch containing lane i
    (pinned by the parity matrix; relied on here)."""
    _x, q, _gt, _idx, _t = built()
    res = engine("exact").search(q)
    return q, np.asarray(res.ids), np.asarray(res.d2)


class FakeEngine:
    """Deterministic engine-shaped object for admission mechanics: results
    derived from the batch bytes, injectable finish failure, close counting.
    No partial support — in-flight deadline hedges fall through to timeout.
    """

    supports_partial = False

    def __init__(self, k: int = 4, fail_finish: bool = False):
        self.k = k
        self.fail_finish = fail_finish
        self.close_calls = 0
        self.finishes = 0

    def begin(self, batch):
        return {"batch": np.asarray(batch, np.float64)}

    def finish_from(self, flight):
        if self.fail_finish:
            raise RuntimeError("injected finish failure")
        self.finishes += 1
        b = flight["batch"]
        base = np.round(b[:, :1] * 1000.0).astype(np.int64)
        ids = base + np.arange(self.k)[None, :]
        d2 = ids.astype(np.float64) / 7.0
        stats = search.SearchStats(
            hops=np.full(b.shape[0], 7.0),
            dist_evals=np.full(b.shape[0], 70.0))
        return BatchResult(ids=ids, d2=d2, stats=stats)

    def close(self):
        self.close_calls += 1


def fake_door(*, deadline_s=100.0, batch_window_s=0.0, max_lanes=4,
              max_queue=256, service_time=0.0, probe_time=0.0,
              eng=None, lane_quantum=1):
    clock = server.VirtualClock()
    eng = FakeEngine() if eng is None else eng
    door = server.FrontDoor(
        {"a": eng},
        [server.QoSClass("a", deadline_s=deadline_s,
                         batch_window_s=batch_window_s, max_lanes=max_lanes,
                         lane_quantum=lane_quantum)],
        max_queue=max_queue, clock=clock,
        dispatcher=server.VirtualDispatcher(
            clock, service_time=service_time, probe_time=probe_time))
    return door, clock, eng


# ------------------------------------------------------------ virtual clock


def test_virtual_clock_orders_by_time_then_submission():
    clock = server.VirtualClock()
    fired = []
    clock.call_at(2.0, fired.append, "late")
    clock.call_at(1.0, fired.append, "first-at-1")
    clock.call_at(1.0, fired.append, "second-at-1")
    t = clock.call_at(1.5, fired.append, "cancelled")
    t.cancel()
    assert clock.pending() == 3
    ran = clock.advance(1.2)
    assert ran == 2 and fired == ["first-at-1", "second-at-1"]
    assert clock.now() == 1.2          # advances to the horizon
    clock.advance(1.0)
    assert fired == ["first-at-1", "second-at-1", "late"]
    # inf never fires but still hands back a cancelable handle.
    t_inf = clock.call_at(math.inf, fired.append, "never")
    clock.advance(1e9)
    assert fired[-1] == "late" and not t_inf.cancelled


def test_virtual_clock_callbacks_see_their_own_fire_time():
    clock = server.VirtualClock()
    seen = []
    clock.call_at(1.0, lambda: (seen.append(clock.now()),
                                clock.call_later(0.5, seen.append, "chain")))
    clock.advance(2.0)
    # The chained event lands at 1.5 (relative to its scheduler's fire
    # time), inside the same advance.
    assert seen == [1.0, "chain"]


# ------------------------------------- bit-identity of admitted results


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 14),
       max_lanes=st.sampled_from([1, 2, 3, 5]),
       window=st.sampled_from([0.0, 0.01, 0.05]),
       two_classes=st.sampled_from([False, True]))
def test_served_results_bit_identical_to_direct(seed, n, max_lanes, window,
                                                two_classes):
    """Randomized arrivals / class mixes / coalescing boundaries: every
    admitted request's served lane is bit-identical to the direct engine
    result for that query."""
    q, ref_ids, ref_d2 = ref_rows()
    rng = np.random.default_rng(seed)
    eng = engine("exact")
    clock = server.VirtualClock()
    classes = [server.QoSClass("a", deadline_s=1e6, batch_window_s=window,
                               max_lanes=max_lanes)]
    engines = {"a": eng}
    if two_classes:
        classes.append(server.QoSClass("b", deadline_s=1e6,
                                       batch_window_s=window,
                                       max_lanes=max_lanes))
        engines["b"] = eng
    door = server.FrontDoor(engines, classes, clock=clock,
                            dispatcher=server.VirtualDispatcher(clock))
    rows = rng.integers(0, q.shape[0], size=n)
    names = [c.name for c in classes]
    futs = []
    for r in rows:
        futs.append(door.submit(q[r], cls=names[rng.integers(len(names))]))
        clock.advance(float(rng.choice([0.0, 0.002, 0.02])))
    clock.advance(1.0)
    for r, f in zip(rows, futs):
        res = f.result(timeout=0)
        assert res.status == server.OK, res
        np.testing.assert_array_equal(res.ids, ref_ids[r])
        np.testing.assert_array_equal(res.d2, ref_d2[r])
    stats = door.stats()
    assert stats["admitted"] == n and stats["ok"] == n
    assert stats["open_lanes"] == 0 and stats["queued_lanes"] == 0


def test_lane_quantum_padding_is_result_transparent():
    """lane_quantum pads dispatches to a lane grid; padded rows are dropped
    and the real lanes stay bit-identical (pinned center)."""
    q, ref_ids, ref_d2 = ref_rows()
    eng = engine("exact")
    clock = server.VirtualClock()
    door = server.FrontDoor(
        {"a": eng},
        [server.QoSClass("a", deadline_s=1e6, batch_window_s=0.01,
                         max_lanes=8, lane_quantum=4)],
        clock=clock, dispatcher=server.VirtualDispatcher(clock))
    futs = [door.submit(q[i]) for i in range(6)]     # 6 lanes -> pad to 8
    clock.advance(0.02)
    for i, f in enumerate(futs):
        res = f.result(timeout=0)
        assert res.status == server.OK
        np.testing.assert_array_equal(res.ids, ref_ids[i])
        np.testing.assert_array_equal(res.d2, ref_d2[i])
    assert door.stats()["dispatches"] == 1


# --------------------------------------------- deadlines, hedges, partials


def test_deadline_hedge_partial_matches_engine_partial():
    """A deadline expiring mid-flight serves the best-so-far partial —
    bit-identical to ``engine.partial_result`` of an identical dispatch —
    and the late full result never overwrites it."""
    q, _ids, _d2 = ref_rows()
    eng = engine("exact")
    assert eng.supports_partial
    clock = server.VirtualClock()
    door = server.FrontDoor(
        {"a": eng}, [server.QoSClass("a", deadline_s=1.0, max_lanes=3)],
        clock=clock,
        dispatcher=server.VirtualDispatcher(clock, service_time=10.0,
                                            probe_time=0.001))
    futs = [door.submit(q[i]) for i in range(3)]     # flush at max_lanes
    ref = eng.partial_result(eng.begin(np.stack([q[0], q[1], q[2]])))
    clock.advance(1.0)                               # deadlines fire
    for i, f in enumerate(futs):
        res = f.result(timeout=0)
        assert res.status == server.PARTIAL
        np.testing.assert_array_equal(res.ids, np.asarray(ref.ids)[i])
        np.testing.assert_array_equal(res.d2, np.asarray(ref.d2)[i])
        assert res.extras.get("partial") is True
    clock.advance(20.0)                              # full result lands late
    assert all(f.result(timeout=0).status == server.PARTIAL for f in futs)
    stats = door.stats()
    assert stats["partial"] == 3 and stats["open_lanes"] == 0


def test_deadline_in_queue_times_out_and_frees_slot():
    door, clock, _ = fake_door(deadline_s=0.5, batch_window_s=10.0,
                               max_lanes=8)
    futs = [door.submit(np.float64([i, 0.0])) for i in range(2)]
    assert door.stats()["queued_lanes"] == 2
    clock.advance(0.5)
    assert [f.result(timeout=0).status for f in futs] == [server.TIMEOUT] * 2
    stats = door.stats()
    assert stats["queued_lanes"] == 0 and stats["open_lanes"] == 0
    # The queue slot is free again: a later submit (with a per-request
    # deadline outlasting the batch window) is served normally.
    f = door.submit(np.float64([5.0, 0.0]), deadline_s=20.0)
    clock.advance(10.0)
    assert f.result(timeout=0).status == server.OK


def test_wedged_dispatch_without_probe_times_out():
    """Total wedge (service and probe never arrive): every in-flight lane
    completes as timeout at its deadline — no future is ever left hanging."""
    door, clock, _ = fake_door(deadline_s=1.0, max_lanes=2,
                               service_time=math.inf, probe_time=math.inf)
    futs = [door.submit(np.float64([i, 0.0])) for i in range(4)]
    clock.advance(1.0)
    assert all(f.result(timeout=0).status == server.TIMEOUT for f in futs)
    assert door.stats()["open_lanes"] == 0


def test_overload_sheds_at_bound_and_hedges_reopen_admission():
    """A wedged backend fills the open-lane bound: later submits shed
    (an explicit response), the bound is never exceeded, and once deadline
    hedges complete the stuck lanes admission reopens."""
    door, clock, _ = fake_door(deadline_s=1.0, max_lanes=2, max_queue=6,
                               service_time=math.inf, probe_time=math.inf)
    futs = [door.submit(np.float64([i, 0.0])) for i in range(15)]
    stats = door.stats()
    assert stats["shed"] == 9 and stats["max_open_lanes"] == 6
    shed_notes = [f.result(timeout=0) for f in futs if f.done()]
    assert len(shed_notes) == 9
    assert all("queue full" in r.note for r in shed_notes)
    clock.advance(1.0)                    # hedges complete the stuck lanes
    assert all(f.done() for f in futs)
    stats = door.stats()
    assert stats["timeout"] == 6 and stats["open_lanes"] == 0
    f = door.submit(np.float64([99.0, 0.0]))   # admission reopened
    assert not f.done() or f.result(timeout=0).status != server.SHED
    clock.advance(2.0)
    assert f.result(timeout=0).status == server.TIMEOUT  # still wedged
    assert door.stats()["max_open_lanes"] <= 6


def test_dispatch_error_surfaces_as_error_status():
    door, clock, _ = fake_door(eng=FakeEngine(fail_finish=True), max_lanes=2)
    futs = [door.submit(np.float64([i, 0.0])) for i in range(2)]
    clock.advance(0.1)
    for f in futs:
        res = f.result(timeout=0)
        assert res.status == server.ERROR
        assert "injected finish failure" in res.note
    assert door.stats()["error"] == 2
    assert door.stats()["open_lanes"] == 0


# ----------------------------------------------------- shutdown / lifecycle


def test_drain_serves_pending_and_closes_shared_engine_once():
    """close(): pending lanes are flushed and served, later submits shed,
    an engine shared by two classes closes exactly once, and close is
    idempotent."""
    eng = FakeEngine()
    clock = server.VirtualClock()
    door = server.FrontDoor(
        {"a": eng, "b": eng},
        [server.QoSClass("a", deadline_s=100.0, batch_window_s=50.0,
                         max_lanes=8),
         server.QoSClass("b", deadline_s=100.0, batch_window_s=50.0,
                         max_lanes=8)],
        clock=clock, dispatcher=server.VirtualDispatcher(clock))
    futs = [door.submit(np.float64([i, 0.0]), cls="a") for i in range(3)]
    futs += [door.submit(np.float64([9.0, 0.0]), cls="b")]
    assert not any(f.done() for f in futs)      # parked behind the window
    server.drain_virtual(door, clock)
    assert door.drained
    assert all(f.result(timeout=0).status == server.OK for f in futs)
    assert eng.close_calls == 1                 # shared engine: exactly once
    shed = door.submit(np.float64([0.0, 0.0]), cls="a")
    res = shed.result(timeout=0)
    assert res.status == server.SHED and "closing" in res.note
    door.close(wait=False)                      # idempotent
    assert eng.close_calls == 1
    stats = door.stats()
    assert stats["ok"] == 4 and stats["shed"] == 1
    assert stats["admitted"] == stats["ok"]


def test_drain_completes_wedged_lanes_via_deadlines():
    """Shutdown with a wedged backend: drain completes every admitted lane
    through its deadline timer, then tears down."""
    door, clock, eng = fake_door(deadline_s=2.0, max_lanes=2,
                                 service_time=math.inf, probe_time=math.inf)
    futs = [door.submit(np.float64([i, 0.0])) for i in range(4)]
    server.drain_virtual(door, clock)
    assert door.drained
    assert all(f.result(timeout=0).status == server.TIMEOUT for f in futs)
    assert eng.close_calls == 1


def test_engine_close_idempotent_and_safe_with_inflight_stream():
    """SearchEngine.close() concurrent with an in-flight ``search_batches``
    stream over a fresh disk tier: the stream completes bit-identically
    (reads degrade to synchronous after close) and double-close is a no-op.
    Synchronised with events only — no sleeps."""
    from tests._backend_fixtures import built_disk_tier

    from repro.index import BlockSlowTier, BlockStore

    _x, q, _gt, _idx, tiered = built()
    tier = BlockSlowTier(BlockStore(built_disk_tier().store.path),
                         cache_nodes=256)
    eng = SearchEngine(TieredBackend(tiered, slow_tier=tier), BUDGET, k=10)
    batches = [q[:8], q[8:20], q[20:32]]
    ref = [eng.search(b) for b in batches]

    first_done = threading.Event()
    closed = threading.Event()
    out = []

    def stream():
        yield batches[0]
        first_done.set()
        assert closed.wait(60), "close() never signalled"
        yield batches[1]
        yield batches[2]

    t = threading.Thread(
        target=lambda: out.extend(eng.search_batches(stream())))
    t.start()
    assert first_done.wait(60)
    eng.close()            # concurrent with the in-flight stream
    eng.close()            # idempotent
    closed.set()
    t.join(timeout=120)
    assert not t.is_alive()
    assert len(out) == 3
    for got, want in zip(out, ref):
        np.testing.assert_array_equal(got.ids, want.ids)
        np.testing.assert_array_equal(got.d2, want.d2)


# ------------------------------------------------ determinism / QoS classes


def _replay_run(seed: int):
    """One randomized front-door scenario; returns a serializable trace."""
    q, _ids, _d2 = ref_rows()
    rng = np.random.default_rng(seed)
    eng = engine("exact")
    clock = server.VirtualClock()
    door = server.FrontDoor(
        {"a": eng, "b": eng},
        [server.QoSClass("a", deadline_s=0.25, batch_window_s=0.02,
                         max_lanes=3),
         server.QoSClass("b", deadline_s=5.0, batch_window_s=0.1,
                         max_lanes=5)],
        max_queue=8, clock=clock,
        dispatcher=server.VirtualDispatcher(clock, service_time=0.3,
                                            probe_time=0.01))
    futs = []
    for _ in range(12):
        r = int(rng.integers(0, q.shape[0]))
        cls = "a" if rng.random() < 0.5 else "b"
        futs.append(door.submit(q[r], cls=cls))
        clock.advance(float(rng.choice([0.0, 0.01, 0.15])))
    clock.advance(30.0)
    trace = []
    for f in futs:
        res = f.result(timeout=0)
        trace.append((res.status, res.qos, round(res.latency, 9),
                      None if res.ids is None else res.ids.tobytes()))
    return trace, door.stats()


def test_identical_runs_replay_bit_exactly():
    """The whole interleaving — statuses, latencies, result bytes, counters
    — replays bit-exactly under the virtual clock."""
    t1, s1 = _replay_run(1234)
    t2, s2 = _replay_run(1234)
    assert t1 == t2 and s1 == s2
    statuses = {s for s, _, _, _ in t1}
    assert server.OK in statuses       # the mix actually exercises serving


def test_per_class_budget_laws_diverge_over_shared_backend():
    """Two QoS classes with their own (lam, l_min) engines over one shared
    backend: the thorough class is granted strictly more budget for the
    same queries — the per-class I/O split the front door exists for."""
    q, _ids, _d2 = ref_rows()
    eng_i = engine("exact")                      # BUDGET: l_min=8
    eng_b = SearchEngine(eng_i.backend,
                         dataclasses.replace(BUDGET, l_min=BUDGET.l_max),
                         k=10)
    clock = server.VirtualClock()
    door = server.FrontDoor(
        {"interactive": eng_i, "batch": eng_b},
        [server.QoSClass("interactive", deadline_s=1e6, max_lanes=8),
         server.QoSClass("batch", deadline_s=1e6, max_lanes=8)],
        clock=clock, dispatcher=server.VirtualDispatcher(clock))
    fi = [door.submit(q[i], cls="interactive") for i in range(8)]
    fb = [door.submit(q[i], cls="batch") for i in range(8)]
    clock.advance(1.0)
    bud_i = [f.result(timeout=0).budget for f in fi]
    bud_b = [f.result(timeout=0).budget for f in fb]
    assert all(b is not None for b in bud_i + bud_b)
    assert np.mean(bud_b) > np.mean(bud_i)
    assert max(bud_i) <= BUDGET.l_max and min(bud_b) == BUDGET.l_max


def test_calibrate_budget_law_per_class():
    """Per-class law fitting: each class meets its own target, a looser
    target fits a higher lam (more I/O savings), and ``class_budget_cfgs``
    deploys one budget config per class."""
    from repro.core import calibrate

    def make_eval(cfg):
        # Synthetic monotone recall response: decreasing in lam, increasing
        # in the floor (the direction the real law has).
        def eval_recall(c):
            return min(1.0, 1.0 - 0.5 * c.lam + 0.001 * c.l_min)
        return eval_recall

    results = calibrate.calibrate_budget_law_per_class(
        make_eval, BUDGET, {"interactive": 0.7, "batch": 0.95}, joint=False)
    assert set(results) == {"interactive", "batch"}
    assert all(r.achieved for r in results.values())
    assert results["interactive"].lam > results["batch"].lam
    cfgs = calibrate.class_budget_cfgs(results, BUDGET)
    assert set(cfgs) == {"interactive", "batch"}
    for name, cfg in cfgs.items():
        assert cfg.lam == results[name].lam
        assert cfg.l_max == BUDGET.l_max
