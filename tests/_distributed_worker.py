"""Multi-device scenarios, executed in a subprocess with 8 host devices.

Invoked by tests/test_distributed.py as
    python tests/_distributed_worker.py <scenario>
Prints one JSON line with the scenario's measurements.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402


def make_mesh(shape=(2, 4), names=("data", "model")):
    return compat.make_mesh(shape, names)


def scenario_sharded_search():
    from repro.core import build, distance
    from repro.distributed import sharded_search as ss
    from repro.pq import pq_encode, train_pq

    mesh = make_mesh()
    n_shards = 8
    n, d = 2048, 32
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    q = jax.random.normal(jax.random.fold_in(key, 1), (64, d), jnp.float32)
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)

    # Build one sub-graph per shard (shard-local ids).
    per = n // n_shards
    cfg = build.BuildConfig(degree=12, beam_width=32, iters=1, batch=128,
                            max_hops=64)
    adjs = []
    for s in range(n_shards):
        adjs.append(build.build_with_alpha(
            x[s * per:(s + 1) * per],
            jnp.full((per,), 1.2, jnp.float32), cfg))
    adj = jnp.concatenate(adjs, axis=0)
    book = train_pq(x, m=8, iters=4)
    codes = pq_encode(x, book)

    arrays = {
        "adj": jax.device_put(adj, NamedSharding(mesh, P(("data", "model"), None))),
        "codes": jax.device_put(codes, NamedSharding(mesh, P(("data", "model"), None))),
        "vectors": jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None))),
        "centroids": jax.device_put(book.centroids, NamedSharding(mesh, P())),
    }
    d2, shard_ids, local_ids = ss.distributed_search(
        mesh, arrays, q, beam_width=32, max_hops=64, k=10, query_chunk=16,
        use_pq=True,
    )
    global_ids = np.asarray(shard_ids) * per + np.asarray(local_ids)
    recall = float(distance.recall_at_k(jnp.asarray(global_ids), gt_i))

    # Hedged-read: drop shard 3.
    ok = jnp.ones((n_shards,), jnp.bool_).at[3].set(False)
    ok = jax.device_put(ok, NamedSharding(mesh, P(("data", "model"))))
    d2b, sb, lb = ss.distributed_search(
        mesh, arrays, q, shard_ok=ok, beam_width=32, max_hops=64, k=10,
        query_chunk=16, use_pq=True,
    )
    gids_b = np.asarray(sb) * per + np.asarray(lb)
    recall_drop = float(distance.recall_at_k(jnp.asarray(gids_b), gt_i))
    from_dead = int((np.asarray(sb) == 3).sum())
    print(json.dumps({
        "recall": recall, "recall_dropped_shard": recall_drop,
        "results_from_dead_shard": from_dead,
    }))


def scenario_checkpoint_reshard(tmpdir):
    from repro.training import checkpoint as ckpt

    mesh_a = make_mesh((2, 4))
    mesh_b = make_mesh((4, 2))
    tree = {
        "w": jax.device_put(
            jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8),
            NamedSharding(mesh_a, P("data", "model")),
        ),
        "b": jax.device_put(jnp.ones((16,)), NamedSharding(mesh_a, P("model"))),
    }
    ckpt.save_checkpoint(tmpdir, 5, tree)
    shardings = {
        "w": NamedSharding(mesh_b, P("data", "model")),
        "b": NamedSharding(mesh_b, P("model")),
    }
    restored, step = ckpt.restore_checkpoint(tmpdir, tree, shardings=shardings)
    same = bool(
        (np.asarray(restored["w"]) == np.asarray(tree["w"])).all()
        and (np.asarray(restored["b"]) == np.asarray(tree["b"])).all()
    )
    new_mesh_ok = restored["w"].sharding.mesh.shape == mesh_b.shape
    print(json.dumps({"step": step, "identical": same,
                      "resharded": bool(new_mesh_ok)}))


def scenario_sharded_train_matches_single():
    """One pjit'd train step on the mesh == the same step on one device."""
    from repro.configs import base as cfg_base
    from repro.models import transformer as tfm
    from repro.training import optimizer as opt_mod
    from repro.training import train_step as ts_mod

    mesh = make_mesh()
    spec = cfg_base.get("qwen2-7b")
    cfg = spec.smoke_config
    key = jax.random.PRNGKey(0)
    params = tfm.init_lm(cfg, key)
    tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    step = ts_mod.make_train_step(
        lambda p, b: tfm.lm_loss(cfg, p, b),
        opt_mod.AdamWConfig(lr=1e-3),
    )
    state = ts_mod.init_train_state(params)
    _, m_single = jax.jit(step)(state, batch)

    from repro.launch import shardings as shard_mod
    state_spec = shard_mod.train_state_specs("lm", jax.eval_shape(lambda: state))
    shardt = jax.tree.map(lambda s: NamedSharding(mesh, s), state_spec,
                          is_leaf=lambda s: isinstance(s, P))
    state_sharded = jax.tree.map(jax.device_put, state, shardt)
    batch_sharded = jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P("data", None))), batch
    )
    _, m_mesh = jax.jit(step)(state_sharded, batch_sharded)
    print(json.dumps({
        "loss_single": float(m_single["loss"]),
        "loss_mesh": float(m_mesh["loss"]),
    }))


def scenario_moe_expert_parallel():
    """shard_map expert-parallel MoE == reference path (ample capacity)."""
    from repro.models import moe as moe_mod
    from repro.models.layers import ShardCtx

    mesh = make_mesh()
    ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model")
    cfg = moe_mod.MoeConfig(d_model=32, n_experts=8, top_k=2, d_expert=16,
                            n_shared=1, d_shared=16, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 32))
    ref, aux_ref = moe_mod.moe_apply(p, cfg, x, ctx=None, n_groups=1)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
    ep, aux_ep = jax.jit(
        lambda pp, xx: moe_mod.moe_apply_expert_parallel(pp, cfg, xx, ctx)
    )(p, xs)
    print(json.dumps({
        "max_err": float(jnp.abs(ep - ref).max()),
        "aux_err": abs(float(aux_ref) - float(aux_ep)),
    }))


def scenario_merge_modes():
    """flat and hierarchical distributed-search merges agree exactly."""
    from repro.core import build
    from repro.distributed import sharded_search as ss
    from repro.pq import pq_encode, train_pq

    mesh = make_mesh()
    n_shards = 8
    n, d = 1024, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d), jnp.float32)
    q = jax.random.normal(jax.random.fold_in(key, 1), (32, d), jnp.float32)
    per = n // n_shards
    cfg = build.BuildConfig(degree=8, beam_width=16, iters=1, batch=128,
                            max_hops=32)
    adj = jnp.concatenate([
        build.build_with_alpha(x[s * per:(s + 1) * per],
                               jnp.full((per,), 1.2, jnp.float32), cfg)
        for s in range(n_shards)
    ])
    book = train_pq(x, m=4, iters=3)
    codes = pq_encode(x, book)
    row = NamedSharding(mesh, P(("data", "model"), None))
    arrays = {
        "adj": jax.device_put(adj, row),
        "codes": jax.device_put(codes, row),
        "vectors": jax.device_put(x, row),
        "centroids": jax.device_put(book.centroids, NamedSharding(mesh, P())),
    }
    outs = {}
    for mode in ("flat", "hierarchical"):
        d2, sid, lid = ss.distributed_search(
            mesh, arrays, q, beam_width=16, max_hops=32, k=5,
            query_chunk=8, use_pq=True, merge=mode)
        outs[mode] = (np.asarray(sid) * per + np.asarray(lid),
                      np.asarray(d2))
    same_ids = bool((outs["flat"][0] == outs["hierarchical"][0]).all())
    same_d2 = bool(np.allclose(outs["flat"][1], outs["hierarchical"][1]))
    print(json.dumps({"ids_match": same_ids, "d2_match": same_d2}))


def scenario_staged_engine():
    """The staged distributed serving path at engine parity: staged ==
    monolithic (bitwise), pipelined == eager (incl. ragged tails),
    permutation-invariant, coalescing-transparent, identity per-shard laws,
    and graceful mid-stream fault injection with pinned jit caches."""
    from repro import serving
    from repro.core import build, distance
    from repro.core.search import AdaptiveBeamBudget
    from repro.distributed import sharded_search as ss

    mesh = make_mesh()
    n_shards = mesh.devices.size
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2048, 32), jnp.float32)
    q = np.asarray(jax.random.normal(jax.random.fold_in(key, 1), (48, 32),
                                     jnp.float32))
    cfg = build.BuildConfig(degree=12, beam_width=32, iters=1, batch=128,
                            max_hops=64)
    arrays, per = ss.build_sharded_arrays(x, mesh, build_cfg=cfg, m_pq=8)
    gt_d, gt_i = distance.brute_force_topk(jnp.asarray(q), x, k=10)
    budget = AdaptiveBeamBudget(l_min=8, l_max=32, lam=0.35, center=8.0)

    def backend(**kw):
        return serving.DistributedBackend(
            mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16,
            beam_budget=budget, budget_buckets=4, **kw)

    staged = serving.SearchEngine(backend(), budget, k=10,
                                  num_buckets="auto")
    mono = serving.SearchEngine(backend(), None, k=10)
    out = {}

    # Staged == monolithic step, bitwise (chunk-divisible batch).
    rs, rm = staged.search(q), mono.search(q)
    out["staged_eq_mono_ids"] = bool((rs.ids == rm.ids).all())
    out["staged_eq_mono_d2"] = bool((rs.d2 == rm.d2).all())

    # Pipelined == eager, ragged tail included (staged accepts raggedness
    # the monolithic step rejects).
    batches = [q[:16], q[16:35], q[35:]]
    piped = list(staged.search_batches(batches))
    out["pipelined_eq_eager"] = all(
        bool((p.ids == staged.search(b).ids).all()
             and (p.d2 == staged.search(b).d2).all())
        for p, b in zip(piped, batches))

    # Zero-query batch through the staged distributed path: the empty
    # fallback must carry the *distributed* continue signature (5-tuple,
    # shard ids included), not a hardcoded single-host 4-tuple.
    r0 = staged.search(q[:0])
    out["zero_query_ok"] = (
        r0.ids.shape == (0, 10) and r0.d2.shape == (0, 10)
        and np.asarray(r0.extras["shard_ids"]).shape == (0, 10))

    # Permutation invariance (pinned center).
    perm = np.random.default_rng(7).permutation(q.shape[0])
    inv = np.argsort(perm)
    rp = staged.search(q[perm])
    out["permutation_invariant"] = bool(
        (np.asarray(rp.ids)[inv] == rs.ids).all())

    # Coalescing: micro-batches merged to the lane threshold, split back.
    coal = serving.SearchEngine(backend(), budget, k=10, num_buckets="auto",
                                coalesce_lanes=24)
    micro = [q[i:i + 8] for i in range(0, 48, 8)]
    res_c = list(coal.search_batches(micro))
    out["coalesce_count"] = len(res_c) == len(micro)
    out["coalesce_identical"] = all(
        bool((c.ids == staged.search(b).ids).all())
        for c, b in zip(res_c, micro))

    # Identity per-shard laws == the scalar law, bitwise.
    laws = (np.full(n_shards, budget.lam, np.float32),
            np.full(n_shards, budget.l_min, np.int32))
    with_laws = serving.SearchEngine(backend(shard_laws=laws), budget, k=10,
                                     num_buckets="auto")
    rl = with_laws.search(q)
    out["identity_laws_bitwise"] = bool(
        (rl.ids == rs.ids).all() and (rl.d2 == rs.d2).all())

    # Fault injection mid-stream: flip shard_ok between batches of a
    # pipelined stream — later batches exclude the dead shard, recall loss
    # is bounded by its data fraction, results stay best-so-far finite
    # under the bucket hop deadlines, and nothing recompiles.
    fb = backend()
    eng = serving.SearchEngine(fb, budget, k=10, num_buckets=None)
    stream = [q[:16]] * 6
    list(eng.search_batches(stream))          # warm every program
    caches = (fb._probe_step._cache_size(),
              fb._continue_step._cache_size())
    dead = jnp.ones((n_shards,), jnp.bool_).at[3].set(False)
    results = []
    for i, res in enumerate(eng.search_batches(stream)):
        results.append(res)
        if i == 1:
            fb.set_shard_ok(dead)
    r_before = float(distance.recall_at_k(jnp.asarray(results[0].ids),
                                          gt_i[:16]))
    r_after = float(distance.recall_at_k(jnp.asarray(results[-1].ids),
                                         gt_i[:16]))
    out["fault_no_dead_results"] = bool(
        (results[-1].extras["shard_ids"] != 3).all())
    out["fault_best_so_far_finite"] = bool(
        np.isfinite(results[-1].d2).all())
    out["fault_recall_bounded"] = bool(
        r_after >= r_before - 1.0 / n_shards - 0.08)
    out["fault_no_recompile"] = (
        (fb._probe_step._cache_size(),
         fb._continue_step._cache_size()) == caches)
    out["recall_before"] = r_before
    out["recall_after"] = r_after
    print(json.dumps(out))


def scenario_front_door():
    """The serving front door over the staged distributed backend: served
    lanes bit-identical to a direct engine dispatch, a mid-stream shard
    loss (``set_shard_ok`` between dispatches) excludes the dead shard from
    later served results, and a wedged mesh dispatch completes as timeout
    (the distributed backend has no host probe view, so no partials) while
    the open-lane bound sheds and every future completes."""
    import math

    from repro import serving
    from repro.core import build
    from repro.core.search import AdaptiveBeamBudget
    from repro.distributed import sharded_search as ss
    from repro.serving import server as sv

    mesh = make_mesh()
    n_shards = mesh.devices.size
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1024, 16), jnp.float32)
    q = np.asarray(jax.random.normal(jax.random.fold_in(key, 1), (32, 16),
                                     jnp.float32))
    cfg = build.BuildConfig(degree=8, beam_width=16, iters=1, batch=128,
                            max_hops=32)
    arrays, per = ss.build_sharded_arrays(x, mesh, build_cfg=cfg, m_pq=4)
    budget = AdaptiveBeamBudget(l_min=8, l_max=16, lam=0.35, center=8.0)
    fb = serving.DistributedBackend(
        mesh, arrays, beam_width=16, max_hops=32, k=5, query_chunk=8,
        beam_budget=budget, budget_buckets=4)
    eng = serving.SearchEngine(fb, budget, k=5, num_buckets=None)
    out = {"supports_partial": bool(eng.supports_partial)}

    clock = sv.VirtualClock()
    door = sv.FrontDoor(
        {"c": eng},
        [sv.QoSClass("c", deadline_s=60.0, batch_window_s=0.01,
                     max_lanes=8)],
        clock=clock, dispatcher=sv.VirtualDispatcher(clock))
    ref = eng.search(q[:8])
    futs = [door.submit(q[i]) for i in range(8)]        # flush at max_lanes
    clock.advance(0.1)
    rows = [f.result(timeout=0) for f in futs]
    out["served_ok"] = all(r.status == "ok" for r in rows)
    out["bit_identical"] = all(
        bool((r.ids == np.asarray(ref.ids)[i]).all()
             and (r.d2 == np.asarray(ref.d2)[i]).all())
        for i, r in enumerate(rows))

    # Shard loss between the front door's dispatches: the next served
    # batch must exclude the dead shard (per-lane extras carry shard ids).
    fb.set_shard_ok(jnp.ones((n_shards,), jnp.bool_).at[3].set(False))
    futs2 = [door.submit(q[8 + i]) for i in range(8)]
    clock.advance(0.1)
    rows2 = [f.result(timeout=0) for f in futs2]
    out["post_flip_ok"] = all(r.status == "ok" for r in rows2)
    out["post_flip_no_dead"] = all(
        bool((np.asarray(r.extras["shard_ids"]) != 3).all()) for r in rows2)

    # Wedged mesh dispatch: deadline hedges find no partial support and
    # complete as timeout; the open-lane bound converts overload to sheds.
    clock2 = sv.VirtualClock()
    door2 = sv.FrontDoor(
        {"c": eng},
        [sv.QoSClass("c", deadline_s=0.5, batch_window_s=0.0, max_lanes=4)],
        max_queue=8, clock=clock2,
        dispatcher=sv.VirtualDispatcher(clock2, service_time=math.inf,
                                        probe_time=0.001))
    futs3 = [door2.submit(q[i % 16]) for i in range(12)]
    clock2.advance(1.0)
    st = door2.stats()
    out["wedge_timeout_no_partials"] = (st["timeout"] == 8
                                        and st["partial"] == 0)
    out["wedge_shed_at_bound"] = (st["shed"] == 4
                                  and st["max_open_lanes"] <= 8)
    out["wedge_all_futures_done"] = all(f.done() for f in futs3)
    print(json.dumps(out))


def scenario_cells_lower():
    from repro.launch import cells as cells_mod

    mesh = make_mesh()
    results = {}
    # decode_32k instead of train_4k: the train cell's full 1M-token shape
    # with the smoke config's tiny attn chunks fully unrolls a 256-step scan
    # (the per-cell dry-run covers it; too slow for this smoke check).
    for arch, shape in [("qwen3-moe-30b-a3b", "decode_32k"),
                        ("bert4rec", "retrieval_cand"),
                        ("mcgi-gist1m", "serve")]:
        cell = cells_mod.build_cell(arch, shape, mesh, smoke=True)
        compiled = cell.lower().compile()
        cost = compat.cost_analysis(compiled)
        results[f"{arch}/{shape}"] = cost.get("flops", 0) > 0
    print(json.dumps(results))


if __name__ == "__main__":
    scen = sys.argv[1]
    if scen == "sharded_search":
        scenario_sharded_search()
    elif scen == "checkpoint_reshard":
        scenario_checkpoint_reshard(sys.argv[2])
    elif scen == "train_match":
        scenario_sharded_train_matches_single()
    elif scen == "cells_lower":
        scenario_cells_lower()
    elif scen == "moe_ep":
        scenario_moe_expert_parallel()
    elif scen == "merge_modes":
        scenario_merge_modes()
    elif scen == "staged_engine":
        scenario_staged_engine()
    elif scen == "front_door":
        scenario_front_door()
    else:
        raise SystemExit(f"unknown scenario {scen}")
