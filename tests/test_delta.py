"""Live mutation: delta tier, merge lifecycle, online-build determinism.

Pinned here:

* ``build_online_mcgi`` is bit-deterministic when ``n % batch != 0`` — the
  ragged-tail regression: wrap-padded batches must scatter only their real
  prefix, and the reverse-insert pad lanes (repeated live destinations with
  all-INVALID candidate pools) must be dropped, or duplicate scatter
  indices make the build depend on the scatter's unspecified winner;
* ``_insert_reverse``'s ``valid`` mask drops pad lanes exactly (the real
  lane's row survives a duplicated destination);
* bounded staleness: a vector is findable the moment ``insert`` returns
  and gone the moment ``delete`` returns (base *and* delta tombstones);
* merge-boundary bit-identity: after ``merge``, ``LiveIndex.search`` is
  bit-identical to a freshly built index of the same live content;
* search-during-merge consistency: a flight begun before ``merge`` (which
  swaps the backend and closes the old disk tier) finishes bit-identical
  to its pre-merge result — the dispatch-time backend snapshot;
* external-id stability across insert/delete/merge cycles;
* the ``lineage`` manifest rider round-trips through the serializer.
"""
from __future__ import annotations

import functools
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build as build_mod
from repro.core import online as online_mod
from repro.index.delta import DeltaTier, LiveIndex

CFG = build_mod.BuildConfig(degree=16, beam_width=32, iters=1, batch=128,
                            max_hops=64)
D = 12


@functools.lru_cache(maxsize=1)
def _corpus():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((390, D)).astype(np.float32)   # 390 % 128 != 0
    q = rng.standard_normal((12, D)).astype(np.float32)
    return x, q


def _live(x, **kw):
    kw.setdefault("merge_threshold", 10_000)               # manual merges
    return LiveIndex(x, CFG, k=5, beam_width=32, max_hops=64, m_pq=4, **kw)


# --------------------------------------------------------------- determinism

def test_online_build_ragged_batch_deterministic():
    """Two builds over a ragged-tail n agree bit for bit — the regression
    pin for the wrap-pad duplicate-id scatters (refine + reverse-insert)."""
    x, _q = _corpus()
    assert x.shape[0] % CFG.batch != 0, "fixture must exercise the pad path"
    a = online_mod.build_online_mcgi(jnp.asarray(x), CFG)
    b = online_mod.build_online_mcgi(jnp.asarray(x), CFG)
    np.testing.assert_array_equal(np.asarray(a.adj), np.asarray(b.adj))
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.lid), np.asarray(b.lid))
    assert int(a.entry) == int(b.entry)


def test_insert_reverse_valid_mask_drops_pad_lanes():
    """A pad lane repeating a live destination with an all-INVALID pool must
    lose to the real lane: the masked call equals the single-lane call."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((40, D)).astype(np.float32))
    adj = build_mod.random_graph(40, CFG.degree, jax.random.PRNGKey(0))
    alpha = jnp.full((40,), 1.1, jnp.float32)
    dest1 = jnp.asarray(np.array([3], np.int32))
    cand1 = jnp.asarray(np.arange(10, 10 + CFG.reverse_cap,
                                  dtype=np.int32)[None])
    ref = build_mod._insert_reverse(x, adj, alpha, dest1, cand1, CFG)

    pad_cand = jnp.full((1, CFG.reverse_cap), build_mod.INVALID, jnp.int32)
    dest2 = jnp.concatenate([dest1, dest1])          # duplicated destination
    cand2 = jnp.concatenate([cand1, pad_cand])
    valid = jnp.asarray(np.array([True, False]))
    got = build_mod._insert_reverse(x, adj, alpha, dest2, cand2, CFG,
                                    valid=valid)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_delta_insert_deterministic():
    """The delta tier's ragged insert chunks reuse the same masked-scatter
    discipline: two identical insert sequences produce identical graphs."""
    x, _q = _corpus()
    graph = online_mod.build_online_mcgi(jnp.asarray(x), CFG)
    rng = np.random.default_rng(2)
    vecs = rng.standard_normal((150, D)).astype(np.float32)  # 150 % 128 != 0
    tiers = []
    for _ in range(2):
        t = DeltaTier(jnp.asarray(x), graph, CFG)
        t.insert(vecs)
        tiers.append(t)
    np.testing.assert_array_equal(np.asarray(tiers[0].adj),
                                  np.asarray(tiers[1].adj))
    np.testing.assert_array_equal(np.asarray(tiers[0].alpha),
                                  np.asarray(tiers[1].alpha))


# ---------------------------------------------------------- staleness bounds

def test_bounded_staleness_insert_findable_immediately():
    x, q = _corpus()
    li = _live(x)
    try:
        for r in range(3):                        # property over rounds
            rng = np.random.default_rng(100 + r)
            near = q[:6] + 0.01 * rng.standard_normal((6, D)).astype(
                np.float32)
            ids = li.insert(near, auto_merge=False)
            ext, _d2 = li.search(q[:6])
            for i in range(6):
                assert ids[i] in ext[i], (r, i)
    finally:
        li.close()


def test_delete_tombstones_base_and_delta():
    x, q = _corpus()
    li = _live(x)
    try:
        ids = li.insert(q[:4] + 1e-3, auto_merge=False)
        ext, _ = li.search(q[:4])
        assert np.isin(ids, ext).any()
        li.delete(ids)                            # delta tombstones
        ext2, _ = li.search(q[:4])
        assert not np.isin(ext2, ids).any()
        base_hit = int(ext2[0, 0])                # base tombstone, in-graph
        li.delete([base_hit])
        ext3, _ = li.search(q[:4])
        assert not (ext3 == base_hit).any()
        with pytest.raises(KeyError):
            li.delete([10 ** 9])
    finally:
        li.close()


# ----------------------------------------------------------- merge lifecycle

def test_merge_boundary_bit_identity():
    """Post-merge searches are bit-identical to a fresh LiveIndex built over
    the same live rows — the acceptance property of the ISSUE."""
    x, q = _corpus()
    li = _live(x)
    li2 = None
    try:
        rng = np.random.default_rng(3)
        ids = li.insert(rng.standard_normal((40, D)).astype(np.float32),
                        auto_merge=False)
        li.delete(ids[:10])
        li.delete(np.arange(5))                   # base deletes too
        assert li.merge() == 1
        ext, d2 = li.search(q)
        st = li._state
        li2 = _live(np.asarray(st.delta.x))       # fresh build, same rows
        extf, d2f = li2.search(q)
        mapped = np.where(extf >= 0, st.ext_of[np.maximum(extf, 0)], -1)
        np.testing.assert_array_equal(mapped, ext)
        np.testing.assert_array_equal(d2f, d2)
    finally:
        li.close()
        if li2 is not None:
            li2.close()


def test_search_during_merge_snapshot(tmp_path):
    """A flight begun before the merge finishes bit-identical to its
    pre-merge result, across the backend swap *and* the old block store's
    tier being closed (reads degrade to synchronous, bytes unchanged)."""
    x, q = _corpus()
    li = _live(x, store_dir=tmp_path, nodes_per_block=4)
    try:
        rng = np.random.default_rng(4)
        ids = li.insert(rng.standard_normal((30, D)).astype(np.float32),
                        auto_merge=False)
        li.delete(ids[:5])
        flt = li._state.delta.live_base_mask()
        pre = li.engine.search(q, filter=flt)
        flight = li.engine.begin(q, filter=flt)
        li.merge()
        got = li.engine.finish_from(flight)
        np.testing.assert_array_equal(got.ids, pre.ids)
        np.testing.assert_array_equal(got.d2, pre.d2)
        # The new generation's store was published atomically.
        names = sorted(p.name for p in tmp_path.iterdir())
        assert any("g1" in n for n in names) and not any(
            n.endswith(".tmp") for n in names)
        ext, _ = li.search(q)
        assert (ext >= 0).all()
    finally:
        li.close()


def test_ext_ids_stable_across_merges():
    x, q = _corpus()
    li = _live(x)
    try:
        rng = np.random.default_rng(5)
        probe = rng.standard_normal((1, D)).astype(np.float32)
        pid = int(li.insert(probe, auto_merge=False)[0])
        for cycle in range(2):
            li.insert(rng.standard_normal((20, D)).astype(np.float32),
                      auto_merge=False)
            li.delete(li.insert(rng.standard_normal((3, D)).astype(
                np.float32), auto_merge=False))
            li.merge()
            ext, _ = li.search(probe, 1)
            assert int(ext[0, 0]) == pid, cycle   # same id, both generations
        assert li.generation == 2
    finally:
        li.close()


def test_auto_merge_threshold_and_lineage(tmp_path):
    x, _q = _corpus()
    li = _live(x, merge_threshold=32)
    try:
        rng = np.random.default_rng(6)
        li.insert(rng.standard_normal((40, D)).astype(np.float32))
        assert li.generation == 1                 # crossed the threshold
        assert li.delta_size == 0
        p = tmp_path / "live.npz"
        li.save(p)
        from repro.index import serializer

        lin = serializer.load_lineage(p)
        assert lin["generation"] == 1 and lin["inserts"] == 40
        assert serializer.load_lineage(_plain_index(tmp_path)) is None
    finally:
        li.close()


def _plain_index(tmp_path: pathlib.Path) -> pathlib.Path:
    """An index saved outside the delta lifecycle (no lineage rider)."""
    from repro.index import build_tiered_index, save_index

    x, _q = _corpus()
    graph = online_mod.build_online_mcgi(jnp.asarray(x), CFG)
    p = tmp_path / "plain.npz"
    save_index(p, build_tiered_index(jnp.asarray(x), graph, m_pq=4))
    return p


def test_merge_async_under_traffic():
    x, q = _corpus()
    li = _live(x)
    try:
        rng = np.random.default_rng(7)
        li.insert(rng.standard_normal((25, D)).astype(np.float32),
                  auto_merge=False)
        t = li.merge_async()
        for _ in range(4):
            ext, _ = li.search(q)
            assert (ext >= 0).all()
        t.join(timeout=300)
        assert not t.is_alive() and li.generation == 1
        ext, _ = li.search(q)
        assert (ext >= 0).all()
    finally:
        li.close()
