"""Engine-specific tests that are not cross-backend parity properties: the
auto-picked bucket family (a pure function of the granted-budget histogram)
and the live recalibration hook.  The pipelining / bucketing / permutation
identity properties formerly here are consolidated in
``tests/test_engine_parity.py`` (shared fixtures:
``tests/_backend_fixtures.py``), where every backend — including the staged
distributed path — is pinned to them together."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import distance
from repro.serving import pipeline as pipe
from tests._backend_fixtures import BUDGET, built
from tests._hypothesis_compat import given, settings, st


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 96))
def test_auto_bucket_ceilings_deterministic(seed, n):
    """The auto-picked family is a pure function of the budget histogram:
    deterministic, permutation-invariant, covering, and tight (every ceiling
    is an occupied budget value)."""
    rng = np.random.default_rng(seed)
    budgets = rng.integers(BUDGET.l_min, BUDGET.l_max + 1, size=n)
    cs = pipe.auto_bucket_ceilings(budgets, BUDGET)
    assert cs == pipe.auto_bucket_ceilings(budgets, BUDGET)
    perm = rng.permutation(n)
    assert cs == pipe.auto_bucket_ceilings(budgets[perm], BUDGET)
    assert list(cs) == sorted(set(cs))           # ascending, distinct
    assert cs[-1] == budgets.max()               # covers every budget
    occupied = set(np.unique(budgets).tolist())
    assert all(int(c) in occupied for c in cs)   # tight ceilings
    # Partition covers each query exactly once, inside its ceiling.
    parts = pipe.partition_by_bucket(budgets, cs)
    seen = np.concatenate([m for _, m, _ in parts])
    assert sorted(seen.tolist()) == list(range(n))
    for bi, members, _ in parts:
        assert (budgets[members] <= cs[bi]).all()


def test_auto_bucket_single_value_degenerates_to_one_bucket():
    """lam=0-style batches (every budget equal) must not be split: the
    launch cost makes one bucket optimal."""
    budgets = np.full(32, 24)
    assert pipe.auto_bucket_ceilings(budgets, BUDGET) == (24,)


def test_recalibrate_updates_live_engine():
    """The recalibration hook refits the budget law in place (lam moves, the
    engine object and backend survive), and the joint variant fits l_min
    too — the Online-MCGI refresh path."""
    x, q, gt_i, idx, _ = built()
    eng = serving.SearchEngine(
        serving.ExactBackend(x, idx.adj, idx.entry),
        dataclasses.replace(BUDGET, center=None), k=10)
    backend = eng.backend
    result = eng.recalibrate(q, gt_i, recall_target=0.9, sample=32)
    assert eng.backend is backend            # engine not rebuilt
    assert eng.budget_cfg.lam == result.lam  # fitted knob is live
    res = eng.search(q)
    assert float(distance.recall_at_k(jnp.asarray(res.ids), gt_i)) > 0.5

    joint = eng.recalibrate(q, gt_i, recall_target=0.9, joint=True,
                            sample=32)
    assert joint.l_min is not None
    assert eng.budget_cfg.l_min == joint.l_min
    assert eng.budget_cfg.lam == joint.lam


def test_recalibrate_rejected_for_distributed_engines():
    """A staged distributed engine must not recalibrate in place: swapping
    budget_cfg would desync it from the backend's compiled beam_budget and
    brick every later search on the probe consistency check. The hook
    rejects cleanly and points at the per-shard pass."""
    import pytest

    class FakeDistributed:
        staged = True
        beam_budget = BUDGET

    eng = serving.SearchEngine(FakeDistributed(), BUDGET, k=10)
    with pytest.raises(NotImplementedError, match="per shard"):
        eng.recalibrate(eval_recall=lambda cfg: 1.0)
