"""Property tests for the staged double-buffered serving engine
(``repro.serving``): pipelining must be a pure wall-clock optimisation.

The engine's contract (see the package docstring) is *result transparency*:
``search_batches`` runs the same compiled programs on the same inputs as
per-batch ``search``, only the dispatch order moves — so pipelined results
are required to be bit-identical, across the exact / PQ / tiered backends,
including ragged final batches and a single-batch stream (no prefetch
partner). The monolithic single-program adaptive path is the ties-tolerant
cross-check (same style as ``tests/test_bucketed_search.py``). The
auto-picked bucket family is a pure function of the granted-budget histogram
(deterministic, permutation-invariant) and never changes results.
"""
import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import build, distance, search
from repro.index import build_tiered_index
from repro.index.disk import search_tiered_adaptive
from repro.serving import pipeline as pipe
from tests._hypothesis_compat import given, settings, st

CFG = build.BuildConfig(degree=24, beam_width=48, iters=2, batch=256,
                       max_hops=96)
# Pinned LID center, as in test_bucketed_search: batch-mean centering makes
# budgets depend on which queries share a batch, which is the *reducer's*
# property; pinning isolates the scheduling property under test.
BUDGET = search.AdaptiveBeamBudget(l_min=8, l_max=48, lam=0.3, center=8.0)
VARIANTS = ("exact", "pq", "tiered")


@functools.lru_cache(maxsize=1)
def _built():
    from repro.data import make_dataset

    x, q = make_dataset("tiny-mixture", seed=0)
    x, q = x[:1500], q[:40]
    idx = build.build_mcgi(x, CFG)
    tiered = build_tiered_index(x, idx, m_pq=8)
    gt_d, gt_i = distance.brute_force_topk(q, x, k=10)
    return x, np.asarray(q), gt_i, idx, tiered


@functools.lru_cache(maxsize=8)
def _engine(variant, num_buckets="auto"):
    x, _, _, idx, tiered = _built()
    if variant == "exact":
        backend = serving.ExactBackend(x, idx.adj, idx.entry)
    elif variant == "pq":
        backend = serving.TieredBackend(tiered, rerank=False)
    else:
        backend = serving.TieredBackend(tiered)
    return serving.SearchEngine(backend, BUDGET, k=10,
                                num_buckets=num_buckets)


def _split(q, batch):
    return [q[i:i + batch] for i in range(0, q.shape[0], batch)]


def _assert_bit_identical(a: serving.BatchResult, b: serving.BatchResult):
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.d2, b.d2)
    np.testing.assert_array_equal(np.asarray(a.stats.hops),
                                  np.asarray(b.stats.hops))
    np.testing.assert_array_equal(np.asarray(a.astats.budget),
                                  np.asarray(b.astats.budget))
    assert a.ceilings == b.ceilings


def _assert_same_up_to_ties(ids_a, d_a, ids_b, d_b, tol=1e-5):
    """Result equality modulo distance ties: distances must match, and any
    id mismatch must sit on a tie (equal distances at that rank)."""
    ids_a, d_a = np.asarray(ids_a), np.asarray(d_a)
    ids_b, d_b = np.asarray(ids_b), np.asarray(d_b)
    both_inf = np.isinf(d_a) & np.isinf(d_b)
    np.testing.assert_allclose(
        np.where(both_inf, 0.0, d_a), np.where(both_inf, 0.0, d_b),
        rtol=tol, atol=tol)
    mism = ids_a != ids_b
    assert np.allclose(d_a[mism], d_b[mism], rtol=tol, atol=tol), (
        "id mismatch without a distance tie")


@settings(max_examples=4, deadline=None)
@given(batch=st.integers(7, 40))
def test_pipelined_bit_identical_to_unpipelined(batch):
    """search_batches == per-batch search, bitwise, on every backend — for
    every batching, including ragged final batches (40 % batch != 0 for most
    draws) and the single-batch stream (batch >= 40: no prefetch partner)."""
    _, q, _, _, _ = _built()
    batches = _split(q, batch)
    for variant in VARIANTS:
        eng = _engine(variant)
        piped = list(eng.search_batches(batches))
        assert len(piped) == len(batches)
        for res_p, qb in zip(piped, batches):
            _assert_bit_identical(res_p, eng.search(qb))


def test_single_batch_stream_degrades_to_search():
    """No prefetch partner: a one-batch stream is exactly search()."""
    _, q, _, _, _ = _built()
    for variant in VARIANTS:
        eng = _engine(variant)
        (res,) = list(eng.search_batches([q]))
        _assert_bit_identical(res, eng.search(q))


@settings(max_examples=3, deadline=None)
@given(batch=st.integers(10, 40), num_buckets=st.integers(2, 5))
def test_engine_matches_monolithic_adaptive_path(batch, num_buckets):
    """The engine (fixed or auto bucket family, pipelined) returns the
    monolithic single-program adaptive path's results up to distance ties —
    the bucketed==unbucketed property lifted to the engine."""
    x, q, _, idx, tiered = _built()
    batches = _split(q, batch)
    for variant, eng in (("exact", _engine("exact", num_buckets)),
                         ("tiered", _engine("tiered", num_buckets)),
                         ("exact", _engine("exact", "auto"))):
        for res, qb in zip(eng.search_batches(batches), batches):
            if variant == "exact":
                ids_m, d_m, stats_m, astats_m = \
                    search.beam_search_exact_adaptive(
                        x, idx.adj, qb, idx.entry, BUDGET, k=10)
            else:
                ids_m, d_m, stats_m, astats_m = search_tiered_adaptive(
                    tiered, qb, BUDGET, k=10)
            _assert_same_up_to_ties(res.ids, res.d2, ids_m, d_m)
            np.testing.assert_array_equal(np.asarray(res.stats.hops),
                                          np.asarray(stats_m.hops))
            np.testing.assert_array_equal(np.asarray(res.astats.budget),
                                          np.asarray(astats_m.budget))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 96))
def test_auto_bucket_ceilings_deterministic(seed, n):
    """The auto-picked family is a pure function of the budget histogram:
    deterministic, permutation-invariant, covering, and tight (every ceiling
    is an occupied budget value)."""
    rng = np.random.default_rng(seed)
    budgets = rng.integers(BUDGET.l_min, BUDGET.l_max + 1, size=n)
    cs = pipe.auto_bucket_ceilings(budgets, BUDGET)
    assert cs == pipe.auto_bucket_ceilings(budgets, BUDGET)
    perm = rng.permutation(n)
    assert cs == pipe.auto_bucket_ceilings(budgets[perm], BUDGET)
    assert list(cs) == sorted(set(cs))           # ascending, distinct
    assert cs[-1] == budgets.max()               # covers every budget
    occupied = set(np.unique(budgets).tolist())
    assert all(int(c) in occupied for c in cs)   # tight ceilings
    # Partition covers each query exactly once, inside its ceiling.
    parts = pipe.partition_by_bucket(budgets, cs)
    seen = np.concatenate([m for _, m, _ in parts])
    assert sorted(seen.tolist()) == list(range(n))
    for bi, members, _ in parts:
        assert (budgets[members] <= cs[bi]).all()


def test_auto_bucket_single_value_degenerates_to_one_bucket():
    """lam=0-style batches (every budget equal) must not be split: the
    launch cost makes one bucket optimal."""
    budgets = np.full(32, 24)
    assert pipe.auto_bucket_ceilings(budgets, BUDGET) == (24,)


def test_recalibrate_updates_live_engine():
    """The recalibration hook refits the budget law in place (lam moves, the
    engine object and backend survive), and the joint variant fits l_min
    too — the Online-MCGI refresh path."""
    x, q, gt_i, idx, _ = _built()
    eng = serving.SearchEngine(
        serving.ExactBackend(x, idx.adj, idx.entry),
        dataclasses.replace(BUDGET, center=None), k=10)
    backend = eng.backend
    result = eng.recalibrate(q, gt_i, recall_target=0.9, sample=32)
    assert eng.backend is backend            # engine not rebuilt
    assert eng.budget_cfg.lam == result.lam  # fitted knob is live
    res = eng.search(q)
    assert float(distance.recall_at_k(jnp.asarray(res.ids), gt_i)) > 0.5

    joint = eng.recalibrate(q, gt_i, recall_target=0.9, joint=True,
                            sample=32)
    assert joint.l_min is not None
    assert eng.budget_cfg.l_min == joint.l_min
    assert eng.budget_cfg.lam == joint.lam
