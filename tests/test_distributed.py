"""Multi-device integration tests (8 virtual host devices, subprocess-
isolated so unit tests keep the default single-device backend)."""
import functools
import json
import pathlib
import subprocess
import sys

import pytest

WORKER = pathlib.Path(__file__).parent / "_distributed_worker.py"
SRC = str(pathlib.Path(__file__).parents[1] / "src")


def _run(scenario: str, *extra: str, timeout=2400) -> dict:
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS",)})
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, str(WORKER), scenario, *extra],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_search_recall_and_hedging():
    r = _run("sharded_search")
    assert r["recall"] >= 0.85, r
    # Dropping 1 of 8 shards loses at most that shard's data fraction (plus
    # noise) and returns nothing from the dead shard.
    assert r["recall_dropped_shard"] >= r["recall"] - 0.2
    assert r["results_from_dead_shard"] == 0


def test_checkpoint_elastic_reshard(tmp_path):
    r = _run("checkpoint_reshard", str(tmp_path))
    assert r["identical"] and r["resharded"] and r["step"] == 5


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    r = _run("train_match")
    # f32 reduction order differs across the 8-way mesh; ~2e-3 absolute on a
    # ~6.25 loss is numerics, not a sharding bug.
    assert abs(r["loss_single"] - r["loss_mesh"]) < 5e-3, r


@pytest.mark.slow
def test_smoke_cells_lower_on_mesh():
    r = _run("cells_lower")
    assert all(r.values()), r


def test_moe_expert_parallel_matches_reference():
    r = _run("moe_ep")
    assert r["max_err"] < 1e-5, r
    assert r["aux_err"] < 1e-5, r


@pytest.mark.slow
def test_merge_modes_agree():
    r = _run("merge_modes")
    assert r["ids_match"] and r["d2_match"], r


@functools.lru_cache(maxsize=1)
def _staged():
    """One worker run shared by the staged-engine assertions below (the
    scenario builds 8 sub-graphs and compiles the mesh programs once)."""
    return _run("staged_engine")


@pytest.mark.slow
def test_staged_engine_parity():
    """Staged distributed serving at engine parity: the probe/continue
    split is bit-identical to the monolithic step, pipelining and
    coalescing are result-transparent (ragged tails included), scheduling
    is permutation-invariant, and identity per-shard laws are pure
    plumbing."""
    r = _staged()
    for key in ("staged_eq_mono_ids", "staged_eq_mono_d2",
                "pipelined_eq_eager", "permutation_invariant",
                "coalesce_count", "coalesce_identical",
                "identity_laws_bitwise", "zero_query_ok"):
        assert r[key], (key, r)


@pytest.mark.slow
def test_front_door_over_distributed_backend():
    """The async front door serving the staged distributed backend under a
    virtual clock: served lanes bit-identical to direct dispatch, a shard
    lost between dispatches vanishes from later served results, and a
    wedged mesh dispatch degrades to timeout (no host probe view, so no
    partial support) with the open-lane bound shedding overload."""
    r = _run("front_door")
    assert not r["supports_partial"], r
    for key in ("served_ok", "bit_identical", "post_flip_ok",
                "post_flip_no_dead", "wedge_timeout_no_partials",
                "wedge_shed_at_bound", "wedge_all_futures_done"):
        assert r[key], (key, r)


@pytest.mark.slow
def test_staged_fault_injection_mid_stream():
    """set_shard_ok flipped between batches of a pipelined stream: later
    batches exclude the dead shard, recall loss is bounded by its data
    fraction, results stay best-so-far finite under the bucket hop
    deadlines, and the jit caches are pinned (no recompilation)."""
    r = _staged()
    for key in ("fault_no_dead_results", "fault_best_so_far_finite",
                "fault_recall_bounded", "fault_no_recompile"):
        assert r[key], (key, r)
