"""Train a ~100M-parameter LM for a few hundred steps with the full
substrate: AdamW + schedule, remat scan, int8 gradient compression, async
checkpointing + resume — the training-side e2e example.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt_mod
from repro.training import train_step as ts_mod
from repro.training.data import LmBatches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    # ~100M params: 12L x d512 (GQA 8/4 heads), 32k vocab.
    cfg = tfm.TransformerConfig(
        name="lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        d_head=64, d_ff=2048, vocab=32768, dtype=jnp.float32,
        attn_chunk_q=64, attn_chunk_k=64,
    )
    key = jax.random.PRNGKey(0)
    params = tfm.init_lm(cfg, key)
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.0f}M params")

    opt_cfg = opt_mod.AdamWConfig(
        lr=6e-4, warmup_steps=20, total_steps=args.steps, schedule="cosine")
    step_fn = jax.jit(ts_mod.make_train_step(
        lambda p, b: tfm.lm_loss(cfg, p, b), opt_cfg,
        compress_grads=args.compress_grads,
    ), donate_argnums=0)
    state = ts_mod.init_train_state(params,
                                    compress_grads=args.compress_grads)

    data = iter(LmBatches(vocab=cfg.vocab, batch=args.batch, seq=args.seq))
    ckpt_dir = tempfile.mkdtemp(prefix="lm100m_ckpt_")
    checkpointer = ckpt.AsyncCheckpointer()

    t0 = time.time()
    first_loss = None
    for step in range(args.steps):
        state, metrics = step_fn(state, next(data))
        if first_loss is None:
            first_loss = float(metrics["loss"])
        if (step + 1) % 20 == 0:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"[train] step {step+1}: loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}")
        if (step + 1) % 100 == 0:
            checkpointer.save(ckpt_dir, step + 1, state)
    checkpointer.wait()
    final = float(metrics["loss"])
    print(f"[train] loss {first_loss:.3f} -> {final:.3f} "
          f"({'improved' if final < first_loss else 'NOT improved'})")

    # Crash-and-resume drill.
    restored, at = ckpt.restore_checkpoint(ckpt_dir, state)
    print(f"[train] resume drill: restored step {at} checkpoint OK")


if __name__ == "__main__":
    main()
