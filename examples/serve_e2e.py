"""End-to-end serving driver (the paper's kind of system is a *serving*
system, so this is the required e2e example): build a disk-resident MCGI
index over ~50k vectors, then serve continuous batched query traffic through
a request batcher and the unified serving engine
(``repro.serving.SearchEngine`` over a ``TieredBackend``), reporting
recall / QPS / I-O / modelled-SSD latency live.

    PYTHONPATH=src python examples/serve_e2e.py [--n 50000] [--seconds 20]
        [--disk PATH]
        [--adaptive [--buckets auto] [--calibrate [--joint]
         [--recall-target 0.95]]]

``--disk PATH`` swaps the in-memory slow tier for the real thing: a
block-aligned store (one checksummed block per node) written to PATH, served
through the hot-node cache with async prefetch — bit-identical results, and
the closing report prints the cache hit rate plus measured block-read
latency next to the ``DiskTierModel``'s modelled number.

Calibration usage
-----------------
``--adaptive`` serves with per-query beam budgets (Prop. 4.2); the strength
of the budget law, ``lam``, trades mean I/O for recall and is geometry
dependent. Rather than hand-tuning it, pass ``--calibrate``: before traffic
starts, the engine's recalibration hook measures recall on a held-out query
sample over the *deployed* two-tier path and bisects for the largest ``lam``
still meeting ``--recall-target`` — maximum budget-law I/O savings subject
to the recall SLO. If even ``lam = 0`` misses the target, the hop budget is
binding and ``hop_factor`` is doubled automatically. ``--joint`` extends the
fit to (lam, l_min) — the smallest feasible budget floor, then the largest
feasible lam at it. The same hook serves index refreshes programmatically
(Online-MCGI inserts shift the LID population):

    engine.update_backend(new_index)           # swap arrays, keep jit caches
    engine.recalibrate(queries, gt_ids, recall_target=0.95, joint=True)

``--buckets`` controls the continue phase's budget buckets — ``auto``
(default) picks the bucket-ceiling family per batch from the granted-budget
histogram; an integer pins the fixed family; 0/1 disables bucketing.
Identical results either way, lower batch wall-clock, because converged
lanes stop burning compute for the batch's slowest query.
"""
import argparse
import dataclasses
import queue
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import BuildConfig, brute_force_topk, build_mcgi, recall_at_k
from repro.core.search import AdaptiveBeamBudget
from repro.data import synthetic
from repro.index import build_tiered_index
from repro.index.disk import DiskTierModel


class RequestBatcher:
    """Production-style micro-batcher: requests queue up; the serving thread
    drains up to ``max_batch`` every ``max_wait_ms``."""

    def __init__(self, max_batch: int = 64, max_wait_ms: float = 5.0):
        self.q: "queue.Queue[tuple[np.ndarray, float]]" = queue.Queue()
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3

    def submit(self, vec: np.ndarray):
        self.q.put((vec, time.perf_counter()))

    def next_batch(self):
        items = []
        deadline = time.perf_counter() + self.max_wait
        while len(items) < self.max_batch:
            try:
                timeout = max(deadline - time.perf_counter(), 0.0)
                items.append(self.q.get(timeout=timeout))
            except queue.Empty:
                break
        return items


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--seconds", type=float, default=15.0)
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--offered-qps", type=float, default=500.0)
    ap.add_argument("--disk", default=None, metavar="PATH",
                    help="serve the slow tier from a block-aligned on-disk "
                         "store at PATH (written first if absent)")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-query adaptive beam budgets (l_min=16, "
                         "l_max=--beam)")
    ap.add_argument("--lam", type=float, default=0.35)
    from repro.launch.serve import buckets_arg

    ap.add_argument("--buckets", default="auto", type=buckets_arg,
                    help="continue-phase bucket family: 'auto' "
                         "(histogram-picked, default), an integer count, "
                         "or 0/1 for the single-program path")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit lam (and hop_factor if binding) to "
                         "--recall-target on a held-out sample before "
                         "serving")
    ap.add_argument("--joint", action="store_true",
                    help="with --calibrate: fit (lam, l_min) jointly")
    ap.add_argument("--recall-target", type=float, default=0.95)
    args = ap.parse_args()
    num_buckets = args.buckets
    if not args.adaptive and (args.calibrate or
                              (num_buckets != "auto" and num_buckets > 1)):
        ap.error("--calibrate/--buckets configure the adaptive engine; "
                 "pass --adaptive as well")
    if args.joint and not args.calibrate:
        ap.error("--joint refines --calibrate; pass both")

    spec = dataclasses.replace(
        synthetic.REGISTRY["sift1b-proxy"], n=args.n, n_queries=1000)
    x, queries = synthetic.make_dataset(spec, seed=0)
    print(f"[e2e] corpus {x.shape}, building index...")
    t0 = time.time()
    graph = build_mcgi(x, BuildConfig(degree=32, beam_width=64, iters=1),
                       progress=print)
    index = build_tiered_index(x, graph, m_pq=16)
    print(f"[e2e] built in {time.time()-t0:.0f}s | fast tier "
          f"{index.fast_tier_bytes()/1e6:.0f}MB, slow tier "
          f"{index.slow_tier_bytes()/1e6:.0f}MB")
    gt_d, gt_ids = brute_force_topk(queries, x, k=10)

    slow_tier = None
    if args.disk:
        import pathlib

        from repro.index import open_or_build_slow_tier

        slow_tier = open_or_build_slow_tier(
            args.disk, index, cache_nodes=4096,
            log=lambda m: print(f"[e2e] {m}"))
        print(f"[e2e] disk slow tier at {args.disk} "
              f"({pathlib.Path(args.disk).stat().st_size/1e6:.0f}MB, "
              f"block {slow_tier.store.block_size}B)")
    backend = serving.TieredBackend(index, slow_tier=slow_tier)
    if args.adaptive:
        budget_cfg = AdaptiveBeamBudget(l_min=min(16, args.beam),
                                        l_max=args.beam, lam=args.lam)
        engine = serving.SearchEngine(backend, budget_cfg, k=10,
                                      num_buckets=num_buckets)
        if args.calibrate:
            result = engine.recalibrate(
                queries, gt_ids, recall_target=args.recall_target,
                joint=args.joint)
            print(f"[e2e] calibrated lam={result.lam:.4f} "
                  f"l_min={engine.budget_cfg.l_min} "
                  f"hop_factor={result.hop_factor} "
                  f"recall={result.recall:.4f} target={result.target:.2f} "
                  f"({'hit' if result.achieved else 'MISSED'})")
    else:
        engine = serving.SearchEngine(backend, None, k=10,
                                      beam_width=args.beam)
    _ = engine.search(queries[:64])  # warm the compile cache

    batcher = RequestBatcher(max_batch=64)
    stop = threading.Event()
    rng = np.random.default_rng(0)
    qn = np.asarray(queries)

    def traffic():
        period = 1.0 / args.offered_qps
        while not stop.is_set():
            batcher.submit(rng.integers(0, qn.shape[0]))
            time.sleep(period)

    t = threading.Thread(target=traffic, daemon=True)
    t.start()

    model = DiskTierModel()
    served = 0
    lat = []
    recs = []
    ios = []
    t_end = time.time() + args.seconds
    while time.time() < t_end:
        items = batcher.next_batch()
        if not items:
            continue
        idxs = np.array([i for i, _ in items])
        submit_times = [s for _, s in items]
        qb = qn[idxs]
        pad = 64 - qb.shape[0]
        # Pad partial batches by cycling real queries, not with zeros: the
        # adaptive engine centers budgets on the batch-mean LID, and a zero
        # vector is a wildly atypical "query" that would skew every real
        # query's budget at low load.
        qb_p = np.pad(qb, ((0, pad), (0, 0)), mode="wrap") if pad else qb
        res = engine.search(jnp.asarray(qb_p))
        now = time.perf_counter()
        lat.extend((now - s) * 1e3 for s in submit_times)
        recs.append(float(recall_at_k(
            jnp.asarray(res.ids[: len(items)]), gt_ids[idxs])))
        ios.append(float(np.mean(np.asarray(res.stats.hops)[: len(items)])))
        served += len(items)
    stop.set()

    print(f"[e2e] served {served} queries in {args.seconds:.0f}s "
          f"({served/args.seconds:.0f} QPS sustained)")
    ssd_ms = float(model.latency_us(
        jnp.float32(np.mean(ios)), rerank_reads=args.beam)) / 1e3
    print(f"[e2e] recall@10={np.mean(recs):.4f} io/query={np.mean(ios):.1f} "
          f"ssd_model={ssd_ms:.2f}ms")
    print(f"[e2e] e2e latency p50={np.percentile(lat,50):.1f}ms "
          f"p95={np.percentile(lat,95):.1f}ms p99={np.percentile(lat,99):.1f}ms")
    if slow_tier is not None:
        st = slow_tier.stats()
        print(f"[e2e] disk tier: hit_rate={st['hit_rate']:.3f} "
              f"blocks_read={st['blocks_read']} "
              f"measured_read={st['measured_read_us']:.1f}us vs "
              f"modelled={model.read_latency_us:.1f}us")


if __name__ == "__main__":
    main()
