"""RAG-style integration: an assigned LM architecture produces document
embeddings; MCGI indexes them; queries retrieve context — the arch-matrix
integration point described in DESIGN.md §4.

Uses the qwen2-7b *smoke* config as the encoder (mean-pooled hidden states)
so the example runs on CPU in seconds; swapping in the full config is a
--full flag away on real hardware.

Two retrieval modes over the same index:

* open retrieval — the plain beam walk; quality signal is topic purity of
  the retrieved context (how often the ANN result is on-topic);
* namespace-scoped retrieval — each query carries an *allowed* mask for its
  own topic (the multi-tenant RAG shape: a tenant's query must only surface
  that tenant's documents).  The mask is enforced in-graph
  (:func:`repro.core.search.pack_filter` pre-seeds the walk's visited
  bitset), so out-of-namespace documents are never expanded, never ranked,
  never returned — purity is 1.0 by construction and the interesting number
  becomes recall against the *within-namespace* ground truth.

    PYTHONPATH=src python examples/rag_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfg_base
from repro.core import BuildConfig, brute_force_topk, build_mcgi, recall_at_k
from repro.core.search import beam_search_exact, pack_filter
from repro.models import transformer as tfm


def embed_corpus(cfg, params, token_batches):
    """Mean-pooled final hidden states as document embeddings."""
    outs = []
    for tokens in token_batches:
        h, _ = tfm.forward(cfg, params, tokens)
        outs.append(h.mean(axis=1))
    e = jnp.concatenate(outs, axis=0).astype(jnp.float32)
    return e / (jnp.linalg.norm(e, axis=1, keepdims=True) + 1e-9)


def main():
    spec = cfg_base.get("qwen2-7b")
    cfg = spec.smoke_config
    key = jax.random.PRNGKey(0)
    params = tfm.init_lm(cfg, key)

    # Synthetic "documents": clustered token sequences (topics share a
    # unigram distribution, so embeddings cluster by topic).
    n_docs, seq, n_topics = 2048, 32, 16
    rng = np.random.default_rng(0)
    topic_vocab = rng.integers(0, cfg.vocab, size=(n_topics, 64))
    topics = rng.integers(0, n_topics, size=n_docs)
    docs = np.stack([
        topic_vocab[t][rng.integers(0, 64, size=seq)] for t in topics
    ]).astype(np.int32)

    batches = [jnp.asarray(docs[i:i + 256]) for i in range(0, n_docs, 256)]
    print(f"[rag] embedding {n_docs} docs with {cfg.name}...")
    emb = embed_corpus(cfg, params, batches)

    print("[rag] building MCGI index over document embeddings...")
    index = build_mcgi(np.asarray(emb), BuildConfig(degree=16, beam_width=32,
                                                    iters=1))

    # Queries: fresh docs from known topics; retrieval should return docs of
    # the same topic.
    q_topics = rng.integers(0, n_topics, size=64)
    q_docs = np.stack([
        topic_vocab[t][rng.integers(0, 64, size=seq)] for t in q_topics
    ]).astype(np.int32)
    q_emb = embed_corpus(cfg, params, [jnp.asarray(q_docs)])

    gt_d, gt_ids = brute_force_topk(q_emb, emb, k=10)
    ids, _, stats = beam_search_exact(
        emb, index.adj, q_emb, index.entry, beam_width=32, k=10)
    r = float(recall_at_k(ids, gt_ids))

    # Topic purity of retrieved contexts (the RAG quality signal).
    retrieved_topics = topics[np.asarray(ids)]
    purity = float((retrieved_topics == q_topics[:, None]).mean())
    print(f"[rag] ANN recall@10 vs exact = {r:.4f} | topic purity of "
          f"retrieved context = {purity:.3f} | io/query="
          f"{float(stats.hops.mean()):.1f}")

    # Namespace-scoped retrieval: each query may only surface its own
    # topic's documents, enforced in-graph via the packed filter.
    allowed = topics[None, :] == q_topics[:, None]           # (Q, n_docs)
    excl = pack_filter(allowed, n_docs)
    f_ids, _, f_stats = beam_search_exact(
        emb, index.adj, q_emb, index.entry, beam_width=32, k=10, excl=excl)
    f_ids_np = np.asarray(f_ids)
    in_ns = allowed[np.arange(q_emb.shape[0])[:, None],
                    np.maximum(f_ids_np, 0)] | (f_ids_np < 0)
    assert in_ns.all(), "in-graph filter leaked out-of-namespace documents"
    d2 = np.einsum("qnd,qnd->qn",
                   np.asarray(q_emb)[:, None] - np.asarray(emb)[None],
                   np.asarray(q_emb)[:, None] - np.asarray(emb)[None],
                   dtype=np.float32)
    d2[~allowed] = np.inf
    gt_ns = np.argsort(d2, axis=1)[:, :10]
    r_ns = float(recall_at_k(f_ids, jnp.asarray(gt_ns)))
    print(f"[rag] namespace-scoped: recall@10 vs within-namespace exact = "
          f"{r_ns:.4f} | out-of-namespace results = 0 (in-graph mask) | "
          f"io/query={float(f_stats.hops.mean()):.1f}")


if __name__ == "__main__":
    main()
