"""Quickstart: build an MCGI index, search it, compare against the paper's
baselines — the 60-second tour of the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    BuildConfig,
    beam_search_exact,
    brute_force_topk,
    build_mcgi,
    build_vamana,
    recall_at_k,
)
from repro.data import make_dataset


def main():
    # 1. A dataset with heterogeneous manifold geometry (MCGI's target regime).
    x, queries = make_dataset("tiny-mixture", seed=0)
    print(f"dataset: {x.shape[0]} points, D={x.shape[1]}")

    gt_d, gt_ids = brute_force_topk(queries, x, k=10)

    # 2. Build MCGI (Algorithm 1): LID calibration + adaptive-alpha refinement.
    cfg = BuildConfig(degree=32, beam_width=64, iters=2)
    t0 = time.time()
    index = build_mcgi(x, cfg, progress=print)
    print(f"MCGI built in {time.time()-t0:.1f}s; "
          f"LID mu={float(index.mu):.2f} sigma={float(index.sigma):.2f}; "
          f"alpha in [{float(index.alpha.min()):.3f}, "
          f"{float(index.alpha.max()):.3f}]")

    # 3. Search (batched beam search) and evaluate.
    for L in (16, 32, 64):
        ids, d2, stats = beam_search_exact(
            x, index.adj, queries, index.entry, beam_width=L, k=10)
        r = float(recall_at_k(ids, gt_ids))
        print(f"  L={L:3d}: recall@10={r:.4f} "
              f"io/query={float(stats.hops.mean()):.1f}")

    # 4. The DiskANN baseline is one call away (constant alpha).
    vam = build_vamana(x, alpha=1.2, cfg=cfg)
    ids, _, stats_v = beam_search_exact(
        x, vam.adj, queries, vam.entry, beam_width=32, k=10)
    print(f"vamana L=32: recall@10={float(recall_at_k(ids, gt_ids)):.4f} "
          f"io/query={float(stats_v.hops.mean()):.1f}")


if __name__ == "__main__":
    main()
