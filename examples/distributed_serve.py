"""Distributed MCGI serving on a virtual 8-device mesh, lowered through the
unified serving engine (``repro.serving.SearchEngine`` over a
``DistributedBackend``): shard the index, fan out queries, merge global
top-k, then kill a shard and watch the hedged merge degrade gracefully — the
fault-tolerance story at example scale. The distributed step is one compiled
program (adaptive budgets and bucket deadlines are in-graph), so the engine
pipelines it at step granularity: ``search_batches`` dispatches batch i+1
before collecting batch i.

    PYTHONPATH=src python examples/distributed_serve.py
(sets XLA_FLAGS itself; run as a script, not inside another jax process)
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import BuildConfig, brute_force_topk, recall_at_k  # noqa: E402
from repro.core import build  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.distributed import sharded_search as ss  # noqa: E402
from repro.pq import pq_encode, train_pq  # noqa: E402


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    n_shards = mesh.devices.size
    x, queries = make_dataset("tiny-mixture", seed=0)
    queries = queries[:64]
    n = (x.shape[0] // n_shards) * n_shards
    x = x[:n]
    per = n // n_shards
    print(f"[dist] {n} points over {n_shards} shards ({per}/shard)")

    cfg = BuildConfig(degree=16, beam_width=32, iters=1, batch=256, max_hops=64)
    adj = jnp.concatenate([
        build.build_with_alpha(x[s * per:(s + 1) * per],
                               jnp.full((per,), 1.2, jnp.float32), cfg)
        for s in range(n_shards)
    ])
    book = train_pq(x, m=8, iters=4)
    codes = pq_encode(x, book)
    row = NamedSharding(mesh, P(("data", "model"), None))
    flag = NamedSharding(mesh, P(("data", "model")))
    arrays = {
        "adj": jax.device_put(adj, row),
        "codes": jax.device_put(codes, row),
        "vectors": jax.device_put(x, row),
        "centroids": jax.device_put(book.centroids, NamedSharding(mesh, P())),
        # Per-shard entry points: each shard starts its walk at its own
        # medoid, not at local row 0.
        "entries": jax.device_put(ss.shard_medoids(x, n_shards), flag),
    }
    gt_d, gt_ids = brute_force_topk(queries, x, k=10)

    from repro import serving  # noqa: E402

    backend = serving.DistributedBackend(
        mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16)
    engine = serving.SearchEngine(backend, k=10)

    # Stream two chunks through the pipelined executor: batch 1 is
    # dispatched before batch 0 is collected (step-granularity overlap).
    res = list(engine.search_batches([queries[:32], queries[32:]]))
    gids = np.concatenate([r.ids for r in res])
    print(f"[dist] all shards up:   recall@10="
          f"{float(recall_at_k(jnp.asarray(gids), gt_ids)):.4f} "
          f"(2-batch double-buffered stream)")

    # Straggler/fault injection: shard 5 misses its deadline — a runtime
    # mask on the live engine, no recompilation.
    ok = jnp.ones((n_shards,), jnp.bool_).at[5].set(False)
    backend.set_shard_ok(jax.device_put(ok, flag))
    res = engine.search(queries)
    r = float(recall_at_k(jnp.asarray(res.ids), gt_ids))
    print(f"[dist] shard 5 dropped: recall@10={r:.4f} "
          f"(graceful: lost ~1/{n_shards} of the data, no recompilation, "
          f"no stall)")
    assert (res.extras["shard_ids"] != 5).all()
    backend.set_shard_ok(jax.device_put(jnp.ones((n_shards,), jnp.bool_),
                                        flag))

    # Adaptive per-query budgets on every shard (Prop. 4.2 in the engine):
    # each shard grants each query a budget from its own probe-phase LID,
    # in-graph — the engine treats the whole step as one monolithic program.
    from repro.core.search import AdaptiveBeamBudget
    adaptive = serving.SearchEngine(
        serving.DistributedBackend(
            mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16,
            beam_budget=AdaptiveBeamBudget(l_min=8, l_max=32, lam=0.35)),
        k=10)
    res = adaptive.search(queries)
    r = float(recall_at_k(jnp.asarray(res.ids), gt_ids))
    print(f"[dist] adaptive budgets: recall@10={r:.4f} "
          f"(per-shard probe -> online LID -> per-query beam budget)")


if __name__ == "__main__":
    main()
