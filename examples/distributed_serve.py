"""Distributed MCGI serving on a virtual 8-device mesh, lowered through the
unified serving engine (``repro.serving.SearchEngine`` over a
``DistributedBackend``): shard the index, fan out queries, merge global
top-k, then kill a shard and watch the hedged merge degrade gracefully — the
fault-tolerance story at example scale.

With a budget law on both the backend and the engine, the distributed step
runs *staged* at full engine parity: the probe program checkpoints every
shard's walk at the probe horizon, the host buckets queries by granted
budget (the mean over shards — a lane's expected per-shard work) while the
next batch's probe runs on the mesh, and per-bucket continue programs
resume the warm walks into the hedged merge. Results are bit-identical to the monolithic
single-program step (asserted below). The example finishes with a per-shard
(lam, l_min) calibration pass — each shard's sub-graph has its own geometry,
so one global law under- or over-budgets some shards.

    PYTHONPATH=src python examples/distributed_serve.py
(sets XLA_FLAGS itself; run as a script, not inside another jax process)
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import BuildConfig, brute_force_topk, recall_at_k  # noqa: E402
from repro.core import calibrate  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.distributed import sharded_search as ss  # noqa: E402


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    n_shards = mesh.devices.size
    x, queries = make_dataset("tiny-mixture", seed=0)
    queries = np.asarray(queries[:64])

    cfg = BuildConfig(degree=16, beam_width=32, iters=1, batch=256, max_hops=64)
    arrays, per = ss.build_sharded_arrays(x, mesh, build_cfg=cfg, m_pq=8)
    x = np.asarray(x)[: per * n_shards]
    print(f"[dist] {per * n_shards} points over {n_shards} shards "
          f"({per}/shard)")
    gt_d, gt_ids = brute_force_topk(jnp.asarray(queries), jnp.asarray(x), k=10)

    from repro import serving  # noqa: E402

    backend = serving.DistributedBackend(
        mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16)
    engine = serving.SearchEngine(backend, k=10)

    # Stream two chunks through the pipelined executor: batch 1 is
    # dispatched before batch 0 is collected (step-granularity overlap for
    # the fixed-beam path).
    res = list(engine.search_batches([queries[:32], queries[32:]]))
    gids = np.concatenate([r.ids for r in res])
    print(f"[dist] all shards up:   recall@10="
          f"{float(recall_at_k(jnp.asarray(gids), gt_ids)):.4f} "
          f"(2-batch double-buffered stream)")

    # Straggler/fault injection: shard 5 misses its deadline — a runtime
    # mask on the live engine, no recompilation.
    flag = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(("data", "model")))
    ok = jnp.ones((n_shards,), jnp.bool_).at[5].set(False)
    backend.set_shard_ok(jax.device_put(ok, flag))
    res = engine.search(queries)
    r = float(recall_at_k(jnp.asarray(res.ids), gt_ids))
    print(f"[dist] shard 5 dropped: recall@10={r:.4f} "
          f"(graceful: lost ~1/{n_shards} of the data, no recompilation, "
          f"no stall)")
    assert (res.extras["shard_ids"] != 5).all()
    backend.set_shard_ok(jax.device_put(jnp.ones((n_shards,), jnp.bool_),
                                        flag))

    # Adaptive per-query budgets on every shard (Prop. 4.2 in the engine),
    # served *staged*: the engine holds the same budget law as the backend,
    # so probe / host-bucket / continue are separate mesh programs and
    # search_batches overlaps batch i+1's probe with batch i's bucketing
    # and continues — sub-step pipelining for the distributed backend.
    from repro.core.search import AdaptiveBeamBudget
    # Pinned LID center: batch-mean centering would make budgets depend on
    # which queries share a probe chunk, and the staged stream's chunking
    # differs from the monolithic full-batch step — the bit-identity shown
    # below is a property of the *scheduling*, so the reducer is pinned.
    budget = AdaptiveBeamBudget(l_min=8, l_max=32, lam=0.35, center=8.0)
    staged_backend = serving.DistributedBackend(
        mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16,
        beam_budget=budget, budget_buckets=4)
    adaptive = serving.SearchEngine(staged_backend, budget, k=10,
                                    num_buckets="auto")
    batches = [queries[:16], queries[16:40], queries[40:]]
    res = list(adaptive.search_batches(batches))
    gids = np.concatenate([r.ids for r in res])
    r = float(recall_at_k(jnp.asarray(gids), gt_ids))
    io = float(np.mean(np.concatenate(
        [np.asarray(b.stats.hops) for b in res])))
    print(f"[dist] staged adaptive:  recall@10={r:.4f} "
          f"io/query={io:.0f} (probe checkpointed at the horizon, "
          f"budget-bucketed continues, pipelined stream)")

    # The staged split is result-transparent: the monolithic one-program
    # step returns the same global top-k, bit for bit.
    mono = serving.SearchEngine(serving.DistributedBackend(
        mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16,
        beam_budget=budget, budget_buckets=4), k=10)
    ref = mono.search(queries)
    assert (np.concatenate([b.d2 for b in res]) == ref.d2).all()
    print("[dist] staged == monolithic step (bit-identical d2)")

    # Per-shard budget laws: fit (lam, l_min) on each shard's own held-out
    # sample — shard geometry differs, so the calibrated laws do too — and
    # serve them as runtime arrays (no recompilation on recalibration).
    fit = calibrate.calibrate_budget_law_per_shard(
        calibrate.shard_exact_recall_evals(
            x, np.asarray(arrays["adj"]), np.asarray(arrays["entries"]),
            queries, n_shards, k=10, sample=32),
        budget, recall_target=0.9, n_shards=n_shards, max_iters=3)
    lam_arr, l_min_arr = fit.law_arrays()
    # hop_factor is global in the step: serve the largest fitted escalation
    # (never tighter than any shard's calibrated deadline).
    budget_srv = fit.serving_budget(budget)
    print(f"[dist] per-shard laws:   lam={np.round(lam_arr, 3).tolist()} "
          f"l_min={l_min_arr.tolist()} hop_factor={budget_srv.hop_factor}")
    per_shard = serving.SearchEngine(
        serving.DistributedBackend(
            mesh, arrays, beam_width=32, max_hops=64, k=10, query_chunk=16,
            beam_budget=budget_srv, budget_buckets=4,
            shard_laws=(lam_arr, l_min_arr)),
        budget_srv, k=10, num_buckets="auto")
    res = per_shard.search(queries)
    r = float(recall_at_k(jnp.asarray(res.ids), gt_ids))
    io = float(np.mean(np.asarray(res.stats.hops)))
    print(f"[dist] per-shard serve:  recall@10={r:.4f} io/query={io:.0f} "
          f"(each shard on its own calibrated budget law)")


if __name__ == "__main__":
    main()
