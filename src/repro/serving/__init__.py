"""repro.serving — the unified MCGI serving engine.

One subsystem owns the serve-time control flow that used to be spread across
``core/search.py`` (adaptive entry points), ``launch/serve.py``,
``launch/cells.py`` and ``distributed/sharded_search.py``:
:class:`~repro.serving.engine.SearchEngine` wraps the exact / PQ / tiered /
distributed backends behind one API, and a staged pipeline executor turns a
stream of query batches into overlapped device + host work.

Stage graph (per batch)
-----------------------

::

    admission ──> probe ──> host-bucket ──> continue ──> slow-tier rerank
    (device put,  (jitted   (sync budgets,  (one cached-  (one batched
     LUT build)    l_min     pick ceilings   jit call per   slow-tier read
                   walk)     from budget     bucket,        + top-k)
                             histogram,      dispatched
                             partition,      back-to-back,
                             pad lanes)      gathered late)

``admission``, ``probe``, ``continue`` and ``rerank`` are device programs
(:mod:`repro.core.search` kernels, jitted once per shape); ``host-bucket`` is
numpy scheduling (:mod:`repro.serving.pipeline`).  The bucket-ceiling family
is auto-picked per batch from the granted-budget histogram
(:func:`~repro.serving.pipeline.auto_bucket_ceilings`), replacing the fixed
``num_buckets=4``.

The distributed backend runs the same stage graph with whole-mesh programs
(:mod:`repro.distributed.sharded_search`): its probe checkpoints every
shard's walk at the probe horizon (PR 1's init/run split lifted to the
mesh), budgets are granted *per shard* (the host schedules on a per-query
reduction of them), and the continue program resumes any query subset into
the local rerank + hedged global merge — there is no separate host rerank
stage.  Fixed-beam serving and engines without a budget law keep the
monolithic one-program step.

The out-of-core backend (:class:`~repro.serving.engine.OutOfCoreBackend`)
serves indices bigger than device memory: only PQ codes steer from HBM,
adjacency + vectors are read at walk time from the block store
(:mod:`repro.index.disk` out-of-core drivers), and the pipeline grows a
*walk-prefetch* stage — the continue phase's first-frontier adjacency
reads are submitted to the tier's worker one stage ahead, bounded by the
backend's ``io_depth``.  Results stay bit-identical to the in-memory
tiered backend (the engine-parity matrix pins it).

Cross-batch admission coalescing (``SearchEngine(coalesce_lanes=)``) merges
micro-batches below the lane threshold into one dispatch and splits the
results back per input batch — order preserved, results per query unchanged
under a pinned LID center.

Buffering contract (double buffering)
-------------------------------------

``SearchEngine.search_batches`` keeps two batches in flight: batch i+1's
``admission`` + ``probe`` are **dispatched** before batch i's budgets are
synced and its continue programs **dispatched**, and batch i-1's continues
are **gathered** only after that.  Because jax dispatch is asynchronous, the
host's blocking transfers (batch i's granted budgets, batch i-1's results)
overlap batch i+1's probe and batch i's continue compute — converged lanes
free real wall-clock instead of the scheduler idling on the next probe sync.
Within a batch, every bucket's continue program is dispatched before any is
gathered, so the device queue never drains while the host reassembles.

Invariants:

* **Result transparency** — scheduling never changes math.  Pipelined
  results are bit-identical to the unpipelined path (same compiled programs,
  same inputs; only dispatch order moves), which is property-tested in
  ``tests/test_serving_pipeline.py``.
* **Order preservation** — results are yielded in admission order, one per
  input batch; a single-batch stream degrades to plain ``search`` (no
  prefetch partner, nothing blocks early).
* **Ragged tails** — the final batch of a stream may be any size; it simply
  jit-caches its own shape.

Live reconfiguration: ``SearchEngine.recalibrate`` refits the budget law
(lam, optionally jointly with l_min) against a recall target and deploys it
in place; ``SearchEngine.update_backend`` swaps refreshed index arrays after
Online-MCGI inserts.  Neither rebuilds the engine.

The serving front door (:mod:`repro.serving.server`) is the layer live
traffic talks to: a bounded admission queue with load shedding, per-class
lane coalescing into engine dispatches, per-request deadlines with QoS
classes (one engine — one calibrated (lam, l_min) — per class over a shared
backend), and a deadline-hedged gather that serves best-so-far partials
from the probe horizon (``SearchEngine.partial_result``).  All timing flows
through an injectable clock/scheduler seam (``WallClock`` in production,
``VirtualClock`` in tests — every interleaving replayable bit-exactly) and
engine execution through a dispatcher seam (``ThreadDispatcher`` /
``VirtualDispatcher``).  Stage graph above the engine:

    submit -> bounded queue -> class flush -> engine begin -> finish/hedge
    (shed when full)  (deadline timers complete queued/late lanes)
"""
from repro.serving.engine import (  # noqa: F401
    BatchResult,
    DistributedBackend,
    ExactBackend,
    OutOfCoreBackend,
    SearchEngine,
    TieredBackend,
)
from repro.serving.pipeline import (  # noqa: F401
    auto_bucket_ceilings,
    bucketed_continue,
    pad_bucket_size,
    partition_by_bucket,
)
from repro.serving.server import (  # noqa: F401
    FrontDoor,
    QoSClass,
    RequestFuture,
    ServedResult,
    ThreadDispatcher,
    VirtualClock,
    VirtualDispatcher,
    WallClock,
    drain_virtual,
)
