"""Async serving front door: admission, QoS classes, deadlines, backpressure.

The engine (:mod:`repro.serving.engine`) answers *batches*; nothing there
owns arrival, queueing, deadlines, or overload.  This module is that owner —
the layer live traffic actually talks to:

    submit ──> bounded arrival queue ──> per-class coalescing ──> dispatch
    (shed          (``max_queue``           (flush at               (engine
     when           lanes across            ``max_lanes`` or         begin +
     full)          all classes)            the batch window)        finish)
                                                    │
                         deadline timers ───────────┘
                         (best-so-far partial at expiry, or timeout)

**QoS classes.**  Each :class:`QoSClass` names its own
:class:`~repro.serving.engine.SearchEngine` — so each class carries its own
calibrated ``(lam, l_min)`` budget law (see
:func:`repro.core.calibrate.calibrate_budget_law_per_class`): an
"interactive" class trades recall for I/O independently of a "batch" class,
while both engines share one backend (and therefore one slow tier, one jit
cache family, one index).

**Admission.**  ``submit`` enqueues one query lane.  Admission is bounded
by ``max_queue`` *open* lanes across all classes (queued + dispatched but
not yet complete): a submit that finds the bound hit is *shed* — its
future completes immediately with status ``"shed"`` (an explicit response,
never a silent drop).  A wedged backend therefore converts into sheds, not
unbounded queues; its stuck lanes complete via their deadline hedges,
which re-opens admission.  Pending lanes of a class are
flushed into one engine dispatch when ``max_lanes`` accumulate or when the
oldest lane has waited ``batch_window_s`` — the front door's own admission
coalescing, upstream of the engine's ``coalesce_lanes`` (which remains the
right tool for *batch* streams; the front door coalesces *lanes*).

**Deadlines.**  Every request carries a deadline (class default, or per
``submit``).  A deadline that expires while the request is still queued
completes it as ``"timeout"`` and frees its queue slot.  One that expires
mid-flight is the *hedge*: the front door asks the engine for a best-so-far
result at the probe horizon (:meth:`SearchEngine.partial_result` — the
probe state's beam reranked through the normal finish path) and completes
the request as ``"partial"``; if even the probe isn't available (a wedged
backend) the request completes as ``"timeout"``.  The full result, when it
eventually lands, never overwrites a completed future — futures complete
exactly once.

**Live index swaps.**  The front door never pins the backend: every
dispatch goes through :meth:`SearchEngine.begin`, which snapshots the
backend's bindings into the flight, and ``finish_from`` /
``partial_result`` run against that snapshot.  So a live
``engine.update_backend(...)`` — e.g. the delta tier publishing a merged
generation (:class:`repro.index.delta.LiveIndex`) — is safe under traffic:
requests in flight at the swap complete against the index they were
dispatched on, requests admitted after it serve the new one, and nothing
observes a half-swapped backend.

**The clock seam.**  All timing flows through an injectable clock/scheduler:
:class:`WallClock` (a daemon timer thread over ``time.monotonic``) in
production, :class:`VirtualClock` in tests.  The virtual clock is a manual
heap of (time, submission-seq) events — same-instant timers fire in
submission order, so every interleaving (bursty arrival, deadline expiry
mid-continue, shed under overload, drain on shutdown) is replayable
bit-exactly, with no ``time.sleep`` anywhere.

**The dispatcher seam.**  How engine work runs is likewise injectable.
:class:`ThreadDispatcher` (production) runs ``finish_from`` on a worker
pool.  :class:`VirtualDispatcher` (tests, benchmarks) runs it
*synchronously at flush* — so served results are bit-identical to a direct
engine call by construction — while modelling the completion as a clock
event at an injectable service time: a constant, a callable, ``math.inf``
(a wedged backend: the completion never arrives and only deadline hedges
complete the futures), or ``"measured"`` (the synchronous call's real wall
time — what :mod:`benchmarks.serving_load` grounds its latency
distributions in).

**Shutdown.**  ``close()`` stops admission (later submits shed), force-
flushes every pending lane, lets every admitted request complete — full
results, or best-so-far/timeout via their deadline timers — and only then
closes each distinct engine exactly once (engine close is idempotent, so
classes sharing a backend are safe).  Idempotent and safe from any thread.

Lane padding: ``QoSClass(lane_quantum=)`` pads each dispatch to a lane-count
grid (repeating the first lane; padded rows are dropped on completion) so a
front door under ragged traffic compiles a bounded family of batch shapes —
the same discipline as the pipeline's bucket ``pad_quantum``.  Under a
pinned LID center padding is result-transparent per lane; with batch-mean
centering, budgets depend on dispatch composition (the reducer's property,
as with any batching choice).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import itertools
import math
import threading
import time
import traceback
from typing import Any, Callable, Iterable, Mapping

import numpy as np

from repro.serving.engine import BatchResult, SearchEngine

__all__ = [
    "OK", "PARTIAL", "TIMEOUT", "SHED", "ERROR",
    "Timer", "VirtualClock", "WallClock",
    "VirtualDispatcher", "ThreadDispatcher",
    "QoSClass", "ServedResult", "RequestFuture", "FrontDoor",
    "drain_virtual",
]

# Response statuses (every admitted request completes with exactly one).
OK = "ok"            # full engine result before the deadline
PARTIAL = "partial"  # deadline hedge: best-so-far at the probe horizon
TIMEOUT = "timeout"  # deadline expired with nothing servable
SHED = "shed"        # refused at admission (queue full, or closing)
ERROR = "error"      # the dispatch raised; see ServedResult.note


# --------------------------------------------------------------------- clocks


class Timer:
    """Cancelable handle for one scheduled callback.  ``cancel`` is a flag,
    not a heap removal — a cancelled entry is skipped when popped."""

    __slots__ = ("when", "cancelled")

    def __init__(self, when: float):
        self.when = when
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class VirtualClock:
    """Deterministic manual-advance clock + scheduler (the test seam).

    Single-threaded by design: callbacks run on the thread calling
    :meth:`advance`, in strict (time, submission order) — two timers at the
    same instant fire in the order they were scheduled, so a replay of the
    same schedule is bit-exact.  ``now`` advances *through* each event's
    timestamp as it fires (a callback scheduling "0.1s later" lands relative
    to its own fire time, not the horizon)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._heap: list = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._now

    def call_at(self, when: float, fn: Callable, *args) -> Timer:
        """Schedule ``fn(*args)`` at absolute time ``when`` (clamped to now;
        ``inf`` never fires — the wedged-dispatch model — but still returns
        a cancelable handle for uniformity)."""
        if not math.isfinite(when):
            return Timer(math.inf)
        t = Timer(max(float(when), self._now))
        heapq.heappush(self._heap, (t.when, next(self._seq), t, fn, args))
        return t

    def call_later(self, delay: float, fn: Callable, *args) -> Timer:
        return self.call_at(self._now + delay, fn, *args)

    def pending(self) -> int:
        """Live (uncancelled) scheduled events — drain checks in tests."""
        return sum(1 for e in self._heap if not e[2].cancelled)

    def advance(self, dt: float) -> int:
        """Run every event due within the next ``dt`` seconds, in order,
        then set now to the horizon.  Returns the number of callbacks run."""
        return self.run_until(self._now + dt)

    def run_until(self, horizon: float) -> int:
        ran = 0
        while self._heap and self._heap[0][0] <= horizon:
            _when, _seq, t, fn, args = heapq.heappop(self._heap)
            if t.cancelled:
                continue
            self._now = t.when
            fn(*args)
            ran += 1
        self._now = max(self._now, float(horizon))
        return ran

    def close(self) -> None:
        self._heap.clear()


class WallClock:
    """Real-time scheduler: one daemon timer thread over ``time.monotonic``
    — the production seam behind the same ``now``/``call_at`` interface as
    :class:`VirtualClock`.  Callback exceptions are printed, never fatal to
    the timer thread."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, name="front-door-timer", daemon=True)
        self._thread.start()

    def now(self) -> float:
        return time.monotonic()

    def call_at(self, when: float, fn: Callable, *args) -> Timer:
        t = Timer(float(when))
        if not math.isfinite(t.when):
            return t
        with self._cv:
            heapq.heappush(self._heap, (t.when, next(self._seq), t, fn, args))
            self._cv.notify()
        return t

    def call_later(self, delay: float, fn: Callable, *args) -> Timer:
        return self.call_at(self.now() + delay, fn, *args)

    def _loop(self) -> None:
        while True:
            with self._cv:
                if self._closed:
                    return
                if not self._heap:
                    self._cv.wait()
                    continue
                delay = self._heap[0][0] - self.now()
                if delay > 0:
                    self._cv.wait(delay)
                    continue
                _when, _seq, t, fn, args = heapq.heappop(self._heap)
            if t.cancelled:
                continue
            try:
                fn(*args)
            except Exception:       # pragma: no cover - defensive
                traceback.print_exc()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify()
        # A deadline callback can itself trigger teardown — never join the
        # timer thread from the timer thread.
        if threading.current_thread() is not self._thread:
            self._thread.join(timeout=5)


# ---------------------------------------------------------------- dispatchers


def _resolve(spec, disp) -> float:
    return float(spec(disp)) if callable(spec) else float(spec)


class ThreadDispatcher:
    """Production dispatch: ``finish`` runs on a small worker pool and the
    completion callback fires from the worker thread.  The probe is
    considered available as soon as the flight was dispatched (``begin``
    already enqueued it on the device), so deadline hedges can always ask
    for a partial."""

    def __init__(self, workers: int = 2):
        import concurrent.futures

        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, workers),
            thread_name_prefix="front-door-dispatch")

    def submit(self, disp: "_Dispatch", finish: Callable[[], BatchResult],
               on_done: Callable[[Any], None]) -> None:
        disp.probe_ready = True

        def run():
            try:
                res = finish()
            except BaseException as e:   # surfaced as status "error"
                res = e
            on_done(res)

        self._pool.submit(run)

    def close(self) -> None:
        # wait=False: the front door's drain already guarantees every
        # dispatch completed — and close may run *on* a worker thread (the
        # last completion claims engine teardown), where waiting would
        # deadlock on joining ourselves.
        self._pool.shutdown(wait=False)


class VirtualDispatcher:
    """Deterministic dispatch for the virtual clock: the engine programs run
    *synchronously at submit* — so served results are bit-identical to a
    direct engine call by construction — while probe availability and
    completion are modelled as clock events at injectable times.

    ``service_time`` / ``probe_time``: seconds (float), a callable
    ``(dispatch) -> seconds``, or for ``service_time`` the string
    ``"measured"`` (the synchronous call's real wall time).  ``math.inf``
    models a wedged backend: the event never fires, and only the requests'
    deadline timers complete their futures (the hedge path)."""

    def __init__(self, clock, service_time: Any = 0.0,
                 probe_time: Any = 0.0):
        self.clock = clock
        self.service_time = service_time
        self.probe_time = probe_time

    def submit(self, disp: "_Dispatch", finish: Callable[[], BatchResult],
               on_done: Callable[[Any], None]) -> None:
        t0 = time.perf_counter()
        try:
            res = finish()
        except BaseException as e:
            res = e
        wall = time.perf_counter() - t0
        if self.service_time == "measured":
            svc = wall
        else:
            svc = _resolve(self.service_time, disp)
        probe = min(_resolve(self.probe_time, disp), svc)
        self.clock.call_later(probe, self._mark_probe, disp)
        self.clock.call_later(svc, on_done, res)

    @staticmethod
    def _mark_probe(disp: "_Dispatch") -> None:
        disp.probe_ready = True

    def close(self) -> None:
        pass


# ------------------------------------------------------------- request model


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """One admission class: its own deadline, coalescing knobs, and (via the
    front door's ``engines`` mapping) its own budget-law engine.

    ``deadline_s`` — default per-request deadline.  ``batch_window_s`` — max
    time the oldest pending lane waits for coalescing partners before the
    class flushes anyway.  ``max_lanes`` — flush as soon as this many lanes
    are pending.  ``lane_quantum`` — pad each dispatch to this lane grid
    (bounded jit-shape family under ragged traffic; see module docstring).
    """

    name: str
    deadline_s: float
    batch_window_s: float = 0.0
    max_lanes: int = 32
    lane_quantum: int = 1


@dataclasses.dataclass
class ServedResult:
    """One request's response.  ``ids``/``d2`` are the lane's top-k (None
    for shed/timeout); ``hops``/``budget`` are the lane's walk cost and
    granted budget when the engine reports them (the per-class I/O
    divergence the load benchmark plots); ``extras`` carries the lane's
    slice of the batch extras (e.g. shard ids, slow-tier counters)."""

    status: str
    qos: str
    t_arrival: float
    t_done: float
    ids: np.ndarray | None = None
    d2: np.ndarray | None = None
    hops: float | None = None
    budget: float | None = None
    note: str = ""
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


class RequestFuture:
    """Completed exactly once; thread-safe.  Under the virtual clock
    nothing ever blocks — drive the clock, then read ``result(timeout=0)``.
    """

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._result: ServedResult | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> ServedResult:
        if not self._event.wait(timeout):
            raise TimeoutError("request not complete")
        return self._result

    def _complete(self, res: ServedResult) -> bool:
        with self._lock:
            if self._result is not None:
                return False
            self._result = res
        self._event.set()
        return True


@dataclasses.dataclass(eq=False)   # identity semantics: queries are arrays
class _Request:
    query: np.ndarray
    cls: QoSClass
    t_arrival: float
    deadline: float
    future: RequestFuture
    dispatch: "_Dispatch | None" = None
    timer: Timer | None = None


@dataclasses.dataclass(eq=False)   # identity semantics, hashable
class _Dispatch:
    """One flushed batch: the engine flight plus completion bookkeeping."""

    cls: QoSClass
    requests: list
    t_dispatch: float
    n_real: int
    flight: Any = None
    probe_ready: bool = False
    done: bool = False
    partial: BatchResult | None = None   # deadline hedge, computed once
    partial_failed: bool = False


# ----------------------------------------------------------------- front door


class FrontDoor:
    """The async admission front door (see module docstring for the story).

    ``engines`` maps class name -> :class:`SearchEngine` (classes may share
    an engine; engines may share a backend).  ``clock`` / ``dispatcher``
    default to production seams (:class:`WallClock`,
    :class:`ThreadDispatcher`); tests inject :class:`VirtualClock` /
    :class:`VirtualDispatcher`.  ``max_queue`` bounds *open* lanes across
    all classes — queued plus dispatched-but-incomplete — so a wedged or
    slow backend fills the bound and later submits shed instead of
    accumulating unbounded work; deadline hedges complete stuck lanes and
    re-open admission (every admitted lane completes by its deadline at
    the latest)."""

    def __init__(self, engines: Mapping[str, SearchEngine],
                 classes: Iterable[QoSClass], *, max_queue: int = 256,
                 clock=None, dispatcher=None):
        self.classes = {c.name: c for c in classes}
        self.engines = dict(engines)
        missing = [n for n in self.classes if n not in self.engines]
        if missing:
            raise ValueError(f"no engine for QoS class(es) {missing}")
        self.max_queue = int(max_queue)
        self._own_clock = clock is None
        self.clock = WallClock() if clock is None else clock
        self._own_dispatcher = dispatcher is None
        self.dispatcher = (ThreadDispatcher() if dispatcher is None
                           else dispatcher)
        self._lock = threading.RLock()
        self._pending: dict[str, list[_Request]] = {
            n: [] for n in self.classes}
        self._window_timers: dict[str, Timer | None] = {
            n: None for n in self.classes}
        self._inflight: set[int] = set()     # id(_Dispatch) of open batches
        self._queued_lanes = 0
        self._open = 0                       # admitted, future not complete
        self._closing = False
        self._engines_closed = False
        self._drained = threading.Event()
        self.counts: dict[str, int] = {
            s: 0 for s in (OK, PARTIAL, TIMEOUT, SHED, ERROR)}
        self.per_class: dict[str, dict[str, int]] = {
            n: {s: 0 for s in (OK, PARTIAL, TIMEOUT, SHED, ERROR)}
            for n in self.classes}
        self.submitted = 0
        self.admitted = 0
        self.dispatches = 0
        self.max_queued_lanes = 0
        self.max_open_lanes = 0

    # ---------------------------------------------------------- admission

    def submit(self, query, cls: str | None = None,
               deadline_s: float | None = None) -> RequestFuture:
        """Admit one query lane into ``cls`` (defaults to the sole class).
        Returns a future that completes exactly once — with a full result,
        a best-so-far partial, a timeout, or an immediate shed."""
        if cls is None:
            if len(self.classes) != 1:
                raise ValueError("multiple QoS classes; name one")
            cls = next(iter(self.classes))
        c = self.classes[cls]
        q = np.asarray(query)
        if q.ndim != 1:
            raise ValueError(f"submit() takes one lane (d,); got {q.shape}")
        fut = RequestFuture()
        with self._lock:
            now = self.clock.now()
            self.submitted += 1
            if self._closing or self._open >= self.max_queue:
                note = ("front door closing" if self._closing
                        else f"queue full ({self.max_queue} open lanes)")
                self._count(SHED, c.name)
                fut._complete(ServedResult(status=SHED, qos=c.name,
                                           t_arrival=now, t_done=now,
                                           note=note))
                return fut
            self.admitted += 1
            self._open += 1
            deadline = now + (c.deadline_s if deadline_s is None
                              else deadline_s)
            req = _Request(query=q, cls=c, t_arrival=now, deadline=deadline,
                           future=fut)
            self._pending[c.name].append(req)
            self._queued_lanes += 1
            self.max_open_lanes = max(self.max_open_lanes, self._open)
            self.max_queued_lanes = max(self.max_queued_lanes,
                                        self._queued_lanes)
            req.timer = self.clock.call_at(deadline, self._on_deadline, req)
            if len(self._pending[c.name]) >= c.max_lanes:
                self._flush_class(c)
            else:
                self._arm_window(c)
        return fut

    # ------------------------------------------------------------ flushing

    def _arm_window(self, c: QoSClass) -> None:
        """(lock held) Keep the invariant: pending lanes of a class always
        have a live window timer at oldest-arrival + batch_window_s."""
        t = self._window_timers[c.name]
        if t is not None:
            t.cancel()
        self._window_timers[c.name] = None
        pend = self._pending[c.name]
        if pend:
            when = max(self.clock.now(),
                       pend[0].t_arrival + c.batch_window_s)
            self._window_timers[c.name] = self.clock.call_at(
                when, self._on_window, c)

    def _on_window(self, c: QoSClass) -> None:
        with self._lock:
            self._window_timers[c.name] = None
            if self._pending[c.name]:
                self._flush_class(c, force=True)

    def _flush_class(self, c: QoSClass, force: bool = False) -> None:
        """(lock held) Pop pending lanes into engine dispatches —
        ``max_lanes`` at a time, all of them when forced (window expiry,
        shutdown drain)."""
        pend = self._pending[c.name]
        while pend and (force or len(pend) >= c.max_lanes):
            take, self._pending[c.name] = pend[:c.max_lanes], pend[c.max_lanes:]
            pend = self._pending[c.name]
            self._dispatch_batch(c, take)
        self._arm_window(c)

    def _dispatch_batch(self, c: QoSClass, reqs: list) -> None:
        """(lock held) One engine dispatch: begin the flight inline (jax
        dispatch is asynchronous), hand the finish to the dispatcher seam."""
        now = self.clock.now()
        self._queued_lanes -= len(reqs)
        lanes = [r.query for r in reqs]
        quantum = max(1, c.lane_quantum)
        pad = (-len(lanes)) % quantum
        batch = np.stack(lanes + [lanes[0]] * pad)
        disp = _Dispatch(cls=c, requests=list(reqs), t_dispatch=now,
                         n_real=len(reqs))
        for r in reqs:
            r.dispatch = disp
        self._inflight.add(id(disp))
        self.dispatches += 1
        engine = self.engines[c.name]
        try:
            disp.flight = engine.begin(batch)
        except BaseException as e:
            self._handle_done(disp, e)
            return
        self.dispatcher.submit(
            disp, functools.partial(engine.finish_from, disp.flight),
            functools.partial(self._handle_done, disp))

    # ---------------------------------------------------------- completion

    def _count(self, status: str, cls: str) -> None:
        self.counts[status] += 1
        self.per_class[cls][status] += 1

    def _complete(self, req: _Request, status: str, now: float,
                  note: str = "") -> None:
        """(lock held) Complete a request without results (shed in queue /
        timeout / error)."""
        if req.timer is not None:
            req.timer.cancel()
        if req.future._complete(ServedResult(
                status=status, qos=req.cls.name, t_arrival=req.t_arrival,
                t_done=now, note=note)):
            self._count(status, req.cls.name)
            self._open -= 1

    def _complete_row(self, req: _Request, res: BatchResult, row: int,
                      status: str, now: float) -> None:
        """(lock held) Complete a request from row ``row`` of a batch
        result (full or partial)."""
        if req.timer is not None:
            req.timer.cancel()
        hops = budget = None
        if res.stats is not None:
            hops = float(np.asarray(res.stats.hops)[row])
        if res.astats is not None:
            # Distributed budgets are per (query, shard): report the mean.
            budget = float(np.mean(np.asarray(res.astats.budget)[row]))
        n = res.ids.shape[0]
        extras = {k: v[row] if isinstance(v, np.ndarray) and v.shape[:1] == (n,)
                  else v for k, v in res.extras.items()}
        if req.future._complete(ServedResult(
                status=status, qos=req.cls.name, t_arrival=req.t_arrival,
                t_done=now, ids=np.array(res.ids[row]),
                d2=np.array(res.d2[row]), hops=hops, budget=budget,
                extras=extras)):
            self._count(status, req.cls.name)
            self._open -= 1

    def _handle_done(self, disp: _Dispatch, res) -> None:
        """Dispatch completion (worker thread or clock event).  Completes
        every still-open future of the batch; deadline hedges that already
        completed a row win — the late full result never overwrites."""
        with self._lock:
            disp.done = True
            self._inflight.discard(id(disp))
            now = self.clock.now()
            for row, req in enumerate(disp.requests):
                if req.future.done():
                    continue
                if isinstance(res, BaseException):
                    self._complete(req, ERROR, now, note=repr(res))
                else:
                    self._complete_row(req, res, row, OK, now)
            should_close = self._drain_check()
        if should_close:
            self._close_engines()

    def _partial_of(self, disp: _Dispatch) -> BatchResult | None:
        """(lock held) Best-so-far batch result at the probe horizon,
        computed at most once per dispatch; None when the probe itself is
        unavailable or the backend has no host-side probe view."""
        if disp.partial is not None:
            return disp.partial
        if disp.partial_failed or not disp.probe_ready:
            return None
        engine = self.engines[disp.cls.name]
        if not engine.supports_partial:
            disp.partial_failed = True
            return None
        try:
            disp.partial = engine.partial_result(disp.flight)
        except Exception:
            disp.partial_failed = True
            return None
        return disp.partial

    def _on_deadline(self, req: _Request) -> None:
        with self._lock:
            if req.future.done():
                return
            now = self.clock.now()
            disp = req.dispatch
            if disp is None:
                # Still queued: free the slot, complete as timeout.
                pend = self._pending[req.cls.name]
                if req in pend:
                    pend.remove(req)
                    self._queued_lanes -= 1
                    self._arm_window(req.cls)
                self._complete(req, TIMEOUT, now,
                               note="deadline expired in queue")
            else:
                res = self._partial_of(disp)
                if res is not None:
                    row = disp.requests.index(req)
                    self._complete_row(req, res, row, PARTIAL, now)
                else:
                    self._complete(req, TIMEOUT, now,
                                   note="deadline expired in flight")
                if all(r.future.done() for r in disp.requests):
                    # A wedged dispatch never reports done; once every lane
                    # is hedged the batch is no longer tracked as open.
                    self._inflight.discard(id(disp))
            should_close = self._drain_check()
        if should_close:
            self._close_engines()

    # ------------------------------------------------------------ lifecycle

    @property
    def drained(self) -> bool:
        """True once every admitted request completed after ``close()``
        (and the engines are closed)."""
        return self._drained.is_set()

    def _drain_check(self) -> bool:
        """(lock held) Claim engine teardown exactly once, when closing and
        every admitted request has completed."""
        if self._closing and self._open == 0 and not self._engines_closed:
            self._engines_closed = True
            return True
        return False

    def _close_engines(self) -> None:
        """Engine/backend teardown, outside the lock (pool shutdowns block).
        Each *distinct* engine closes exactly once; engine close itself is
        idempotent, so classes sharing a backend are safe too."""
        seen: list = []
        for eng in self.engines.values():
            if not any(eng is s for s in seen):
                seen.append(eng)
                eng.close()
        if self._own_dispatcher:
            self.dispatcher.close()
        if self._own_clock:
            self.clock.close()
        self._drained.set()

    def close(self, wait: bool = True, timeout: float | None = None) -> None:
        """Drain and shut down: stop admitting (later submits shed), flush
        every pending lane immediately, let every admitted request complete
        (full results, or best-so-far/timeout via its deadline timer), then
        close each distinct engine exactly once.  Idempotent, any thread.

        ``wait`` blocks until drained — meaningful with the wall clock only;
        under a virtual clock use :func:`drain_virtual` (close can't drive
        virtual time)."""
        with self._lock:
            first = not self._closing
            self._closing = True
            if first:
                for c in self.classes.values():
                    if self._pending[c.name]:
                        self._flush_class(c, force=True)
            should_close = self._drain_check()
        if should_close:
            self._close_engines()
        if wait and not self._drained.wait(timeout):
            raise TimeoutError("front door did not drain in time")

    # -------------------------------------------------------- observability

    def stats(self) -> dict:
        """Admission/outcome counters (snapshot)."""
        with self._lock:
            return {
                "submitted": self.submitted,
                "admitted": self.admitted,
                "dispatches": self.dispatches,
                "queued_lanes": self._queued_lanes,
                "open_lanes": self._open,
                "max_queued_lanes": self.max_queued_lanes,
                "max_open_lanes": self.max_open_lanes,
                **{s: self.counts[s]
                   for s in (OK, PARTIAL, TIMEOUT, SHED, ERROR)},
                "per_class": {n: dict(c)
                              for n, c in self.per_class.items()},
            }


def drain_virtual(door: FrontDoor, clock: VirtualClock, *,
                  step: float = 0.05, max_steps: int = 100_000) -> None:
    """Close a virtual-clock front door and advance the clock until it
    drains (tests and benchmarks share this; the wall-clock path just calls
    ``close(wait=True)``)."""
    door.close(wait=False)
    for _ in range(max_steps):
        if door.drained:
            return
        clock.advance(step)
    raise RuntimeError("front door failed to drain under the virtual clock")
