"""The unified serving engine: one API over exact / PQ / tiered /
distributed backends, with a staged double-buffered batch pipeline.

See the package docstring (:mod:`repro.serving`) for the stage graph and the
buffering contract.  The short version:

* :class:`SearchEngine` wraps a *backend* (how distances are evaluated and
  where the slow tier lives) behind ``search`` (one batch) and
  ``search_batches`` (a stream, double-buffered).
* *Staged* backends (:class:`ExactBackend`, :class:`TieredBackend`, and
  :class:`DistributedBackend` when built with a budget law) expose the
  adaptive engine's probe / continue / rerank programs separately, so the
  pipeline can put the host's bucket scheduling *between* device programs of
  different batches.  Results are bit-identical to the unpipelined path —
  the same jitted programs run on the same inputs; only dispatch order moves.
  The distributed backend's stages are whole-mesh programs (shard walks
  checkpoint their frontiers at the probe horizon; see
  :func:`repro.distributed.sharded_search.make_distributed_probe`), its
  granted budgets are *per shard* (host scheduling reduces them to a
  per-query effective budget — the mean over shards, a lane's expected
  per-shard work), and its continue program ends in the hedged merge
  instead of a host rerank.
* *Monolithic* dispatch (fixed-beam serving on any backend, and the
  distributed backend without an engine-level budget law) runs one compiled
  program per batch; the pipeline still overlaps batch i's host-side
  collection with batch i+1's dispatched program.

Admission coalescing: ``coalesce_lanes=`` merges micro-batches below the
threshold into one dispatch batch (per-query result order preserved — each
input batch still yields its own :class:`BatchResult`), so a hot batcher
emitting tiny batches doesn't pay a full pipeline round per handful of
lanes.

Disk slow tier: a :class:`TieredBackend` built with a
:class:`repro.index.disk.BlockSlowTier` serves the rerank fetch from the
block-aligned on-disk store.  The pipeline then grows a third stage —
*prefetch* — between continue-dispatch and gather: batch i's candidate
blocks are read on the tier's host worker thread while batch i+1's continue
programs occupy the device, and the gather stage joins the future.  Cache
hit/miss and measured block-read-latency counters ride in each
``BatchResult.extras["slow_tier"]``.  With a frequency-aware hot tier
(``BlockSlowTier(hot_nodes=...)``) the gather stage additionally kicks one
non-blocking *promotion tick* per batch (``backend.promotion_tick``) — the
hot tier's promoter thread digests the access frequencies the finished
batch recorded while the younger batches' device programs and prefetches
run, so promotion work sits between pipeline stages but never on them;
the promotion counters ride in the same ``extras["slow_tier"]`` payload.

Recalibration is a first-class hook: :meth:`SearchEngine.recalibrate` refits
the budget law (lam — and jointly l_min, see
:func:`repro.core.calibrate.calibrate_budget_law_joint`) against a recall
target on held-out queries and swaps the fitted config into the live engine.
Online-MCGI inserts shift the LID population, so an index refresh calls
:meth:`SearchEngine.update_backend` + ``recalibrate`` instead of rebuilding
the engine; jit caches are keyed on shapes and survive both.

The serving front door (:mod:`repro.serving.server`) sits *above* this
module and owns what the engine deliberately doesn't: arrival, queueing,
deadlines, and overload.  Its request path is admission (bounded queue,
shed when full) -> per-class lane coalescing -> engine dispatch -> deadline
gather.  Three engine hooks carry it:

* :meth:`SearchEngine.begin` — the dispatch stage alone (admission + probe,
  or the whole monolithic program), returning the in-flight handle without
  blocking.  The front door begins a flight the moment a class's lanes
  flush, so device work starts while the completion is still queued behind
  older batches.
* :meth:`SearchEngine.finish_from` — the remaining stages of a begun flight
  (schedule / prefetch / gather).  ``begin`` + ``finish_from`` is exactly
  :meth:`SearchEngine.search` — bit-identical results — just split at a
  seam the front door can put a scheduler between.
* :meth:`SearchEngine.partial_result` — the *deadline-aware gather*: the
  probe state's beam reranked through the normal finish path, a servable
  best-so-far answer for a request whose deadline expired mid-continue.
  Never consumes the flight; a later ``finish_from`` still yields the full
  result.  Available on staged single-host backends (the distributed probe
  state is a mesh checkpoint with no host-side beam view; see
  :attr:`SearchEngine.supports_partial`).

Per-QoS-class budget laws need no engine feature at all: the front door
simply holds one engine per class (sharing one backend — jit caches are
keyed on config + shapes, so classes don't trample each other), each with
its own calibrated (lam, l_min)
(:func:`repro.core.calibrate.calibrate_budget_law_per_class`).
"""
from __future__ import annotations

import copy
import dataclasses
import threading
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod
from repro.serving import pipeline as pipe

Array = jax.Array


@dataclasses.dataclass
class BatchResult:
    """One batch's results, host-side (numpy), original query order."""

    ids: np.ndarray                       # (Q, k)
    d2: np.ndarray                        # (Q, k)
    stats: search_mod.SearchStats | None = None
    astats: search_mod.AdaptiveStats | None = None
    ceilings: tuple[int, ...] | None = None   # bucket family actually used
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)


def _split_result(res: BatchResult, sizes: list[int]) -> list[BatchResult]:
    """Split a coalesced dispatch's result back into per-input-batch results
    (per-query extras are sliced on axis 0; non-array extras — e.g. the
    slow-tier cache counters — describe the merged dispatch and are shared;
    ``ceilings`` likewise)."""
    outs, off = [], 0
    for s in sizes:
        sl = slice(off, off + s)
        off += s
        stats = None if res.stats is None else search_mod.SearchStats(
            hops=res.stats.hops[sl], dist_evals=res.stats.dist_evals[sl])
        astats = None if res.astats is None else search_mod.AdaptiveStats(
            q_lid=res.astats.q_lid[sl], budget=res.astats.budget[sl])
        outs.append(BatchResult(
            ids=res.ids[sl], d2=res.d2[sl], stats=stats, astats=astats,
            ceilings=res.ceilings,
            extras={k: v[sl] if isinstance(v, np.ndarray) else v
                    for k, v in res.extras.items()}))
    return outs


class _StagedRerankMixin:
    """Shared staged-protocol tail of the single-host backends.

    ``schedule_budgets`` — granted budgets are already per-query scalars, so
    the host scheduler uses them as is.  ``finish`` — the gathered continue
    parts are (beam_ids, beam_d, hops, evals); rerank them into the final
    top-k and assemble the :class:`BatchResult` (``prefetch`` is the joined
    disk-tier fetch future when the pipeline's prefetch stage ran).
    """

    def schedule_budgets(self, budgets_np: np.ndarray) -> np.ndarray:
        return budgets_np

    def partial_parts(self, probe_state) -> tuple:
        """The probe-horizon view of the walk — (beam_ids, beam_d, hops,
        evals) sliced straight out of the warm probe state, the same part
        layout :meth:`finish` reranks.  The serving front door's deadline
        gather serves these as a best-so-far result when a request's
        deadline expires mid-continue (:meth:`SearchEngine.partial_result`).
        Unfilled beam slots are INVALID/inf and the rerank masks them, so a
        partial is always servable once the probe ran."""
        beam_ids, beam_d, _beam_exp, _visited, hops, evals = probe_state
        return beam_ids, beam_d, hops, evals

    def finish_extras(self) -> dict[str, Any]:
        """Per-batch observability payload (backends override)."""
        return {}

    def finish(self, queries, parts, k: int, *, q_lid,
               budgets_np, prefetch=None) -> BatchResult:
        beam_ids, beam_d, hops, evals = parts
        ids, d2 = self.rerank(beam_ids, beam_d, queries, k,
                              prefetch=prefetch)
        return BatchResult(
            ids=np.asarray(ids), d2=np.asarray(d2),
            stats=search_mod.SearchStats(hops=np.asarray(hops),
                                         dist_evals=np.asarray(evals)),
            astats=search_mod.AdaptiveStats(q_lid=np.asarray(q_lid),
                                            budget=budgets_np),
            extras=self.finish_extras())


class ExactBackend(_StagedRerankMixin):
    """Full-precision in-memory backend (benchmark mode): exact distances
    steer the walk; the final "rerank" is just the beam's top-k slice."""

    staged = True

    def __init__(self, x: Array, adj: Array, entry: Array,
                 step_kernel: str | None = None):
        self.step_kernel = step_kernel
        self.update(x, adj, entry)

    def update(self, x: Array, adj: Array, entry: Array) -> None:
        """Swap the index arrays in place (Online-MCGI refresh path)."""
        self.x, self.adj, self.entry = x, adj, entry

    def set_step_kernel(self, step_kernel: str | None) -> None:
        """Select the walk's hop implementation ("reference" | "pallas" |
        "auto"); a static jit key, so switching recompiles but never rebuilds
        the backend."""
        self.step_kernel = step_kernel

    def num_nodes(self) -> int:
        return int(self.x.shape[0])

    def admit(self, queries: Array) -> Array:
        return jnp.asarray(queries)

    def probe(self, ctxs, budget_cfg, excl=None):
        return search_mod._probe_exact_jit(
            self.x, self.adj, ctxs, self.entry, budget_cfg,
            step_kernel=self.step_kernel, excl=excl)

    def continue_fn(self, budget_cfg):
        import functools

        return functools.partial(search_mod._continue_exact_jit, self.x,
                                 self.adj, budget_cfg=budget_cfg,
                                 step_kernel=self.step_kernel)

    def rerank(self, beam_ids, beam_d, queries, k: int, prefetch=None):
        return beam_ids[:, :k], beam_d[:, :k]

    def fixed(self, queries, *, beam_width: int, max_hops: int, k: int,
              excl=None):
        ids, d2, stats = search_mod.beam_search_exact(
            self.x, self.adj, queries, self.entry, beam_width=beam_width,
            max_hops=max_hops, k=k, step_kernel=self.step_kernel, excl=excl)
        return ids, d2, stats, None

    def recall_eval(self, queries, gt_ids, *, k, sample, seed, base_cfg):
        from repro.core import calibrate as calib

        return calib.exact_recall_eval(
            self.x, self.adj, self.entry, queries, gt_ids, k=k,
            sample=sample, seed=seed, base_cfg=base_cfg)


class TieredBackend(_StagedRerankMixin):
    """The deployed two-tier path: PQ codes route the walk (fast tier), the
    final beam is reranked from full-precision vectors (slow tier).
    ``rerank=False`` serves raw ADC results (the pure-PQ variant).

    ``slow_tier`` plugs the rerank's node fetch: ``None`` keeps the
    in-memory rows of ``index.vectors`` (fused in-graph gather); a
    :class:`repro.index.disk.BlockSlowTier` serves it from the block-aligned
    on-disk store instead — the fetch moves to the host (cache + checksummed
    block reads), the rerank arithmetic stays the same jitted program, and
    results are bit-identical.  A disk tier sets :attr:`prefetches`, which
    makes the engine's pipeline insert an async-prefetch stage: batch i's
    block reads run on the tier's worker thread while batch i+1's continue
    programs occupy the device."""

    staged = True

    _UNSET = object()

    def __init__(self, index, rerank: bool = True, slow_tier=None,
                 step_kernel: str | None = None):
        self.do_rerank = rerank
        self.slow_tier = None
        self.step_kernel = step_kernel
        self.update(index, slow_tier=slow_tier)

    def set_step_kernel(self, step_kernel: str | None) -> None:
        """Select the walk's hop implementation (see
        :meth:`ExactBackend.set_step_kernel`)."""
        self.step_kernel = step_kernel

    def update(self, index, slow_tier=_UNSET) -> None:
        """Swap the tiered index (and the slow tier) in place (Online-MCGI
        refresh path).  A disk-backed backend refuses an index refresh that
        doesn't also name its slow tier: the old block store holds the old
        vectors, so silently keeping it would serve stale reranks and
        silently dropping it would quietly fall back to host memory — pass
        ``slow_tier=`` (a store written from the new vectors, or ``None``
        for in-memory rows) explicitly."""
        if slow_tier is TieredBackend._UNSET:
            if self.slow_tier is not None and self.slow_tier.is_disk:
                raise ValueError(
                    "this backend serves its slow tier from a block store; "
                    "refresh with update(index, slow_tier=...) — a "
                    "BlockSlowTier over a store written from the new "
                    "vectors, or None to return to in-memory rows")
            slow_tier = None
        old = self.slow_tier
        self.index = index
        self.slow_tier = slow_tier
        # A replaced disk tier owns a worker thread — shut it down (the
        # refresh path would otherwise leak one thread per index swap).
        if (old is not None and old is not slow_tier
                and getattr(old, "is_disk", False)):
            old.close()

    def close(self) -> None:
        """Release backend resources: shuts down a disk slow tier's worker
        thread (idempotent; in-memory tiers hold nothing closeable)."""
        if self.slow_tier is not None and getattr(self.slow_tier, "is_disk",
                                                  False):
            self.slow_tier.close()

    @property
    def prefetches(self) -> bool:
        """Whether the rerank fetch is worth hiding behind device work."""
        return (self.do_rerank and self.slow_tier is not None
                and self.slow_tier.is_disk)

    def num_nodes(self) -> int:
        return int(self.index.codes.shape[0])

    def admit(self, queries: Array) -> Array:
        from repro.index.disk import _query_luts

        return _query_luts(self.index, jnp.asarray(queries))

    def probe(self, ctxs, budget_cfg, excl=None):
        return search_mod._probe_pq_jit(
            self.index.codes, self.index.graph.adj, ctxs,
            self.index.graph.entry, budget_cfg,
            step_kernel=self.step_kernel, excl=excl)

    def continue_fn(self, budget_cfg):
        import functools

        return functools.partial(
            search_mod._continue_pq_jit, self.index.codes,
            self.index.graph.adj, budget_cfg=budget_cfg,
            step_kernel=self.step_kernel)

    def prefetch_rerank(self, parts):
        """Submit the slow-tier block fetch for gathered continue ``parts``
        (beam_ids first) to the tier's host worker; returns a future the
        engine hands back to :meth:`finish` one pipeline stage later."""
        return self.slow_tier.prefetch(np.asarray(parts[0]))

    def rerank(self, beam_ids, beam_d, queries, k: int, prefetch=None):
        if not self.do_rerank:
            return beam_ids[:, :k], beam_d[:, :k]
        if self.prefetches:
            from repro.index.disk import rerank_with_slow_tier

            return rerank_with_slow_tier(
                self.slow_tier, np.asarray(beam_ids), queries, k,
                prefetched=prefetch.result() if prefetch is not None
                else None)
        x_slow = (jnp.asarray(self.slow_tier.vectors)
                  if self.slow_tier is not None else self.index.vectors)
        return search_mod._rerank_slow_tier_jit(
            jnp.asarray(beam_ids), x_slow, jnp.asarray(queries), k=k)

    def finish_extras(self) -> dict[str, Any]:
        if self.slow_tier is None or not self.slow_tier.is_disk:
            return {}
        return {"slow_tier": self.slow_tier.stats()}

    def promotion_tick(self):
        """Kick one hot-tier promotion round on the disk tier's promoter
        thread (non-blocking; None without a disk tier or hot tier).  The
        engine calls this at every pipeline gather."""
        if self.slow_tier is None or not getattr(self.slow_tier, "is_disk",
                                                 False):
            return None
        tick = getattr(self.slow_tier, "promotion_tick", None)
        return tick() if tick is not None else None

    def fixed(self, queries, *, beam_width: int, max_hops: int, k: int,
              excl=None):
        from repro.index.disk import rerank_with_slow_tier, search_tiered

        if self.prefetches:
            # Disk mode: run the walk un-reranked at full beam width, then
            # rerank through the block store (blocking here — fixed-beam
            # dispatch has no later stage to hide the fetch behind).
            beam_ids, _beam_d, stats = search_tiered(
                self.index, queries, beam_width=beam_width,
                max_hops=max_hops, k=beam_width, rerank=False,
                step_kernel=self.step_kernel, excl=excl)
            ids, d2 = rerank_with_slow_tier(
                self.slow_tier, np.asarray(beam_ids), queries, k)
            return ids, d2, stats, None
        ids, d2, stats = search_tiered(
            self.index, queries, beam_width=beam_width, max_hops=max_hops,
            k=k, rerank=self.do_rerank, step_kernel=self.step_kernel,
            excl=excl)
        return ids, d2, stats, None

    def recall_eval(self, queries, gt_ids, *, k, sample, seed, base_cfg):
        from repro.core import calibrate as calib

        return calib.tiered_recall_eval(
            self.index, queries, gt_ids, k=k, sample=sample, seed=seed,
            base_cfg=base_cfg)


class OutOfCoreBackend(_StagedRerankMixin):
    """Serve an index bigger than device memory: only the PQ codes (and
    codebook + entry) live in HBM to steer the walk — adjacency *and*
    full-precision vectors stay in the block store and are read at walk /
    rerank time through the slow tier's worker thread.

    The walk runs the out-of-core drivers of :mod:`repro.index.disk`
    (:func:`~repro.index.disk.ooc_probe` /
    :func:`~repro.index.disk.ooc_continue`): each hop is split at the
    frontier selection so the host can fetch ``adj[u]`` from the store
    between two small device programs, with ``io_groups`` lane groups
    round-robined to overlap one group's block reads with another's device
    hop.  Results are bit-identical to the in-memory
    :class:`TieredBackend` (the engine-parity matrix pins it).

    ``walk_prefetches`` makes the engine insert a *walk-prefetch* stage:
    the continue phase's first frontier is known as soon as the probe's
    budgets are granted, so up to ``io_depth`` of those adjacency blocks
    are submitted to the tier's worker one pipeline stage before the
    continue runs — a pure cache warm-up, never a result change.

    ``step_kernel`` is accepted for engine-API parity but the out-of-core
    hop always runs the reference op chain: the fused Pallas step fuses
    the full-adjacency HBM gather, which is exactly what this backend
    avoids having in device memory.  (Reference and fused are bit-identical
    anyway, so the parity matrix's kernel axis stays meaningful.)
    """

    staged = True
    prefetches = True        # the rerank fetch is always a disk read here
    walk_prefetches = True

    def __init__(self, codes, codebook, entry, slow_tier, *,
                 io_groups: int = 2, io_depth: int = 32,
                 step_kernel: str | None = None):
        self.io_groups = io_groups
        self.io_depth = io_depth
        self.step_kernel = step_kernel
        self.slow_tier = None
        self.update(codes, codebook, entry, slow_tier=slow_tier)

    def update(self, codes, codebook, entry, *, slow_tier) -> None:
        """Swap the steering arrays and the block-store tier in place
        (Online-MCGI refresh path).  ``slow_tier`` is a required keyword:
        the store holds the graph itself here, so a refresh that doesn't
        name it would either serve a stale graph or silently lose the
        index.  A replaced tier's worker thread is shut down."""
        if slow_tier is None or not getattr(slow_tier, "is_disk", False):
            raise ValueError(
                "out-of-core serving needs a BlockSlowTier over a store "
                "holding the graph's adjacency + vectors")
        old = self.slow_tier
        self.codes = jnp.asarray(codes)
        self.codebook = codebook
        self.entry = jnp.asarray(entry)
        self.slow_tier = slow_tier
        # Unless the tier was built with an explicit worker count, size its
        # prefetch pool to the round-robin group count — one I/O worker per
        # group is what lets one group's block reads actually overlap
        # another's device hop (a single worker would serialise them).
        adopt = getattr(slow_tier, "default_io_workers", None)
        if adopt is not None:
            adopt(self.io_groups)
        if old is not None and old is not slow_tier:
            old.close()

    def close(self) -> None:
        """Shut down the slow tier's worker thread (idempotent)."""
        if self.slow_tier is not None:
            self.slow_tier.close()

    def set_step_kernel(self, step_kernel: str | None) -> None:
        """Recorded for engine-API parity; the out-of-core walk always runs
        the reference hop ops (see the class docstring)."""
        self.step_kernel = step_kernel

    def admit(self, queries: Array) -> Array:
        # Same LUT ops as the tiered admit (repro.index.disk._query_luts),
        # so admission is bit-identical between the two backends.
        from repro.pq import build_lut

        q = jnp.asarray(queries)
        d_book = self.codebook.m * self.codebook.dsub
        if q.shape[1] < d_book:
            q = jnp.pad(q, ((0, 0), (0, d_book - q.shape[1])))
        return build_lut(q, self.codebook.centroids)

    def num_nodes(self) -> int:
        return int(self.codes.shape[0])

    def probe(self, ctxs, budget_cfg, excl=None):
        from repro.index import disk as disk_mod

        return disk_mod.ooc_probe(
            self.codes, ctxs, self.entry, int(self.codes.shape[0]),
            budget_cfg, self.slow_tier, io_groups=self.io_groups,
            excl=excl)

    def continue_fn(self, budget_cfg):
        from repro.index import disk as disk_mod

        def cont(sub_state, sub_ctxs, sub_budgets, sub_hop_limits):
            return disk_mod.ooc_continue(
                self.codes, sub_state, sub_ctxs, sub_budgets,
                sub_hop_limits, budget_cfg.l_max, self.slow_tier,
                io_groups=self.io_groups)

        return cont

    def prefetch_walk(self, probe_state, budgets, hop_limits):
        """Submit the continue phase's first-frontier adjacency reads (up
        to ``io_depth`` nodes) to the tier's worker — the walk-prefetch
        stage's work.  Cache warm-up only; returns the future (or None when
        every lane already converged in the probe)."""
        from repro.index import disk as disk_mod

        u = disk_mod.ooc_first_frontier(
            probe_state, budgets, hop_limits,
            int(probe_state[0].shape[1]))
        u = u[u >= 0][:self.io_depth]
        if u.size == 0:
            return None
        return self.slow_tier.prefetch_adj(u)

    def prefetch_rerank(self, parts):
        """See :meth:`TieredBackend.prefetch_rerank`."""
        return self.slow_tier.prefetch(np.asarray(parts[0]))

    def rerank(self, beam_ids, beam_d, queries, k: int, prefetch=None):
        from repro.index.disk import rerank_with_slow_tier

        return rerank_with_slow_tier(
            self.slow_tier, np.asarray(beam_ids), queries, k,
            prefetched=prefetch.result() if prefetch is not None else None)

    def finish_extras(self) -> dict[str, Any]:
        return {"slow_tier": self.slow_tier.stats()}

    def promotion_tick(self):
        """See :meth:`TieredBackend.promotion_tick` — here the walk itself
        benefits: promoted adjacency rows turn walk-time block reads into
        dense-array hits."""
        tick = getattr(self.slow_tier, "promotion_tick", None)
        return tick() if tick is not None else None

    def fixed(self, queries, *, beam_width: int, max_hops: int, k: int,
              excl=None):
        from repro.index import disk as disk_mod

        ctxs = self.admit(queries)
        nq = int(ctxs.shape[0])
        states = search_mod.ooc_init_pq(
            self.codes, ctxs, self.entry, int(self.codes.shape[0]),
            beam_width, excl=excl)
        state = disk_mod.ooc_walk(
            self.codes, states, ctxs,
            jnp.full((nq,), jnp.int32(beam_width)),
            jnp.full((nq,), jnp.int32(max_hops)),
            beam_width, self.slow_tier, self.io_groups)
        if excl is not None:
            state = search_mod._scrub_state_jit(state, excl)
        ids, d2 = disk_mod.rerank_with_slow_tier(
            self.slow_tier, np.asarray(state[0]), queries, k)
        stats = search_mod.SearchStats(hops=np.asarray(state[4]),
                                       dist_evals=np.asarray(state[5]))
        return ids, d2, stats, None


class DistributedBackend:
    """Sharded scatter-gather serving over a mesh: each shard walks its own
    sub-graph (adaptive budgets and bucket deadlines are *in-graph* —
    see :mod:`repro.distributed.sharded_search`).

    Two execution shapes:

    * built with ``beam_budget`` and driven by an engine holding the *same*
      budget config, the backend is **staged**: the probe program
      checkpoints every shard's walk at the probe horizon and the continue
      program resumes any query subset (warm state) and ends in the hedged
      merge — so ``search_batches`` overlaps batch i+1's mesh-wide probe
      with batch i's host bucketing and per-bucket continues.  Granted
      budgets are per (query, shard); the host schedules on their per-query
      mean (see :meth:`schedule_budgets`).
    * without an engine-level budget config the whole step stays one
      compiled program (:func:`~repro.distributed.sharded_search.make_distributed_search`)
      and the pipeline overlaps at step granularity — the dry-run-priced
      shape, and the only one that runs fixed-beam.

    ``shard_laws=(lam (S,), l_min (S,))`` threads per-shard calibrated
    budget laws through both shapes as runtime arrays (see
    :func:`repro.core.calibrate.calibrate_budget_law_per_shard`) — updating
    them never recompiles.
    """

    def __init__(self, mesh, arrays: dict, *, beam_width: int, max_hops: int,
                 k: int, query_chunk: int = 128, use_pq: bool = True,
                 beam_budget=None, budget_buckets: int | None = None,
                 shard_ok=None, shard_laws=None, merge: str = "hierarchical",
                 step_kernel: str | None = None):
        from repro.distributed import sharded_search as ss

        self.mesh = mesh
        self.arrays = dict(arrays)
        n_shards = mesh.devices.size
        self.rows_per_shard = arrays["vectors"].shape[0] // n_shards
        if "entries" not in self.arrays:
            self.arrays["entries"] = ss.shard_medoids(
                arrays["vectors"], n_shards)
        self.shard_ok = (shard_ok if shard_ok is not None
                         else jnp.ones((n_shards,), jnp.bool_))
        self.beam_budget = beam_budget
        self.shard_laws = None
        if shard_laws is not None:
            self.shard_laws = (jnp.asarray(shard_laws[0], jnp.float32),
                               jnp.asarray(shard_laws[1], jnp.int32))
        self._build_kw = dict(
            beam_width=beam_width, max_hops=max_hops, k=k,
            query_chunk=query_chunk, use_pq=use_pq,
            budget_buckets=budget_buckets, merge=merge)
        self.step_kernel = step_kernel
        # One more bucket costs one more *whole-mesh* program (n_shards
        # shard walks + merge collectives + the checkpoint-state gather),
        # not one more single-host kernel launch: scale the scheduler's
        # modelled launch cost accordingly so the bucket DP only splits a
        # batch when the lane-hop savings clear the real dispatch price.
        self.launch_cost_hops = pipe.BUCKET_LAUNCH_COST_HOPS * n_shards
        self._build_programs()

    def _build_programs(self) -> None:
        """(Re)jit the mesh programs against the current ``step_kernel``.

        The step kernel is a builder-time knob of the shard walk, so the
        jitted monolithic/probe/continue programs are rebuilt when it
        changes; the jit wrappers are fresh objects, so stale-kernel
        programs can't be served from a cache."""
        from repro.distributed import sharded_search as ss

        kw = self._build_kw
        # jit the monolithic step: the builder returns a raw traceable (what
        # cells.py lowers); serving it eagerly would retrace per call.
        self.step = jax.jit(ss.make_distributed_search(
            self.mesh, beam_width=kw["beam_width"], max_hops=kw["max_hops"],
            k=kw["k"], query_chunk=kw["query_chunk"], use_pq=kw["use_pq"],
            beam_budget=self.beam_budget,
            budget_buckets=kw["budget_buckets"], merge=kw["merge"],
            per_shard_laws=self.shard_laws is not None,
            step_kernel=self.step_kernel))
        self._probe_step = self._continue_step = None
        if self.beam_budget is not None:
            self._probe_step = jax.jit(ss.make_distributed_probe(
                self.mesh, budget_cfg=self.beam_budget,
                max_hops=kw["max_hops"], query_chunk=kw["query_chunk"],
                use_pq=kw["use_pq"], budget_buckets=kw["budget_buckets"],
                per_shard_laws=self.shard_laws is not None,
                step_kernel=self.step_kernel))
            self._continue_step = jax.jit(ss.make_distributed_continue(
                self.mesh, budget_cfg=self.beam_budget, k=kw["k"],
                use_pq=kw["use_pq"], merge=kw["merge"],
                step_kernel=self.step_kernel))

    def set_step_kernel(self, step_kernel: str | None) -> None:
        """Select the shard walk's hop implementation ("reference" |
        "pallas" | "auto") and rebuild the jitted mesh programs."""
        self.step_kernel = step_kernel
        self._build_programs()

    @property
    def staged(self) -> bool:
        """Stageable iff the walk is adaptive (the probe horizon exists)."""
        return self.beam_budget is not None

    @staticmethod
    def make_step(mesh, *, beam_width: int, max_hops: int, k: int,
                  query_chunk: int = 128, use_pq: bool = True,
                  beam_budget=None, budget_buckets: int | None = None,
                  per_shard_laws: bool = False,
                  step_kernel: str | None = None):
        """The raw jit-able sharded step — what launch/cells.py lowers for
        the dry-run (same builder the live backend runs)."""
        from repro.distributed import sharded_search as ss

        return ss.make_distributed_search(
            mesh, beam_width=beam_width, max_hops=max_hops, k=k,
            query_chunk=query_chunk, use_pq=use_pq, beam_budget=beam_budget,
            budget_buckets=budget_buckets, per_shard_laws=per_shard_laws,
            step_kernel=step_kernel)

    def set_shard_ok(self, shard_ok) -> None:
        """Runtime straggler/fault mask — no recompilation.  Consumed at
        merge time, so in a pipelined stream the new mask applies to every
        continue program dispatched after the call."""
        self.shard_ok = shard_ok

    def _laws(self) -> tuple:
        return self.shard_laws if self.shard_laws is not None else ()

    # ------------------------------------------------- monolithic protocol

    def dispatch(self, queries):
        a = self.arrays
        return self.step(a["adj"], a["codes"], a["vectors"], a["centroids"],
                         jnp.asarray(queries), self.shard_ok, a["entries"],
                         *self._laws())

    def collect(self, handles) -> BatchResult:
        d2, shard_ids, local_ids = handles
        sid = np.asarray(shard_ids).astype(np.int64)
        lid = np.asarray(local_ids).astype(np.int64)
        gids = sid * self.rows_per_shard + lid
        return BatchResult(ids=gids, d2=np.asarray(d2),
                           extras={"shard_ids": sid, "local_ids": lid})

    # ----------------------------------------------------- staged protocol

    def admit(self, queries) -> Array:
        return jnp.asarray(queries)

    def probe(self, ctxs, budget_cfg, excl=None):
        if excl is not None:
            raise NotImplementedError(
                "filtered search is not supported on the distributed "
                "backend: the filter bitset is indexed by global node id "
                "while the mesh programs checkpoint shard-local walks with "
                "no global-id view (see ROADMAP carry-overs)")
        if budget_cfg != self.beam_budget:
            raise ValueError(
                "staged distributed serving needs the engine's budget_cfg "
                f"to equal the backend's beam_budget; got {budget_cfg} vs "
                f"{self.beam_budget}")
        a = self.arrays
        return self._probe_step(a["adj"], a["codes"], a["vectors"],
                                a["centroids"], ctxs, a["entries"],
                                *self._laws())

    def continue_fn(self, budget_cfg):
        a = self.arrays

        def cont(sub_state, sub_queries, sub_budgets, sub_hop_limits):
            return self._continue_step(
                a["adj"], a["codes"], a["vectors"], a["centroids"],
                sub_state, sub_queries, sub_budgets, sub_hop_limits,
                self.shard_ok)

        return cont

    def schedule_budgets(self, budgets_np: np.ndarray) -> np.ndarray:
        """Per-query effective budget for host scheduling: the *mean* over
        shards — the expected per-shard work a lane adds to a continue
        program.  The max over shards is useless as a key: with many
        independently-centered shard laws, nearly every query draws ~l_max
        on *some* shard (an extreme statistic of S noisy probe estimates),
        so the histogram collapses to one bucket.  Scheduling never changes
        math either way; the continue programs always receive the raw
        per-shard grants."""
        return np.rint(budgets_np.mean(axis=1)).astype(np.int32)

    def finish(self, queries, parts, k: int, *, q_lid,
               budgets_np, prefetch=None) -> BatchResult:
        d2, shard_ids, local_ids, hops, evals = parts
        sid = shard_ids.astype(np.int64)
        lid = local_ids.astype(np.int64)
        return BatchResult(
            ids=sid * self.rows_per_shard + lid, d2=d2,
            stats=search_mod.SearchStats(hops=hops, dist_evals=evals),
            astats=search_mod.AdaptiveStats(q_lid=np.asarray(q_lid),
                                            budget=budgets_np),
            extras={"shard_ids": sid, "local_ids": lid})


@dataclasses.dataclass
class _InFlight:
    """One admitted batch whose device programs are dispatched, not collected.

    ``backend`` is the flight's *snapshot* of the engine's backend, taken at
    dispatch (a shallow ``copy.copy``): every post-dispatch stage runs
    against it, so a concurrent :meth:`SearchEngine.update_backend` (the
    delta-tier merge publishing a new index + block store) never mixes two
    index versions inside one flight.  The shallow copy freezes the
    attribute *bindings* (index, codes, slow tier); a replaced disk tier is
    closed by ``update`` but a closed tier still serves synchronous reads,
    so the snapshot stays fully functional until its last gather.
    """

    queries: Any
    backend: Any = None
    excl: Any = None           # packed filter words ((Q, nw) uint32) or None
    ctxs: Any = None
    probe_state: Any = None
    budgets: Any = None
    hop_limits: Any = None
    q_lid: Any = None
    handles: Any = None        # monolithic mode: the dispatched program's outputs
    # Filled by the schedule stage (staged mode):
    budgets_np: Any = None
    ceilings: tuple[int, ...] | None = None
    dispatched: Any = None     # [(members, continue handles)] or full-batch handles
    # Filled by the walk-prefetch stage (out-of-core backend only):
    walk_prefetch: Any = None  # future of the first-frontier adjacency reads
    # Filled by the prefetch stage (disk slow tier only):
    parts: Any = None          # continue outputs, synced to host numpy
    prefetch: Any = None       # future of the slow tier's block fetch


class SearchEngine:
    """One serving API over every backend, with a double-buffered pipeline.

    Modes:
      * ``budget_cfg=None`` — fixed-beam serving at ``beam_width``.
      * ``budget_cfg=AdaptiveBeamBudget(...)`` — the adaptive engine
        (probe -> budget -> bucketed continue -> rerank), staged per batch.

    ``num_buckets``: ``"auto"`` (default) picks the bucket-ceiling family per
    batch from the granted-budget histogram
    (:func:`repro.serving.pipeline.auto_bucket_ceilings`); an int >= 2 pins
    the historical fixed family; ``None``/1 disables bucketing (single
    continue program).  Scheduling never changes results.

    ``step_kernel`` ("reference" | "pallas" | "auto") selects the walk's hop
    implementation on the backend (``backend.set_step_kernel``): the
    reference hop chain or the fused Pallas beam step
    (:mod:`repro.kernels.beam_step`) — bit-identical results either way
    (the engine-parity kernel axis asserts it per backend and variant).

    ``search`` serves one batch, unpipelined.  ``search_batches`` serves a
    stream with double buffering: batch i+1's admission + probe are
    *dispatched* before batch i's bucketing/continue are *collected*, so the
    accelerator works through the next probe while the host partitions the
    current batch (jax dispatch is asynchronous).  Each batch's results are
    bit-identical between the two entry points — the same compiled programs
    run on the same inputs; only the moment of the blocking host transfer
    moves.

    Batches may be ragged (each shape jit-caches separately; pad upstream to
    a shape quantum if compile count matters).  ``coalesce_lanes`` instead
    merges *small* batches inside the engine: consecutive batches are
    concatenated until the merged lane count reaches the threshold, the
    merged batch flows through the pipeline once, and the results are split
    back so every input batch still yields its own :class:`BatchResult`
    (per-query order preserved) — the cross-batch admission coalescing a hot
    upstream batcher needs.  Coalescing is result-transparent per query
    under a pinned LID center; with batch-mean centering, budgets depend on
    which queries share a dispatch (the reducer's property, as with any
    batching choice).

    The engine is mutable where serving needs it to be: :meth:`recalibrate`
    refits the budget law in place; :meth:`update_backend` swaps refreshed
    index arrays (Online-MCGI inserts) without losing the engine or its jit
    caches.
    """

    def __init__(self, backend, budget_cfg=None, *, k: int = 10,
                 beam_width: int = 48, max_hops: int = 2048,
                 num_buckets: int | str | None = "auto",
                 pad_quantum: int = 4, coalesce_lanes: int | None = None,
                 step_kernel: str | None = None):
        self.backend = backend
        if step_kernel is not None:
            # The knob lives on the backend (it keys the jitted walk
            # programs); the engine-level parameter is pure convenience.
            backend.set_step_kernel(step_kernel)
        self.budget_cfg = budget_cfg
        self.k = k
        self.beam_width = beam_width
        self.max_hops = max_hops
        self.num_buckets = num_buckets
        # Bucket lane counts are padded to this grid (jit-cache shape family
        # vs lane inflation; a per-accelerator tuning knob). The engine's
        # default is finer than the historical 8: with tight DP-chosen
        # ceilings and serving-size micro-batches, quantum-4 padding was
        # measured (CPU) to cut padded-lane inflation enough to beat the
        # extra compile shapes.
        self.pad_quantum = pad_quantum
        self.coalesce_lanes = coalesce_lanes
        self._close_lock = threading.Lock()
        self._closed = False
        backend_budget = getattr(backend, "beam_budget", None)
        if (budget_cfg is not None and backend_budget is not None
                and budget_cfg != backend_budget):
            raise ValueError(
                "engine budget_cfg and the distributed backend's beam_budget "
                "must be the same config (the staged programs are compiled "
                f"against the latter): {budget_cfg} vs {backend_budget}")

    # ------------------------------------------------------------- serving

    def search(self, queries, *, filter=None) -> BatchResult:
        """Serve one batch (unpipelined): all stages back to back.

        ``filter`` is a boolean *allowed* mask over the index's nodes —
        ``(n,)`` shared by every query or ``(Q, n)`` per query (a tenant
        namespace, an attribute predicate, the delta tier's live set).  It
        is enforced *in-graph*: the packed mask pre-seeds the walk's visited
        bitset (see :func:`repro.core.search.pack_filter`), so out-of-filter
        nodes never enter the beam and can never be returned — queries with
        fewer than k in-filter reachable nodes pad with INVALID/inf lanes.
        """
        f = self._dispatch(queries, filter)
        if self._walk_prefetching():
            f = self._walk_prefetch(f)
        f = self._schedule(f)
        if self._prefetching():
            f = self._prefetch(f)
        return self._gather(f)

    def search_batches(self, batches: Iterable, *,
                       filter=None) -> Iterator[BatchResult]:
        """Serve a stream of query batches, double-buffered.

        Two batches are in flight (three with a disk slow tier, whose extra
        prefetch stage deepens the window by one): batch i+1's admission +
        probe are dispatched before batch i's budgets are synced and its
        continue programs dispatched, and the oldest batch's continues are
        gathered only after that — the device queue always holds the next
        batch's work while the host buckets and reassembles (and, disk, the
        tier's worker reads blocks). Yields one :class:`BatchResult` per
        input batch, in order. A single-batch stream degrades to exactly
        :meth:`search` (no prefetch partner). The generator is lazy —
        iterate it to drive the pipeline.

        With ``coalesce_lanes`` set, micro-batches below the threshold are
        merged before dispatch and their results split back on gather — one
        result per *input* batch either way.

        ``filter`` (see :meth:`search`) is either one allowed mask shared by
        every batch (``(n,)`` bool), or an iterable yielding one entry per
        input batch — each ``(n,)``, ``(Q_b, n)``, or ``None`` for an
        unfiltered batch.  Coalesced dispatches concatenate the member
        batches' per-query masks (``None`` members expand to all-True), so
        coalescing stays result-transparent per query.
        """
        pairs = self._with_filters(batches, filter)
        if not self.coalesce_lanes or self.coalesce_lanes <= 1:
            yield from self._stream(pairs)
            return
        groups: list[list[int]] = []   # lane counts of each merged dispatch
        for res in self._stream(self._coalesced(pairs, groups)):
            sizes = groups.pop(0)
            if len(sizes) == 1:
                yield res
            else:
                yield from _split_result(res, sizes)

    def _with_filters(self, batches: Iterable, flt) -> Iterator:
        """Pair each query batch with its allowed mask (or None).

        A single array-like ``flt`` is the shared-mask form; any other
        non-None value is treated as an iterable of per-batch masks.
        """
        if flt is None:
            for qb in batches:
                yield np.asarray(qb), None
            return
        if isinstance(flt, (np.ndarray, jax.Array, list, tuple)):
            try:
                shared = np.asarray(flt)
            except ValueError:       # ragged per-batch list
                shared = None
            if (shared is not None and shared.ndim == 1
                    and shared.dtype != object):
                shared = shared.astype(bool)
                for qb in batches:
                    yield np.asarray(qb), shared
                return
        for qb, m in zip(batches, flt):
            yield np.asarray(qb), None if m is None else np.asarray(m)

    def _coalesced(self, pairs: Iterable, groups: list) -> Iterator:
        """Merge consecutive (batch, mask) pairs until ``coalesce_lanes``
        lanes are admitted; append each flushed group's per-batch sizes to
        ``groups`` (recorded at dispatch, so the split plan is always ahead
        of the results)."""
        pend: list[np.ndarray] = []
        pend_m: list = []
        lanes = 0

        def flush():
            groups.append([b.shape[0] for b in pend])
            qb = pend[0] if len(pend) == 1 else np.concatenate(pend)
            if all(m is None for m in pend_m):
                return qb, None
            n = self.backend.num_nodes()
            rows = [np.broadcast_to(
                        np.ones(n, bool) if m is None else m.astype(bool),
                        (b.shape[0], n))
                    for b, m in zip(pend, pend_m)]
            return qb, np.concatenate(rows)

        for qb, m in pairs:
            pend.append(qb)
            pend_m.append(m)
            lanes += qb.shape[0]
            if lanes >= self.coalesce_lanes:
                yield flush()
                pend, pend_m, lanes = [], [], 0
        if pend:
            yield flush()

    def _stream(self, pairs: Iterable) -> Iterator[BatchResult]:
        """The double-buffered pipeline core (one result per input batch).

        ``flight`` holds the batches between dispatch and gather as
        ``[stage index, state]``, oldest first; every new dispatch advances
        each in-flight batch by exactly one stage, newest first — so within
        a tick the order is: dispatch batch i's probe, schedule batch i-1
        (continues enter the device queue), prefetch batch i-2's block reads
        (disk slow tier only), gather the oldest.  With the default stage
        list that is exactly the historical two-in-flight pipeline; a disk
        slow tier adds the prefetch stage, making it three deep so the block
        reads of one batch overlap the continue programs of the next.
        """
        stages: list = [self._schedule]
        if self._walk_prefetching():
            # Runs *before* the bucket/continue stage: the out-of-core
            # backend's first-frontier adjacency reads go to the tier's
            # worker while the newest batch's probe occupies the device.
            stages.insert(0, self._walk_prefetch)
        if self._prefetching():
            stages.append(self._prefetch)
        flight: list[list] = []

        def advance() -> BatchResult | None:
            done = None
            for ent in reversed(flight):
                si, f = ent
                if si < len(stages):
                    ent[1] = stages[si](f)
                    ent[0] = si + 1
                else:
                    done = self._gather(f)
            if done is not None:
                flight.pop(0)
            return done

        for qb, flt in pairs:
            new = self._dispatch(qb, flt)  # batch i hits the device queue first
            res = advance()
            flight.append([0, new])
            if res is not None:
                yield res
        while flight:
            res = advance()
            if res is not None:
                yield res

    # -------------------------------------------- front-door dispatch seam

    def begin(self, queries, *, filter=None) -> _InFlight:
        """Dispatch one batch and return its in-flight handle without
        blocking — the front half of :meth:`search`, split out so the
        serving front door (:mod:`repro.serving.server`) can start device
        work at flush time and finish it on its own scheduler.  Pair with
        :meth:`finish_from` (full result) and :meth:`partial_result`
        (best-so-far at a deadline).  ``filter`` as in :meth:`search`; the
        flight carries its backend snapshot, so a backend refresh between
        ``begin`` and ``finish_from`` never mixes index versions."""
        return self._dispatch(queries, filter)

    def finish_from(self, f: _InFlight) -> BatchResult:
        """Run the remaining stages of a :meth:`begin` flight and gather
        the batch.  ``begin`` + ``finish_from`` executes exactly the stage
        sequence of :meth:`search` — same compiled programs, same inputs,
        bit-identical results."""
        if self._staged() and f.dispatched is None:
            if self._walk_prefetching() and f.walk_prefetch is None:
                f = self._walk_prefetch(f)
            f = self._schedule(f)
            if self._prefetching() and f.prefetch is None:
                f = self._prefetch(f)
        return self._gather(f)

    @property
    def supports_partial(self) -> bool:
        """Whether :meth:`partial_result` can serve a best-so-far answer:
        a staged engine whose backend exposes a host-side probe view
        (``partial_parts``).  The distributed probe state is a whole-mesh
        checkpoint — its beams live shard-local with no host reassembly
        short of the merge program — so the front door falls back to plain
        timeouts there."""
        return (self._staged()
                and hasattr(self.backend, "partial_parts"))

    def partial_result(self, f: _InFlight) -> BatchResult:
        """Best-so-far gather at the probe horizon — the deadline-aware
        gather of the serving front door.  The probe state's beam is
        reranked through the backend's normal finish path (slow-tier fetch
        included, synchronously — a deadline hedge has no later stage to
        hide I/O behind), so a partial is a real servable result: valid
        ids, true distances, just from a shorter walk.  The flight is not
        consumed — :meth:`finish_from` can still run afterwards and sees
        the same probe state.  ``extras["partial"]`` marks the result."""
        if not self.supports_partial:
            raise ValueError(
                "partial results need a staged engine over a backend with "
                "a host-side probe view (partial_parts); the distributed "
                "mesh state has none")
        parts = tuple(np.asarray(a)
                      for a in f.backend.partial_parts(f.probe_state))
        budgets_np = (f.budgets_np if f.budgets_np is not None
                      else np.asarray(f.budgets))
        res = f.backend.finish(f.queries, parts, self.k, q_lid=f.q_lid,
                               budgets_np=budgets_np)
        res.extras["partial"] = True
        return res

    # ------------------------------------------------- pipeline stage thirds

    def _pack_filter(self, flt, nq: int):
        """Normalise an allowed mask to packed exclusion words (or None)."""
        if flt is None:
            return None
        if not hasattr(self.backend, "num_nodes"):
            raise NotImplementedError(
                "filtered search is not supported on this backend (no "
                "global node-id view; see DistributedBackend.probe)")
        n = self.backend.num_nodes()
        allowed = np.asarray(flt, dtype=bool)
        if allowed.ndim == 1:
            allowed = np.broadcast_to(allowed, (nq, n))
        if allowed.shape != (nq, n):
            raise ValueError(
                f"filter mask shape {allowed.shape} != ({nq}, {n}) "
                "(expected an allowed mask of (n,) or (Q, n) bool)")
        return search_mod.pack_filter(allowed, n)

    def _dispatch(self, queries, flt=None) -> _InFlight:
        """Admission + probe (staged) or the whole program (monolithic);
        returns device handles without blocking.  The flight snapshots the
        backend (shallow copy) so every later stage — including ones that
        run after an :meth:`update_backend` — sees one consistent index
        version."""
        backend = copy.copy(self.backend)
        excl = self._pack_filter(flt, int(np.asarray(queries).shape[0]))
        if not self._staged():
            if hasattr(backend, "dispatch"):
                if excl is not None:
                    raise NotImplementedError(
                        "filtered search is not supported on the "
                        "distributed backend (no global node-id view)")
                handles = backend.dispatch(queries)
            else:
                q = jnp.asarray(queries)
                handles = backend.fixed(
                    q, beam_width=self.beam_width, max_hops=self.max_hops,
                    k=self.k, excl=excl)
            return _InFlight(queries=queries, backend=backend, excl=excl,
                             handles=handles)
        ctxs = backend.admit(queries)
        probe_state, budgets, hop_limits, q_lid = backend.probe(
            ctxs, self.budget_cfg, excl=excl)
        return _InFlight(queries=queries, backend=backend, excl=excl,
                         ctxs=ctxs, probe_state=probe_state,
                         budgets=budgets, hop_limits=hop_limits, q_lid=q_lid)

    def _schedule(self, f: _InFlight) -> _InFlight:
        """Host-bucket stage: sync the granted budgets (the transfer the
        lookahead hides), pick the bucket family, dispatch every continue
        program.  Monolithic batches pass through untouched.

        Bucket membership keys on the backend's *scheduling* view of the
        budgets (``schedule_budgets`` — per-query scalars for the single-host
        backends, the mean over shards for the distributed one); the continue
        programs always receive the raw granted budgets, so scheduling never
        changes math.
        """
        if not self._staged():
            return f
        cfg = self.budget_cfg
        f.budgets_np = np.asarray(f.budgets)
        sched = f.backend.schedule_budgets(f.budgets_np)
        f.ceilings = self._resolve_ceilings(sched, cfg)
        cont = f.backend.continue_fn(cfg)
        if f.ceilings is None or len(f.ceilings) <= 1:
            f.dispatched = cont(f.probe_state, f.ctxs, f.budgets,
                                f.hop_limits)
        else:
            f.dispatched = pipe.dispatch_bucketed_continue(
                cont, f.probe_state, f.ctxs, f.budgets, f.hop_limits,
                f.ceilings, budgets_np=sched,
                quantum=self.pad_quantum)
        return f

    def _walk_prefetch(self, f: _InFlight) -> _InFlight:
        """Out-of-core walk-prefetch stage: submit the continue phase's
        first-frontier adjacency block reads (bounded by the backend's
        ``io_depth``) to the tier's worker thread — they land in the
        tier's cache while other batches' device programs run.  Pure cache
        warm-up; results never depend on it."""
        if self._staged():
            f.walk_prefetch = f.backend.prefetch_walk(
                f.probe_state, f.budgets, f.hop_limits)
        return f

    def _prefetch(self, f: _InFlight) -> _InFlight:
        """Disk-slow-tier stage: sync the continue outputs to host numpy and
        submit the rerank's block reads to the tier's worker thread.  Runs
        right after the *next* batch's continue programs were dispatched, so
        the block reads overlap that device work; :meth:`_gather` joins the
        future one stage later.  Absent from the stage list unless the
        backend's slow tier is disk-backed."""
        if self._staged():
            f.parts = self._continue_parts(f)
            f.prefetch = f.backend.prefetch_rerank(f.parts)
        return f

    def _continue_parts(self, f: _InFlight) -> tuple:
        """Continue outputs as host numpy, original query order."""
        if f.parts is not None:
            return f.parts
        if f.ceilings is None or len(f.ceilings) <= 1:
            return tuple(np.asarray(a) for a in f.dispatched)
        return pipe.gather_bucketed_continue(
            f.budgets_np.shape[0], f.dispatched)

    def _gather(self, f: _InFlight) -> BatchResult:
        """Collection stage: pull continue results, finish (rerank or the
        distributed id reassembly), restore original query order.  Then —
        with the batch's results already in hand — kick one hot-tier
        promotion round on the disk tier's promoter thread
        (``backend.promotion_tick``, non-blocking; a no-op for backends
        without a frequency-aware tier): the tick digests the frequency
        the batch just recorded while the next batches' stages run."""
        res = self._collect(f)
        tick = getattr(self.backend, "promotion_tick", None)
        if tick is not None:
            tick()
        return res

    def _collect(self, f: _InFlight) -> BatchResult:
        if not self._staged():
            if hasattr(f.backend, "collect"):
                return f.backend.collect(f.handles)
            ids, d2, stats, astats = f.handles
            return BatchResult(
                ids=np.asarray(ids), d2=np.asarray(d2), stats=stats,
                astats=astats,
                extras=getattr(f.backend, "finish_extras", dict)())
        parts = self._continue_parts(f)
        res = f.backend.finish(f.queries, parts, self.k, q_lid=f.q_lid,
                               budgets_np=f.budgets_np,
                               prefetch=f.prefetch)
        res.ceilings = f.ceilings
        return res

    def _staged(self) -> bool:
        return self.budget_cfg is not None and self.backend.staged

    def _prefetching(self) -> bool:
        """Whether the pipeline should run the disk-prefetch stage."""
        return self._staged() and getattr(self.backend, "prefetches", False)

    def _walk_prefetching(self) -> bool:
        """Whether the pipeline should run the walk-prefetch stage (the
        out-of-core backend reads adjacency at walk time)."""
        return (self._staged()
                and getattr(self.backend, "walk_prefetches", False))

    def _resolve_ceilings(self, budgets_np, cfg) -> tuple[int, ...] | None:
        if self.num_buckets == "auto":
            return pipe.auto_bucket_ceilings(
                budgets_np, cfg, quantum=self.pad_quantum,
                launch_cost_hops=getattr(self.backend, "launch_cost_hops",
                                         pipe.BUCKET_LAUNCH_COST_HOPS))
        if self.num_buckets is None or self.num_buckets <= 1:
            return None
        return search_mod.budget_bucket_ceilings(
            cfg.l_min, cfg.l_max, self.num_buckets)

    # ------------------------------------------------------- live reconfigure

    def recalibrate(self, queries=None, gt_ids=None, *,
                    recall_target: float = 0.95, joint: bool = False,
                    sample: int = 256, seed: int = 0,
                    eval_recall: Callable | None = None,
                    make_eval: Callable | None = None, **fit_kw):
        """Refit the budget law against ``recall_target`` and deploy it.

        The hook Online-MCGI needs: inserts shift the LID population, so an
        index refresh calls :meth:`update_backend` then this — the engine
        object, its backend wiring, and its shape-keyed jit caches all
        survive; only the (lam, hop_factor[, l_min]) knobs move (one
        recompile of probe/continue, since the config is a static jit key).

        ``joint=True`` runs the joint (lam, l_min) fit
        (:func:`repro.core.calibrate.calibrate_budget_law_joint`); otherwise
        the lam bisection of :func:`~repro.core.calibrate.calibrate_budget_law`.
        Evaluators default to the backend's own recall measurement on a
        held-out sample of ``queries``/``gt_ids``; pass ``eval_recall`` /
        ``make_eval`` to override.  Returns the
        :class:`~repro.core.calibrate.CalibrationResult`; the fitted config is
        already live on return.
        """
        from repro.core import calibrate as calib

        if self.budget_cfg is None:
            raise ValueError("recalibrate() needs an adaptive engine "
                             "(budget_cfg is None)")
        if getattr(self.backend, "beam_budget", None) is not None:
            # Swapping budget_cfg here would desync it from the staged
            # programs compiled against the backend's beam_budget and brick
            # every later search() on the consistency check in probe().
            raise NotImplementedError(
                "distributed engines recalibrate per shard: fit "
                "repro.core.calibrate.calibrate_budget_law_per_shard and "
                "rebuild the DistributedBackend with shard_laws= (runtime "
                "arrays — the rebuild recompiles nothing) and the fit's "
                "serving_budget()")
        base = self.budget_cfg
        if joint:
            if make_eval is None:
                if queries is None or gt_ids is None:
                    raise ValueError("joint recalibration needs queries + "
                                     "gt_ids (or make_eval)")
                make_eval = lambda cfg: self.backend.recall_eval(
                    queries, gt_ids, k=self.k, sample=sample, seed=seed,
                    base_cfg=cfg)
            result = calib.calibrate_budget_law_joint(
                make_eval, base, recall_target, **fit_kw)
        else:
            if eval_recall is None:
                if queries is None or gt_ids is None:
                    raise ValueError("recalibration needs queries + gt_ids "
                                     "(or eval_recall)")
                eval_recall = self.backend.recall_eval(
                    queries, gt_ids, k=self.k, sample=sample, seed=seed,
                    base_cfg=base)
            result = calib.calibrate_budget_law(
                eval_recall, base, recall_target, **fit_kw)
        self.budget_cfg = result.budget_cfg(base)
        return result

    def update_backend(self, *args, **kw) -> None:
        """Swap refreshed index arrays into the live backend (Online-MCGI
        insert path); see the backend's ``update`` signature.  Backends
        owning a disk slow tier close the replaced tier's worker thread
        as part of ``update``."""
        self.backend.update(*args, **kw)

    def close(self) -> None:
        """Release backend-owned resources (disk slow tiers own a worker
        thread).  Idempotent and safe to call concurrently — from any
        thread, including while a ``search_batches`` stream is in flight:
        exactly one caller runs the backend teardown, and a closed disk
        tier keeps serving synchronous reads (its prefetch degrades
        gracefully; see :meth:`repro.index.disk.BlockSlowTier.close`), so
        in-flight batches complete with bit-identical results.  Backends
        without resources are a no-op."""
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        close = getattr(self.backend, "close", None)
        if close is not None:
            close()
