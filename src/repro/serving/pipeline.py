"""Host-side scheduling for the staged serving pipeline.

This module owns everything the serving engine does *between* jitted device
programs: partitioning a batch into budget buckets, padding bucket lane
counts, choosing the bucket-ceiling family from the granted-budget histogram,
and reassembling per-bucket results into the original query order. The
device-side programs themselves (probe / continue / rerank) stay in
:mod:`repro.core.search` — they are pure kernels; this file is the scheduler
that drives them.

Two gather disciplines are provided:

* eager (:func:`bucketed_continue`) — each bucket's results are pulled to
  the host before the next bucket is dispatched.  This is the historical
  behaviour that ``repro.core.search.beam_search_{exact,pq}_adaptive``'s
  ``num_buckets=`` convenience keeps, byte for byte, so existing callers
  and property tests see no change.
* deferred (:func:`dispatch_bucketed_continue` +
  :func:`gather_bucketed_continue`) — every bucket's continue program is
  dispatched before any result is gathered, so the device queue runs the
  buckets back to back while the host does its numpy reassembly.  The
  staged engine (:class:`repro.serving.engine.SearchEngine`) runs the two
  halves in different pipeline stages; results are the same arrays either
  way (identical programs, identical inputs — only the moment of the
  blocking transfer moves).

Host work that is *not* scheduling also rides between the stages this
module defines: the engine's gather stage ends by kicking the disk tier's
hot-node promotion tick (``backend.promotion_tick`` — see
:mod:`repro.index.hot_tier`), a non-blocking submit to the tier's promoter
thread.  It lives at the stage boundary for the same reason the bucket
scheduling does: the device queue already holds the younger batches' work,
so the host cycles spent there are free — and the promotion I/O itself runs
on its own thread against a private store handle, so no pipeline stage (or
fetch) ever waits on it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod

# Continue-phase dispatch overhead expressed in modelled lane-hops: one more
# bucket costs one more (dispatch + host gather + pad) round trip.  The value
# is a scheduling constant, not a measured quantity — it only has to be large
# enough that splitting a bucket which saves fewer than ~a padded row of hops
# is rejected (measured CPU-only break-even is a few hundred lane-hops).
BUCKET_LAUNCH_COST_HOPS = 512


def pad_bucket_size(n: int, quantum: int = 8) -> int:
    """Round a bucket's lane count up to a multiple of ``quantum``.

    A vmapped ``while_loop`` pays full body cost for *every* lane on every
    iteration (padding lanes are not free), so the pad grid must be fine:
    multiples of 8 cap the inflation at <= 12.5% for any bucket of >= 8 real
    lanes, while keeping the jit cache to at most Q/8 shapes per bucket —
    coarser (power-of-two) padding was measured to give back the entire
    bucketing win on the largest bucket (66 -> 128 lanes ~= 2x its work).
    """
    return max(quantum, ((n + quantum - 1) // quantum) * quantum)


def partition_by_bucket(
    budgets: np.ndarray, ceilings: tuple[int, ...], quantum: int = 8
) -> list[tuple[int, np.ndarray, np.ndarray]]:
    """Group queries by bucket: [(bucket_index, members, padded_members)].

    ``members`` are original batch positions; ``padded_members`` repeats
    ``members[0]`` up to the padded lane count (those lanes' results are
    discarded on reassembly).  Empty buckets are skipped.  Membership is a
    per-query property of the granted budget, never of batch order.
    """
    ceil_arr = np.asarray(ceilings, dtype=np.int64)
    bucket_idx = np.minimum(
        np.searchsorted(ceil_arr, np.asarray(budgets), side="left"),
        len(ceilings) - 1)
    out = []
    for bi in range(len(ceilings)):
        members = np.nonzero(bucket_idx == bi)[0]
        if members.size == 0:
            continue
        padded = np.concatenate([
            members,
            np.full(pad_bucket_size(members.size, quantum) - members.size,
                    members[0]),
        ])
        out.append((bi, members, padded))
    return out


def auto_bucket_ceilings(
    budgets: np.ndarray,
    budget_cfg: "search_mod.AdaptiveBeamBudget",
    max_buckets: int = 8,
    quantum: int = 8,
    launch_cost_hops: int = BUCKET_LAUNCH_COST_HOPS,
) -> tuple[int, ...]:
    """Pick the bucket-ceiling family from the granted-budget histogram.

    Replaces the fixed ``num_buckets=4`` default.  The batch's occupied
    budget values v_1 < ... < v_m are partitioned into at most
    ``max_buckets`` contiguous groups; a group's ceiling is its own largest
    occupied value (tight — a halving family's ceilings sit above the
    occupied values and buy nothing), and its modelled cost is

        padded_lanes * hop_factor * ceiling  +  launch_cost_hops

    (each bucket's vmapped while-loop is bounded by its slowest lane, itself
    bounded by the ceiling-derived hop limit, and pays every padded lane on
    every iteration; each extra bucket costs one more dispatch + host
    gather).  The exact minimiser over all contiguous partitions is found by
    a small dynamic program — O(m^2 * max_buckets) with m bounded by the
    distinct granted budgets, at most l_max - l_min + 1.  Ties break toward
    fewer buckets.  The choice is a pure function of the budget *histogram*
    — deterministic, and invariant under batch permutation — and scheduling
    never changes results, so auto-picking is result-transparent.
    """
    budgets = np.asarray(budgets)
    values, counts = np.unique(budgets, return_counts=True)
    m = values.size
    if m == 0:
        return (int(budget_cfg.l_max),)
    k_max = min(max_buckets, m)
    csum = np.concatenate([[0], np.cumsum(counts)])  # O(1) group counts

    def group_cost(i: int, j: int) -> float:
        """Cost of one bucket covering values[i:j] (j exclusive)."""
        lanes = pad_bucket_size(int(csum[j] - csum[i]), quantum)
        return (lanes * budget_cfg.hop_factor * int(values[j - 1])
                + launch_cost_hops)

    # best[j] = (cost, partition) for values[:j] using any number of groups
    # <= k_max; rebuilt k layers deep.
    inf = float("inf")
    prev = [inf] * (m + 1)
    prev[0] = 0.0
    cuts: list[list[tuple[int, ...] | None]] = [[None] * (m + 1)]
    cuts[0][0] = ()
    best_cost, best_cs = inf, None
    for _k in range(k_max):
        cur = [inf] * (m + 1)
        cur_cuts: list[tuple[int, ...] | None] = [None] * (m + 1)
        for j in range(1, m + 1):
            for i in range(j):
                if prev[i] == inf:
                    continue
                c = prev[i] + group_cost(i, j)
                if c < cur[j]:
                    cur[j] = c
                    cur_cuts[j] = cuts[-1][i] + (int(values[j - 1]),)
        cuts.append(cur_cuts)
        prev = cur
        if cur[m] < best_cost:  # strict: ties keep fewer buckets
            best_cost, best_cs = cur[m], cur_cuts[m]
    assert best_cs is not None
    return best_cs


def bucketed_continue(
    continue_fn,
    probe_state,
    ctxs,
    budgets,
    hop_limits,
    ceilings: tuple[int, ...],
):
    """Budget-bucketed continue phase over one batch.

    Queries are grouped by granted budget into the ``ceilings`` buckets and
    each bucket resumes as its own (cached-jit) continue call. A vmapped
    ``while_loop`` iterates until its *slowest* lane converges, so in the
    single-program path a batch with one hard query burns every easy lane's
    compute until the hard one finishes; per-bucket, the slowest lane is
    bounded by the bucket's own ceiling-derived hop limit — converged lanes
    actually free compute instead of idling.

    Per-query budgets/hop limits are passed through *unquantized*, so every
    lane computes exactly what the unbucketed path would: results are
    identical (scheduling changes, math doesn't). Buckets are padded to a
    multiple-of-8 lane count (repeating a member row, results discarded) so
    the jit cache sees a bounded shape family at <= 12.5% lane inflation.

    This is the eager discipline the core ``num_buckets=`` entry points
    keep; the staged engine instead drives the deferred halves
    (:func:`dispatch_bucketed_continue` + :func:`gather_bucketed_continue`)
    from different pipeline stages, so every bucket is dispatched before any
    is gathered and another batch's programs sit in between.

    Returns (beam_ids, beam_d, hops, evals) as numpy, original query order.
    """
    q = ctxs.shape[0]
    out = None
    for _bi, members, padded in partition_by_bucket(
            np.asarray(budgets), ceilings):
        handles = _dispatch_bucket(continue_fn, probe_state, ctxs, budgets,
                                   hop_limits, padded)
        out = _scatter_bucket(out, q, members, handles)
    if out is None:  # zero-query batch: no buckets — dispatch a zero-lane
        # program so the empty outputs carry the *program's* signature
        # (single-host continues return 4 arrays, distributed returns 5)
        members, handles = _zero_lane_bucket(continue_fn, probe_state, ctxs,
                                             budgets, hop_limits)
        out = _scatter_bucket(out, q, members, handles)
    return out


def dispatch_bucketed_continue(
    continue_fn,
    probe_state,
    ctxs,
    budgets,
    hop_limits,
    ceilings: tuple[int, ...],
    budgets_np: np.ndarray | None = None,
    quantum: int = 8,
) -> list[tuple[np.ndarray, tuple]]:
    """Dispatch half of the deferred discipline: partition the batch and
    enqueue every bucket's continue program; nothing blocks.  Returns
    [(members, device handles)] for :func:`gather_bucketed_continue` —
    the staged engine runs the two halves in different pipeline stages, so
    another batch's programs sit between dispatch and gather."""
    if budgets_np is None:
        budgets_np = np.asarray(budgets)
    dispatched = [
        (members, _dispatch_bucket(continue_fn, probe_state, ctxs, budgets,
                                   hop_limits, padded))
        for _bi, members, padded in partition_by_bucket(budgets_np, ceilings,
                                                        quantum)
    ]
    if not dispatched:   # zero-query batch — see bucketed_continue
        dispatched = [_zero_lane_bucket(continue_fn, probe_state, ctxs,
                                        budgets, hop_limits)]
    return dispatched


def gather_bucketed_continue(q: int, dispatched):
    """Gather half: pull every dispatched bucket to the host and reassemble
    original query order.

    Generic over the continue program's output signature: any tuple of
    per-lane arrays (axis 0 = query lanes) reassembles — the single-host
    backends return (beam_ids, beam_d, hops, evals), the distributed staged
    backend returns its merged (d2, shard_id, local_id, hops, evals).
    Returns the same-length tuple of (q, ...) numpy arrays.
    """
    out = None
    for members, handles in dispatched:
        out = _scatter_bucket(out, q, members, handles)
    assert out is not None, "no buckets dispatched"
    return out


def _dispatch_bucket(continue_fn, probe_state, ctxs, budgets, hop_limits,
                     padded: np.ndarray):
    sel = jnp.asarray(padded)
    sub_state = jax.tree_util.tree_map(lambda a: a[sel], probe_state)
    return continue_fn(sub_state, ctxs[sel], budgets[sel], hop_limits[sel])


def _zero_lane_bucket(continue_fn, probe_state, ctxs, budgets, hop_limits):
    """A (members, handles) pair for a zero-lane dispatch of the continue
    program: its outputs are empty but correctly typed/shaped, whatever the
    program's signature — the generic way to produce a zero-query batch's
    result tuple without hardcoding any backend's output arity."""
    none = np.empty((0,), np.int64)
    return none, _dispatch_bucket(continue_fn, probe_state, ctxs, budgets,
                                  hop_limits, none)


def _scatter_bucket(out, q: int, members, handles):
    """Pull one bucket's device results and place them at their original
    batch positions, dropping the padding lanes. Output buffers are
    allocated lazily from the first bucket's shapes/dtypes (shape metadata
    only — no device sync)."""
    if out is None:
        out = tuple(
            np.empty((q,) + tuple(h.shape[1:]), dtype=np.dtype(h.dtype))
            for h in handles)
    m = members.size
    for buf, h in zip(out, handles):
        buf[members] = np.asarray(h)[:m]
    return out
