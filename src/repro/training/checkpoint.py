"""Sharded checkpointing with elastic resharding — the fault-tolerance layer.

Layout: one directory per step, one ``.npy`` per pytree leaf (path-encoded
filename) + a JSON manifest (step, tree structure, shapes, dtypes, mesh the
checkpoint was written under). Leaves are written via host transfers of
*per-shard* slices so a 512-device array never needs a contiguous host copy
beyond one leaf at a time.

Elastic restore: arrays are re-`device_put` with the *target* mesh's
shardings, so a checkpoint written on (2,16,16) restores onto (16,16) or a
future (4,16,16) unchanged — the resharding test in
``tests/test_checkpoint.py`` exercises mesh-shape changes both ways.

An async flavour hands the host write to a background thread (training
continues; ``wait()`` joins before the next save), which is how large-scale
runs hide checkpoint latency.
"""
from __future__ import annotations

import json
import pathlib
import re
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _leaf_name(path) -> str:
    raw = "/".join(str(p) for p in path)
    return _SAFE.sub("_", raw).strip("_") or "leaf"


def save_checkpoint(
    directory: str | pathlib.Path, step: int, tree: Params, extra: dict | None = None
) -> pathlib.Path:
    directory = pathlib.Path(directory)
    out = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            # numpy can't serialise ml_dtypes natively; store the raw bits.
            arr = arr.view(np.uint16)
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": logical_dtype}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if out.exists():
        import shutil

        shutil.rmtree(out)
    tmp.rename(out)  # atomic publish: partial checkpoints never visible
    return out


def restore_checkpoint(
    directory: str | pathlib.Path,
    target_tree: Params,
    step: int | None = None,
    shardings: Params | None = None,
) -> tuple[Params, int]:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (matching pytree of NamedSharding / None) enables elastic
    restore onto a different mesh than the checkpoint was written from.
    """
    directory = pathlib.Path(directory)
    if step is None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in directory.glob("step_*")
        )
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        step = steps[-1]
    src = directory / f"step_{step:08d}"

    paths_and_leaves = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: s is None or hasattr(s, "spec")
        )
        if shardings is not None
        else [None] * len(paths_and_leaves[0])
    )
    manifest = json.loads((src / "manifest.json").read_text())
    dtypes = {m["name"]: m["dtype"] for m in manifest["leaves"]}
    new_leaves = []
    for (path, leaf), shard in zip(paths_and_leaves[0], shard_leaves):
        name = _leaf_name(path)
        arr = np.load(src / f"{name}.npy")
        if dtypes.get(name) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        expected = tuple(leaf.shape)
        assert tuple(arr.shape) == expected, (name, arr.shape, expected)
        if shard is not None:
            new_leaves.append(jax.device_put(arr, shard))
        else:
            new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), new_leaves
    )
    return tree, step


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*"))
    return steps[-1] if steps else None


def prune_old(directory: str | pathlib.Path, keep: int = 3) -> None:
    """Rolling window of checkpoints (disk hygiene on long runs)."""
    import shutil

    directory = pathlib.Path(directory)
    steps = sorted(directory.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (training never blocks on disk)."""

    def __init__(self) -> None:
        self._thread: threading.Thread | None = None

    def save(self, directory, step: int, tree: Params, extra=None) -> None:
        self.wait()
        # Materialise on host *before* handing to the thread so the device
        # buffers are free to be donated by the next step.
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self._thread = threading.Thread(
            target=save_checkpoint, args=(directory, step, host_tree, extra)
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
