"""Generic train-step builder shared by every architecture.

``make_train_step(loss_fn, opt_cfg)`` returns a pure function
    (state, batch) -> (state, metrics)
suitable for jit/pjit: value_and_grad, global-norm clip, AdamW, optional int8
gradient compression with error feedback. The loss_fn closure carries the
model config and the ShardCtx, so the same builder serves LM, GNN and recsys
training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import compression as comp_mod
from repro.training import optimizer as opt_mod

Array = jax.Array
Params = Any
LossFn = Callable[[Params, dict], tuple[Array, dict]]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Params
    opt: Params
    error_feedback: Params | None = None

    @property
    def step(self) -> Array:
        return self.opt["step"]


def init_train_state(
    params: Params, compress_grads: bool = False
) -> TrainState:
    return TrainState(
        params=params,
        opt=opt_mod.adamw_init(params),
        error_feedback=(
            comp_mod.init_error_feedback(params) if compress_grads else None
        ),
    )


def make_train_step(
    loss_fn: LossFn,
    opt_cfg: opt_mod.AdamWConfig,
    compress_grads: bool = False,
):
    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        err = state.error_feedback
        if compress_grads:
            grads, err = comp_mod.compress_grads_with_feedback(grads, err)
        params, opt, opt_metrics = opt_mod.adamw_update(
            opt_cfg, state.params, grads, state.opt
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params=params, opt=opt, error_feedback=err), metrics

    return train_step
