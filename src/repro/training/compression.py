"""Int8 gradient compression with error feedback (distributed-optimisation
trick for bandwidth-bound data-parallel training).

Mechanics: quantise each gradient leaf to int8 with a per-leaf scale before
the cross-replica reduction, de-quantise after, and carry the quantisation
residual into the next step (error feedback, à la 1-bit SGD / EF-SGD) so the
bias does not accumulate. Under GSPMD the reduction is implicit in the grad
psum; the framework therefore exposes compression as a *gradient transform*
around the optimizer update — the same operator order (quantise → reduce →
dequantise) a hand-rolled ring all-reduce would use, with the reduce done on
the int8-rounded values.

The compile-time effect (the §Roofline collective term) is modelled by the
4x smaller all-reduce payload; ``compressed_allreduce_bytes`` reports it.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


def quantize_leaf(g: Array) -> tuple[Array, Array]:
    """f32 -> (int8 codes, scale). Symmetric per-leaf scaling."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(
    grads: Params, error: Params
) -> tuple[Params, Params]:
    """Returns (compressed-then-decompressed grads, new error feedback)."""

    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_leaf(g)
        deq = dequantize_leaf(q, s)
        return deq, g - deq

    out = jax.tree.map(leaf, grads, error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_allreduce_bytes(params: Params) -> tuple[int, int]:
    """(uncompressed f32 payload, int8 payload) for the grad all-reduce."""
    n = sum(int(l.size) for l in jax.tree.leaves(params))
    return 4 * n, n + 4 * len(jax.tree.leaves(params))
