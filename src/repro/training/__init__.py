from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, wsd_schedule  # noqa: F401
from repro.training.train_step import TrainState, make_train_step  # noqa: F401
