"""Synthetic data pipelines, one per architecture family.

Deterministic, seeded, host-side generators yielding fixed-shape device
batches — the same contract a production loader (tf.data / grain) fulfils.
LM batches follow a Zipfian unigram over the vocab (so losses move like
text, not like uniform noise); recsys batches draw power-law item/category
popularity; the GNN pipeline wraps the fanout sampler.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _zipf_ids(rng: np.random.Generator, shape, vocab: int, a: float = 1.1):
    # Truncated Zipf via inverse-CDF on a precomputed table is overkill here;
    # numpy's zipf + modulo keeps the tail bounded and the draw fast.
    raw = rng.zipf(a, size=shape)
    return (raw % vocab).astype(np.int32)


@dataclasses.dataclass
class LmBatches:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict[str, Array]]:
        rng = np.random.default_rng(self.seed)
        while True:
            toks = _zipf_ids(rng, (self.batch, self.seq + 1), self.vocab)
            yield {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:].astype(np.int32)),
            }


@dataclasses.dataclass
class DlrmBatches:
    vocab_sizes: tuple[int, ...]
    n_dense: int
    batch: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict[str, Array]]:
        rng = np.random.default_rng(self.seed)
        while True:
            dense = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
            sparse = np.stack(
                [_zipf_ids(rng, (self.batch,), v) for v in self.vocab_sizes],
                axis=1,
            )
            # Click-ish labels correlated with a random linear readout.
            w = rng.normal(size=(self.n_dense,))
            p = 1.0 / (1.0 + np.exp(-(dense @ w) * 0.5))
            labels = (rng.uniform(size=self.batch) < p).astype(np.float32)
            yield {
                "dense": jnp.asarray(dense),
                "sparse": jnp.asarray(sparse),
                "labels": jnp.asarray(labels),
            }


@dataclasses.dataclass
class SeqRecBatches:
    """Shared by MIND (hist/target) and BERT4Rec (cloze)."""

    n_items: int
    batch: int
    seq: int
    n_mask: int = 20
    seed: int = 0

    def mind_iter(self) -> Iterator[dict[str, Array]]:
        rng = np.random.default_rng(self.seed)
        while True:
            hist = _zipf_ids(rng, (self.batch, self.seq), self.n_items)
            lens = rng.integers(self.seq // 2, self.seq + 1, size=self.batch)
            mask = np.arange(self.seq)[None, :] < lens[:, None]
            target = _zipf_ids(rng, (self.batch,), self.n_items)
            yield {
                "hist": jnp.asarray(hist),
                "hist_mask": jnp.asarray(mask),
                "target": jnp.asarray(target),
            }

    def bert4rec_iter(self, mask_token: int) -> Iterator[dict[str, Array]]:
        rng = np.random.default_rng(self.seed)
        while True:
            seq = _zipf_ids(rng, (self.batch, self.seq), self.n_items)
            pos = np.stack(
                [
                    rng.choice(self.seq, size=self.n_mask, replace=False)
                    for _ in range(self.batch)
                ]
            ).astype(np.int32)
            labels = np.take_along_axis(seq, pos, axis=1)
            masked = seq.copy()
            np.put_along_axis(masked, pos, mask_token, axis=1)
            yield {
                "seq": jnp.asarray(masked),
                "seq_mask": jnp.ones((self.batch, self.seq), bool),
                "mlm_positions": jnp.asarray(pos),
                "mlm_labels": jnp.asarray(labels),
            }


def random_graph_data(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int, seed: int = 0
):
    """Synthetic homophilous graph: community-structured edges + class-
    correlated features (so a GNN can actually learn)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    # 80% intra-class edges, 20% random.
    n_intra = int(0.8 * n_edges)
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    srcs, dsts = [], []
    cls = rng.integers(0, n_classes, size=n_intra)
    for c in range(n_classes):
        members = by_class[c]
        cnt = int((cls == c).sum())
        if len(members) < 2 or cnt == 0:
            continue
        srcs.append(rng.choice(members, size=cnt))
        dsts.append(rng.choice(members, size=cnt))
    srcs.append(rng.integers(0, n_nodes, size=n_edges - sum(len(s) for s in srcs)))
    dsts.append(rng.integers(0, n_nodes, size=n_edges - sum(len(d) for d in dsts)))
    src = np.concatenate(srcs)[:n_edges]
    dst = np.concatenate(dsts)[:n_edges]
    mask = rng.uniform(size=n_nodes) < 0.5  # train mask
    return feats, np.stack([src, dst]).astype(np.int32), labels, mask
