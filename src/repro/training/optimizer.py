"""AdamW + learning-rate schedules, from scratch (no optax in this image).

Includes the WSD (Warmup-Stable-Decay) schedule the minicpm-2b assignment
calls out [arXiv:2404.06395] alongside the standard cosine schedule.
Optimizer state mirrors the parameter tree (same shardings), so FSDP-sharded
parameters get FSDP-sharded moments for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"       # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1        # WSD: fraction of steps in final decay


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * (0.5 * (1.0 + jnp.cos(jnp.pi * t)))


def wsd_schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Warmup-Stable-Decay: linear warmup, flat plateau, sharp final decay
    (MiniCPM uses exponential-style annealing in the last ~10%)."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
    t = jnp.clip(
        (step - decay_start) / jnp.maximum(cfg.total_steps - decay_start, 1.0),
        0.0, 1.0,
    )
    decay = 0.5 ** (t * 6.0)  # ~64x down by the end, MiniCPM-style
    return cfg.lr * warm * decay


def schedule_fn(cfg: AdamWConfig) -> Callable[[Array], Array]:
    if cfg.schedule == "cosine":
        return lambda s: cosine_schedule(cfg, s)
    if cfg.schedule == "wsd":
        return lambda s: wsd_schedule(cfg, s)
    return lambda s: jnp.full((), cfg.lr, jnp.float32)


def adamw_init(params: Params) -> Params:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Params) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, Array]:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _is_matrix(p: Array) -> bool:
    # Weight decay on matrices/embeddings only (norms & biases exempt),
    # treating stacked-layer leading axes as batch dims.
    return p.ndim >= 2


def adamw_update(
    cfg: AdamWConfig, params: Params, grads: Params, state: Params
) -> tuple[Params, Params, dict[str, Array]]:
    step = state["step"] + 1
    lr = schedule_fn(cfg)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )
