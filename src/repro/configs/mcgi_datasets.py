"""The paper's own dataset configurations (Table 2/3) as dry-run archs.

These lower the *distributed MCGI search step* at the paper's full N —
SIFT1B / T2I-1B at 10^9 points on the production mesh — proving the sharded
serving path is coherent at billion scale even though this host can only
*execute* reduced-N benchmarks. Build parameters (R, L_build, alpha range,
m_PQ) are the paper's Table 2 values.
"""
import dataclasses

from repro.configs import base


@dataclasses.dataclass(frozen=True)
class McgiDatasetConfig:
    name: str
    n: int
    d: int
    degree: int          # R
    l_build: int         # L_build
    m_pq: int | None     # PQ bytes (None = full precision in memory tier)
    data_dtype: str      # "float32" | "uint8"
    alpha_min: float = 1.0
    alpha_max: float = 1.5
    queries: int = 4096          # global query batch for the serve step
    l_search: int = 128
    k: int = 10
    max_hops: int = 192


_DATASETS = (
    McgiDatasetConfig("mcgi-sift1m", 1_000_000, 128, 64, 100, None, "float32"),
    McgiDatasetConfig("mcgi-glove100", 1_200_000, 100, 64, 100, None, "float32"),
    McgiDatasetConfig("mcgi-gist1m", 1_000_000, 960, 96, 150, None, "float32"),
    McgiDatasetConfig("mcgi-sift1b", 1_000_000_000, 128, 32, 50, 16, "uint8"),
    McgiDatasetConfig("mcgi-t2i1b", 1_000_000_000, 200, 32, 50, 16, "float32"),
)


def _smoke(cfg: McgiDatasetConfig) -> McgiDatasetConfig:
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n=4096, queries=64, l_search=32,
        max_hops=64, degree=min(cfg.degree, 16), d=min(cfg.d, 64),
    )


for _cfg in _DATASETS:
    base.register(
        base.ArchSpec(
            arch_id=_cfg.name,
            family="mcgi",
            config=_cfg,
            smoke_config=_smoke(_cfg),
            shapes=(
                base.ShapeCell(
                    "serve", base.MCGI_SEARCH,
                    {"queries": _cfg.queries, "k": _cfg.k},
                ),
            ),
            source="paper Table 2/3",
        )
    )
