"""The paper's own dataset configurations (Table 2/3) as dry-run archs.

These lower the *distributed MCGI search step* at the paper's full N —
SIFT1B / T2I-1B at 10^9 points on the production mesh — proving the sharded
serving path is coherent at billion scale even though this host can only
*execute* reduced-N benchmarks. Build parameters (R, L_build, alpha range,
m_PQ) are the paper's Table 2 values.
"""
import dataclasses

from repro.configs import base


@dataclasses.dataclass(frozen=True)
class McgiDatasetConfig:
    name: str
    n: int
    d: int
    degree: int          # R
    l_build: int         # L_build
    m_pq: int | None     # PQ bytes (None = full precision in memory tier)
    data_dtype: str      # "float32" | "uint8"
    alpha_min: float = 1.0
    alpha_max: float = 1.5
    queries: int = 4096          # global query batch for the serve step
    l_search: int = 128
    k: int = 10
    max_hops: int = 192
    # Adaptive budget-law serving defaults (Prop. 4.2 + calibration pass).
    # ``lam`` and ``l_min`` are *jointly* calibrated against
    # ``recall_target`` on held-out query samples of the matching proxy
    # datasets (repro.core.calibrate.calibrate_budget_law_joint: smallest
    # feasible budget floor, then largest feasible lam at that floor);
    # re-fit after any index build-parameter change. Higher-LID datasets
    # (GIST/T2I mixtures) need a stronger budget spread *and* a higher
    # floor than the near-homogeneous SIFT geometry, whose easy lanes
    # tolerate l_min = l_search/16.
    lam: float = 0.35
    l_min: int | None = None     # None -> max(8, l_search // 8)
    probe_hops: int = 8
    hop_factor: int = 4
    recall_target: float = 0.95
    budget_buckets: int = 4      # ceiling of the auto-picked bucket family
    # Per-shard calibrated budget laws (repro.core.calibrate
    # .calibrate_budget_law_per_shard on shard-local held-out queries).
    # None broadcasts the global (lam, l_min) — the identity laws — so the
    # distributed serve cell always lowers the per-shard variant (runtime
    # arrays; a later calibration swaps values in without recompiling).
    shard_lam: tuple[float, ...] | None = None
    shard_l_min: tuple[int, ...] | None = None

    def beam_budget(self):
        """The serving engine's AdaptiveBeamBudget for this dataset:
        l_max = l_search (same worst-case quality budget as fixed-beam),
        l_min the jointly calibrated floor (default: an eighth, floor 8)."""
        from repro.core.search import AdaptiveBeamBudget

        l_min = self.l_min if self.l_min is not None else max(
            8, self.l_search // 8)
        return AdaptiveBeamBudget(
            l_min=min(l_min, self.l_search), l_max=self.l_search,
            lam=self.lam, probe_hops=self.probe_hops,
            hop_factor=self.hop_factor)

    def calibrated_beam_budget(self, eval_recall):
        """Re-fit this dataset's budget law against its own recall target.

        ``eval_recall`` measures one candidate config on held-out queries
        (``repro.core.calibrate.{exact,tiered}_recall_eval``); the stored
        ``lam`` default is the seed, ``recall_target`` the constraint. Run
        after any index build-parameter change and fold the fitted values
        back into this config.
        """
        from repro.core.calibrate import calibrate_budget_law

        base = self.beam_budget()
        return calibrate_budget_law(
            eval_recall, base, self.recall_target).budget_cfg(base)

    def shard_budget_laws(self, n_shards: int):
        """Per-shard (lam (S,), l_min (S,)) runtime arrays for the
        distributed step (``per_shard_laws`` builders / ``shard_laws=`` on
        the backend).

        Stored per-shard fits must match ``n_shards``; with none stored the
        global law broadcasts (identical results to the scalar law — the
        arrays exist so the compiled program accepts calibrated values
        later without recompilation).
        """
        import numpy as np

        base = self.beam_budget()
        if self.shard_lam is not None or self.shard_l_min is not None:
            lam = self.shard_lam if self.shard_lam is not None \
                else (base.lam,) * n_shards
            l_min = self.shard_l_min if self.shard_l_min is not None \
                else (base.l_min,) * n_shards
            assert len(lam) == n_shards and len(l_min) == n_shards, (
                len(lam), len(l_min), n_shards)
            return (np.asarray(lam, np.float32), np.asarray(l_min, np.int32))
        return (np.full((n_shards,), base.lam, np.float32),
                np.full((n_shards,), base.l_min, np.int32))

    def jointly_calibrated_beam_budget(self, make_eval):
        """Joint (lam, l_min) re-fit against this dataset's recall target.

        ``make_eval`` builds a recall evaluator specialised to one candidate
        floor (``lambda cfg: calibrate.tiered_recall_eval(..., base_cfg=cfg)``);
        the fitted floor and exponent come back as a ready-to-serve budget.
        Fold the fitted values into this config's ``lam``/``l_min`` defaults
        after any index build-parameter change.
        """
        from repro.core.calibrate import calibrate_budget_law_joint

        base = self.beam_budget()
        return calibrate_budget_law_joint(
            make_eval, base, self.recall_target).budget_cfg(base)


_DATASETS = (
    # (lam, l_min) pairs from the joint calibration pass on the proxies:
    # SIFT-like geometry sustains the halved floor (l_search/16), the
    # high-LID GIST/T2I mixtures keep the default eighth.
    McgiDatasetConfig("mcgi-sift1m", 1_000_000, 128, 64, 100, None, "float32",
                      lam=0.25, l_min=8),
    McgiDatasetConfig("mcgi-glove100", 1_200_000, 100, 64, 100, None,
                      "float32", lam=0.3, l_min=8),
    McgiDatasetConfig("mcgi-gist1m", 1_000_000, 960, 96, 150, None, "float32",
                      lam=0.5, l_min=16),
    McgiDatasetConfig("mcgi-sift1b", 1_000_000_000, 128, 32, 50, 16, "uint8",
                      lam=0.25, l_min=8),
    McgiDatasetConfig("mcgi-t2i1b", 1_000_000_000, 200, 32, 50, 16, "float32",
                      lam=0.45, l_min=16),
)


def _smoke(cfg: McgiDatasetConfig) -> McgiDatasetConfig:
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n=4096, queries=64, l_search=32,
        max_hops=64, degree=min(cfg.degree, 16), d=min(cfg.d, 64),
    )


for _cfg in _DATASETS:
    base.register(
        base.ArchSpec(
            arch_id=_cfg.name,
            family="mcgi",
            config=_cfg,
            smoke_config=_smoke(_cfg),
            shapes=(
                base.ShapeCell(
                    "serve", base.MCGI_SEARCH,
                    {"queries": _cfg.queries, "k": _cfg.k},
                ),
            ),
            source="paper Table 2/3",
        )
    )
