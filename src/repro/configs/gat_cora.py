"""gat-cora [arXiv:1710.10903]: 2-layer GAT, d_hidden=8, 8 heads, attn
aggregator. Four graph regimes with their published stats:

  * full_graph_sm : Cora        (2,708 nodes / 10,556 edges / 1,433 feats / 7 cls)
  * minibatch_lg  : Reddit      (232,965 / 114,615,892 / 602 feats / 41 cls),
                    sampled 1024-node batches, fanout 15-10
  * ogb_products  : ogbn-products (2,449,029 / 61,859,140 / 100 feats / 47 cls)
  * molecule      : 128-graph batches of <=30-node molecules (graph-level task)

The GAT layer config is fixed by the assignment; per-regime input dims/classes
follow the named datasets.
"""
import dataclasses

from repro.configs import base
from repro.models.gnn import GatConfig


@dataclasses.dataclass(frozen=True)
class GatArchConfig:
    """Per-regime GAT instantiations share the assigned layer hyper-params."""

    d_hidden: int = 8
    n_heads: int = 8

    def for_regime(self, d_in: int, n_classes: int) -> GatConfig:
        return GatConfig(
            d_in=d_in, d_hidden=self.d_hidden, n_heads=self.n_heads,
            n_classes=n_classes, n_layers=2,
        )


CONFIG = GatArchConfig()
SMOKE_CONFIG = GatArchConfig(d_hidden=4, n_heads=2)

# Sampled-block padding for minibatch_lg: 1024 seeds, fanout (15, 10) =>
# <= 1024*(1 + 15 + 150) nodes and <= 1024*15 + 15360*10 edges; padded to
# static shapes for jit.
_MB_NODES = base.pad_to(1024 * (1 + 15 + 150), 256)      # 170,240
_MB_EDGES = base.pad_to(1024 * 15 + 1024 * 15 * 10, 256)  # 168,960

SHAPES = (
    base.ShapeCell(
        "full_graph_sm", base.GNN_TRAIN,
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7,
         "level": "node"},
    ),
    base.ShapeCell(
        "minibatch_lg", base.GNN_TRAIN,
        {"n_nodes": _MB_NODES, "n_edges": _MB_EDGES, "d_feat": 602,
         "n_classes": 41, "level": "node", "batch_nodes": 1024,
         "fanout": (15, 10), "full_graph_nodes": 232965,
         "full_graph_edges": 114615892},
        note="Reddit; dry-run lowers the per-block train step at the padded "
             "sampler output shapes; the sampler itself is host-side "
             "(models/gnn.py::NeighborSampler).",
    ),
    base.ShapeCell(
        "ogb_products", base.GNN_TRAIN,
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
         "n_classes": 47, "level": "node"},
    ),
    base.ShapeCell(
        "molecule", base.GNN_TRAIN,
        {"n_nodes": 30, "n_edges": 64, "batch_graphs": 128, "d_feat": 32,
         "n_classes": 2, "level": "graph"},
        note="128 molecules batched block-diagonally: 3,840 nodes / 8,192 "
             "edges per step, mean-pooled graph readout.",
    ),
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="gat-cora",
        family="gnn",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=SHAPES,
        source="arXiv:1710.10903",
    )
)
