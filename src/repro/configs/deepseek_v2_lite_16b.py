"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L d_model=2048 16H MLA
(kv_lora=512, nope=128, rope=64, v=128), vocab=102400, MoE 64 routed top-6 +
2 shared experts (d_expert=1408), first layer dense (d_ff=10944).

Assignment note: the bracketed "160 routed" in the pool entry contradicts its
own "MoE 64e top-6"; the primary spec (64 routed, matching the published
V2-Lite) is used — recorded in DESIGN.md §4."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.attention import MlaConfig
from repro.models.moe import MoeConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,  # the first_k_dense layer's FFN
    vocab=102400,
    attention="mla",
    mla=MlaConfig(
        d_model=2048, n_heads=16, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    ),
    moe=MoeConfig(
        d_model=2048, n_experts=64, top_k=6, d_expert=1408,
        n_shared=2, d_shared=1408,
    ),
    first_k_dense=1,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = TransformerConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=192,
    vocab=512,
    attention="mla",
    mla=MlaConfig(
        d_model=64, n_heads=4, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        attn_chunk_q=16, attn_chunk_k=16,
    ),
    moe=MoeConfig(d_model=64, n_experts=8, top_k=2, d_expert=48,
                  n_shared=2, d_shared=48),
    first_k_dense=1,
    dtype=jnp.float32,
    attn_chunk_q=16,
    attn_chunk_k=16,
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="deepseek-v2-lite-16b",
        family="lm",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.lm_shapes(),
        source="arXiv:2405.04434",
    )
)
