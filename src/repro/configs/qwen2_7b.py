"""qwen2-7b [arXiv:2407.10671]: dense, 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, QKV bias."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-7b",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = TransformerConfig(
    name="qwen2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=160,
    vocab=512,
    qkv_bias=True,
    dtype=jnp.float32,
    attn_chunk_q=16,
    attn_chunk_k=16,
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="qwen2-7b",
        family="lm",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.lm_shapes(),
        source="arXiv:2407.10671",
    )
)
