"""deepfm [arXiv:1703.04247]: 39 sparse fields (Criteo: 13 bucketised dense +
26 categorical), embed_dim=10, deep MLP 400-400-400, FM interaction.
Field vocabulary sized to Criteo-Kaggle scale (~34M total features)."""
from repro.configs import base
from repro.models.recsys import DeepFmConfig

CONFIG = DeepFmConfig(
    n_fields=39,
    vocab_per_field=871_264,  # 39 * 871,264 ~= 34M one-hot features
    embed_dim=10,
    mlp=(400, 400, 400),
)

SMOKE_CONFIG = DeepFmConfig(
    n_fields=6, vocab_per_field=500, embed_dim=8, mlp=(32, 32)
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="deepfm",
        family="recsys",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.RECSYS_SHAPES,
        source="arXiv:1703.04247",
    )
)
