"""Architecture registry: one module per assigned architecture (exact
published configs) plus the paper's own dataset configs. ``--arch <id>``
resolution goes through repro.configs.base.get()."""
from repro.configs.base import ArchSpec, ShapeCell, all_archs, get  # noqa: F401
