"""deepseek-coder-33b [arXiv:2401.14196]: dense llama-arch, 62L d_model=7168
56H (GQA kv=8) d_ff=19200 vocab=32256."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=19200,
    vocab=32256,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = TransformerConfig(
    name="deepseek-coder-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=160,
    vocab=512,
    dtype=jnp.float32,
    attn_chunk_q=16,
    attn_chunk_k=16,
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="deepseek-coder-33b",
        family="lm",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.lm_shapes(),
        source="arXiv:2401.14196",
    )
)
