"""minicpm-2b [arXiv:2404.06395]: dense llama-like, 40L d_model=2304 36H (MHA,
kv=36, d_head=64) d_ff=5760 vocab=122753; tied embeddings; mup-style scaling
(scale_emb=12, scale_depth=1.4, dim_model_base=256); trained with the WSD
schedule (repro/training/optimizer.py::wsd_schedule).

vocab is padded 122753 -> 122880 (multiple of 256) for clean mesh sharding —
standard TPU vocab padding; the extra logits are never labelled."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import TransformerConfig

VOCAB_RAW = 122753
VOCAB_PADDED = base.pad_to(VOCAB_RAW, 256)  # 122880

CONFIG = TransformerConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab=VOCAB_PADDED,
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    dim_model_base=256,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = TransformerConfig(
    name="minicpm-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    vocab=512,
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    dim_model_base=32,
    dtype=jnp.float32,
    attn_chunk_q=16,
    attn_chunk_k=16,
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="minicpm-2b",
        family="lm",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.lm_shapes(),
        source="arXiv:2404.06395",
    )
)
