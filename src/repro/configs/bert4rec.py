"""bert4rec [arXiv:1904.06690]: bidirectional sequential recommender —
embed_dim=64, 2 blocks, 2 heads, seq_len=200, cloze training. Item corpus
sized to the retrieval_cand cell (10^6 candidates)."""
from repro.configs import base
from repro.models.recsys import Bert4RecConfig

CONFIG = Bert4RecConfig(
    n_items=1_000_000,
    embed_dim=64,
    n_blocks=2,
    n_heads=2,
    seq_len=200,
)

SMOKE_CONFIG = Bert4RecConfig(
    n_items=2000, embed_dim=32, n_blocks=2, n_heads=2, seq_len=24
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="bert4rec",
        family="recsys",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.RECSYS_SHAPES,
        source="arXiv:1904.06690",
    )
)
