"""Config-system core: architecture specs, shape cells, the registry.

Every assigned architecture registers an :class:`ArchSpec` binding
  * its exact published configuration (``config``),
  * a reduced same-family smoke configuration (``smoke_config``),
  * its shape-cell set (each cell knows which step kind it lowers).

``launch/cells.py`` turns (spec, cell, mesh) into a concrete
(step_fn, arg_specs) pair for the dry-run; ``launch/train.py`` /
``serve.py`` use the same specs to run real steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

# Step kinds a shape cell can lower.
TRAIN = "train"            # train_step (fwd+bwd+optimizer)
PREFILL = "prefill"        # LM prefill forward
DECODE = "decode"          # LM single-token decode vs KV cache
SERVE = "serve"            # recsys forward scoring
RETRIEVAL = "retrieval"    # 1 user vs n_candidates scoring
GNN_TRAIN = "gnn_train"    # full-graph or sampled-block train step
MCGI_SEARCH = "mcgi_search"  # distributed beam search (the paper's serving)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str
    meta: dict[str, Any]
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys" | "mcgi"
    config: Any
    smoke_config: Any
    shapes: tuple[ShapeCell, ...]
    source: str = ""                  # provenance tag from the assignment

    def cell(self, name: str) -> ShapeCell:
        for c in self.shapes:
            if c.name == name:
                return c
        raise KeyError(f"{self.arch_id} has no shape {name!r}: "
                       f"{[c.name for c in self.shapes]}")


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    assert spec.arch_id not in _REGISTRY, spec.arch_id
    _REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    _ensure_loaded()
    return dict(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # Importing the config modules populates the registry.
    from repro.configs import (  # noqa: F401
        bert4rec,
        deepfm,
        deepseek_coder_33b,
        deepseek_v2_lite_16b,
        dlrm_mlperf,
        gat_cora,
        mcgi_datasets,
        mind,
        minicpm_2b,
        qwen2_7b,
        qwen3_moe_30b_a3b,
    )

    _LOADED = True


def pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


# The four LM shape cells shared by all five LM archs (assignment block).
def lm_shapes(*, sub_quadratic: bool = False) -> tuple[ShapeCell, ...]:
    note_500k = (
        "decode vs 524288-token KV cache is O(S)/step and runs; a 500k "
        "*prefill* would be quadratic — full-attention archs skip that "
        "(DESIGN.md §4)."
    )
    return (
        ShapeCell("train_4k", TRAIN, {"seq": 4096, "batch": 256}),
        ShapeCell("prefill_32k", PREFILL, {"seq": 32768, "batch": 32}),
        ShapeCell("decode_32k", DECODE, {"seq": 32768, "batch": 128}),
        ShapeCell("long_500k", DECODE, {"seq": 524288, "batch": 1},
                  note=note_500k),
    )


RECSYS_SHAPES = (
    ShapeCell("train_batch", TRAIN, {"batch": 65536}),
    ShapeCell("serve_p99", SERVE, {"batch": 512}),
    ShapeCell("serve_bulk", SERVE, {"batch": 262144}),
    ShapeCell("retrieval_cand", RETRIEVAL, {"batch": 1, "n_candidates": 1_000_000}),
)
