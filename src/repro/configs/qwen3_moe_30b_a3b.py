"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d_model=2048 32H (GQA kv=4)
vocab=151936, MoE 128 experts top-8, expert d_ff=768, QK-norm, no shared
experts. ~30.5B total / ~3.3B active parameters."""
import jax.numpy as jnp

from repro.configs import base
from repro.models.moe import MoeConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,  # unused (all layers MoE); kept for record
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoeConfig(d_model=2048, n_experts=128, top_k=8, d_expert=768),
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = TransformerConfig(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=512,
    qk_norm=True,
    moe=MoeConfig(d_model=64, n_experts=8, top_k=2, d_expert=96),
    dtype=jnp.float32,
    attn_chunk_q=16,
    attn_chunk_k=16,
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="qwen3-moe-30b-a3b",
        family="lm",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.lm_shapes(),
        source="hf:Qwen/Qwen3-30B-A3B",
    )
)
