"""dlrm-mlperf [arXiv:1906.00091]: the MLPerf DLRM benchmark config on Criteo
1TB — 13 dense features, 26 categorical tables (published cardinalities, ~190M
rows x 128 = ~97 GB fp32 fused table), bottom MLP 13-512-256-128, top MLP
1024-1024-512-256-1, dot interaction."""
from repro.configs import base
from repro.models.recsys import CRITEO_1TB_VOCABS, DlrmConfig

CONFIG = DlrmConfig(
    n_dense=13,
    vocab_sizes=CRITEO_1TB_VOCABS,
    embed_dim=128,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
)

SMOKE_CONFIG = DlrmConfig(
    n_dense=13,
    vocab_sizes=(1000, 500, 200, 50, 7),
    embed_dim=16,
    bot_mlp=(32, 16),
    top_mlp=(64, 32, 1),
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="dlrm-mlperf",
        family="recsys",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.RECSYS_SHAPES,
        source="arXiv:1906.00091 (MLPerf)",
    )
)
