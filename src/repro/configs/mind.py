"""mind [arXiv:1904.08030]: multi-interest retrieval — embed_dim=64,
n_interests=4, capsule routing iters=3, history length 50. Item corpus sized
to the retrieval_cand cell (10^6 candidates)."""
from repro.configs import base
from repro.models.recsys import MindConfig

CONFIG = MindConfig(
    n_items=1_000_000,
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
)

SMOKE_CONFIG = MindConfig(
    n_items=2000, embed_dim=16, n_interests=4, capsule_iters=3, hist_len=20
)

SPEC = base.register(
    base.ArchSpec(
        arch_id="mind",
        family="recsys",
        config=CONFIG,
        smoke_config=SMOKE_CONFIG,
        shapes=base.RECSYS_SHAPES,
        source="arXiv:1904.08030",
    )
)
