"""Cell builders: (arch spec, shape cell, mesh) -> a concrete lowerable step.

A *cell* is one (architecture x input-shape) entry of the assignment matrix.
``build_cell`` returns a :class:`Cell` with
  * fn           — the step function (train/prefill/decode/serve/retrieval/
                   mcgi_search),
  * arg_specs    — ShapeDtypeStructs with NamedShardings attached (no host
                   allocation: params come from jax.eval_shape),
  * donate       — argnums donated (state/cache), for honest memory analysis.

The same builders power dryrun.py (lower+compile), train.py and serve.py —
so what the dry-run proves is exactly what the launchers run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base
from repro.launch import mesh as mesh_mod
from repro.launch import shardings as shard_mod
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.models.layers import ShardCtx
from repro.training import optimizer as opt_mod
from repro.training import train_step as ts_mod

Array = jax.Array


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    arg_specs: tuple
    donate: tuple[int, ...] = ()
    note: str = ""

    def lower(self):
        jitted = jax.jit(self.fn, donate_argnums=self.donate)
        return jitted.lower(*self.arg_specs)


def _named(mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _ctx(mesh) -> ShardCtx:
    return ShardCtx(mesh=mesh, dp=mesh_mod.dp_axes(mesh), tp="model")


def _state_specs(family: str, mesh, init_fn):
    """TrainState arg specs via eval_shape + family sharding rules."""
    state_shapes = jax.eval_shape(
        lambda k: ts_mod.init_train_state(init_fn(k)), jax.random.PRNGKey(0)
    )
    spec_tree = shard_mod.train_state_specs(family, state_shapes)
    shard_tree = jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
    return shard_mod.attach(state_shapes, shard_tree)


def _param_specs(family: str, mesh, init_fn):
    shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    spec_tree = shard_mod.param_specs(family, shapes)
    shard_tree = jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
    return shard_mod.attach(shapes, shard_tree)


# ------------------------------------------------------------------ LM cells

def _lm_cell(spec: cfg_base.ArchSpec, cell: cfg_base.ShapeCell, mesh,
             smoke: bool = False, layer_unroll: int = 1) -> Cell:
    cfg: tfm.TransformerConfig = spec.smoke_config if smoke else spec.config
    # The attention KV scan is always unrolled here so a layer body's cost is
    # exact; the layer loop's unroll factor is a dry-run knob — dryrun.py
    # compiles at two factors and solves for the per-layer cost (XLA prices a
    # while-loop body exactly once).
    cfg = dataclasses.replace(
        cfg, unroll_layers=layer_unroll, attn_unroll=True,
        mla=(None if cfg.mla is None
             else dataclasses.replace(cfg.mla, attn_unroll=True)),
    )
    ctx = _ctx(mesh)
    dp = mesh_mod.dp_axes(mesh)
    meta = cell.meta
    b, s = meta["batch"], meta["seq"]

    if cell.kind == cfg_base.TRAIN:
        opt_cfg = opt_mod.AdamWConfig(schedule="wsd" if "minicpm" in spec.arch_id
                                      else "cosine")
        loss_fn = lambda p, batch: tfm.lm_loss(cfg, p, batch, ctx)
        step = ts_mod.make_train_step(loss_fn, opt_cfg)
        state_specs = _state_specs("lm", mesh, lambda k: tfm.init_lm(cfg, k))
        batch_specs = {
            "tokens": _sds((b, s), jnp.int32, _named(mesh, dp, None)),
            "labels": _sds((b, s), jnp.int32, _named(mesh, dp, None)),
        }
        return Cell(spec.arch_id, cell.name, step, (state_specs, batch_specs),
                    donate=(0,))

    if cell.kind == cfg_base.PREFILL:
        fn = lambda p, tokens: tfm.prefill(cfg, p, tokens, ctx)
        param_specs = _param_specs("lm", mesh, lambda k: tfm.init_lm(cfg, k))
        tok = _sds((b, s), jnp.int32, _named(mesh, dp, None))
        return Cell(spec.arch_id, cell.name, fn, (param_specs, tok))

    if cell.kind == cfg_base.DECODE:
        # ctx constraints keep the MoE expert einsum sharded where the
        # weights live (no per-step weight all-gather) and pin the KV-cache
        # layout; per-entry divisibility filtering makes them valid for the
        # batch=1 long-context cells too (§Perf iteration 2).
        fn = lambda p, cache, tokens, kv_len: tfm.decode_step(
            cfg, p, cache, tokens, kv_len, ctx=ctx
        )
        param_specs = _param_specs("lm", mesh, lambda k: tfm.init_lm(cfg, k))
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, b, s, dtype=jnp.bfloat16)
        )
        # KV cache layout: batch over dp when it divides, sequence over the
        # remaining axes (long-context: sequence over everything).
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if b % dp_size == 0:
            cache_spec = {"batch": dp, "seq": "model"}
        else:
            cache_spec = {"batch": None, "seq": tuple(mesh.axis_names)}

        def cache_sharding(leaf):
            # leaves: (L, B, S, ...) — gqa k/v are rank 5, mla c_kv/k_rope rank 4
            trail = (None,) * (leaf.ndim - 3)
            return _named(mesh, None, cache_spec["batch"], cache_spec["seq"],
                          *trail)

        cache_specs = jax.tree.map(
            lambda l: _sds(l.shape, l.dtype, cache_sharding(l)), cache_shapes
        )
        tok_shard = _named(mesh, dp, None) if b % dp_size == 0 \
            else _named(mesh, None, None)
        len_shard = _named(mesh, dp) if b % dp_size == 0 else _named(mesh)
        tok = _sds((b, 1), jnp.int32, tok_shard)
        kvl = _sds((b,), jnp.int32, len_shard)
        return Cell(spec.arch_id, cell.name, fn,
                    (param_specs, cache_specs, tok, kvl), donate=(1,),
                    note=cell.note)

    raise ValueError(cell.kind)


# ----------------------------------------------------------------- GNN cells

def _gnn_cell(spec: cfg_base.ArchSpec, cell: cfg_base.ShapeCell, mesh,
              smoke: bool = False) -> Cell:
    arch_cfg = spec.smoke_config if smoke else spec.config
    meta = cell.meta
    ctx = _ctx(mesh)
    dp = mesh_mod.dp_axes(mesh)
    dev = mesh.devices.size

    level = meta["level"]
    if level == "graph":
        n_graphs = meta["batch_graphs"]
        n_nodes = cfg_base.pad_to(meta["n_nodes"] * n_graphs, max(dev, 512))
        n_edges = cfg_base.pad_to(meta["n_edges"] * n_graphs, max(dev, 512))
    else:
        n_nodes = cfg_base.pad_to(meta["n_nodes"], max(dev, 512))
        n_edges = cfg_base.pad_to(meta["n_edges"], max(dev, 512))
    gat_cfg = arch_cfg.for_regime(meta["d_feat"], meta["n_classes"])

    if level == "graph":
        loss_fn = lambda p, batch: gnn_mod.gat_graph_loss(gat_cfg, p, batch, ctx)
    else:
        loss_fn = lambda p, batch: gnn_mod.gat_loss(gat_cfg, p, batch, ctx)
    opt_cfg = opt_mod.AdamWConfig(lr=5e-3, weight_decay=5e-4)
    step = ts_mod.make_train_step(loss_fn, opt_cfg)
    state_specs = _state_specs(
        "gnn", mesh, lambda k: gnn_mod.gat_init(k, gat_cfg)
    )
    batch_specs = {
        "features": _sds((n_nodes, meta["d_feat"]), jnp.float32,
                         _named(mesh, dp, None)),
        "edge_index": _sds((2, n_edges), jnp.int32, _named(mesh, None, dp)),
    }
    if level == "graph":
        batch_specs["graph_ids"] = _sds((n_nodes,), jnp.int32, _named(mesh, dp))
        batch_specs["labels"] = _sds((meta["batch_graphs"],), jnp.int32,
                                     _named(mesh, None))
    else:
        batch_specs["labels"] = _sds((n_nodes,), jnp.int32, _named(mesh, dp))
        batch_specs["mask"] = _sds((n_nodes,), jnp.bool_, _named(mesh, dp))
    return Cell(spec.arch_id, cell.name, step, (state_specs, batch_specs),
                donate=(0,), note=cell.note)


# -------------------------------------------------------------- recsys cells

def _recsys_forward_fns(arch_id: str, cfg, ctx):
    if arch_id == "dlrm-mlperf":
        return {
            "loss": lambda p, b: recsys_mod.dlrm_loss(cfg, p, b, ctx),
            "serve": lambda p, b: recsys_mod.dlrm_forward(
                cfg, p, b["dense"], b["sparse"], ctx),
            "retrieval": lambda p, b: recsys_mod.dlrm_retrieval(cfg, p, b, ctx),
        }
    if arch_id == "deepfm":
        return {
            "loss": lambda p, b: recsys_mod.deepfm_loss(cfg, p, b, ctx),
            "serve": lambda p, b: recsys_mod.deepfm_forward(cfg, p, b["sparse"], ctx),
            "retrieval": lambda p, b: recsys_mod.deepfm_retrieval(cfg, p, b, ctx),
        }
    if arch_id == "mind":
        return {
            "loss": lambda p, b: recsys_mod.mind_loss(cfg, p, b, ctx),
            "serve": lambda p, b: recsys_mod.mind_retrieval(
                cfg, p, {**b, "candidates": b["candidates"]}, ctx),
            "retrieval": lambda p, b: recsys_mod.mind_retrieval(cfg, p, b, ctx),
        }
    if arch_id == "bert4rec":
        return {
            "loss": lambda p, b: recsys_mod.bert4rec_loss(cfg, p, b, ctx),
            "serve": lambda p, b: recsys_mod.bert4rec_retrieval(cfg, p, b, ctx),
            "retrieval": lambda p, b: recsys_mod.bert4rec_retrieval(cfg, p, b, ctx),
        }
    raise KeyError(arch_id)


def _recsys_batch_specs(arch_id: str, cfg, mesh, kind: str, meta) -> dict:
    dp = mesh_mod.dp_axes(mesh)
    dev = mesh.devices.size
    b = meta.get("batch", 1)
    every = tuple(mesh.axis_names)

    def bsh(*spec):
        return _named(mesh, *spec)

    if arch_id == "dlrm-mlperf":
        specs = {
            "dense": _sds((b, cfg.n_dense), jnp.float32, bsh(dp, None)),
            "sparse": _sds((b, cfg.n_sparse), jnp.int32, bsh(dp, None)),
        }
    elif arch_id == "deepfm":
        specs = {"sparse": _sds((b, cfg.n_fields), jnp.int32, bsh(dp, None))}
    elif arch_id == "mind":
        specs = {
            "hist": _sds((b, cfg.hist_len), jnp.int32, bsh(dp, None)),
            "hist_mask": _sds((b, cfg.hist_len), jnp.bool_, bsh(dp, None)),
        }
    elif arch_id == "bert4rec":
        specs = {
            "seq": _sds((b, cfg.seq_len), jnp.int32, bsh(dp, None)),
            "seq_mask": _sds((b, cfg.seq_len), jnp.bool_, bsh(dp, None)),
        }
    else:
        raise KeyError(arch_id)

    if kind == cfg_base.TRAIN:
        if arch_id in ("dlrm-mlperf", "deepfm"):
            specs["labels"] = _sds((b,), jnp.float32, bsh(dp))
        elif arch_id == "mind":
            specs["target"] = _sds((b,), jnp.int32, bsh(dp))
        elif arch_id == "bert4rec":
            n_mask = 20
            specs["mlm_positions"] = _sds((b, n_mask), jnp.int32, bsh(dp, None))
            specs["mlm_labels"] = _sds((b, n_mask), jnp.int32, bsh(dp, None))
    if kind == cfg_base.RETRIEVAL:
        c = cfg_base.pad_to(meta["n_candidates"], max(dev, 512))
        specs["candidates"] = _sds((c,), jnp.int32, bsh(every))
        # batch=1 cells replicate the user-side inputs.
        for k, v in list(specs.items()):
            if k != "candidates" and v.shape[0] == 1:
                specs[k] = _sds(v.shape, v.dtype, bsh(*([None] * v.ndim)))
    if kind == cfg_base.SERVE and arch_id in ("mind", "bert4rec"):
        # Online scoring against a served candidate slate (100/query here).
        specs["candidates"] = _sds((100,), jnp.int32, bsh(None))
    return specs


def _recsys_cell(spec: cfg_base.ArchSpec, cell: cfg_base.ShapeCell, mesh,
                 smoke: bool = False) -> Cell:
    cfg = spec.smoke_config if smoke else spec.config
    ctx = _ctx(mesh)
    fns = _recsys_forward_fns(spec.arch_id, cfg, ctx)
    init_map = {
        "dlrm-mlperf": lambda k: recsys_mod.dlrm_init(k, cfg),
        "deepfm": lambda k: recsys_mod.deepfm_init(k, cfg),
        "mind": lambda k: recsys_mod.mind_init(k, cfg),
        "bert4rec": lambda k: recsys_mod.bert4rec_init(k, cfg),
    }
    init_fn = init_map[spec.arch_id]
    batch_specs = _recsys_batch_specs(spec.arch_id, cfg, mesh, cell.kind,
                                      cell.meta)

    if cell.kind == cfg_base.TRAIN:
        opt_cfg = opt_mod.AdamWConfig(lr=1e-3, weight_decay=0.0)
        step = ts_mod.make_train_step(lambda p, b: fns["loss"](p, b), opt_cfg)
        state_specs = _state_specs("recsys", mesh, init_fn)
        return Cell(spec.arch_id, cell.name, step, (state_specs, batch_specs),
                    donate=(0,))

    fn = fns["serve" if cell.kind == cfg_base.SERVE else "retrieval"]
    param_specs = _param_specs("recsys", mesh, init_fn)
    return Cell(spec.arch_id, cell.name, fn, (param_specs, batch_specs))


# ---------------------------------------------------------------- MCGI cells

def _mcgi_cell(spec: cfg_base.ArchSpec, cell: cfg_base.ShapeCell, mesh,
               smoke: bool = False) -> Cell:
    from repro.distributed import sharded_search as ss
    from repro.serving import DistributedBackend

    cfg = spec.smoke_config if smoke else spec.config
    dtype = jnp.uint8 if cfg.data_dtype == "uint8" else jnp.float32
    # PQ subspaces need d % m == 0; pad the vector dim (T2I: 200 -> 208),
    # the standard zero-pad that leaves L2 distances unchanged.
    d_pad = cfg_base.pad_to(cfg.d, cfg.m_pq) if cfg.m_pq else cfg.d
    specs = ss.sharded_index_specs(
        mesh, n=cfg.n, d=d_pad, degree=cfg.degree, m_pq=cfg.m_pq,
        n_queries=cell.meta["queries"] if not smoke else cfg.queries,
        data_dtype=dtype, per_shard_laws=True,
    )
    # The serve cell lowers the *deployed* engine: the serving subsystem's
    # distributed step with per-query adaptive budgets (the dataset's jointly
    # calibrated budget law, threaded as *per-shard* runtime arrays so a
    # shard recalibration never recompiles the serving program) and in-graph
    # budget buckets / hop deadlines — what production serves
    # (repro.serving.SearchEngine over a DistributedBackend) is what the
    # dry-run prices.
    step = DistributedBackend.make_step(
        mesh, beam_width=cfg.l_search, max_hops=cfg.max_hops,
        k=cell.meta["k"], query_chunk=min(128, cfg.queries),
        use_pq=cfg.m_pq is not None,
        beam_budget=cfg.beam_budget(),
        budget_buckets=cfg.budget_buckets,
        per_shard_laws=True,
    )
    args = (specs.adj, specs.codes, specs.vectors, specs.centroids,
            specs.queries, specs.shard_ok, specs.entries,
            specs.shard_lam, specs.shard_l_min)
    return Cell(spec.arch_id, cell.name, step, args)


_FAMILY_BUILDERS = {
    "lm": _lm_cell,
    "gnn": _gnn_cell,
    "recsys": _recsys_cell,
    "mcgi": _mcgi_cell,
}


def build_cell(arch_id: str, shape_name: str, mesh, smoke: bool = False,
               layer_unroll: int = 1) -> Cell:
    spec = cfg_base.get(arch_id)
    cell = spec.cell(shape_name)
    if spec.family == "lm":
        return _lm_cell(spec, cell, mesh, smoke=smoke,
                        layer_unroll=layer_unroll)
    return _FAMILY_BUILDERS[spec.family](spec, cell, mesh, smoke=smoke)


def layer_loop_length(arch_id: str) -> int | None:
    """Trip count of the arch's layer scan (None = no scan loop)."""
    spec = cfg_base.get(arch_id)
    if spec.family == "lm":
        return spec.config.n_layers
    return None


def small_divisor(n: int) -> int:
    for d in (2, 3, 4, 5, 7):
        if n % d == 0:
            return d
    return n


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair in the assignment (incl. MCGI serve cells)."""
    out = []
    for arch_id, spec in cfg_base.all_archs().items():
        for cell in spec.shapes:
            out.append((arch_id, cell.name))
    return out
