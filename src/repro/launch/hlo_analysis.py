"""Compiled-HLO analysis: collective-bytes extraction + roofline terms.

``collective_bytes`` parses the (per-device, SPMD-partitioned) compiled HLO
text and sums the *operand* payload of every communication op —
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute —
grouped by op kind. cost_analysis() has no collective term, so this parser is
the source for §Roofline's third term.

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# A shaped operand/result token: e.g. bf16[8,128]{1,0} or f32[] or
# (f32[2,4], u32[]) tuples are handled by matching each element.
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    by_kind: dict[str, int]
    by_kind_count: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            # Match the op name ("all-gather(", "all-gather-start(", ...).
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                kind = c
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue  # payload counted at the -start op
        # Operand payload: shaped tokens inside the call parens.
        paren = rhs.find("(")
        operand_str = rhs[paren + 1 :]
        op_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operand_str)
        )
        if op_bytes == 0:
            # Fall back to result shape (operand types not always inlined).
            op_bytes = sum(
                _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(rhs[:paren])
            )
        by_kind[kind] = by_kind.get(kind, 0) + op_bytes
        counts[kind] = counts.get(kind, 0) + 1
    return CollectiveStats(by_kind=by_kind, by_kind_count=counts)


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes_per_device: float,
    n_chips: int,
    ici_links: int = 4,
) -> dict:
    """The three §Roofline terms, in seconds.

    cost_analysis numbers from a partitioned module are per-device; the
    compute/memory terms therefore divide by per-chip peaks directly. The
    collective term divides the per-device payload by the per-chip ICI
    bandwidth x links (a 2D/3D torus drives several links concurrently; we
    report the optimistic all-links figure and the single-link bound).
    """
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    coll_1link = collective_bytes_per_device / ICI_BW
    coll_alllinks = collective_bytes_per_device / (ICI_BW * ici_links)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_alllinks,
        "collective_s_single_link": coll_1link,
    }
    dominant = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
