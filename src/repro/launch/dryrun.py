import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import (device count locks at
# first backend init); everything below may import jax freely.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh and
the 2x16x16 multi-pod mesh:

    lowered  = jax.jit(step, ...).lower(*arg_specs)      # ShapeDtypeStructs
    compiled = lowered.compile()
    memory_analysis(), cost_analysis(), collective-bytes(HLO)

and writes one JSON artifact per cell under experiments/dryrun/. Roofline
terms (benchmarks/roofline.py) and EXPERIMENTS.md §Dry-run read these
artifacts.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multipod
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --list

(note: no ``from __future__`` here — the XLA_FLAGS lines must stay first.)
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _measure(cell):
    """lower+compile one cell variant -> (metrics dict, mem stats, compile_s)."""
    from repro.launch import hlo_analysis

    t0 = time.time()
    compiled = cell.lower().compile()
    t_compile = time.time() - t0
    from repro import compat

    cost = compat.cost_analysis(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    metrics = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collective_total": float(coll.total_bytes),
        "collective_by_kind": dict(coll.by_kind),
        "collective_counts": dict(coll.by_kind_count),
    }
    return metrics, compiled.memory_analysis(), t_compile


def _extrapolate(m1: dict, mu: dict, u: int, n_layers: int) -> dict:
    """XLA prices a while-loop body once. With partial unroll u the body
    appears u times, so body = (F(u) - F(1)) / (u - 1) and the true total is
    F(1) + (L - 1) * body — exact for every additive metric."""
    out = {}
    for k in ("flops", "bytes_accessed", "transcendentals", "collective_total"):
        body = (mu[k] - m1[k]) / (u - 1)
        out[k] = m1[k] + (n_layers - 1) * max(body, 0.0)
    by_kind = {}
    kinds = set(m1["collective_by_kind"]) | set(mu["collective_by_kind"])
    for kk in kinds:
        a = m1["collective_by_kind"].get(kk, 0)
        b = mu["collective_by_kind"].get(kk, 0)
        body = (b - a) / (u - 1)
        by_kind[kk] = a + (n_layers - 1) * max(body, 0.0)
    out["collective_by_kind"] = by_kind
    out["collective_counts"] = m1["collective_counts"]
    return out


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path) -> dict:
    import jax

    from repro.launch import cells as cells_mod
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = cells_mod.build_cell(arch, shape, mesh)
    t_build = time.time() - t0

    t0 = time.time()
    lowered = cell.lower()
    t_lower = time.time() - t0
    del lowered

    metrics1, mem, t_compile = _measure(cell)
    loop_len = cells_mod.layer_loop_length(arch)
    accounting = "exact"
    if loop_len and loop_len > 1:
        u = cells_mod.small_divisor(loop_len)
        cell_u = cells_mod.build_cell(arch, shape, mesh, layer_unroll=u)
        metrics_u, _, t_compile_u = _measure(cell_u)
        metrics = _extrapolate(metrics1, metrics_u, u, loop_len)
        accounting = f"loop-differential(u={u}, L={loop_len})"
        t_compile += t_compile_u
    else:
        metrics = metrics1

    n_chips = mesh.devices.size
    flops = metrics["flops"]
    bytes_accessed = metrics["bytes_accessed"]
    terms = hlo_analysis.roofline_terms(
        hlo_flops=flops,
        hlo_bytes=bytes_accessed,
        collective_bytes_per_device=metrics["collective_total"],
        n_chips=n_chips,
    )

    record = {
        "arch": arch,
        "shape": shape,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "note": cell.note,
        "timings_s": {
            "build": t_build, "lower": t_lower, "compile": t_compile,
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_per_device_bytes": (
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_accessed,
            "transcendentals": metrics["transcendentals"],
            "accounting": accounting,
        },
        "collectives": {
            "per_device_bytes_by_kind": metrics["collective_by_kind"],
            "counts_by_kind": metrics["collective_counts"],
            "per_device_total_bytes": metrics["collective_total"],
        },
        "roofline": terms,
        "jax_version": jax.__version__,
    }

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "pod2" if multi_pod else "pod1"
    path = out_dir / f"{arch}__{shape}__{tag}.json"
    path.write_text(json.dumps(record, indent=2))
    print(
        f"[dryrun] {arch}/{shape} mesh={record['mesh']} OK  "
        f"compile={t_compile:.1f}s flops/dev={flops:.3e} "
        f"coll/dev={metrics['collective_total']:.3e}B "
        f"dominant={terms['dominant']} [{accounting}]"
    )
    return record


def run_all(multi_pod: bool, out_dir: pathlib.Path, only_missing: bool) -> int:
    """Run every cell in a subprocess (isolation: one bad cell can't take the
    sweep down; also resets XLA memory between 33B-param lowerings)."""
    from repro.configs import base as cfg_base  # light import; no jax devices

    failures = []
    tag = "pod2" if multi_pod else "pod1"
    for arch_id, spec in cfg_base.all_archs().items():
        for cell in spec.shapes:
            path = out_dir / f"{arch_id}__{cell.name}__{tag}.json"
            if only_missing and path.exists():
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch_id, "--shape", cell.name,
            ]
            if multi_pod:
                cmd.append("--multipod")
            print(f"[dryrun] >>> {arch_id}/{cell.name} ({tag})", flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch_id, cell.name))
                print(f"[dryrun] FAILED {arch_id}/{cell.name}", flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        return 1
    print("[dryrun] all cells passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.list:
        from repro.configs import base as cfg_base

        for arch_id, spec in cfg_base.all_archs().items():
            for cell in spec.shapes:
                print(f"{arch_id:24s} {cell.name:16s} {cell.kind}")
        return 0
    if args.all:
        return run_all(args.multipod, out_dir, args.only_missing)
    assert args.arch and args.shape, "--arch and --shape (or --all/--list)"
    run_one(args.arch, args.shape, args.multipod, out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
