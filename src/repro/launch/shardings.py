"""Parameter/activation sharding rules for every architecture family.

One table of (path-regex -> PartitionSpec template) per family, applied over
``jax.eval_shape`` trees — the single source of truth used by the dry-run,
the trainer and the server. Templates are written against logical axis names
(dp = 'data', tp = 'model'); the pod axis replicates parameters (DP across
pods) and shards batches.

Conventions (see DESIGN.md §5):
  * LM: FSDP over data + tensor-parallel over model; stacked-layer leading
    axis always unsharded; vocab padded so every sharded dim divides 16/256.
  * GNN: GAT parameters are KBs — replicated; the graph (inputs) shards.
  * RecSys: embedding tables row-sharded over (data x model); MLPs replicated.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Params = Any

# ----------------------------------------------------------------- LM rules

_LM_RULES: list[tuple[str, P]] = [
    (r"embed$", P("model", "data")),
    (r"lm_head$", P("data", "model")),
    (r"ln_", P()),
    (r"(q_norm|k_norm|kv_norm)$", P()),
    (r"layers/attn/(wq|wk|wv)$", P(None, "data", "model")),
    (r"layers/attn/(bq|bk|bv)$", P(None, "model")),
    (r"layers/attn/wo$", P(None, "model", "data")),
    (r"layers/attn/w_dkv$", P(None, "data", None)),
    (r"layers/attn/(w_uk|w_uv)$", P(None, None, "model")),
    (r"layers/moe/router$", P(None, "data", None)),
    (r"layers/moe/(w_gate|w_up)$", P(None, "model", "data", None)),
    (r"layers/moe/w_down$", P(None, "model", None, "data")),
    (r"layers/moe/shared/(w_gate|w_up)$", P(None, "data", "model")),
    (r"layers/moe/shared/w_down$", P(None, "model", "data")),
    (r"layers/ffn/(w_gate|w_up)$", P(None, "data", "model")),
    (r"layers/ffn/w_down$", P(None, "model", "data")),
]

# ------------------------------------------------------------- recsys rules

_RECSYS_RULES: list[tuple[str, P]] = [
    (r"(table|items|first_order)$", P(("data", "model"), None)),
    (r".*", P()),  # MLPs / norms / scalars replicated
]

_GNN_RULES: list[tuple[str, P]] = [(r".*", P())]

_FAMILY_RULES = {"lm": _LM_RULES, "recsys": _RECSYS_RULES, "gnn": _GNN_RULES}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for(family: str, path_str: str, leaf) -> P:
    for pat, spec in _FAMILY_RULES[family]:
        if re.search(pat, path_str):
            # Trim/extend the template to the leaf rank (scalars -> P()).
            entries = list(spec)
            if len(entries) > leaf.ndim:
                # Drop leading Nones first (stacked-layer templates applied to
                # unstacked leaves), then trailing.
                while len(entries) > leaf.ndim and entries and entries[0] is None:
                    entries.pop(0)
                entries = entries[: leaf.ndim]
            while len(entries) < leaf.ndim:
                entries.append(None)
            return P(*entries)
    return P()


def param_specs(family: str, params_shapes: Params) -> Params:
    """Pytree of PartitionSpec matching a params eval_shape tree."""

    def one(path, leaf):
        return spec_for(family, _path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def with_sharding(mesh, spec_tree: Params) -> Params:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def attach(shape_tree: Params, sharding_tree: Params) -> Params:
    """ShapeDtypeStructs with shardings attached (dry-run argument specs)."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree,
        sharding_tree,
    )


def train_state_specs(family: str, state_shapes) -> Params:
    """Specs for a TrainState: params/m/v share the param rules; step and
    error feedback follow params' structure."""
    p_spec = param_specs(family, state_shapes.params)
    opt_spec = {
        "m": p_spec,
        "v": jax.tree.map(lambda s: s, p_spec),
        "step": P(),
    }
    ef = state_shapes.error_feedback
    from repro.training.train_step import TrainState

    return TrainState(
        params=p_spec,
        opt=opt_spec,
        error_feedback=None if ef is None else jax.tree.map(lambda s: s, p_spec),
    )


def check_divisibility(shape_tree: Params, spec_tree: Params, mesh) -> list[str]:
    """Report leaves whose sharded dims don't divide the mesh axes (these
    would silently pad on real hardware — we require exact tiling)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    problems = []

    def one(path, leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            if leaf.shape[dim] % total != 0:
                problems.append(
                    f"{_path_str(path)}: dim{dim}={leaf.shape[dim]} "
                    f"not divisible by {axes}={total}"
                )

    jax.tree_util.tree_map_with_path(
        one, shape_tree, spec_tree,
    )
    return problems
