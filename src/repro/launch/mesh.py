"""Production mesh definitions.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import
and only then builds meshes.

Axis semantics:
  pod   — 2 pods of 256 chips (multi-pod only); replica/extra-DP axis
  data  — batch / FSDP / index-shard axis
  model — tensor / expert / sequence axis
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU tests (requires >= n_data*n_model host devices)."""
    return compat.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh: ('pod','data') or ('data',)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_devices(mesh) -> int:
    return mesh.devices.size
