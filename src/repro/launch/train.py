"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 200 --batch 8 --seq 128 [--smoke] [--ckpt-dir DIR] \
        [--compress-grads] [--resume]

Runs the same train step the dry-run lowers, on whatever devices exist
(1 CPU here; a real mesh in deployment via --mesh data,model=...). Includes
the fault-tolerance loop: periodic async checkpoints, resume-from-latest,
rolling retention.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import base as cfg_base
    from repro.models import transformer as tfm
    from repro.training import checkpoint as ckpt
    from repro.training import optimizer as opt_mod
    from repro.training import train_step as ts_mod
    from repro.training.data import LmBatches

    spec = cfg_base.get(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see examples/ for others"
    cfg = spec.smoke_config if args.smoke else spec.config

    key = jax.random.PRNGKey(0)
    params = tfm.init_lm(cfg, key)
    opt_cfg = opt_mod.AdamWConfig(
        lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
        schedule="wsd" if "minicpm" in args.arch else "cosine",
    )
    step_fn = jax.jit(ts_mod.make_train_step(
        lambda p, b: tfm.lm_loss(cfg, p, b),
        opt_cfg, compress_grads=args.compress_grads,
    ), donate_argnums=0)
    state = ts_mod.init_train_state(params, compress_grads=args.compress_grads)

    start = 0
    checkpointer = ckpt.AsyncCheckpointer()
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
        state, start = ckpt.restore_checkpoint(args.ckpt_dir, state)
        print(f"[train] resumed from step {start}")

    data = iter(LmBatches(vocab=cfg.vocab, batch=args.batch, seq=args.seq))
    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0:
            jax.block_until_ready(metrics["loss"])
            tps = tokens_done / (time.time() - t0)
            print(f"[train] step={step+1} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} tok/s={tps:.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpointer.save(args.ckpt_dir, step + 1, state)
            ckpt.prune_old(args.ckpt_dir, keep=3)
    checkpointer.wait()
    if args.ckpt_dir:
        ckpt.save_checkpoint(args.ckpt_dir, args.steps, state)
    print("[train] done")


if __name__ == "__main__":
    main()
