"""MCGI serving launcher — build (or load) a tiered index and serve batched
queries through the unified serving engine (:mod:`repro.serving`), reporting
the paper's operational metrics (QPS, recall if ground truth is available,
I/O per query, modelled SSD latency).

    PYTHONPATH=src python -m repro.launch.serve --dataset tiny-mixture \
        --beam 48 --batch 64 --num-batches 20 [--index PATH] [--online] \
        [--disk PATH] [--distributed N] [--kernel reference|pallas|auto] \
        [--adaptive [--l-min 16] [--l-max 64] [--lam 0.35] [--buckets auto] \
         [--pipeline] [--calibrate [--joint | --per-shard] \
          [--recall-target 0.95]]]

``--adaptive`` serves the per-query adaptive-beam engine (Prop. 4.2
deployed): each query's budget is set from its probe-phase LID, so easy
queries stop paying slow-tier reads for hard ones. ``--buckets`` controls
the continue phase's bucket family — ``auto`` (default) picks it per batch
from the granted-budget histogram, an integer pins the fixed family, 0/1
disables bucketing. ``--pipeline`` streams the batches through the
double-buffered executor (batch i+1's probe dispatched before batch i is
collected) instead of blocking per batch — identical results, higher
throughput. ``--calibrate`` refits ``lam`` (and ``hop_factor`` if binding)
to ``--recall-target`` on a held-out sample before serving; with ``--joint``
the budget floor ``l_min`` is fitted too (smallest feasible floor, then the
largest feasible lam at it). All serving paths — fixed and adaptive — lower
through :class:`repro.serving.SearchEngine`.

``--disk PATH`` serves the slow tier out of core: a block-aligned store
(one checksummed block per node: vector + adjacency) is written to PATH if
absent and the rerank fetches candidate blocks from it — through the
hot-node cache (entry-proximal nodes pinned) and, with ``--pipeline``, the
async-prefetch stage that overlaps batch i's block reads with batch i+1's
continue programs. Results are bit-identical to the in-memory slow tier;
the final report adds measured block-read latency next to the
``DiskTierModel``'s modelled figure plus the cache hit rate and fetch
latency percentiles. ``--cache-nodes`` / ``--pin-nodes`` size the LRU and
the statically pinned entry-proximal set; ``--hot-nodes`` (with
``--hot-chunk`` / ``--freq-decay``) adds the frequency-aware hot tier —
per-stream promotion/demotion counters are reported at the end; and
``--io-workers`` sizes the tier's prefetch pool.

``--serve`` (with ``--adaptive``) runs the closed-loop *front door* instead
of the batch benchmark: live requests are paced at ``--qps`` (Poisson or
bursty ``--arrival``), admitted into two QoS classes (``--interactive-frac``
splits the mix) with their own deadlines (``--deadline-ms`` /
``--batch-deadline-ms``) and their own budget-law engines over the shared
backend — with ``--calibrate``, one (lam, l_min) law per class is fitted to
``--interactive-recall-target`` / ``--recall-target``.  The report is
per-class: outcome counts, latency p50/p99 vs the deadline, recall, and the
per-class I/O counters (mean granted budget, walk hops).  Timing runs on
the production wall-clock seam (:class:`repro.serving.server.WallClock` +
``ThreadDispatcher``); the deterministic virtual-clock twin of this loop is
``benchmarks/serving_load.py``.

``--filter-frac F`` serves a multi-tenant workload: the corpus is split
into ~``1/F`` namespaces and every query carries an *allowed* mask for its
namespace, enforced in-graph (the packed filter pre-seeds the walk's
visited bitset — excluded nodes are never expanded and never returned, no
post-filtering). Recall is reported against the per-namespace ground truth
and the report counts out-of-filter results (must be 0). Single-host only:
the distributed backend has no global-id view for the bitset (see ROADMAP
carry-overs).

``--distributed N`` shards the dataset over N virtual host devices (one
locally built sub-graph per shard) and serves scatter-gather through a
``DistributedBackend``. With ``--adaptive`` the distributed step runs
*staged* at full engine parity — probe checkpointed at the horizon, host
bucket scheduling between mesh programs, per-bucket continues into the
hedged merge — so ``--pipeline`` overlaps batch i+1's distributed probe
with batch i's bucketing and continues. ``--calibrate --per-shard`` fits
one (lam, l_min) law per shard on shard-local held-out queries and serves
the laws as runtime arrays. Sets XLA_FLAGS itself, so run it as the
process entry point (the flag must precede the first jax import).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _distributed_engine(args, x, queries, budget_cfg, num_buckets):
    """Shard the dataset over the virtual mesh and build the distributed
    serving engine (staged at engine parity when adaptive; per-shard
    calibrated budget laws with --calibrate --per-shard). Returns
    (engine, x truncated to the sharded row count)."""
    import numpy as np

    from repro import compat, serving
    from repro.core import build, calibrate
    from repro.distributed import sharded_search as ss

    mesh = compat.make_mesh((args.distributed,), ("data",))
    n_shards = mesh.devices.size
    t0 = time.time()
    arrays, per = ss.build_sharded_arrays(
        x, mesh, build_cfg=build.BuildConfig(), m_pq=args.m_pq)
    print(f"[serve] sharded build in {time.time()-t0:.1f}s: "
          f"{per * n_shards} points over {n_shards} shards ({per}/shard)")
    shard_laws = None
    if args.calibrate:
        fit = calibrate.calibrate_budget_law_per_shard(
            calibrate.shard_exact_recall_evals(
                np.asarray(arrays["vectors"]), np.asarray(arrays["adj"]),
                np.asarray(arrays["entries"]), np.asarray(queries),
                n_shards, k=args.k, sample=args.calib_sample),
            budget_cfg, recall_target=args.recall_target,
            n_shards=n_shards)
        shard_laws = fit.law_arrays()
        # hop_factor is global in the step: serve the largest fitted
        # escalation so no shard runs under a tighter deadline than it was
        # calibrated to.
        budget_cfg = fit.serving_budget(budget_cfg)
        print(f"[serve] per-shard laws "
              f"({'hit' if fit.achieved else 'partial'}): "
              f"lam={np.round(shard_laws[0], 3).tolist()} "
              f"l_min={shard_laws[1].tolist()} "
              f"hop_factor={budget_cfg.hop_factor}")
    backend = serving.DistributedBackend(
        mesh, arrays, beam_width=args.beam, max_hops=2048, k=args.k,
        query_chunk=args.batch, beam_budget=budget_cfg,
        budget_buckets=(4 if budget_cfg is not None else None),
        shard_laws=shard_laws, step_kernel=args.kernel)
    engine = serving.SearchEngine(backend, budget_cfg, k=args.k,
                                  num_buckets=num_buckets)
    return engine, x[: per * n_shards]


def _report_disk_tier(backend, model) -> None:
    """Measured slow-tier figures next to the DiskTierModel's modelled ones
    (stats stay readable after engine close)."""
    st = backend.slow_tier.stats()
    lat = backend.slow_tier.fetch_latency_us()
    print(f"[serve] disk tier: hit_rate={st['hit_rate']:.3f} "
          f"(hits={st['cache_hits']} misses={st['cache_misses']}) "
          f"blocks_read={st['blocks_read']} "
          f"measured_read={st['measured_read_us']:.1f}us vs "
          f"modelled={model.read_latency_us:.1f}us "
          f"fetch p50={lat['fetch_p50_us']:.0f}us "
          f"p99={lat['fetch_p99_us']:.0f}us")
    if "hot_capacity" in st:
        print(f"[serve] hot tier: resident={st['hot_nodes']}"
              f"/{st['hot_capacity']} hot_hits={st['hot_hits']} "
              f"promotions={st['promotions']} "
              f"demotions={st['demotions']} "
              f"ticks={st['promotion_ticks']} "
              f"promotion_io_blocks={st['promotion_io_blocks']}")


def _serve_front_door(args, backend, index, queries, gt_i,
                      budget_cfg, num_buckets) -> None:
    """Closed-loop front-door serving on the wall clock: one budget-law
    engine per QoS class over the shared backend, arrival pacing at --qps,
    per-class SLO report."""
    import dataclasses

    import numpy as np

    from repro import serving
    from repro.core import calibrate
    from repro.serving import server as sv

    laws = {"interactive": budget_cfg,
            "batch": dataclasses.replace(budget_cfg,
                                         l_min=budget_cfg.l_max)}
    if args.calibrate:
        def make_eval(cfg):
            return calibrate.tiered_recall_eval(
                index, queries, np.asarray(gt_i), k=args.k,
                sample=args.calib_sample, base_cfg=cfg)

        fits = calibrate.calibrate_budget_law_per_class(
            make_eval, budget_cfg,
            {"interactive": args.interactive_recall_target,
             "batch": args.recall_target},
            joint=args.joint)
        laws = calibrate.class_budget_cfgs(fits, budget_cfg)
        for name, r in fits.items():
            print(f"[serve] class {name}: lam={r.lam:.4f} "
                  f"l_min={laws[name].l_min} recall={r.recall:.4f} "
                  f"({'hit' if r.achieved else 'MISSED'} {r.target:.2f})")
    lanes = {"interactive": 8, "batch": 32}
    engines = {name: serving.SearchEngine(backend, law, k=args.k,
                                          num_buckets=num_buckets)
               for name, law in laws.items()}
    classes = [
        sv.QoSClass("interactive", deadline_s=args.deadline_ms / 1e3,
                    batch_window_s=0.002, max_lanes=lanes["interactive"],
                    lane_quantum=lanes["interactive"]),
        sv.QoSClass("batch", deadline_s=args.batch_deadline_ms / 1e3,
                    batch_window_s=0.02, max_lanes=lanes["batch"],
                    lane_quantum=lanes["batch"]),
    ]
    qn = np.asarray(queries)
    for name, eng in engines.items():      # warm the padded dispatch shape
        eng.search(qn[:lanes[name]])
    rng = np.random.default_rng(0)
    n = args.requests
    if args.arrival == "poisson":
        arr = np.cumsum(rng.exponential(1.0 / args.qps, size=n))
    else:                                  # bursty: on/off modulated Poisson
        out, t, on, phase_end = [], 0.0, True, 0.05
        while len(out) < n:
            t += float(rng.exponential(
                1.0 / (args.qps * 8.0 if on else args.qps / 8.0)))
            if t >= phase_end:
                t, on = phase_end, not on
                phase_end += 0.05 if on else 0.2
            else:
                out.append(t)
        arr = np.asarray(out)
    rows = rng.integers(0, qn.shape[0], size=n)
    cls_of = ["interactive" if rng.random() < args.interactive_frac
              else "batch" for _ in range(n)]
    door = sv.FrontDoor(engines, classes)
    t0 = time.perf_counter()
    futs = []
    for t_arr, row, cls in zip(arr, rows, cls_of):
        lag = t_arr - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        futs.append((int(row), cls, door.submit(qn[row], cls=cls)))
    door.close(wait=True, timeout=600)
    wall = time.perf_counter() - t0
    gt = np.asarray(gt_i)
    print(f"[serve] front door: {n} requests in {wall:.2f}s "
          f"({n / wall:.1f} qps, offered {args.qps:.0f}, "
          f"arrival={args.arrival})")
    for c in classes:
        rs = [(row, f.result(timeout=0)) for row, cls, f in futs
              if cls == c.name]
        lat = [r.latency * 1e3 for _, r in rs if r.status != "shed"]
        ok = [(row, r) for row, r in rs if r.status == "ok"]
        counts: dict[str, int] = {}
        for _, r in rs:
            counts[r.status] = counts.get(r.status, 0) + 1
        rec = (float(np.mean([np.isin(r.ids, gt[row][: args.k]).mean()
                              for row, r in ok])) if ok else float("nan"))
        bud = (float(np.mean([r.budget for _, r in ok
                              if r.budget is not None]))
               if ok else float("nan"))
        hops = (float(np.mean([r.hops for _, r in ok
                               if r.hops is not None]))
                if ok else float("nan"))
        p50 = float(np.percentile(lat, 50)) if lat else float("nan")
        p99 = float(np.percentile(lat, 99)) if lat else float("nan")
        print(f"[serve] class {c.name}: {counts} "
              f"lat p50={p50:.1f}ms p99={p99:.1f}ms "
              f"(deadline {c.deadline_s * 1e3:.0f}ms) "
              f"recall@{args.k}={rec:.4f} meanL={bud:.1f} hops={hops:.1f}")
    st = door.stats()
    print(f"[serve] admission: submitted={st['submitted']} "
          f"admitted={st['admitted']} shed={st['shed']} "
          f"dispatches={st['dispatches']} "
          f"max_open={st['max_open_lanes']}/{door.max_queue}")


def buckets_arg(value: str):
    """--buckets accepts 'auto' (histogram-picked family) or an integer."""
    if value == "auto":
        return value
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected 'auto' or an integer, got {value!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny-mixture")
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-batches", type=int, default=10)
    ap.add_argument("--m-pq", type=int, default=8)
    ap.add_argument("--index", default=None, help="load/save index path")
    ap.add_argument("--disk", default=None, metavar="PATH",
                    help="serve the slow tier from a block-aligned on-disk "
                         "store at PATH (written there first if absent); "
                         "bit-identical results, real block I/O")
    ap.add_argument("--cache-nodes", type=int, default=4096,
                    help="with --disk: hot-node LRU capacity")
    ap.add_argument("--pin-nodes", type=int, default=256,
                    help="with --disk: statically pinned entry-proximal "
                         "node count (0 disables pinning)")
    ap.add_argument("--hot-nodes", type=int, default=0,
                    help="with --disk: capacity of the frequency-aware hot "
                         "tier (0 disables it); hot nodes are promoted in "
                         "chunks off the serving path and demoted as the "
                         "traffic's hot set drifts — results stay "
                         "bit-identical")
    ap.add_argument("--hot-chunk", type=int, default=256,
                    help="with --hot-nodes: max promotions per tick")
    ap.add_argument("--freq-decay", type=float, default=0.5,
                    help="with --hot-nodes: per-tick EMA decay of the "
                         "per-node access frequencies")
    ap.add_argument("--io-workers", type=int, default=None,
                    help="with --disk: prefetch worker threads (default: "
                         "1 for the rerank-only tier; the out-of-core "
                         "backend adopts its io_groups)")
    ap.add_argument("--online", action="store_true",
                    help="build with Online-MCGI (Algorithm 2)")
    ap.add_argument("--vamana", action="store_true",
                    help="baseline build (static alpha=1.2)")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-query adaptive beam budgets (Prop. 4.2)")
    ap.add_argument("--l-min", type=int, default=16)
    ap.add_argument("--l-max", type=int, default=None,
                    help="adaptive budget ceiling (default: --beam)")
    ap.add_argument("--lam", type=float, default=0.35)
    ap.add_argument("--buckets", default="auto", type=buckets_arg,
                    help="continue-phase bucket family: 'auto' (histogram-"
                         "picked, default), an integer count, or 0/1 for "
                         "the single-program path")
    ap.add_argument("--pipeline", action="store_true",
                    help="double-buffered batch stream (identical results, "
                         "higher throughput)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit lam to --recall-target on a held-out sample "
                         "before serving")
    ap.add_argument("--joint", action="store_true",
                    help="with --calibrate: fit (lam, l_min) jointly")
    ap.add_argument("--per-shard", action="store_true",
                    help="with --calibrate --distributed: fit one "
                         "(lam, l_min) law per shard on shard-local "
                         "held-out queries")
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--calib-sample", type=int, default=256)
    ap.add_argument("--filter-frac", type=float, default=None, metavar="F",
                    help="multi-tenant filtered serving: split the corpus "
                         "into ~1/F namespaces and enforce each query's "
                         "namespace in-graph (recall measured against the "
                         "filtered ground truth)")
    ap.add_argument("--serve", action="store_true",
                    help="closed-loop front-door serving (QoS classes, "
                         "deadlines, load shedding) instead of the batch "
                         "benchmark; requires --adaptive")
    ap.add_argument("--qps", type=float, default=200.0,
                    help="with --serve: offered arrival rate")
    ap.add_argument("--requests", type=int, default=256,
                    help="with --serve: total requests to pace in")
    ap.add_argument("--interactive-frac", type=float, default=0.5,
                    help="with --serve: fraction of requests in the "
                         "interactive class (rest are batch)")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="with --serve: interactive-class deadline")
    ap.add_argument("--batch-deadline-ms", type=float, default=2000.0,
                    help="with --serve: batch-class deadline")
    ap.add_argument("--arrival", default="poisson",
                    choices=("poisson", "bursty"),
                    help="with --serve: arrival process (bursty = on/off "
                         "modulated Poisson)")
    ap.add_argument("--interactive-recall-target", type=float, default=0.85,
                    help="with --serve --calibrate: interactive class's "
                         "recall target (--recall-target is the batch "
                         "class's)")
    ap.add_argument("--distributed", type=int, default=0, metavar="N",
                    help="shard over N virtual host devices and serve "
                         "scatter-gather (staged at engine parity with "
                         "--adaptive)")
    ap.add_argument("--kernel", default="auto",
                    choices=("reference", "pallas", "auto"),
                    help="beam-walk hop implementation: the reference hop "
                         "chain, the fused Pallas beam step (interpret mode "
                         "off-TPU), or auto (fused on TPU / under "
                         "REPRO_PALLAS_INTERPRET=1, reference otherwise; "
                         "default) — bit-identical results either way")
    args = ap.parse_args()
    num_buckets = args.buckets
    if not args.adaptive and (args.calibrate or args.pipeline
                              or (num_buckets != "auto" and num_buckets > 1)):
        ap.error("--calibrate/--buckets/--pipeline configure the adaptive "
                 "engine; pass --adaptive as well")
    if args.joint and not args.calibrate:
        ap.error("--joint refines --calibrate; pass both")
    if args.serve and not args.adaptive:
        ap.error("--serve runs per-class budget-law engines (and deadline "
                 "hedges need the staged probe); pass --adaptive")
    if args.serve and args.distributed:
        ap.error("--serve is the single-host front door (the distributed "
                 "backend has no host probe view for deadline partials)")
    if args.serve and args.pipeline:
        ap.error("--pipeline is the batch-stream benchmark mode; --serve "
                 "paces individual requests through the front door")
    if args.per_shard and not (args.calibrate and args.distributed):
        ap.error("--per-shard refines --calibrate for --distributed serving;"
                 " pass all three")
    if args.distributed and args.calibrate and not args.per_shard:
        ap.error("distributed calibration is per-shard (shard geometry "
                 "differs); pass --per-shard")
    if args.filter_frac is not None:
        if not (0.0 < args.filter_frac <= 1.0):
            ap.error("--filter-frac must be in (0, 1]")
        if args.distributed:
            ap.error("--filter-frac is single-host: the filter bitset is "
                     "indexed by global node id, which the sharded walk "
                     "has no view of")
        if args.serve:
            ap.error("--filter-frac drives the batch benchmark; the front "
                     "door paces unfiltered requests")
    if args.distributed and (args.index or args.online or args.vamana):
        ap.error("--distributed builds per-shard sub-graphs in process; "
                 "--index/--online/--vamana apply to single-host serving")
    if args.distributed and args.disk:
        ap.error("--disk is the single-host out-of-core slow tier; the "
                 "distributed path keeps per-shard slow tiers in memory")
    if args.distributed:
        if "jax" in sys.modules:
            ap.error("--distributed must set XLA_FLAGS before jax is "
                     "imported; run repro.launch.serve as the process "
                     "entry point")
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.distributed} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax.numpy as jnp
    import numpy as np

    from repro import serving
    from repro.core import build, distance, online, search
    from repro.data import make_dataset
    from repro.index import build_tiered_index, load_index, save_index
    from repro.index.disk import DiskTierModel

    x, queries = make_dataset(args.dataset, seed=0)
    import pathlib

    model = DiskTierModel()
    budget_cfg = None
    if args.adaptive:
        l_max = args.l_max or args.beam
        budget_cfg = search.AdaptiveBeamBudget(
            l_min=min(args.l_min, l_max), l_max=l_max, lam=args.lam)

    if args.distributed:
        engine, x = _distributed_engine(args, x, queries, budget_cfg,
                                        num_buckets)
        gt_d, gt_i = distance.brute_force_topk(queries, x, k=args.k)
        rerank_batch = budget_cfg.l_max if budget_cfg else args.beam
    else:
        if args.index and pathlib.Path(args.index).exists():
            index = load_index(args.index)
            print(f"[serve] loaded index: n={index.n}")
        else:
            cfg = build.BuildConfig()
            t0 = time.time()
            if args.online:
                graph = online.build_online_mcgi(x, cfg, progress=print)
            elif args.vamana:
                graph = build.build_vamana(x, 1.2, cfg, progress=print)
            else:
                graph = build.build_mcgi(x, cfg, progress=print)
            index = build_tiered_index(x, graph, m_pq=args.m_pq)
            print(f"[serve] built index in {time.time()-t0:.1f}s "
                  f"(fast tier {index.fast_tier_bytes()/1e6:.1f}MB, "
                  f"slow tier {index.slow_tier_bytes()/1e6:.1f}MB)")
            if args.index:
                save_index(args.index, index)

        gt_d, gt_i = distance.brute_force_topk(queries, x, k=args.k)
        slow_tier = None
        if args.disk:
            from repro.index import open_or_build_slow_tier

            slow_tier = open_or_build_slow_tier(
                args.disk, index, cache_nodes=args.cache_nodes,
                pin_nodes=args.pin_nodes, io_workers=args.io_workers,
                hot_nodes=args.hot_nodes, hot_chunk=args.hot_chunk,
                freq_decay=args.freq_decay,
                log=lambda m: print(f"[serve] {m}"))
            hot_part = (f" hot={args.hot_nodes} (chunk={args.hot_chunk} "
                        f"decay={args.freq_decay})" if args.hot_nodes else "")
            print(f"[serve] disk slow tier: n={slow_tier.store.n} "
                  f"block={slow_tier.store.block_size}B "
                  f"pinned={slow_tier.stats()['pinned_nodes']}" + hot_part)
        backend = serving.TieredBackend(index, slow_tier=slow_tier,
                                        step_kernel=args.kernel)
        if args.serve:
            _serve_front_door(args, backend, index, queries, gt_i,
                              budget_cfg, num_buckets)
            if args.disk:
                _report_disk_tier(backend, model)
            return
        if args.adaptive:
            engine = serving.SearchEngine(backend, budget_cfg, k=args.k,
                                          num_buckets=num_buckets)
            if args.calibrate:
                result = engine.recalibrate(
                    queries, gt_i, recall_target=args.recall_target,
                    joint=args.joint, sample=args.calib_sample)
                fitted = engine.budget_cfg
                print(f"[serve] calibrated lam={result.lam:.4f} "
                      f"l_min={fitted.l_min} hop_factor={result.hop_factor} "
                      f"recall={result.recall:.4f} "
                      f"(target {result.target:.2f}, "
                      f"{'hit' if result.achieved else 'MISSED'}, "
                      f"{len(result.history)} evals)")
            rerank_batch = engine.budget_cfg.l_max
        else:
            engine = serving.SearchEngine(backend, None, k=args.k,
                                          beam_width=args.beam)
            rerank_batch = args.beam

    # Warmup compile.
    _ = engine.search(queries[: args.batch])
    rng = np.random.default_rng(0)
    sels = [rng.integers(0, queries.shape[0], args.batch)
            for _ in range(args.num_batches)]
    qn = np.asarray(queries)
    batches = [qn[s] for s in sels]
    xn = np.asarray(x)
    masks = None
    gts = [np.asarray(gt_i)[s] for s in sels]
    out_of_filter = 0
    if args.filter_frac is not None:
        # Multi-tenant namespaces: each node lives in one of ~1/F tenants,
        # each query is allowed exactly its tenant's nodes.  Ground truth is
        # recomputed per batch inside the namespace — unfiltered gt would
        # mis-score a correctly filtered answer.
        tenants = max(2, round(1.0 / args.filter_frac))
        ns_rng = np.random.default_rng(1)
        node_ns = ns_rng.integers(0, tenants, size=xn.shape[0])
        masks, gts = [], []
        for s, qb in zip(sels, batches):
            q_ns = ns_rng.integers(0, tenants, size=qb.shape[0])
            allowed = node_ns[None, :] == q_ns[:, None]
            d2 = np.einsum("qnd,qnd->qn", qb[:, None] - xn[None],
                           qb[:, None] - xn[None], dtype=np.float32)
            d2[~allowed] = np.inf
            masks.append(allowed)
            gts.append(np.argsort(d2, axis=1)[:, : args.k])
        print(f"[serve] filtered serving: {tenants} namespaces "
              f"(~{xn.shape[0] // tenants} nodes each), masks enforced "
              f"in-graph")
        _ = engine.search(batches[0], filter=masks[0])  # warm filtered path
    lat_ms, recalls, ios, budgets = [], [], [], []

    def account(res, sel, t0, bi):
        nonlocal out_of_filter
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        recalls.append(float(distance.recall_at_k(
            jnp.asarray(res.ids), jnp.asarray(gts[bi]))))
        if masks is not None:
            ids = np.asarray(res.ids)
            ok = masks[bi][np.arange(ids.shape[0])[:, None],
                           np.maximum(ids, 0)] | (ids < 0)
            out_of_filter += int((~ok).sum())
        if res.stats is not None:
            ios.append(float(np.mean(np.asarray(res.stats.hops))))
        if res.astats is not None:
            budgets.append(float(np.mean(np.asarray(res.astats.budget))))

    t_all = time.perf_counter()
    if args.pipeline:
        # Double-buffered stream: per-batch latency is completion-to-
        # completion (the pipeline hides the probe sync inside it).
        t0 = t_all
        stream = engine.search_batches(batches, filter=masks)
        for bi, (res, sel) in enumerate(zip(stream, sels)):
            account(res, sel, t0, bi)
            t0 = time.perf_counter()
    else:
        for bi, (qb, sel) in enumerate(zip(batches, sels)):
            t0 = time.perf_counter()
            flt = None if masks is None else masks[bi]
            account(engine.search(qb, filter=flt), sel, t0, bi)
    total = time.perf_counter() - t_all
    if args.pipeline and len(lat_ms) > 1:
        # The first completion spans the whole pipeline fill (two batches
        # dispatched + scheduled before anything is gathered); keep it in
        # the throughput figure but not in the steady-state percentiles.
        lat_ms = lat_ms[1:]
    qps = args.batch * args.num_batches / total
    # The monolithic distributed step reports no hop counters (the staged
    # adaptive path does); skip the I/O-derived figures when absent.
    io_part = ssd_part = ""
    if ios:
        ssd_ms = float(model.latency_us(
            jnp.float32(np.mean(ios)), rerank_reads=rerank_batch,
            overlapped=args.pipeline)) / 1e3
        io_part = f"io/query={np.mean(ios):.1f} "
        ssd_part = f" ssd_model={ssd_ms:.2f}ms/query"
    extra = f"meanL={np.mean(budgets):.1f} " if budgets else ""
    mode = "pipelined" if args.pipeline else "per-batch"
    print(f"[serve] recall@{args.k}={np.mean(recalls):.4f} qps={qps:.1f} "
          f"{io_part}{extra}({mode}) "
          f"batch_lat p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms" + ssd_part)
    if masks is not None:
        print(f"[serve] filter enforcement: out_of_filter={out_of_filter} "
              f"(in-graph, must be 0)")
    if not args.distributed and args.disk:
        _report_disk_tier(backend, model)


if __name__ == "__main__":
    main()
