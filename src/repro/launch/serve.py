"""MCGI serving launcher — build (or load) a tiered index and serve batched
queries, reporting the paper's operational metrics (QPS, recall if ground
truth is available, I/O per query, modelled SSD latency).

    PYTHONPATH=src python -m repro.launch.serve --dataset tiny-mixture \
        --beam 48 --batch 64 --num-batches 20 [--index PATH] [--online] \
        [--adaptive [--l-min 16] [--l-max 64] [--lam 0.35] [--buckets 4] \
         [--calibrate [--recall-target 0.95]]]

``--adaptive`` switches to the per-query adaptive-beam engine
(Prop. 4.2 deployed): each query's budget is set from its probe-phase LID,
so easy queries stop paying slow-tier reads for hard ones. ``--buckets N``
runs the continue phase budget-bucketed: queries grouped by granted budget,
each bucket jitted to its own ceiling, so converged lanes free real compute
(identical results, lower wall-clock). ``--calibrate`` fits ``lam`` (and, if
needed, ``hop_factor``) to ``--recall-target`` on a held-out query sample
before serving, instead of trusting the ``--lam`` default.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny-mixture")
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-batches", type=int, default=10)
    ap.add_argument("--m-pq", type=int, default=8)
    ap.add_argument("--index", default=None, help="load/save index path")
    ap.add_argument("--online", action="store_true",
                    help="build with Online-MCGI (Algorithm 2)")
    ap.add_argument("--vamana", action="store_true",
                    help="baseline build (static alpha=1.2)")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-query adaptive beam budgets (Prop. 4.2)")
    ap.add_argument("--l-min", type=int, default=16)
    ap.add_argument("--l-max", type=int, default=None,
                    help="adaptive budget ceiling (default: --beam)")
    ap.add_argument("--lam", type=float, default=0.35)
    ap.add_argument("--buckets", type=int, default=0,
                    help="budget buckets for the continue phase "
                         "(0/1 = single-program path)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit lam to --recall-target on a held-out sample "
                         "before serving")
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--calib-sample", type=int, default=256)
    args = ap.parse_args()
    if not args.adaptive and (args.calibrate or args.buckets > 1):
        ap.error("--calibrate/--buckets configure the adaptive engine; "
                 "pass --adaptive as well")

    from repro.core import build, distance, online, search
    from repro.data import make_dataset
    from repro.index import build_tiered_index, load_index, save_index
    from repro.index.disk import (DiskTierModel, search_tiered,
                                  search_tiered_adaptive)

    x, queries = make_dataset(args.dataset, seed=0)
    import pathlib

    if args.index and pathlib.Path(args.index).exists():
        index = load_index(args.index)
        print(f"[serve] loaded index: n={index.n}")
    else:
        cfg = build.BuildConfig()
        t0 = time.time()
        if args.online:
            graph = online.build_online_mcgi(x, cfg, progress=print)
        elif args.vamana:
            graph = build.build_vamana(x, 1.2, cfg, progress=print)
        else:
            graph = build.build_mcgi(x, cfg, progress=print)
        index = build_tiered_index(x, graph, m_pq=args.m_pq)
        print(f"[serve] built index in {time.time()-t0:.1f}s "
              f"(fast tier {index.fast_tier_bytes()/1e6:.1f}MB, "
              f"slow tier {index.slow_tier_bytes()/1e6:.1f}MB)")
        if args.index:
            save_index(args.index, index)

    gt_d, gt_i = distance.brute_force_topk(queries, x, k=args.k)
    model = DiskTierModel()

    if args.adaptive:
        l_max = args.l_max or args.beam
        budget_cfg = search.AdaptiveBeamBudget(
            l_min=min(args.l_min, l_max), l_max=l_max, lam=args.lam)
        if args.calibrate:
            from repro.core import calibrate as calib

            result = calib.calibrate_budget_law(
                calib.tiered_recall_eval(
                    index, queries, gt_i, k=args.k,
                    sample=args.calib_sample),
                budget_cfg, args.recall_target)
            budget_cfg = result.budget_cfg(budget_cfg)
            print(f"[serve] calibrated lam={result.lam:.4f} "
                  f"hop_factor={result.hop_factor} "
                  f"recall={result.recall:.4f} "
                  f"(target {result.target:.2f}, "
                  f"{'hit' if result.achieved else 'MISSED'}, "
                  f"{len(result.history)} evals)")
        rerank_batch = budget_cfg.l_max
        num_buckets = args.buckets if args.buckets > 1 else None

        def run(qb):
            ids, d2, stats, astats = search_tiered_adaptive(
                index, qb, budget_cfg, k=args.k, num_buckets=num_buckets)
            return ids, stats, astats
    else:
        rerank_batch = args.beam

        def run(qb):
            ids, d2, stats = search_tiered(index, qb, beam_width=args.beam,
                                           k=args.k)
            return ids, stats, None

    # Warmup compile.
    _ = run(queries[: args.batch])
    lat_ms, recalls, ios, budgets = [], [], [], []
    rng = np.random.default_rng(0)
    t_all = time.time()
    for i in range(args.num_batches):
        sel = rng.integers(0, queries.shape[0], args.batch)
        qb = queries[sel]
        t0 = time.time()
        ids, stats, astats = run(qb)
        jax.block_until_ready(ids)
        lat_ms.append((time.time() - t0) * 1e3)
        recalls.append(float(distance.recall_at_k(ids, gt_i[sel])))
        ios.append(float(stats.hops.mean()))
        if astats is not None:
            budgets.append(float(astats.budget.mean()))
    total = time.time() - t_all
    qps = args.batch * args.num_batches / total
    ssd_ms = float(model.latency_us(
        jnp.float32(np.mean(ios)), rerank_reads=rerank_batch)) / 1e3
    extra = f"meanL={np.mean(budgets):.1f} " if budgets else ""
    print(f"[serve] recall@{args.k}={np.mean(recalls):.4f} qps={qps:.1f} "
          f"io/query={np.mean(ios):.1f} {extra}"
          f"batch_lat p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms "
          f"ssd_model={ssd_ms:.2f}ms/query")


if __name__ == "__main__":
    main()
