"""MCGI serving launcher — build (or load) a tiered index and serve batched
queries through the unified serving engine (:mod:`repro.serving`), reporting
the paper's operational metrics (QPS, recall if ground truth is available,
I/O per query, modelled SSD latency).

    PYTHONPATH=src python -m repro.launch.serve --dataset tiny-mixture \
        --beam 48 --batch 64 --num-batches 20 [--index PATH] [--online] \
        [--adaptive [--l-min 16] [--l-max 64] [--lam 0.35] [--buckets auto] \
         [--pipeline] [--calibrate [--joint] [--recall-target 0.95]]]

``--adaptive`` serves the per-query adaptive-beam engine (Prop. 4.2
deployed): each query's budget is set from its probe-phase LID, so easy
queries stop paying slow-tier reads for hard ones. ``--buckets`` controls
the continue phase's bucket family — ``auto`` (default) picks it per batch
from the granted-budget histogram, an integer pins the fixed family, 0/1
disables bucketing. ``--pipeline`` streams the batches through the
double-buffered executor (batch i+1's probe dispatched before batch i is
collected) instead of blocking per batch — identical results, higher
throughput. ``--calibrate`` refits ``lam`` (and ``hop_factor`` if binding)
to ``--recall-target`` on a held-out sample before serving; with ``--joint``
the budget floor ``l_min`` is fitted too (smallest feasible floor, then the
largest feasible lam at it). All serving paths — fixed and adaptive — lower
through :class:`repro.serving.SearchEngine`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def buckets_arg(value: str):
    """--buckets accepts 'auto' (histogram-picked family) or an integer."""
    if value == "auto":
        return value
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected 'auto' or an integer, got {value!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny-mixture")
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-batches", type=int, default=10)
    ap.add_argument("--m-pq", type=int, default=8)
    ap.add_argument("--index", default=None, help="load/save index path")
    ap.add_argument("--online", action="store_true",
                    help="build with Online-MCGI (Algorithm 2)")
    ap.add_argument("--vamana", action="store_true",
                    help="baseline build (static alpha=1.2)")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-query adaptive beam budgets (Prop. 4.2)")
    ap.add_argument("--l-min", type=int, default=16)
    ap.add_argument("--l-max", type=int, default=None,
                    help="adaptive budget ceiling (default: --beam)")
    ap.add_argument("--lam", type=float, default=0.35)
    ap.add_argument("--buckets", default="auto", type=buckets_arg,
                    help="continue-phase bucket family: 'auto' (histogram-"
                         "picked, default), an integer count, or 0/1 for "
                         "the single-program path")
    ap.add_argument("--pipeline", action="store_true",
                    help="double-buffered batch stream (identical results, "
                         "higher throughput)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit lam to --recall-target on a held-out sample "
                         "before serving")
    ap.add_argument("--joint", action="store_true",
                    help="with --calibrate: fit (lam, l_min) jointly")
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--calib-sample", type=int, default=256)
    args = ap.parse_args()
    num_buckets = args.buckets
    if not args.adaptive and (args.calibrate or args.pipeline
                              or (num_buckets != "auto" and num_buckets > 1)):
        ap.error("--calibrate/--buckets/--pipeline configure the adaptive "
                 "engine; pass --adaptive as well")
    if args.joint and not args.calibrate:
        ap.error("--joint refines --calibrate; pass both")

    from repro import serving
    from repro.core import build, distance, online, search
    from repro.data import make_dataset
    from repro.index import build_tiered_index, load_index, save_index
    from repro.index.disk import DiskTierModel

    x, queries = make_dataset(args.dataset, seed=0)
    import pathlib

    if args.index and pathlib.Path(args.index).exists():
        index = load_index(args.index)
        print(f"[serve] loaded index: n={index.n}")
    else:
        cfg = build.BuildConfig()
        t0 = time.time()
        if args.online:
            graph = online.build_online_mcgi(x, cfg, progress=print)
        elif args.vamana:
            graph = build.build_vamana(x, 1.2, cfg, progress=print)
        else:
            graph = build.build_mcgi(x, cfg, progress=print)
        index = build_tiered_index(x, graph, m_pq=args.m_pq)
        print(f"[serve] built index in {time.time()-t0:.1f}s "
              f"(fast tier {index.fast_tier_bytes()/1e6:.1f}MB, "
              f"slow tier {index.slow_tier_bytes()/1e6:.1f}MB)")
        if args.index:
            save_index(args.index, index)

    gt_d, gt_i = distance.brute_force_topk(queries, x, k=args.k)
    model = DiskTierModel()

    backend = serving.TieredBackend(index)
    if args.adaptive:
        l_max = args.l_max or args.beam
        budget_cfg = search.AdaptiveBeamBudget(
            l_min=min(args.l_min, l_max), l_max=l_max, lam=args.lam)
        engine = serving.SearchEngine(backend, budget_cfg, k=args.k,
                                      num_buckets=num_buckets)
        if args.calibrate:
            result = engine.recalibrate(
                queries, gt_i, recall_target=args.recall_target,
                joint=args.joint, sample=args.calib_sample)
            fitted = engine.budget_cfg
            print(f"[serve] calibrated lam={result.lam:.4f} "
                  f"l_min={fitted.l_min} hop_factor={result.hop_factor} "
                  f"recall={result.recall:.4f} "
                  f"(target {result.target:.2f}, "
                  f"{'hit' if result.achieved else 'MISSED'}, "
                  f"{len(result.history)} evals)")
        rerank_batch = engine.budget_cfg.l_max
    else:
        engine = serving.SearchEngine(backend, None, k=args.k,
                                      beam_width=args.beam)
        rerank_batch = args.beam

    # Warmup compile.
    _ = engine.search(queries[: args.batch])
    rng = np.random.default_rng(0)
    sels = [rng.integers(0, queries.shape[0], args.batch)
            for _ in range(args.num_batches)]
    qn = np.asarray(queries)
    batches = [qn[s] for s in sels]
    lat_ms, recalls, ios, budgets = [], [], [], []

    def account(res, sel, t0):
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        recalls.append(float(distance.recall_at_k(
            jnp.asarray(res.ids), gt_i[sel])))
        ios.append(float(np.mean(np.asarray(res.stats.hops))))
        if res.astats is not None:
            budgets.append(float(np.mean(np.asarray(res.astats.budget))))

    t_all = time.perf_counter()
    if args.pipeline:
        # Double-buffered stream: per-batch latency is completion-to-
        # completion (the pipeline hides the probe sync inside it).
        t0 = t_all
        for res, sel in zip(engine.search_batches(batches), sels):
            account(res, sel, t0)
            t0 = time.perf_counter()
    else:
        for qb, sel in zip(batches, sels):
            t0 = time.perf_counter()
            account(engine.search(qb), sel, t0)
    total = time.perf_counter() - t_all
    if args.pipeline and len(lat_ms) > 1:
        # The first completion spans the whole pipeline fill (two batches
        # dispatched + scheduled before anything is gathered); keep it in
        # the throughput figure but not in the steady-state percentiles.
        lat_ms = lat_ms[1:]
    qps = args.batch * args.num_batches / total
    ssd_ms = float(model.latency_us(
        jnp.float32(np.mean(ios)), rerank_reads=rerank_batch,
        overlapped=args.pipeline)) / 1e3
    extra = f"meanL={np.mean(budgets):.1f} " if budgets else ""
    mode = "pipelined" if args.pipeline else "per-batch"
    print(f"[serve] recall@{args.k}={np.mean(recalls):.4f} qps={qps:.1f} "
          f"io/query={np.mean(ios):.1f} {extra}({mode}) "
          f"batch_lat p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms "
          f"ssd_model={ssd_ms:.2f}ms/query")


if __name__ == "__main__":
    main()
