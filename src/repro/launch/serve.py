"""MCGI serving launcher — build (or load) a tiered index and serve batched
queries, reporting the paper's operational metrics (QPS, recall if ground
truth is available, I/O per query, modelled SSD latency).

    PYTHONPATH=src python -m repro.launch.serve --dataset tiny-mixture \
        --beam 48 --batch 64 --num-batches 20 [--index PATH] [--online]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny-mixture")
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-batches", type=int, default=10)
    ap.add_argument("--m-pq", type=int, default=8)
    ap.add_argument("--index", default=None, help="load/save index path")
    ap.add_argument("--online", action="store_true",
                    help="build with Online-MCGI (Algorithm 2)")
    ap.add_argument("--vamana", action="store_true",
                    help="baseline build (static alpha=1.2)")
    args = ap.parse_args()

    from repro.core import build, distance, online
    from repro.data import make_dataset
    from repro.index import build_tiered_index, load_index, save_index
    from repro.index.disk import DiskTierModel, search_tiered

    x, queries = make_dataset(args.dataset, seed=0)
    import pathlib

    if args.index and pathlib.Path(args.index).exists():
        index = load_index(args.index)
        print(f"[serve] loaded index: n={index.n}")
    else:
        cfg = build.BuildConfig()
        t0 = time.time()
        if args.online:
            graph = online.build_online_mcgi(x, cfg, progress=print)
        elif args.vamana:
            graph = build.build_vamana(x, 1.2, cfg, progress=print)
        else:
            graph = build.build_mcgi(x, cfg, progress=print)
        index = build_tiered_index(x, graph, m_pq=args.m_pq)
        print(f"[serve] built index in {time.time()-t0:.1f}s "
              f"(fast tier {index.fast_tier_bytes()/1e6:.1f}MB, "
              f"slow tier {index.slow_tier_bytes()/1e6:.1f}MB)")
        if args.index:
            save_index(args.index, index)

    gt_d, gt_i = distance.brute_force_topk(queries, x, k=args.k)
    model = DiskTierModel()

    # Warmup compile.
    _ = search_tiered(index, queries[: args.batch], beam_width=args.beam,
                      k=args.k)
    lat_ms, recalls, ios = [], [], []
    rng = np.random.default_rng(0)
    t_all = time.time()
    for i in range(args.num_batches):
        sel = rng.integers(0, queries.shape[0], args.batch)
        qb = queries[sel]
        t0 = time.time()
        ids, d2, stats = search_tiered(index, qb, beam_width=args.beam,
                                       k=args.k)
        jax.block_until_ready(ids)
        lat_ms.append((time.time() - t0) * 1e3)
        recalls.append(float(distance.recall_at_k(ids, gt_i[sel])))
        ios.append(float(stats.hops.mean()))
    total = time.time() - t_all
    qps = args.batch * args.num_batches / total
    print(f"[serve] recall@{args.k}={np.mean(recalls):.4f} qps={qps:.1f} "
          f"io/query={np.mean(ios):.1f} "
          f"batch_lat p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms "
          f"ssd_model={np.mean(ios)*model.read_latency_us/1e3:.2f}ms/query")


if __name__ == "__main__":
    main()
