"""Version compatibility for the jax API surface this repo targets.

The serving/distributed code is written against the current jax API
(``jax.shard_map``, ``jax.sharding.AxisType``); older runtimes (<= 0.4.x)
ship the same functionality under ``jax.experimental.shard_map`` with the
``check_rep`` spelling and have no mesh axis types. Routing every use
through this module keeps the rest of the codebase on the modern spelling.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: axis types are part of the public sharding API.
    from jax.sharding import AxisType as _AxisType
except ImportError:
    _AxisType = None


def make_mesh(shape, names):
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    if _AxisType is not None:
        return jax.make_mesh(shape, names,
                             axis_types=(_AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict.

    Older jax returns a one-element list of per-computation dicts; newer jax
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
