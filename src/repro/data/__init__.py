from repro.data.synthetic import (  # noqa: F401
    DatasetSpec,
    gaussian_subspace_clusters,
    make_dataset,
    mixture_of_manifolds,
    swiss_roll_hd,
    uniform_hypercube,
)
