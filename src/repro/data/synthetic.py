"""Synthetic datasets with controllable manifold geometry.

The paper's story is about the gap between ambient dimensionality D and Local
Intrinsic Dimensionality: SIFT (D=128, LID~14), GIST (D=960, LID~22), T2I
(D=200, LID~18, heterogeneous). Offline benchmarks here use generators whose
*true* intrinsic dimensionality is known, so (a) the LID estimator can be
validated quantitatively and (b) the MCGI-vs-Vamana comparison can be run on
geometry the technique targets (heterogeneous-LID mixtures) and on geometry it
should be neutral on (uniform low-LID), mirroring RQ1's two regimes.

Every generator returns float32 (N, D) plus a disjoint query set.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def _random_rotation(key: Array, d: int) -> Array:
    a = jax.random.normal(key, (d, d))
    q, r = jnp.linalg.qr(a)
    return q * jnp.sign(jnp.diag(r))[None, :]


def uniform_hypercube(key: Array, n: int, d: int) -> Array:
    """Uniform ambient-dimensional data: LID ~= D everywhere (worst case)."""
    return jax.random.uniform(key, (n, d), dtype=jnp.float32)


def gaussian_subspace_clusters(
    key: Array,
    n: int,
    d_ambient: int,
    d_intrinsic: int,
    n_clusters: int = 16,
    noise: float = 0.01,
) -> Array:
    """Points on ``n_clusters`` random ``d_intrinsic``-dim affine subspaces
    embedded in ``d_ambient`` dims + isotropic noise.  True LID ~= d_intrinsic.
    """
    keys = jax.random.split(key, 4)
    per = n // n_clusters + 1
    basis = jax.random.normal(keys[0], (n_clusters, d_ambient, d_intrinsic))
    basis = basis / jnp.linalg.norm(basis, axis=1, keepdims=True)
    centers = jax.random.normal(keys[1], (n_clusters, d_ambient)) * 4.0
    coeff = jax.random.normal(keys[2], (n_clusters, per, d_intrinsic))
    pts = jnp.einsum("cdi,cpi->cpd", basis, coeff) + centers[:, None, :]
    pts = pts.reshape(-1, d_ambient)[:n]
    pts = pts + noise * jax.random.normal(keys[3], pts.shape)
    return pts.astype(jnp.float32)


def swiss_roll_hd(key: Array, n: int, d_ambient: int, noise: float = 0.01) -> Array:
    """Classic 2-manifold (swiss roll) rotated into ``d_ambient`` dims —
    high curvature, LID ~= 2; geodesic != Euclidean (the paper's §1 mismatch)."""
    k1, k2, k3 = jax.random.split(key, 3)
    t = 1.5 * jnp.pi * (1.0 + 2.0 * jax.random.uniform(k1, (n,)))
    h = 21.0 * jax.random.uniform(k2, (n,))
    roll = jnp.stack([t * jnp.cos(t), h, t * jnp.sin(t)], axis=1) / 10.0
    pad = jnp.zeros((n, d_ambient - 3))
    x = jnp.concatenate([roll, pad], axis=1)
    rot = _random_rotation(k3, d_ambient)
    x = x @ rot + noise * jax.random.normal(k3, (n, d_ambient))
    return x.astype(jnp.float32)


def mixture_of_manifolds(
    key: Array,
    n: int,
    d_ambient: int,
    intrinsic_dims: tuple[int, ...] = (2, 8, 24),
    noise: float = 0.01,
) -> Array:
    """Heterogeneous-LID mixture — the geometry MCGI is designed for
    (flat regions where alpha can relax, complex regions where it must not).
    """
    parts = []
    keys = jax.random.split(key, len(intrinsic_dims))
    per = n // len(intrinsic_dims)
    for i, (kk, di) in enumerate(zip(keys, intrinsic_dims)):
        m = per if i < len(intrinsic_dims) - 1 else n - per * (len(intrinsic_dims) - 1)
        parts.append(
            gaussian_subspace_clusters(
                kk, m, d_ambient, di, n_clusters=max(2, 8 // (i + 1)), noise=noise
            )
        )
    return jnp.concatenate(parts, axis=0)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """A named benchmark dataset: proxy for one of the paper's five."""

    name: str
    n: int
    d: int
    n_queries: int
    generator: Callable[[Array, int, int], Array]
    description: str = ""


def _gist_like(key, n, d):
    return mixture_of_manifolds(key, n, d, intrinsic_dims=(4, 12, 32))


def _sift_like(key, n, d):
    return gaussian_subspace_clusters(key, n, d, d_intrinsic=14, n_clusters=32)


def _glove_like(key, n, d):
    x = gaussian_subspace_clusters(key, n, d, d_intrinsic=18, n_clusters=64)
    return x / (jnp.linalg.norm(x, axis=1, keepdims=True) + 1e-9)


def _t2i_like(key, n, d):
    return mixture_of_manifolds(key, n, d, intrinsic_dims=(6, 18, 40))


REGISTRY: dict[str, DatasetSpec] = {
    # Reduced-N proxies of the paper's five benchmarks (full-D where feasible
    # on this host; billion-scale N is exercised via the dry-run).
    "sift1m-proxy": DatasetSpec("sift1m-proxy", 100_000, 128, 1000, _sift_like,
                                "SIFT1M proxy: D=128, moderate homogeneous LID"),
    "glove-proxy": DatasetSpec("glove-proxy", 100_000, 100, 1000, _glove_like,
                               "GloVe-100 proxy: unit-norm, D=100"),
    "gist1m-proxy": DatasetSpec("gist1m-proxy", 50_000, 960, 500, _gist_like,
                                "GIST1M proxy: D=960, heterogeneous high LID"),
    "sift1b-proxy": DatasetSpec("sift1b-proxy", 200_000, 128, 1000, _sift_like,
                                "SIFT1B reduced-N proxy (PQ + two-tier path)"),
    "t2i-proxy": DatasetSpec("t2i-proxy", 200_000, 200, 1000, _t2i_like,
                             "T2I-1B reduced-N proxy: cross-modal-like mixture"),
    # Small variants for tests.
    "tiny-mixture": DatasetSpec("tiny-mixture", 4000, 64, 100, _gist_like,
                                "test-scale heterogeneous mixture"),
    "tiny-uniform": DatasetSpec("tiny-uniform", 2000, 32, 100,
                                lambda k, n, d: uniform_hypercube(k, n, d),
                                "test-scale uniform cube"),
}


def make_dataset(spec: DatasetSpec | str, seed: int = 0) -> tuple[Array, Array]:
    """Returns (base, queries).

    Base and queries are split from one draw so queries lie on the *same*
    manifolds as the base set (generators with random subspaces would
    otherwise place queries off-manifold).
    """
    if isinstance(spec, str):
        spec = REGISTRY[spec]
    key = jax.random.PRNGKey(seed)
    kg, ks = jax.random.split(key)
    pool = spec.generator(kg, spec.n + spec.n_queries, spec.d)
    perm = jax.random.permutation(ks, pool.shape[0])
    pool = pool[perm]
    return pool[: spec.n], pool[spec.n :]
