"""Product Quantisation codebook training (Jegou et al. [19]; paper Table 2
uses m_PQ = 16 bytes at billion scale).

Splits D dims into M contiguous subspaces of D/M dims and trains a K=256
centroid k-means codebook per subspace; a vector's code is its per-subspace
nearest-centroid ids — M bytes per vector in the fast tier.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.ivf import kmeans

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PqCodebook:
    """centroids: (M, K, dsub).  D = M * dsub; K <= 256 so codes fit uint8."""

    centroids: Array

    @property
    def m(self) -> int:
        return self.centroids.shape[0]

    @property
    def k(self) -> int:
        return self.centroids.shape[1]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]


def split_subspaces(x: Array, m: int) -> Array:
    """(N, D) -> (M, N, dsub). D must be divisible by M (configs guarantee;
    odd dims are padded by the caller)."""
    n, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    return x.reshape(n, m, d // m).transpose(1, 0, 2)


def train_pq(
    x: Array, m: int = 16, k: int = 256, iters: int = 8, seed: int = 0,
    sample: int | None = 65536,
) -> PqCodebook:
    """Train per-subspace codebooks on (a sample of) the dataset."""
    n = x.shape[0]
    if sample is not None and n > sample:
        idx = jax.random.choice(jax.random.PRNGKey(seed), n, (sample,), replace=False)
        x = x[idx]
    subs = split_subspaces(x, m)  # (M, N', dsub)
    books = []
    for j in range(m):
        books.append(
            kmeans(subs[j], k=k, iters=iters, key=jax.random.PRNGKey(seed + 31 * j))
        )
    return PqCodebook(centroids=jnp.stack(books))
