from repro.pq.codebook import PqCodebook, train_pq  # noqa: F401
from repro.pq.adc import build_lut, adc_distances  # noqa: F401
from repro.pq.encode import pq_encode, pq_decode  # noqa: F401
