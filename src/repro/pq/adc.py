"""Asymmetric Distance Computation (ADC).

For a query q the per-subspace distance table

    LUT[m, c] = || q_m - centroid[m, c] ||^2           (M, K)

turns every approximate distance into M byte-indexed lookups:

    d2_hat(q, x_i) = sum_m LUT[m, code_i[m]].

On CPU this is the AVX2 hot loop of DiskANN; the TPU-native form is either a
VMEM gather (small fan-out, used inside beam search) or the one-hot matmul
``onehot(codes) @ LUT`` which feeds the MXU for bulk scans — that variant is
the Pallas kernel ``repro.kernels.pq_scan``; this module is its jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.pq.codebook import PqCodebook

Array = jax.Array


@jax.jit
def build_lut(queries: Array, centroids: Array) -> Array:
    """(Q, D), (M, K, dsub) -> (Q, M, K) squared-distance tables."""
    # Explicit dsub (not -1): a zero-query batch has size 0, which -1
    # inference can't divide through.
    q_subs = queries.reshape(queries.shape[0], centroids.shape[0],
                             centroids.shape[2])  # (Q,M,dsub)
    diff = q_subs[:, :, None, :] - centroids[None, :, :, :]  # (Q,M,K,dsub)
    return jnp.sum(diff * diff, axis=-1)


@jax.jit
def adc_distances(luts: Array, codes: Array) -> Array:
    """(Q, M, K) LUTs x (N, M) codes -> (Q, N) approximate distances.

    Gather formulation (oracle). The Pallas kernel computes the same via
    one-hot matmul per 128-row code tile.
    """
    c = codes.astype(jnp.int32)  # (N, M)
    m = luts.shape[1]

    def per_query(lut):  # lut (M, K)
        gathered = lut[jnp.arange(m)[None, :], c]  # (N, M)
        return gathered.sum(axis=-1)

    return jax.vmap(per_query)(luts)


@functools.partial(jax.jit, static_argnames=("k",))
def adc_topk(luts: Array, codes: Array, k: int) -> tuple[Array, Array]:
    """Bulk ADC scan + top-k (the retrieval_cand serving primitive)."""
    d = adc_distances(luts, codes)
    vals, ids = jax.lax.top_k(-d, k)
    return -vals, ids.astype(jnp.int32)
