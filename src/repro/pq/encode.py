"""Vector <-> PQ code transforms."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.pq.codebook import PqCodebook, split_subspaces

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("chunk",))
def _encode_chunked(x: Array, centroids: Array, chunk: int = 16384) -> Array:
    m, k, dsub = centroids.shape
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))

    def enc_chunk(xs):
        subs = xs.reshape(xs.shape[0], m, dsub).transpose(1, 0, 2)  # (M, c, dsub)

        def per_sub(sub, cb):
            d2 = (
                jnp.sum(sub * sub, axis=1, keepdims=True)
                - 2.0 * sub @ cb.T
                + jnp.sum(cb * cb, axis=1)[None, :]
            )
            return jnp.argmin(d2, axis=1).astype(jnp.uint8)

        return jax.vmap(per_sub)(subs, centroids).T  # (c, M)

    chunks = xp.reshape(-1, chunk, x.shape[1])
    codes = jax.lax.map(enc_chunk, chunks)
    return codes.reshape(-1, m)[:n]


def pq_encode(x: Array, book: PqCodebook, chunk: int = 16384) -> Array:
    """(N, D) -> (N, M) uint8 codes."""
    return _encode_chunked(x, book.centroids, chunk=chunk)


def pq_decode(codes: Array, book: PqCodebook) -> Array:
    """(N, M) codes -> (N, D) reconstructed vectors (codebook centroids)."""
    m = book.m
    gathered = jax.vmap(
        lambda j: book.centroids[j][codes[:, j].astype(jnp.int32)], out_axes=1
    )(jnp.arange(m))  # (N, M, dsub)
    return gathered.reshape(codes.shape[0], -1)
