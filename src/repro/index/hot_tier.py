"""Frequency-aware hot tier: chunked async promotion/demotion of graph nodes.

Under real serving traffic (Zipfian, millions of users) the hot node set
drifts — a static pinned set + LRU (:class:`repro.index.disk.BlockSlowTier`)
cannot follow it.  This module adds the missing policy, following the
CacheEmbedding shape (a frequency-tracking manager that promotes hot rows
into a fast tier in chunks and evicts cold ones), applied to graph nodes:

* **per-node EMA frequency** — the tier's exact per-fetch distinct-id
  counting (PR 5) feeds ``freq[id] += 1``; every promotion tick halves the
  whole array (``freq *= decay``), so the score is an exponential moving
  average of access counts and old traffic ages out.  A shifted hot set
  therefore *overtakes* the old one instead of fighting a monotone counter.
* **dense hot storage** — promoted records live in preallocated arrays
  (``vectors (capacity, D)``, ``adj (capacity, R)``) with O(1) membership
  (``slot[id]`` — slot index or -1) probed on the tier's fetch path between
  the pinned set and the LRU.  A hot hit costs two array reads, no dict,
  no block I/O.
* **chunked async promotion/demotion** — :meth:`HotTier.submit_tick` runs
  one tick on the tier's *own* single-thread promoter (never the prefetch
  pool, so a promotion chunk can never queue ahead of a serving prefetch):
  snapshot + decay the frequencies, select up to ``chunk`` hottest
  non-resident nodes, read their records through a *private*
  :class:`~repro.index.blockstore.BlockStore` handle (promotion I/O shares
  neither the serving ``_io_lock`` nor the serving I/O counters — a fetch
  never waits on a promotion read, and ``blocks_read`` stays exact for the
  serving stream), and install them under the shared cache lock (a bounded
  memcpy — no I/O is ever done under the lock).  Demotion is metadata-only:
  records are immutable, so clearing ``slot[old]`` just changes *where* the
  next fetch reads the same bytes — search results stay bit-identical by
  construction.
* **hysteresis** — a resident node is only demoted for a strictly-hotter
  candidate (by the same frequency snapshot), so ties never thrash the
  tier; statically pinned ids are excluded from promotion (they already
  live in the fastest probe).

Device mirror (``device_mirror=True``): after each tick the hot arrays are
re-uploaded as jax device arrays (``device_vectors`` / ``device_adj`` /
``device_node_of``) — the steering-side fast tier a fused out-of-core hop
would index instead of host memory.  Off by default: on this CPU testbed
the upload costs more than the host probe saves, and wiring the OOC hop to
consume it is hardware-gated (see ROADMAP).
"""
from __future__ import annotations

import concurrent.futures
import threading

import numpy as np

from repro.index.blockstore import BlockStore


class HotTier:
    """Frequency-tracked fast tier above a :class:`BlockSlowTier`'s LRU.

    Owned by the tier: shares its cache lock (``lock``), probes on its fetch
    path, and is ticked via :meth:`submit_tick` from the serving engine's
    gather stage.  All mutation of residency (``slot`` / ``node_of`` / the
    record arrays) happens on the single promoter thread, under the shared
    lock only for the install memcpy — so fetches either see the old
    location (LRU/miss) or the new one (hot), both serving identical bytes.
    """

    def __init__(self, store: BlockStore, n: int, capacity: int, *,
                 chunk: int = 256, decay: float = 0.5,
                 lock: threading.Lock, exclude_ids=None,
                 device_mirror: bool = False):
        self.store = store              # private handle: promotion I/O only
        self.capacity = int(capacity)
        self.chunk = max(1, int(chunk))
        self.decay = float(decay)
        self._lock = lock               # shared with the owning BlockSlowTier
        self.device_mirror = bool(device_mirror)
        # Per-node EMA access frequency (written under the shared lock by
        # the tier's fetch path; snapshotted + decayed at each tick).
        self.freq = np.zeros(n, np.float32)
        # Membership: node id -> hot slot (-1 absent) and the inverse map.
        self.slot = np.full(n, -1, np.int32)
        self.node_of = np.full(self.capacity, -1, np.int64)
        self.vectors = np.zeros((self.capacity, store.d), np.float32)
        self.adj = np.full((self.capacity, store.r), -1, np.int32)
        self._excluded = (np.unique(np.asarray(exclude_ids, np.int64))
                          if exclude_ids is not None else
                          np.empty(0, np.int64))
        self.n_hot = 0
        self.hot_hits = 0
        self.promotions = 0
        self.demotions = 0
        self.ticks = 0
        self.device_vectors = None
        self.device_adj = None
        self.device_node_of = None
        self._pool = None               # lazy: tiers that never tick stay free
        self._closed = False

    # ------------------------------------------------------------- lifecycle

    def submit_tick(self) -> "concurrent.futures.Future":
        """Enqueue one promotion tick on the promoter thread (the caller —
        :meth:`BlockSlowTier.promotion_tick` — holds the shared lock and has
        already checked there is no tick in flight)."""
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="hot-tier-promoter")
        return self._pool.submit(self._tick)

    def close(self, wait: bool = True) -> None:
        """Shut down the promoter thread (idempotent); a tick in flight
        completes first when ``wait``.  Residency stays probe-able — only
        future ticks are refused."""
        pool, self._pool = self._pool, None
        self._closed = True
        if pool is not None:
            pool.shutdown(wait=wait)

    # ------------------------------------------------------------- the tick

    def _tick(self) -> int:
        """One promotion round; returns the number of nodes promoted.

        Runs entirely on the promoter thread.  ``slot`` / ``node_of`` are
        only ever written here, so the selection below reads them without
        the lock; the lock guards just the frequency snapshot+decay and the
        final install memcpy.
        """
        with self._lock:
            snap = self.freq.copy()
            self.freq *= self.decay
            self.ticks += 1
        if self._excluded.size:
            snap[self._excluded] = 0.0
        n = snap.shape[0]
        cap = self.capacity
        # Hottest `cap` candidates with nonzero score, hottest first.
        if n > cap:
            top = np.argpartition(-snap, cap - 1)[:cap]
        else:
            top = np.arange(n)
        top = top[snap[top] > 0.0]
        top = top[np.argsort(-snap[top], kind="stable")]
        cand = top[self.slot[top] < 0][:self.chunk].astype(np.int64)
        if cand.size == 0:
            return 0
        free_slots = np.nonzero(self.node_of < 0)[0]
        n_free = min(free_slots.size, cand.size)
        victim_slots = np.empty(0, np.int64)
        need = cand.size - n_free
        if need > 0:
            resident = np.nonzero(self.node_of >= 0)[0]
            coldest = resident[np.argsort(snap[self.node_of[resident]],
                                          kind="stable")][:need]
            extra = cand[n_free:]
            # Hysteresis: pair the hottest extras with the coldest
            # residents; keep a pair only if strictly hotter.  Both sides
            # are sorted, so `keep` is a true prefix.
            keep = snap[extra] > snap[self.node_of[coldest]]
            k = int(keep.size if keep.all() else keep.argmin())
            victim_slots = coldest[:k].astype(np.int64)
            cand = np.concatenate([cand[:n_free], extra[:k]])
        if cand.size == 0:
            return 0
        slots = np.concatenate(
            [free_slots[:n_free].astype(np.int64), victim_slots])
        # Promotion I/O on the private store handle — off the serving path.
        vecs, adjs = self.store.read_many(cand)
        with self._lock:
            old = self.node_of[slots]
            demoted = old[old >= 0]
            if demoted.size:
                self.slot[demoted] = -1
            self.vectors[slots] = vecs
            self.adj[slots] = adjs
            self.node_of[slots] = cand
            self.slot[cand] = slots
            self.n_hot += int(cand.size) - int(demoted.size)
            self.promotions += int(cand.size)
            self.demotions += int(demoted.size)
        if self.device_mirror:
            self._upload()
        return int(cand.size)

    def _upload(self) -> None:
        """Refresh the device-resident mirror of the hot arrays (steering
        fast tier for a fused OOC hop; see the module docstring)."""
        import jax.numpy as jnp

        with self._lock:
            v, a, ids = (self.vectors.copy(), self.adj.copy(),
                         self.node_of.copy())
        self.device_vectors = jnp.asarray(v)
        self.device_adj = jnp.asarray(a)
        self.device_node_of = jnp.asarray(ids)

    # ---------------------------------------------------------- observability

    def stats(self) -> dict:
        """Promotion counters (caller holds the shared lock — this is read
        from :meth:`BlockSlowTier.stats` at every pipeline gather).
        Promotion I/O is reported from the private store handle, so it never
        pollutes the serving stream's ``blocks_read`` / ``io_blocks``."""
        return {
            "hot_capacity": self.capacity,
            "hot_nodes": self.n_hot,
            "hot_hits": self.hot_hits,
            "promotions": self.promotions,
            "demotions": self.demotions,
            "promotion_ticks": self.ticks,
            "promotion_io_blocks": self.store.stats.io_blocks,
            "promotion_read_time_s": self.store.stats.read_time_s,
        }

    def reset_stats(self) -> None:
        """Zero the counters (caller holds the shared lock).  Residency and
        the frequency EMA are *state*, not stats — they survive."""
        self.hot_hits = self.promotions = self.demotions = self.ticks = 0
        self.store.reset_stats()
