"""Live index mutation: the in-memory delta-graph tier + merge lifecycle.

The serving stack of PRs 3-9 is read-only: an index is built offline
(:func:`repro.core.build.build_mcgi` / :func:`repro.core.online.build_online_mcgi`),
published to a block store, and served immutably.  This module adds the
write path as an LSM-style two-tier structure:

  base tier   : the last *published* index — immutable; served by the normal
                :class:`repro.serving.SearchEngine` (PQ-routed walk +
                slow-tier rerank, :class:`~repro.index.disk.BlockSlowTier`
                keeps serving reads throughout).
  delta tier  : an in-memory overlay (:class:`DeltaTier`) absorbing inserts
                and deletes.  Inserts are wired into a private *combined*
                graph (base adjacency + rows for the new nodes) through
                Online-MCGI's ``_rewire_batch_online`` — each inserted
                node's neighbourhood is found by a greedy search towards its
                own vector, its LID estimated on the fly from that candidate
                pool, and its edges alpha-pruned with the node-specific
                ``alpha(u)``; new edges are mirrored with re-pruning of the
                destinations (:func:`repro.core.build._insert_reverse`).
                This is the NSG/Vamana lesson applied online: edge *quality*
                is repaired as the graph mutates, never just appended.
                Deletes are a tombstone set — nothing is unlinked eagerly.

Searches fan out over both tiers (:meth:`LiveIndex.search`): the base
engine runs with the base tombstones excluded *in-graph* (the packed filter
pre-seeds the walk's visited bitset — see
:func:`repro.core.search.pack_filter`), the delta tier contributes its
exact top-k over the live inserted vectors (a memtable scan — exact and
deterministic, the right call while the delta is merge-bounded), and the
two candidate pools — disjoint by construction — merge through the normal
full-precision rerank (:func:`repro.core.search._rerank_from_vecs`).

Periodic merge (:meth:`LiveIndex.merge`) compacts live content into a new
base: a deterministic from-scratch :func:`build_online_mcgi` over the live
rows in insertion order (bit-reproducible — the ragged-batch scatters are
pad-masked), a fresh PQ fast tier, a block-aware
:func:`~repro.core.prune.greedy_block_pack` layout, and an atomic
tmp-rename store publish (:func:`repro.index.blockstore.write_block_store`)
under a *generation-numbered* path — readers of the old store are never
torn.  The live engine swaps via ``update_backend`` (each in-flight request
finishes against its dispatch-time backend snapshot; see
:class:`repro.serving.engine._InFlight`), with an optional drift-triggered
``recalibrate`` when the merged population's mean LID moved.  At a merge
boundary (empty delta, no tombstones) :meth:`LiveIndex.search` serves the
engine's result directly, so it is bit-identical to a freshly built index
of the same live content.

External ids are stable across merges: every insert gets a monotonically
increasing id; compaction keeps live rows in insertion order, so the
``ext_of`` map stays sorted and delete-by-external-id is a binary search.
"""
from __future__ import annotations

import dataclasses
import pathlib
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import online as online_mod
from repro.core import search as search_mod
from repro.core.types import GraphIndex

Array = jax.Array
INVALID = build_mod.INVALID


class DeltaTier:
    """In-memory mutable overlay over an immutable base :class:`GraphIndex`.

    Holds the *combined* state: base vectors + appended delta vectors, base
    adjacency + rows for the delta nodes (wired by the online rewire), the
    per-node alpha/LID the rewire computed, and the tombstone mask.  The
    base arrays are never mutated in place — the tier owns copies-on-extend
    (jnp concatenation), so the published index keeps serving unchanged.

    The population statistics (mu, sigma) and the entry medoid are frozen
    from the base build (Algorithm 2's bootstrap: per-node LID is estimated
    on the fly, the population calibration is not re-run per insert).
    """

    def __init__(self, x_base: Array, graph: GraphIndex,
                 cfg: build_mod.BuildConfig):
        self.cfg = cfg
        self.n_base = int(np.asarray(x_base).shape[0])
        self.x = jnp.asarray(x_base)
        self.adj = jnp.asarray(graph.adj)
        self.alpha = jnp.asarray(graph.alpha)
        self.lid = jnp.asarray(graph.lid)
        self.mu = jnp.asarray(graph.mu)
        self.sigma = jnp.asarray(graph.sigma)
        self.entry = jnp.asarray(graph.entry)
        self.tombstone = np.zeros((self.n_base,), dtype=bool)

    # ------------------------------------------------------------ properties

    @property
    def n(self) -> int:
        """Combined node count (base + delta, tombstones included)."""
        return int(self.x.shape[0])

    @property
    def n_delta(self) -> int:
        return self.n - self.n_base

    @property
    def live_mask(self) -> np.ndarray:
        return ~self.tombstone

    def live_base_mask(self) -> np.ndarray | None:
        """Allowed mask over *base* nodes for the base engine's in-graph
        filter — None when no base node is tombstoned (the unfiltered walk
        is byte-identical to the historical path, so don't filter for
        nothing)."""
        base = self.tombstone[: self.n_base]
        return None if not base.any() else ~base

    # ------------------------------------------------------------- mutation

    def insert(self, vecs) -> np.ndarray:
        """Absorb a batch of vectors; returns their combined-local ids.

        Each ``cfg.batch``-sized chunk is wired by one
        ``_rewire_batch_online`` step against the *current* combined graph
        (new rows start edge-less, exactly like Algorithm 2's refinement of
        an un-refined node), then mirrored into its destinations with
        re-pruning.  Chunks smaller than ``cfg.batch`` wrap-pad their id
        list and scatter only the real prefix — the same masked-scatter
        discipline as the deterministic online build.
        """
        vecs = np.asarray(vecs, dtype=np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        m = vecs.shape[0]
        if m == 0:
            return np.empty((0,), np.int64)
        first = self.n
        cfg = self.cfg
        for lo in range(0, m, cfg.batch):
            chunk = vecs[lo: lo + cfg.batch]
            ids = np.arange(self.n, self.n + chunk.shape[0], dtype=np.int32)
            real = ids.size
            # Extend the combined state: new rows enter edge-less (INVALID
            # adjacency) with placeholder alpha/LID that the rewire below
            # overwrites for the real lanes.
            self.x = jnp.concatenate([self.x, jnp.asarray(chunk)])
            self.adj = jnp.concatenate([
                self.adj,
                jnp.full((real, self.adj.shape[1]), INVALID, jnp.int32)])
            mid_alpha = 0.5 * (cfg.alpha_min + cfg.alpha_max)
            self.alpha = jnp.concatenate(
                [self.alpha, jnp.full((real,), mid_alpha, jnp.float32)])
            self.lid = jnp.concatenate(
                [self.lid, jnp.full((real,), self.mu, jnp.float32)])

            ids_np = np.resize(ids, cfg.batch)  # wrap-pad to the jit shape
            node_ids = jnp.asarray(ids_np)
            rows, _, alpha_u, lid_u = online_mod._rewire_batch_online(
                self.x, self.adj, self.mu, self.sigma, self.entry,
                node_ids, cfg)
            keep = node_ids[:real]
            self.adj = self.adj.at[keep].set(rows[:real])
            self.alpha = self.alpha.at[keep].set(alpha_u[:real])
            self.lid = self.lid.at[keep].set(lid_u[:real])
            dest, cand = build_mod._reverse_pairs(
                ids_np[:real], np.asarray(rows)[:real], cfg.reverse_cap)
            for ds in range(0, dest.shape[0], cfg.batch):
                dslice = dest[ds: ds + cfg.batch]
                cslice = cand[ds: ds + cfg.batch]
                dvalid = None
                if dslice.size < cfg.batch:
                    pad = cfg.batch - dslice.size
                    dvalid = jnp.asarray(np.arange(cfg.batch) < dslice.size)
                    dslice = np.concatenate([dslice, dslice[:1].repeat(pad)])
                    cslice = np.concatenate([
                        cslice,
                        np.full((pad, cfg.reverse_cap), INVALID, np.int32)])
                self.adj = build_mod._insert_reverse(
                    self.x, self.adj, self.alpha, jnp.asarray(dslice),
                    jnp.asarray(cslice), cfg, valid=dvalid)
        self.tombstone = np.concatenate(
            [self.tombstone, np.zeros((m,), dtype=bool)])
        return np.arange(first, first + m, dtype=np.int64)

    def delete(self, local_ids) -> None:
        """Tombstone combined-local ids (base or delta).  Edges are left in
        place — a tombstoned node stays *navigable* (the filtered walk
        traverses it, it just can't be returned), which is what keeps the
        graph connected without eager unlinking."""
        self.tombstone[np.asarray(local_ids, dtype=np.int64)] = True

    # -------------------------------------------------------------- queries

    def delta_topk(self, queries, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k over the *live delta* vectors (the memtable scan).

        Returns (ids (Q, k) combined-local, d2 (Q, k)) — INVALID/inf padded
        when fewer than k delta nodes are live.  Exact and deterministic:
        the bounded-staleness guarantee (an inserted vector is findable the
        moment ``insert`` returns) rests on this scan, not on walk luck.
        """
        queries = np.asarray(queries, dtype=np.float32)
        nq = queries.shape[0]
        live = np.flatnonzero(~self.tombstone[self.n_base:]) + self.n_base
        ids = np.full((nq, k), INVALID, np.int64)
        d2 = np.full((nq, k), np.inf, np.float32)
        if live.size == 0:
            return ids, d2
        xd = np.asarray(self.x[jnp.asarray(live)])
        diff = queries[:, None, :] - xd[None]
        dist = np.einsum("qnd,qnd->qn", diff, diff, dtype=np.float32)
        take = min(k, live.size)
        order = np.argsort(dist, axis=1)[:, :take]
        ids[:, :take] = live[order]
        d2[:, :take] = np.take_along_axis(dist, order, axis=1)
        return ids, d2

    def search_exact(self, queries, *, beam_width: int, k: int,
                     max_hops: int = 2048):
        """Exact in-graph walk over the live combined graph — the quality
        view of the incremental edge repair (what the churn benchmark's
        recall-under-churn measures), with tombstones excluded in-graph.

        This is Online-MCGI serving its own mutating graph: base and delta
        nodes rank in one beam over the rewired adjacency.  Returns
        (ids, d2, stats) in combined-local ids.
        """
        queries = jnp.asarray(queries)
        excl = None
        if self.tombstone.any():
            excl = search_mod.pack_filter(
                np.broadcast_to(self.live_mask,
                                (queries.shape[0], self.n)), self.n)
        return search_mod.beam_search_exact(
            self.x, self.adj, queries, self.entry, beam_width=beam_width,
            max_hops=max_hops, k=k, excl=excl)


@dataclasses.dataclass
class _LiveState:
    """One generation's consistent (delta, ext_of) pair — replaced atomically
    at merge publish, so a search that grabbed the old state keeps a
    consistent view while the swap happens."""

    delta: DeltaTier
    ext_of: np.ndarray          # combined-local id -> stable external id
    generation: int


class LiveIndex:
    """Mutable serving front: base engine + delta tier + merge compaction.

    One object owns the whole lifecycle: build the initial base, serve
    fan-out searches under mutation, and compact the delta back into a
    published base when it grows past ``merge_threshold``.

    ``store_dir`` switches the base engine's slow tier to a block store
    (:class:`~repro.index.disk.BlockSlowTier`): each merge publishes a new
    *generation-numbered* store file by atomic tmp-rename and swaps it in
    with ``update_backend`` — readers of the old generation (in-flight
    requests holding their dispatch-time backend snapshot) finish against a
    closed-but-readable tier.  Without it the slow tier is in-memory rows.

    ``calib`` (queries array) arms drift-triggered recalibration: when a
    merge moves the population's mean LID by more than ``drift_threshold``,
    the engine's budget law is refit against the new content
    (:meth:`repro.serving.SearchEngine.recalibrate` with brute-force ground
    truth over the merged rows).
    """

    def __init__(self, x0, cfg: build_mod.BuildConfig, *,
                 budget_cfg=None, k: int = 10, beam_width: int = 48,
                 max_hops: int = 2048, m_pq: int = 8, pq_seed: int = 0,
                 store_dir: str | pathlib.Path | None = None,
                 nodes_per_block: int = 4, merge_threshold: int = 256,
                 calib=None, recall_target: float = 0.95,
                 drift_threshold: float = 0.25, engine_kw: dict | None = None):
        from repro.serving import engine as engine_mod

        self.cfg = cfg
        self.k = k
        self.beam_width = beam_width
        self.max_hops = max_hops
        self.m_pq = m_pq
        self.pq_seed = pq_seed
        self.budget_cfg = budget_cfg
        self.store_dir = None if store_dir is None else pathlib.Path(store_dir)
        self.nodes_per_block = nodes_per_block
        self.merge_threshold = merge_threshold
        self.calib = None if calib is None else np.asarray(calib, np.float32)
        self.recall_target = recall_target
        self.drift_threshold = drift_threshold
        self._engine_mod = engine_mod
        self._engine_kw = dict(engine_kw or {})
        self._merge_lock = threading.Lock()
        self._next_ext = 0
        self.lineage: dict[str, Any] = {"generation": 0, "merges": 0,
                                        "inserts": 0, "deletes": 0}

        x0 = np.asarray(x0, dtype=np.float32)
        graph, index, slow_tier = self._build_base(x0, generation=0)
        backend = engine_mod.TieredBackend(index, slow_tier=slow_tier)
        self.engine = engine_mod.SearchEngine(
            backend, budget_cfg, k=k, beam_width=beam_width,
            max_hops=max_hops, **self._engine_kw)
        self._state = _LiveState(
            delta=DeltaTier(x0, graph, cfg),
            ext_of=np.arange(x0.shape[0], dtype=np.int64), generation=0)
        self._next_ext = x0.shape[0]

    # ------------------------------------------------------------- plumbing

    def _build_base(self, x_new: np.ndarray, generation: int):
        """Deterministic base build + (optionally) store publish for one
        generation's live rows.  The block store gets the block-aware packed
        layout and lands under a generation-numbered name via the atomic
        tmp-rename publish of ``write_block_store``."""
        from repro.index import disk as disk_mod

        graph = online_mod.build_online_mcgi(jnp.asarray(x_new), self.cfg)
        index = disk_mod.build_tiered_index(
            jnp.asarray(x_new), graph, m_pq=self.m_pq, seed=self.pq_seed)
        slow_tier = None
        if self.store_dir is not None:
            slot_of = build_mod.block_layout(graph, self.nodes_per_block)
            slow_tier = disk_mod.open_or_build_slow_tier(
                self.store_dir / f"live.g{generation}.blocks", index,
                nodes_per_block=self.nodes_per_block, slot_of=slot_of)
        return graph, index, slow_tier

    @property
    def generation(self) -> int:
        return self._state.generation

    @property
    def delta_size(self) -> int:
        st = self._state
        return int(st.delta.n_delta + st.delta.tombstone.sum())

    @property
    def n_live(self) -> int:
        return int(self._state.delta.live_mask.sum())

    def _locate(self, ext_ids) -> np.ndarray:
        """External ids -> combined-local ids (``ext_of`` stays sorted:
        compaction preserves insertion order, inserts append)."""
        st = self._state
        ext_ids = np.asarray(ext_ids, dtype=np.int64)
        loc = np.searchsorted(st.ext_of, ext_ids)
        ok = (loc < st.ext_of.size) & (st.ext_of[np.minimum(
            loc, st.ext_of.size - 1)] == ext_ids)
        if not ok.all():
            raise KeyError(f"unknown/deleted external ids "
                           f"{ext_ids[~ok][:8].tolist()}")
        return loc

    # ------------------------------------------------------------- mutation

    def insert(self, vecs, *, auto_merge: bool = True) -> np.ndarray:
        """Insert vectors; returns their stable external ids.  With
        ``auto_merge`` the delta compacts once it crosses
        ``merge_threshold`` (the periodic-merge policy inlined at the write
        path — callers wanting a background merge call
        :meth:`merge_async` themselves)."""
        st = self._state
        local = st.delta.insert(vecs)
        ext = np.arange(self._next_ext, self._next_ext + local.size,
                        dtype=np.int64)
        self._next_ext += local.size
        st.ext_of = np.concatenate([st.ext_of, ext])
        self.lineage["inserts"] += int(local.size)
        if auto_merge and self.delta_size >= self.merge_threshold:
            self.merge()
        return ext

    def delete(self, ext_ids) -> None:
        """Tombstone by external id — excluded from every search from now
        on (in-graph on the base tier, masked on the delta scan), reclaimed
        at the next merge."""
        st = self._state
        st.delta.delete(self._locate(ext_ids))
        self.lineage["deletes"] += int(np.asarray(ext_ids).size)

    # -------------------------------------------------------------- serving

    def search(self, queries, k: int | None = None):
        """Fan-out search over base + delta; returns (ext_ids, d2).

        At a merge boundary (empty delta, no tombstones) this is *exactly*
        the engine's result — same compiled programs, no extra ops — so it
        is bit-identical to serving a freshly built index of the same live
        content.  Otherwise: base engine with tombstones excluded in-graph,
        exact delta scan, and the normal full-precision rerank merging the
        two (disjoint) candidate pools.
        """
        k = self.k if k is None else k
        st = self._state
        queries = np.asarray(queries, dtype=np.float32)
        if st.delta.n_delta == 0 and not st.delta.tombstone.any():
            res = self.engine.search(queries)
            ids = res.ids.astype(np.int64)
            ext = np.where(ids >= 0, st.ext_of[np.maximum(ids, 0)], INVALID)
            return ext, res.d2
        res = self.engine.search(queries, filter=st.delta.live_base_mask())
        base_ids = res.ids.astype(np.int64)
        delta_ids, _delta_d2 = st.delta.delta_topk(queries, k)
        cand = np.concatenate([base_ids, delta_ids], axis=1)
        safe = np.maximum(cand, 0)
        vecs = np.asarray(st.delta.x)[safe]
        ids_l, d2 = search_mod._rerank_from_vecs_jit(
            jnp.asarray(cand), jnp.asarray(vecs), jnp.asarray(queries), k=k)
        ids_l = np.asarray(ids_l)
        ext = np.where(ids_l >= 0, st.ext_of[np.maximum(ids_l, 0)], INVALID)
        return ext, np.asarray(d2)

    def search_local(self, queries, k: int | None = None):
        """Like :meth:`search` but in combined-local ids (test plumbing for
        bit-identity against a fresh build of the same rows)."""
        ext, d2 = self.search(queries, k)
        st = self._state
        loc = np.where(ext >= 0,
                       np.searchsorted(st.ext_of, np.maximum(ext, 0)),
                       INVALID)
        return loc, d2

    # ---------------------------------------------------------------- merge

    def merge(self) -> int:
        """Compact live content into a new published base generation.

        Deterministic from-scratch rebuild over the live rows in insertion
        order, fresh PQ tier, packed block layout, atomic store publish,
        live engine swap (``update_backend`` — in-flight requests finish on
        their dispatch-time snapshot), optional drift-triggered
        recalibration, delta re-base.  Returns the new generation number.
        Serialised: concurrent calls run one merge at a time.
        """
        with self._merge_lock:
            st = self._state
            gen = st.generation + 1
            live = np.flatnonzero(st.delta.live_mask)
            x_new = np.asarray(st.delta.x)[live]
            old_mu = float(np.asarray(st.delta.mu))
            graph, index, slow_tier = self._build_base(x_new, generation=gen)
            if self.store_dir is not None:
                self.engine.update_backend(index, slow_tier=slow_tier)
            else:
                self.engine.update_backend(index, slow_tier=None)
            new_mu = float(np.asarray(graph.mu))
            if (self.budget_cfg is not None and self.calib is not None
                    and abs(new_mu - old_mu) > self.drift_threshold):
                gt = _brute_force_gt(x_new, self.calib, self.k)
                self.engine.recalibrate(self.calib, gt,
                                        recall_target=self.recall_target)
                self.lineage["recalibrations"] = (
                    self.lineage.get("recalibrations", 0) + 1)
            self.lineage.update(generation=gen,
                                merges=self.lineage["merges"] + 1,
                                live=int(x_new.shape[0]),
                                mu=new_mu)
            # Atomic re-base: one assignment publishes the new (delta,
            # ext_of) pair; readers holding the old state stay consistent.
            self._state = _LiveState(
                delta=DeltaTier(x_new, graph, self.cfg),
                ext_of=st.ext_of[live].copy(), generation=gen)
            return gen

    def merge_async(self) -> threading.Thread:
        """Run :meth:`merge` on a background thread (the periodic-merge
        deployment shape); traffic keeps flowing — the engine swap inside
        is snapshot-consistent for in-flight requests.  Join the returned
        thread to wait for the publish."""
        t = threading.Thread(target=self.merge, name="delta-merge",
                             daemon=True)
        t.start()
        return t

    def save(self, path) -> None:
        """Persist the current *base* generation with the delta/merge
        lineage riding in the index manifest (see
        :func:`repro.index.serializer.save_index`)."""
        from repro.index import serializer

        serializer.save_index(
            path, self.engine.backend.index,
            version=2 if self.store_dir is not None else 1,
            nodes_per_block=(self.nodes_per_block
                             if self.store_dir is not None else 1),
            lineage=dict(self.lineage))

    def close(self) -> None:
        self.engine.close()


def _brute_force_gt(x: np.ndarray, queries: np.ndarray,
                    k: int) -> np.ndarray:
    """Exact top-k ids over ``x`` for recalibration ground truth."""
    diff = queries[:, None, :].astype(np.float32) - x[None].astype(np.float32)
    d2 = np.einsum("qnd,qnd->qn", diff, diff)
    return np.argsort(d2, axis=1)[:, :k]
