"""Block-aligned on-disk node store — the *real* slow tier.

DiskANN/BAMG layout: one node's full-precision vector and its adjacency list
live in the same aligned disk block, so a beam expansion (or a rerank fetch)
is exactly one block read.  The repo's :class:`repro.index.disk.DiskTierModel`
prices that read analytically; this module makes it physical:

    block 0      : header — magic + JSON manifest, zero padded
    block 1 + i  : node i — [vector f32 (D,)] [adj i32 (R,)] [crc32 u32],
                   zero padded to ``block_size``

``block_size`` is the record payload rounded up to a multiple of
:data:`SECTOR` (512B — SSD sector alignment, so a record never straddles an
unaligned boundary).  All fields are little-endian; the file is
byte-identical across hosts.  Reads go through one shared ``np.memmap``
(pages fault in on first touch — the OS page cache is the "SSD controller"
on this testbed; on a real deployment the same layout reads with
O_DIRECT/io_uring at sector granularity).

Every record carries a CRC32 over its payload: a torn write, bit rot, or a
wrong-length file surfaces as a typed error (:class:`BlockChecksumError`,
:class:`BlockStoreTruncatedError`, :class:`BlockStoreFormatError`) instead
of silently serving garbage neighbours.

The serving-side cache/prefetch policy lives in
:class:`repro.index.disk.BlockSlowTier`; this module is only the storage
format and its (counted, timed) reader.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
import zlib

import numpy as np

MAGIC = b"MCGIBLK2"
FORMAT = "repro.blockstore.v2"
SECTOR = 512


class BlockStoreError(Exception):
    """Base class for slow-tier storage faults."""


class BlockStoreFormatError(BlockStoreError):
    """Bad magic / unknown format / manifest inconsistent with the file."""


class BlockStoreTruncatedError(BlockStoreError):
    """File shorter than the manifest's node count implies."""


class BlockChecksumError(BlockStoreError):
    """A node record's payload fails its CRC32 (torn write / bit rot)."""


def block_size_for(d: int, r: int) -> int:
    """Record bytes (vector + adjacency + crc) rounded up to a sector."""
    payload = d * 4 + r * 4 + 4
    return ((payload + SECTOR - 1) // SECTOR) * SECTOR


def vectors_crc32(vectors: np.ndarray) -> int:
    """Content fingerprint of a slow tier (little-endian f32 bytes).

    Written into the store manifest and cross-checked by consumers that
    already hold the vectors (or, for v2 indexes, recorded in the npz
    manifest): geometry alone — (n, d, r) — cannot tell two builds of the
    same shape apart, and a stale store with matching shape would otherwise
    serve wrong reranks silently.
    """
    arr = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    return zlib.crc32(arr)   # buffer protocol: no store-sized copy


@dataclasses.dataclass
class BlockReadStats:
    """Cumulative reader counters (reset with :meth:`BlockStore.reset_stats`).

    ``read_time_s`` is host wall time spent inside block reads — the
    *measured* counterpart of ``DiskTierModel.read_latency_us * blocks_read``.
    """

    blocks_read: int = 0
    read_time_s: float = 0.0

    def measured_read_us(self) -> float:
        """Mean measured latency per block read, in microseconds."""
        if self.blocks_read == 0:
            return 0.0
        return self.read_time_s * 1e6 / self.blocks_read


class BlockStore:
    """Reader over one block file (see the module docstring for the layout).

    Open is cheap (header block only); node reads are memmap slices, each
    CRC-verified.  ``read_many`` is the serving entry point: it returns the
    (n, D) vectors and (n, R) adjacency for a batch of node ids and counts
    every record touched in :attr:`stats`.
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        try:
            raw = np.memmap(self.path, dtype=np.uint8, mode="r")
        except (FileNotFoundError, ValueError) as e:
            raise BlockStoreFormatError(
                f"cannot open block store {self.path}: {e}") from e
        if raw.size < SECTOR or bytes(raw[: len(MAGIC)]) != MAGIC:
            raise BlockStoreFormatError(
                f"{self.path}: not a block store (bad magic)")
        hlen = int(raw[len(MAGIC): len(MAGIC) + 4].view("<u4")[0])
        if hlen <= 0 or len(MAGIC) + 4 + hlen > raw.size:
            raise BlockStoreFormatError(
                f"{self.path}: header length {hlen} exceeds the file")
        try:
            manifest = json.loads(
                bytes(raw[len(MAGIC) + 4: len(MAGIC) + 4 + hlen]))
        except json.JSONDecodeError as e:
            raise BlockStoreFormatError(
                f"{self.path}: unreadable manifest: {e}") from e
        if manifest.get("format") != FORMAT:
            raise BlockStoreFormatError(
                f"{self.path}: format {manifest.get('format')!r}, "
                f"expected {FORMAT!r}")
        self.n = int(manifest["n"])
        self.d = int(manifest["d"])
        self.r = int(manifest["r"])
        self.block_size = int(manifest["block_size"])
        # Content fingerprint (absent only in stores from before it existed).
        v = manifest.get("vectors_crc32")
        self.vectors_crc32 = None if v is None else int(v)
        if self.block_size < block_size_for(self.d, self.r):
            raise BlockStoreFormatError(
                f"{self.path}: block_size {self.block_size} cannot hold a "
                f"(d={self.d}, r={self.r}) record")
        if self.block_size > raw.size:  # header block itself must fit
            raise BlockStoreTruncatedError(
                f"{self.path}: file smaller than one block")
        expect = (1 + self.n) * self.block_size
        if raw.size < expect:
            raise BlockStoreTruncatedError(
                f"{self.path}: {raw.size} bytes on disk, manifest needs "
                f"{expect} ({self.n} nodes x {self.block_size}B + header)")
        self._mm = raw
        self.stats = BlockReadStats()

    def reset_stats(self) -> None:
        self.stats = BlockReadStats()

    # ------------------------------------------------------------- reading

    def read_many(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Read the records of ``ids`` (1-D int array, each in [0, n)).

        Returns (vectors (len, D) f32, adj (len, R) i32); raises
        :class:`BlockChecksumError` naming the first corrupt node.  Each id
        in the argument counts as one block read (callers dedupe — the
        cache layer above does).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise IndexError(
                f"node id out of range [0, {self.n}): "
                f"{ids[(ids < 0) | (ids >= self.n)][0]}")
        t0 = time.perf_counter()
        bs, d, r = self.block_size, self.d, self.r
        payload = d * 4 + r * 4
        # One fancy-indexed gather over the block-matrix view: rows fault in
        # via the page cache exactly like queue_depth concurrent block reads.
        blocks = self._mm[: (1 + self.n) * bs].reshape(1 + self.n, bs)
        recs = np.ascontiguousarray(blocks[1 + ids, : payload + 4])
        stored = recs[:, payload: payload + 4].view("<u4").ravel()
        for row, i in enumerate(ids):
            # crc32 over the contiguous row view: no per-record copy on the
            # hot read path (this time is part of the measured read latency).
            if zlib.crc32(recs[row, :payload]) != int(stored[row]):
                raise BlockChecksumError(
                    f"{self.path}: node {int(i)} payload fails CRC32 "
                    "(torn write or bit rot)")
        vecs = recs[:, : d * 4].view("<f4").reshape(-1, d)
        adj = recs[:, d * 4: payload].view("<i4").reshape(-1, r)
        self.stats.blocks_read += int(ids.size)
        self.stats.read_time_s += time.perf_counter() - t0
        return vecs, adj

def write_block_store(
    path: str | pathlib.Path,
    vectors: np.ndarray,
    adj: np.ndarray,
    block_size: int | None = None,
) -> pathlib.Path:
    """Write a block store for (vectors (N, D) f32, adj (N, R) i32).

    ``block_size`` defaults to the tight sector-aligned record size; a larger
    multiple of :data:`SECTOR` is accepted (e.g. to pin 4K pages).
    """
    path = pathlib.Path(path)
    vectors = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    adj = np.ascontiguousarray(np.asarray(adj), dtype="<i4")
    assert vectors.ndim == 2 and adj.ndim == 2, (vectors.shape, adj.shape)
    assert vectors.shape[0] == adj.shape[0], (vectors.shape, adj.shape)
    n, d = vectors.shape
    r = adj.shape[1]
    tight = block_size_for(d, r)
    if block_size is None:
        block_size = tight
    if block_size < tight or block_size % SECTOR:
        raise ValueError(
            f"block_size {block_size} must be a sector multiple >= {tight}")
    manifest = json.dumps({
        "format": FORMAT, "n": n, "d": d, "r": r, "block_size": block_size,
        "checksum": "crc32", "vectors_crc32": zlib.crc32(vectors),
    }).encode()
    if len(MAGIC) + 4 + len(manifest) > block_size:
        raise ValueError("manifest does not fit the header block")
    payload = d * 4 + r * 4
    blocks = np.zeros((1 + n, block_size), dtype=np.uint8)
    blocks[0, : len(MAGIC)] = np.frombuffer(MAGIC, np.uint8)
    blocks[0, len(MAGIC): len(MAGIC) + 4] = np.frombuffer(
        np.uint32(len(manifest)).astype("<u4").tobytes(), np.uint8)
    blocks[0, len(MAGIC) + 4: len(MAGIC) + 4 + len(manifest)] = (
        np.frombuffer(manifest, np.uint8))
    blocks[1:, : d * 4] = vectors.view(np.uint8).reshape(n, d * 4)
    blocks[1:, d * 4: payload] = adj.view(np.uint8).reshape(n, r * 4)
    crcs = np.empty((n,), dtype="<u4")
    rows = blocks[1:, :payload]
    for i in range(n):
        crcs[i] = zlib.crc32(rows[i])   # contiguous row view, no copy
    blocks[1:, payload: payload + 4] = crcs.view(np.uint8).reshape(n, 4)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        blocks.tofile(f)   # no tobytes() double-copy of a store-sized buffer
    tmp.replace(path)  # atomic publish: no half-written store under readers
    return path


def ensure_block_store(
    path: str | pathlib.Path,
    vectors: np.ndarray,
    adj: np.ndarray,
    log=None,
) -> BlockStore:
    """Open the store at ``path`` if its content fingerprint matches
    ``vectors``; otherwise — absent, unreadable (any
    :class:`BlockStoreError`), or stale — write it fresh and open that.

    The one bootstrap every consumer shares (serve launcher, e2e example,
    benchmarks): geometry can collide between two builds, a torn file must
    not crash the "rewrite if needed" promise, and the fingerprint is the
    only content identity.  ``log`` (e.g. ``print``) narrates what happened.
    """
    path = pathlib.Path(path)
    vectors = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    if path.exists():
        try:
            store = BlockStore(path)
            if store.vectors_crc32 == zlib.crc32(vectors):
                return store
            reason = "stale (content fingerprint mismatch)"
        except BlockStoreError as e:
            reason = f"unreadable ({type(e).__name__})"
        if log:
            log(f"block store {path} is {reason}; rewriting")
    write_block_store(path, vectors, adj)
    if log:
        log(f"wrote block store {path} ({path.stat().st_size/1e6:.1f}MB)")
    return BlockStore(path)
