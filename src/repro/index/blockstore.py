"""Block-aligned on-disk node store — the *real* slow tier.

DiskANN/BAMG layout: one node's full-precision vector and its adjacency list
live in the same aligned disk block, so a beam expansion (or a rerank fetch)
is exactly one block read.  The repo's :class:`repro.index.disk.DiskTierModel`
prices that read analytically; this module makes it physical:

    block 0      : header — magic + JSON manifest, zero padded
    block 1 + i  : node i — [vector f32 (D,)] [adj i32 (R,)] [crc32 u32],
                   zero padded to ``block_size``

``block_size`` is the record payload rounded up to a multiple of
:data:`SECTOR` (512B — SSD sector alignment, so a record never straddles an
unaligned boundary).  All fields are little-endian; the file is
byte-identical across hosts.  Reads go through one shared ``np.memmap``
(pages fault in on first touch — the OS page cache is the "SSD controller"
on this testbed; on a real deployment the same layout reads with
O_DIRECT/io_uring at sector granularity).

Block-aware layout (BAMG)
-------------------------
The walk reads adjacency at I/O-device granularity, which is larger than
one 512B record — ``nodes_per_block`` groups that many consecutive record
slots into one *I/O block* (e.g. 8 x 512B = one 4K page), the unit the
out-of-core walk fetches and caches.  ``slot_of`` permutes nodes across
record slots so that co-expanded neighbours (greedy packing at build time,
:func:`repro.core.prune.greedy_block_pack`) land in the same I/O block —
one page read covers a hop's expansion.  The permutation is persisted in
dedicated slot-table blocks between the header and the records:

    block 0                      : header (manifest carries
                                   ``nodes_per_block`` / ``layout`` /
                                   ``slot_table_blocks``)
    blocks 1 .. T                : slot table — node id -> record slot,
                                   ``<i4``, zero padded (T = 0 for the
                                   node-order layout)
    block 1 + T + s              : the record of node ``node_of[s]``

Default-layout files (``nodes_per_block=1``, no permutation) are written
without any of the new manifest keys — byte-identical to the historical
format, and historical files read back as ``nodes_per_block=1``.
:attr:`BlockReadStats.io_blocks` counts distinct I/O blocks touched — the
blocks-per-query numerator reported by ``benchmarks/disk_io.py``.

Every record carries a CRC32 over its payload: a torn write, bit rot, or a
wrong-length file surfaces as a typed error (:class:`BlockChecksumError`,
:class:`BlockStoreTruncatedError`, :class:`BlockStoreFormatError`) instead
of silently serving garbage neighbours.

The serving-side cache/prefetch policy lives in
:class:`repro.index.disk.BlockSlowTier`; this module is only the storage
format and its (counted, timed) reader.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
import zlib

import numpy as np

MAGIC = b"MCGIBLK2"
FORMAT = "repro.blockstore.v2"
SECTOR = 512


class BlockStoreError(Exception):
    """Base class for slow-tier storage faults."""


class BlockStoreFormatError(BlockStoreError):
    """Bad magic / unknown format / manifest inconsistent with the file."""


class BlockStoreTruncatedError(BlockStoreError):
    """File shorter than the manifest's node count implies."""


class BlockChecksumError(BlockStoreError):
    """A node record's payload fails its CRC32 (torn write / bit rot)."""


def block_size_for(d: int, r: int) -> int:
    """Record bytes (vector + adjacency + crc) rounded up to a sector."""
    payload = d * 4 + r * 4 + 4
    return ((payload + SECTOR - 1) // SECTOR) * SECTOR


def vectors_crc32(vectors: np.ndarray) -> int:
    """Content fingerprint of a slow tier (little-endian f32 bytes).

    Written into the store manifest and cross-checked by consumers that
    already hold the vectors (or, for v2 indexes, recorded in the npz
    manifest): geometry alone — (n, d, r) — cannot tell two builds of the
    same shape apart, and a stale store with matching shape would otherwise
    serve wrong reranks silently.
    """
    arr = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    return zlib.crc32(arr)   # buffer protocol: no store-sized copy


@dataclasses.dataclass
class BlockReadStats:
    """Cumulative reader counters (reset with :meth:`BlockStore.reset_stats`).

    ``read_time_s`` is host wall time spent inside block reads — the
    *measured* counterpart of ``DiskTierModel.read_latency_us * blocks_read``.
    ``io_blocks`` counts distinct I/O blocks (``nodes_per_block`` record
    slots each) touched per read call — equal to ``blocks_read`` for the
    default one-record-per-block layout, strictly smaller when a packed
    layout makes co-expanded records share a block.
    """

    blocks_read: int = 0
    read_time_s: float = 0.0
    io_blocks: int = 0

    def measured_read_us(self) -> float:
        """Mean measured latency per block read, in microseconds."""
        if self.blocks_read == 0:
            return 0.0
        return self.read_time_s * 1e6 / self.blocks_read


class BlockStore:
    """Reader over one block file (see the module docstring for the layout).

    Open is cheap (header block only); node reads are memmap slices, each
    CRC-verified.  ``read_many`` is the serving entry point: it returns the
    (n, D) vectors and (n, R) adjacency for a batch of node ids and counts
    every record touched in :attr:`stats`.
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        try:
            raw = np.memmap(self.path, dtype=np.uint8, mode="r")
        except (FileNotFoundError, ValueError) as e:
            raise BlockStoreFormatError(
                f"cannot open block store {self.path}: {e}") from e
        if raw.size < SECTOR or bytes(raw[: len(MAGIC)]) != MAGIC:
            raise BlockStoreFormatError(
                f"{self.path}: not a block store (bad magic)")
        hlen = int(raw[len(MAGIC): len(MAGIC) + 4].view("<u4")[0])
        if hlen <= 0 or len(MAGIC) + 4 + hlen > raw.size:
            raise BlockStoreFormatError(
                f"{self.path}: header length {hlen} exceeds the file")
        try:
            manifest = json.loads(
                bytes(raw[len(MAGIC) + 4: len(MAGIC) + 4 + hlen]))
        except json.JSONDecodeError as e:
            raise BlockStoreFormatError(
                f"{self.path}: unreadable manifest: {e}") from e
        if manifest.get("format") != FORMAT:
            raise BlockStoreFormatError(
                f"{self.path}: format {manifest.get('format')!r}, "
                f"expected {FORMAT!r}")
        self.n = int(manifest["n"])
        self.d = int(manifest["d"])
        self.r = int(manifest["r"])
        self.block_size = int(manifest["block_size"])
        # Content fingerprint (absent only in stores from before it existed).
        v = manifest.get("vectors_crc32")
        self.vectors_crc32 = None if v is None else int(v)
        if self.block_size < block_size_for(self.d, self.r):
            raise BlockStoreFormatError(
                f"{self.path}: block_size {self.block_size} cannot hold a "
                f"(d={self.d}, r={self.r}) record")
        if self.block_size > raw.size:  # header block itself must fit
            raise BlockStoreTruncatedError(
                f"{self.path}: file smaller than one block")
        # Layout rider (absent -> the historical one-record-per-block file).
        self.nodes_per_block = int(manifest.get("nodes_per_block", 1))
        self.layout = manifest.get("layout", "node-order")
        table_blocks = int(manifest.get("slot_table_blocks", 0))
        if self.nodes_per_block < 1:
            raise BlockStoreFormatError(
                f"{self.path}: nodes_per_block {self.nodes_per_block} < 1")
        self._data_start = 1 + table_blocks
        expect = (self._data_start + self.n) * self.block_size
        if raw.size < expect:
            raise BlockStoreTruncatedError(
                f"{self.path}: {raw.size} bytes on disk, manifest needs "
                f"{expect} ({self.n} nodes x {self.block_size}B + header)")
        self._mm = raw
        if table_blocks:
            tbl = raw[self.block_size: self.block_size * self._data_start]
            slot_of = tbl[: self.n * 4].view("<i4").astype(np.int64)
            crc = manifest.get("slot_table_crc32")
            if crc is not None and zlib.crc32(
                    np.ascontiguousarray(slot_of.astype("<i4"))) != int(crc):
                raise BlockStoreFormatError(
                    f"{self.path}: slot table fails its CRC32")
            if not np.array_equal(np.sort(slot_of), np.arange(self.n)):
                raise BlockStoreFormatError(
                    f"{self.path}: slot table is not a permutation")
            self.slot_of = slot_of
            self.node_of = np.empty_like(slot_of)
            self.node_of[slot_of] = np.arange(self.n, dtype=np.int64)
        else:
            self.slot_of = None   # identity layout
            self.node_of = None
        self.stats = BlockReadStats()

    def reset_stats(self) -> None:
        self.stats = BlockReadStats()

    @property
    def slot_table_crc32(self) -> int | None:
        """CRC32 of the persisted ``<i4`` slot table (None for identity)."""
        if self.slot_of is None:
            return None
        return zlib.crc32(np.ascontiguousarray(self.slot_of.astype("<i4")))

    # ------------------------------------------------------------- reading

    def io_block_of(self, ids: np.ndarray) -> np.ndarray:
        """The I/O block index holding each node's record."""
        ids = np.asarray(ids, dtype=np.int64)
        slots = ids if self.slot_of is None else self.slot_of[ids]
        return slots // self.nodes_per_block

    def _check_range(self, ids: np.ndarray) -> None:
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise IndexError(
                f"node id out of range [0, {self.n}): "
                f"{ids[(ids < 0) | (ids >= self.n)][0]}")

    def _records_at(self, slots: np.ndarray,
                    named: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """CRC-checked record payloads at ``slots``; ``named`` are the node
        ids to blame in checksum errors."""
        bs, d, r = self.block_size, self.d, self.r
        payload = d * 4 + r * 4
        # One fancy-indexed gather over the block-matrix view: rows fault in
        # via the page cache exactly like queue_depth concurrent block reads.
        blocks = self._mm[: (self._data_start + self.n) * bs].reshape(
            self._data_start + self.n, bs)
        recs = np.ascontiguousarray(
            blocks[self._data_start + slots, : payload + 4])
        stored = recs[:, payload: payload + 4].view("<u4").ravel()
        for row, i in enumerate(named):
            # crc32 over the contiguous row view: no per-record copy on the
            # hot read path (this time is part of the measured read latency).
            if zlib.crc32(recs[row, :payload]) != int(stored[row]):
                raise BlockChecksumError(
                    f"{self.path}: node {int(i)} payload fails CRC32 "
                    "(torn write or bit rot)")
        vecs = recs[:, : d * 4].view("<f4").reshape(-1, d)
        adj = recs[:, d * 4: payload].view("<i4").reshape(-1, r)
        return vecs, adj

    def read_many(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Read the records of ``ids`` (1-D int array, each in [0, n)).

        Returns (vectors (len, D) f32, adj (len, R) i32); raises
        :class:`BlockChecksumError` naming the first corrupt node.  Each id
        in the argument counts as one block read (callers dedupe — the
        cache layer above does).
        """
        ids = np.asarray(ids, dtype=np.int64)
        self._check_range(ids)
        t0 = time.perf_counter()
        slots = ids if self.slot_of is None else self.slot_of[ids]
        vecs, adj = self._records_at(slots, ids)
        self.stats.blocks_read += int(ids.size)
        self.stats.io_blocks += int(
            np.unique(slots // self.nodes_per_block).size)
        self.stats.read_time_s += time.perf_counter() - t0
        return vecs, adj

    def read_blocks(
        self, block_ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched multi-block fetch: every record of the given I/O blocks.

        The walk-time read path — a miss on one node pulls its whole I/O
        block so the cache layer can keep all co-located records (that is
        the whole point of the packed layout).  Returns
        ``(node_ids, vectors, adj)`` for every record slot covered, in slot
        order.  Counts one ``io_blocks`` per distinct block and one
        ``blocks_read`` per record returned.
        """
        block_ids = np.unique(np.asarray(block_ids, dtype=np.int64))
        npb = self.nodes_per_block
        n_blocks = (self.n + npb - 1) // npb
        if block_ids.size and (block_ids.min() < 0
                               or block_ids.max() >= n_blocks):
            raise IndexError(
                f"I/O block out of range [0, {n_blocks}): "
                f"{block_ids[(block_ids < 0) | (block_ids >= n_blocks)][0]}")
        t0 = time.perf_counter()
        slots = (block_ids[:, None] * npb + np.arange(npb)).ravel()
        slots = slots[slots < self.n]
        node_ids = slots if self.node_of is None else self.node_of[slots]
        vecs, adj = self._records_at(slots, node_ids)
        self.stats.blocks_read += int(slots.size)
        self.stats.io_blocks += int(block_ids.size)
        self.stats.read_time_s += time.perf_counter() - t0
        return node_ids, vecs, adj

def write_block_store(
    path: str | pathlib.Path,
    vectors: np.ndarray,
    adj: np.ndarray,
    block_size: int | None = None,
    nodes_per_block: int = 1,
    slot_of: np.ndarray | None = None,
) -> pathlib.Path:
    """Write a block store for (vectors (N, D) f32, adj (N, R) i32).

    ``block_size`` defaults to the tight sector-aligned record size; a larger
    multiple of :data:`SECTOR` is accepted (e.g. to pin 4K pages).

    ``nodes_per_block`` sets the I/O-block granularity (how many record
    slots one device read covers); ``slot_of`` (an (N,) permutation,
    node id -> record slot — e.g. :func:`repro.core.prune.greedy_block_pack`)
    packs co-expanded neighbours into shared I/O blocks.  The default
    ``(1, None)`` writes the historical byte-exact format with none of the
    layout keys.
    """
    path = pathlib.Path(path)
    vectors = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    adj = np.ascontiguousarray(np.asarray(adj), dtype="<i4")
    assert vectors.ndim == 2 and adj.ndim == 2, (vectors.shape, adj.shape)
    assert vectors.shape[0] == adj.shape[0], (vectors.shape, adj.shape)
    n, d = vectors.shape
    r = adj.shape[1]
    tight = block_size_for(d, r)
    if block_size is None:
        block_size = tight
    if block_size < tight or block_size % SECTOR:
        raise ValueError(
            f"block_size {block_size} must be a sector multiple >= {tight}")
    if nodes_per_block < 1:
        raise ValueError(f"nodes_per_block {nodes_per_block} must be >= 1")
    manifest_fields = {
        "format": FORMAT, "n": n, "d": d, "r": r, "block_size": block_size,
        "checksum": "crc32", "vectors_crc32": zlib.crc32(vectors),
    }
    table_blocks = 0
    if slot_of is not None:
        slot_of = np.ascontiguousarray(np.asarray(slot_of), dtype="<i4")
        if not np.array_equal(np.sort(slot_of.astype(np.int64)),
                              np.arange(n)):
            raise ValueError("slot_of must be a permutation of [0, n)")
        table_blocks = (n * 4 + block_size - 1) // block_size
    if slot_of is not None or nodes_per_block > 1:
        manifest_fields.update(
            nodes_per_block=nodes_per_block,
            layout="packed" if slot_of is not None else "node-order",
            slot_table_blocks=table_blocks)
        if slot_of is not None:
            manifest_fields["slot_table_crc32"] = zlib.crc32(slot_of)
    manifest = json.dumps(manifest_fields).encode()
    if len(MAGIC) + 4 + len(manifest) > block_size:
        raise ValueError("manifest does not fit the header block")
    payload = d * 4 + r * 4
    data_start = 1 + table_blocks
    blocks = np.zeros((data_start + n, block_size), dtype=np.uint8)
    blocks[0, : len(MAGIC)] = np.frombuffer(MAGIC, np.uint8)
    blocks[0, len(MAGIC): len(MAGIC) + 4] = np.frombuffer(
        np.uint32(len(manifest)).astype("<u4").tobytes(), np.uint8)
    blocks[0, len(MAGIC) + 4: len(MAGIC) + 4 + len(manifest)] = (
        np.frombuffer(manifest, np.uint8))
    if table_blocks:
        blocks[1:data_start].reshape(-1)[: n * 4] = slot_of.view(np.uint8)
        # Records land at their assigned slots: row `data_start + slot_of[i]`
        # holds node i.  node_order[s] = the node stored at slot s.
        node_order = np.empty((n,), dtype=np.int64)
        node_order[slot_of.astype(np.int64)] = np.arange(n)
    else:
        node_order = np.arange(n)
    blocks[data_start:, : d * 4] = (
        vectors[node_order].view(np.uint8).reshape(n, d * 4))
    blocks[data_start:, d * 4: payload] = (
        adj[node_order].view(np.uint8).reshape(n, r * 4))
    crcs = np.empty((n,), dtype="<u4")
    rows = blocks[data_start:, :payload]
    for i in range(n):
        crcs[i] = zlib.crc32(rows[i])   # contiguous row view, no copy
    blocks[data_start:, payload: payload + 4] = crcs.view(np.uint8).reshape(
        n, 4)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        blocks.tofile(f)   # no tobytes() double-copy of a store-sized buffer
    tmp.replace(path)  # atomic publish: no half-written store under readers
    return path


def ensure_block_store(
    path: str | pathlib.Path,
    vectors: np.ndarray,
    adj: np.ndarray,
    log=None,
    nodes_per_block: int = 1,
    slot_of: np.ndarray | None = None,
) -> BlockStore:
    """Open the store at ``path`` if its content fingerprint matches
    ``vectors`` (and its layout matches the requested one); otherwise —
    absent, unreadable (any :class:`BlockStoreError`), stale, or laid out
    differently — write it fresh and open that.

    The one bootstrap every consumer shares (serve launcher, e2e example,
    benchmarks): geometry can collide between two builds, a torn file must
    not crash the "rewrite if needed" promise, and the fingerprint is the
    only content identity.  ``log`` (e.g. ``print``) narrates what happened.
    """
    path = pathlib.Path(path)
    vectors = np.ascontiguousarray(np.asarray(vectors), dtype="<f4")
    want_table_crc = (
        None if slot_of is None
        else zlib.crc32(np.ascontiguousarray(np.asarray(slot_of), "<i4")))
    if path.exists():
        try:
            store = BlockStore(path)
            if store.vectors_crc32 != zlib.crc32(vectors):
                reason = "stale (content fingerprint mismatch)"
            elif (store.nodes_per_block != nodes_per_block
                  or store.slot_table_crc32 != want_table_crc):
                reason = "laid out differently"
            else:
                return store
        except BlockStoreError as e:
            reason = f"unreadable ({type(e).__name__})"
        if log:
            log(f"block store {path} is {reason}; rewriting")
    write_block_store(path, vectors, adj, nodes_per_block=nodes_per_block,
                      slot_of=slot_of)
    if log:
        log(f"wrote block store {path} ({path.stat().st_size/1e6:.1f}MB)")
    return BlockStore(path)
