"""Two-tier disk-resident index model (paper §1/§5 serving architecture).

DiskANN's node layout packs (full vector + adjacency list) into SSD sectors;
the search holds PQ codes in RAM, routes on them, and pays one SSD read per
expanded node. On the TPU adaptation:

  fast tier  (HBM)   : PQ codes (N, M) uint8 + adjacency (N, R) int32
  slow tier          : full-precision vectors (N, D) — either host-memory
                       rows (:class:`InMemorySlowTier`, the benchmark mode)
                       or a block-aligned on-disk store
                       (:class:`BlockSlowTier` over
                       :class:`repro.index.blockstore.BlockStore`, the
                       out-of-core deployment: one aligned block per node,
                       vector + adjacency + checksum, read via memmap)

The *cost model* is preserved exactly: every node expansion is one slow-tier
"read" and the per-query hop counter of :class:`repro.core.search.SearchStats`
is the I/O metric the paper's Figures 2a/2c report. :class:`DiskTierModel`
converts counted reads into modelled latency so benchmarks can report the
paper's latency numbers under an explicit, documented hardware model — and
with a :class:`BlockSlowTier` the same read counts come back *measured*
(``BlockStore.stats``), so ``benchmarks/disk_io.py`` prints modelled and
measured block-read latency side by side for one query stream.

The slow tier is pluggable behind the small :class:`SlowTier` protocol
(``fetch_beams`` — the rerank's batched node fetch): ``TieredIndex`` keeps
its in-memory rows, and serving swaps in the block store via
``TieredBackend(index, slow_tier=BlockSlowTier(...))`` without touching the
walk kernels (the fast tier routes identically either way, and the rerank
arithmetic is shared — results are bit-identical between tiers).
:class:`BlockSlowTier` adds what a real disk tier needs: a hot-node cache
(bounded LRU + statically pinned entry-proximal nodes, exact hit/miss
counters surfaced in engine stats) and an async host-thread prefetch the
staged pipeline uses to overlap batch i's block reads with batch i+1's
continue programs.

Serving architecture: the functions below (:func:`search_tiered`,
:func:`search_tiered_adaptive`) are the kernel-level entry points over one
tiered index; production serving lowers through
:class:`repro.serving.SearchEngine` with a :class:`~repro.serving.TieredBackend`
— the staged pipeline (admission -> probe -> host-bucket -> continue ->
slow-tier rerank, double-buffered across batches) drives these same compiled
programs, auto-picks the continue phase's bucket family from the
granted-budget histogram, coalesces micro-batches below the admission lane
threshold, and hosts the recalibration hook for Online-MCGI index refreshes.
At billion scale the index shards across a mesh
(:mod:`repro.distributed.sharded_search`, one locally built sub-graph +
PQ codes + slow-tier rows per shard) behind the same engine API: the
distributed step runs staged at engine parity — shard walks checkpointed at
the probe horizon, per-shard budget laws (each shard's own calibrated
(lam, l_min); see :func:`repro.core.calibrate.calibrate_budget_law_per_shard`)
granting per-(query, shard) budgets in-graph, host bucket scheduling between
mesh programs, and per-bucket continues resuming into the shard-local exact
rerank + hedged global merge. ``DiskTierModel.latency_us(...,
overlapped=True)`` is the matching latency model: the rerank batch of batch
i is issued while batch i+1's walk computes, so per-batch modelled time is
the max of the two stages, not their sum.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
from typing import Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod
from repro.core.types import GraphIndex
from repro.index.blockstore import BlockStore
from repro.pq import PqCodebook, build_lut, pq_encode, train_pq

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiskTierModel:
    """Latency model for the slow tier.

    Defaults approximate the paper's testbed (Micron 5300 PRO SATA SSD):
    ~90us random 4K read; beam-width reads issued concurrently with an
    effective queue depth. Swap-in constants for NVMe (~20us) or host-DRAM
    over PCIe (~2us) to study other deployments.
    """

    read_latency_us: float = 90.0
    queue_depth: int = 8

    def latency_us(self, reads: Array, rerank_reads: Array | int = 0,
                   *, overlapped: bool = False) -> Array:
        """Modelled wall time for ``reads`` sequential beam expansions plus an
        optional final rerank batch of ``rerank_reads`` node fetches.

        Each expansion is a dependent read (graph traversal is a pointer
        chase), so the ``reads`` term is serial. The rerank batch has no
        dependencies, so its reads are issued ``queue_depth`` at a time:
        ceil(rerank_reads / queue_depth) serialised rounds.

        ``overlapped=True`` models the staged double-buffered engine
        (``repro.serving.SearchEngine.search_batches``): reads are issued
        while compute proceeds — batch i's independent rerank reads are in
        flight during batch i+1's dependent walk chain, so in steady state a
        batch costs the *max* of the two stages instead of their sum. The
        dependent chain itself cannot be hidden (each hop's address comes
        from the previous read); only the stage overlap is modelled.
        """
        serial = reads.astype(jnp.float32) * self.read_latency_us
        rerank_reads = jnp.asarray(rerank_reads, jnp.float32)
        rounds = jnp.ceil(rerank_reads / max(self.queue_depth, 1))
        rerank_time = rounds * self.read_latency_us
        if overlapped:
            return jnp.maximum(serial, rerank_time)
        return serial + rerank_time


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TieredIndex:
    """A disk-resident MCGI/Vamana index: graph + PQ fast tier + slow tier."""

    graph: GraphIndex
    codebook: PqCodebook
    codes: Array       # (N, M) uint8 — fast tier
    vectors: Array     # (N, D) f32   — slow tier rows (in-memory mode; disk
                       # deployments serve these from a BlockSlowTier instead)

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    def fast_tier_bytes(self) -> int:
        return (
            self.codes.size
            + self.graph.adj.size * 4
            + self.codebook.centroids.size * 4
        )

    def slow_tier_bytes(self) -> int:
        return self.vectors.size * 4


def build_tiered_index(
    x: Array, graph: GraphIndex, m_pq: int = 16, seed: int = 0
) -> TieredIndex:
    # PQ needs D % M == 0; zero-pad the PQ view (T2I: 200 -> 208). L2 over
    # zero-padded dims is unchanged; the slow tier keeps the original x.
    d = x.shape[1]
    pad = (-d) % m_pq
    x_pq = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    book = train_pq(x_pq, m=m_pq, seed=seed)
    codes = pq_encode(x_pq, book)
    return TieredIndex(graph=graph, codebook=book, codes=codes, vectors=x)


def search_tiered(
    index: TieredIndex,
    queries: Array,
    beam_width: int,
    k: int = 10,
    max_hops: int = 2048,
    rerank: bool = True,
    step_kernel: str | None = None,
) -> tuple[Array, Array, search_mod.SearchStats]:
    """PQ-routed beam search with slow-tier rerank (the deployed path)."""
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, beam_width=beam_width, max_hops=max_hops,
        k=k, rerank=rerank, step_kernel=step_kernel,
    )


def search_tiered_adaptive(
    index: TieredIndex,
    queries: Array,
    budget_cfg: search_mod.AdaptiveBeamBudget,
    k: int = 10,
    rerank: bool = True,
    num_buckets: int | None = None,
    step_kernel: str | None = None,
) -> tuple[Array, Array, search_mod.SearchStats, search_mod.AdaptiveStats]:
    """Per-query adaptive-beam serving path (Prop. 4.2 in the engine).

    Same tiers and cost model as :func:`search_tiered`, but each query's beam
    budget is set from its own probe-phase LID estimate — easy queries retire
    early and stop paying slow-tier reads for the hard ones. Returns
    (ids, d2, stats, adaptive_stats); ``adaptive_stats`` carries the
    per-query LID and granted budget for observability.

    ``num_buckets`` >= 2 runs the continue phase budget-bucketed (queries
    grouped by granted budget, each bucket jitted to its own ceiling) so
    converged lanes free real compute; results are identical to the
    single-program path.
    """
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq_adaptive(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, budget_cfg=budget_cfg, k=k, rerank=rerank,
        num_buckets=num_buckets, step_kernel=step_kernel,
    )


def _query_luts(index: TieredIndex, queries: Array) -> Array:
    """Per-query ADC LUTs, zero-padding queries to the PQ-padded dim."""
    d_book = index.codebook.m * index.codebook.dsub
    q_pq = (jnp.pad(queries, ((0, 0), (0, d_book - queries.shape[1])))
            if queries.shape[1] < d_book else queries)
    return build_lut(q_pq, index.codebook.centroids)


# --------------------------------------------------------------------------
# Pluggable slow tier: the rerank's batched node fetch, served from memory
# rows or from the block-aligned disk store.
# --------------------------------------------------------------------------


class SlowTier(Protocol):
    """What the serving rerank needs from a slow tier.

    ``fetch_beams(beam_ids (Q, L) int) -> (Q, L, D) float32`` — the batched
    node fetch of the final beam (negative/INVALID lanes are clamped to node
    0, exactly like the in-memory ``x_slow[max(ids, 0)]`` gather; the rerank
    masks them to inf afterwards).  ``is_disk`` tells the engine whether the
    fetch is worth hiding behind the next batch's device programs.
    """

    is_disk: bool

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray: ...


class InMemorySlowTier:
    """The historical slow tier: full-precision rows in (host/device) memory.

    Exists so callers can treat both tiers uniformly; the serving backends
    keep their fused in-graph gather for this case (same math, no host hop).
    """

    is_disk = False

    def __init__(self, vectors: Array):
        # Held as a device array: the serving rerank passes it straight into
        # the jitted gather, so construction pays the upload once, not every
        # batch.
        self.vectors = jnp.asarray(vectors)

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray:
        safe = np.maximum(np.asarray(beam_ids, np.int64), 0)
        return np.asarray(self.vectors)[safe]


class BlockSlowTier:
    """Disk-resident slow tier over a :class:`~repro.index.blockstore.BlockStore`.

    Adds the serving policy the raw store doesn't have:

    * **hot-node cache** — a bounded LRU of recently fetched vectors plus a
      statically *pinned* set (entry-proximal nodes: every walk funnels
      through the medoid's neighbourhood, so those blocks are the hottest in
      any trace and should never be evicted).  Hit/miss counters are exact —
      each distinct node id per fetch counts once, hit or miss — and are
      surfaced per batch in the engine's ``BatchResult.extras``.
    * **async prefetch** — :meth:`prefetch` runs the fetch on a host worker
      thread and returns a future; the staged pipeline submits batch i's
      rerank fetch right after batch i+1's continue programs are dispatched,
      so the block reads and the device compute overlap.

    Thread safety: the cache and counters are guarded by a lock that is
    *never* held across block I/O (a separate lock serialises store reads),
    so :meth:`stats` — called at every pipeline gather — returns immediately
    even while a prefetch read is in flight; blocking there would stall the
    host loop on exactly the I/O the prefetch stage exists to hide.  The
    engine has at most one prefetch in flight per tier; concurrent external
    fetches stay correct (worst case a doubly-read block, counters exact per
    call).  Counters start at zero: the pinned-set load is construction,
    not serving traffic.
    """

    is_disk = True

    def __init__(self, store: BlockStore, cache_nodes: int = 4096,
                 pinned_ids=None):
        self.store = store
        self.cache_nodes = int(cache_nodes)
        self._lru: "collections.OrderedDict[int, np.ndarray]" = (
            collections.OrderedDict())
        self._pinned: dict[int, np.ndarray] = {}
        self._lock = threading.Lock()       # cache + counters; no I/O under it
        self._io_lock = threading.Lock()    # block-store reads
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="slow-tier-prefetch")
        self.hits = 0
        self.misses = 0
        if pinned_ids is not None:
            ids = np.unique(np.asarray(pinned_ids, np.int64))
            if ids.size:
                vecs, _ = store.read_many(ids)
                self._pinned = {int(i): vecs[j].copy()
                                for j, i in enumerate(ids)}
        store.reset_stats()   # serving counters exclude the pinned load

    # ------------------------------------------------------------- fetching

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """(len(ids), D) float32 for a flat id array (duplicates fine —
        each *distinct* id counts once toward hits/misses and block reads)."""
        ids = np.asarray(ids, np.int64).ravel()
        uniq, inverse = np.unique(ids, return_inverse=True)
        out = np.empty((uniq.size, self.store.d), np.float32)
        with self._lock:                      # probe the cache, count
            missing: list[tuple[int, int]] = []
            for j, i in enumerate(uniq.tolist()):
                v = self._pinned.get(i)
                if v is None and (v := self._lru.get(i)) is not None:
                    self._lru.move_to_end(i)
                if v is None:
                    missing.append((j, i))
                else:
                    out[j] = v
            self.hits += uniq.size - len(missing)
            self.misses += len(missing)
        if missing:
            with self._io_lock:               # the block reads — cache lock free
                vecs, _ = self.store.read_many(
                    np.asarray([i for _, i in missing], np.int64))
            with self._lock:                  # insert what was read
                for (j, i), v in zip(missing, vecs):
                    out[j] = v
                    if self.cache_nodes > 0:
                        self._lru[i] = v.copy()
                        while len(self._lru) > self.cache_nodes:
                            self._lru.popitem(last=False)
        return out[inverse]

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray:
        beam_ids = np.asarray(beam_ids)
        safe = np.maximum(beam_ids, 0)
        flat = self.fetch(safe.ravel())
        return flat.reshape(*safe.shape, self.store.d)

    def prefetch(self, beam_ids: np.ndarray) -> "concurrent.futures.Future":
        """Submit :meth:`fetch_beams` to the host worker; the caller joins
        the future at rerank time (the staged pipeline joins it one stage
        later, after the next batch's continues are on the device queue)."""
        return self._pool.submit(self.fetch_beams, np.asarray(beam_ids))

    # ---------------------------------------------------------- observability

    def stats(self) -> dict:
        """Cumulative cache + I/O counters (exact on a replayed stream)."""
        with self._lock:
            total = self.hits + self.misses
            return {
                "cache_hits": self.hits,
                "cache_misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "pinned_nodes": len(self._pinned),
                "cached_nodes": len(self._lru),
                "blocks_read": self.store.stats.blocks_read,
                "read_time_s": self.store.stats.read_time_s,
                "measured_read_us": self.store.stats.measured_read_us(),
            }

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = 0
            self.store.reset_stats()

    def clear_cache(self) -> None:
        """Empty the LRU (cold-cache experiments); the pinned set stays —
        it is static by design."""
        with self._lock:
            self._lru.clear()


def entry_proximal_ids(adj, entry, limit: int = 256) -> np.ndarray:
    """BFS order from the entry medoid, truncated to ``limit`` nodes — the
    static pin set for the hot-node cache (every query's walk starts here)."""
    adj = np.asarray(adj)
    entry = int(np.asarray(entry))
    seen = {entry}
    order = [entry]
    frontier = [entry]
    while frontier and len(order) < limit:
        nxt = []
        for u in frontier:
            for v in adj[u].tolist():
                if v >= 0 and v not in seen:
                    seen.add(v)
                    order.append(v)
                    nxt.append(v)
                    if len(order) >= limit:
                        return np.asarray(order, np.int64)
        frontier = nxt
    return np.asarray(order, np.int64)


def open_or_build_slow_tier(path, index: TieredIndex,
                            cache_nodes: int = 4096, pin_nodes: int = 256,
                            log=None) -> BlockSlowTier:
    """The serving bootstrap every ``--disk PATH`` consumer shares: open (or
    write — absent/unreadable/stale, see
    :func:`repro.index.blockstore.ensure_block_store`) the block store for
    ``index`` and wrap it in a :class:`BlockSlowTier` with the
    entry-proximal neighbourhood pinned."""
    from repro.index.blockstore import ensure_block_store

    store = ensure_block_store(path, np.asarray(index.vectors),
                               np.asarray(index.graph.adj), log=log)
    pinned = (entry_proximal_ids(index.graph.adj, index.graph.entry,
                                 limit=pin_nodes) if pin_nodes > 0 else None)
    return BlockSlowTier(store, cache_nodes=cache_nodes, pinned_ids=pinned)


def rerank_with_slow_tier(slow_tier, beam_ids, queries, k: int,
                          prefetched: np.ndarray | None = None):
    """Slow-tier rerank of a full beam through the pluggable tier.

    Host-gathers the beam's vectors (``prefetched`` skips the gather — the
    joined result of :meth:`BlockSlowTier.prefetch`) and runs the same
    jitted arithmetic as the fused in-memory rerank
    (:func:`repro.core.search._rerank_from_vecs`) — bit-identical results.
    """
    vecs = (prefetched if prefetched is not None
            else slow_tier.fetch_beams(np.asarray(beam_ids)))
    return search_mod._rerank_from_vecs_jit(
        jnp.asarray(beam_ids), jnp.asarray(vecs), jnp.asarray(queries), k=k)
