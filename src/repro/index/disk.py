"""Two-tier disk-resident index model (paper §1/§5 serving architecture).

DiskANN's node layout packs (full vector + adjacency list) into SSD sectors;
the search holds PQ codes in RAM, routes on them, and pays one SSD read per
expanded node. On the TPU adaptation:

  fast tier  (HBM)   : PQ codes (N, M) uint8 + adjacency (N, R) int32
  slow tier          : full-precision vectors (N, D) — either host-memory
                       rows (:class:`InMemorySlowTier`, the benchmark mode)
                       or a block-aligned on-disk store
                       (:class:`BlockSlowTier` over
                       :class:`repro.index.blockstore.BlockStore`, the
                       out-of-core deployment: one aligned block per node,
                       vector + adjacency + checksum, read via memmap)

The *cost model* is preserved exactly: every node expansion is one slow-tier
"read" and the per-query hop counter of :class:`repro.core.search.SearchStats`
is the I/O metric the paper's Figures 2a/2c report. :class:`DiskTierModel`
converts counted reads into modelled latency so benchmarks can report the
paper's latency numbers under an explicit, documented hardware model — and
with a :class:`BlockSlowTier` the same read counts come back *measured*
(``BlockStore.stats``), so ``benchmarks/disk_io.py`` prints modelled and
measured block-read latency side by side for one query stream.

The slow tier is pluggable behind the small :class:`SlowTier` protocol
(``fetch_beams`` — the rerank's batched node fetch): ``TieredIndex`` keeps
its in-memory rows, and serving swaps in the block store via
``TieredBackend(index, slow_tier=BlockSlowTier(...))`` without touching the
walk kernels (the fast tier routes identically either way, and the rerank
arithmetic is shared — results are bit-identical between tiers).
:class:`BlockSlowTier` adds what a real disk tier needs: a hot-node cache
(bounded LRU of full records — vector *and* adjacency row — plus statically
pinned entry-proximal nodes, exact hit/miss counters surfaced in engine
stats) and an async host-thread prefetch the staged pipeline uses to
overlap batch i's block reads with batch i+1's continue programs.  Tiers
own worker threads, so they are closeable (``close()`` / context manager);
``TieredBackend`` closes a replaced disk tier on index refresh.

Three-tier storage and the promotion lifecycle
----------------------------------------------
With ``hot_nodes > 0`` the tier grows a frequency-aware *hot tier*
(:class:`repro.index.hot_tier.HotTier`) and the storage hierarchy becomes
three levels, each a strict superset of speed over the one below:

  hot tier   : dense preallocated record arrays, O(1) membership probe
               (``slot[id]``) — the fastest host copy of the traffic's
               current hot set; optionally mirrored to device arrays
               (``device_mirror``) as the steering fast tier a fused
               out-of-core hop would index
  block cache: the pinned set + LRU of this class — host-DRAM records
               keyed by node id, populated by demand misses
  SSD        : the block-aligned store (:class:`BlockStore`) — one
               checksummed aligned block per ``nodes_per_block`` records

The lifecycle: every fetch adds 1 to each distinct accessed id's EMA score
(the exact PR 5 hit/miss counting, extended per node).  The serving
engine's gather stage calls :meth:`BlockSlowTier.promotion_tick` once per
batch — non-blocking: it submits (at most) one tick to the hot tier's own
promoter thread and returns.  A tick snapshots + decays the scores
(``freq *= decay`` — old traffic ages out, so a shifted hot set overtakes
the old one), selects up to ``hot_chunk`` hottest non-resident nodes, reads
their records through a *private* store handle (promotion I/O never holds
the serving ``_io_lock`` and never counts in the serving stream's I/O
stats), and installs them under the cache lock as a bounded memcpy —
demoting the coldest residents only for strictly-hotter candidates
(hysteresis).  Demotion is metadata-only and records are immutable, so the
hot tier changes *where* a record is read from, never its bytes: search
results are bit-identical with the tier on or off (the engine-parity
matrix pins the hot axis).

Out-of-core walk (indices bigger than device memory)
----------------------------------------------------
With a ``TieredBackend`` the *walk* still needs the whole adjacency in HBM
— only the rerank is out-of-core.  The out-of-core serving path
(:class:`repro.serving.OutOfCoreBackend`) drops that requirement: device
memory holds only the PQ codes (+ codebook and entry), and the walk reads
adjacency rows at walk time through this module's :func:`ooc_probe` /
:func:`ooc_continue` drivers.  Each hop is split at the frontier selection
(:func:`repro.core.search._select_frontier` /
:func:`~repro.core.search._expand_frontier`): a small device program picks
every lane's next node ``u`` and yields it to the host, the host fetches
``adj[u]`` from the block store through :meth:`BlockSlowTier.fetch_adj`
(block-granular: one I/O-block read caches all co-located records, which
is what the build-time packed layout is for), and the next device program
expands the fetched rows and selects the following frontier.  Lanes are
round-robined across ``io_groups`` so one group's block reads run on the
tier's worker thread while another group's hop program runs on the device.
Per-lane activity masks replicate the vmapped ``while_loop``'s
select-masking exactly, so results are bit-identical to the in-memory walk
(the engine-parity matrix pins ooc against the in-memory tiered reference).

The staged pipeline adds a *walk-prefetch* stage for this backend: the
continue phase's first frontier is computable as soon as the probe and the
budget grant finish, so the engine submits those adjacency block reads
(bounded by the backend's ``io_depth``) one stage ahead — they land in the
tier's cache while other batches' device programs run, exactly like the
rerank prefetch stage hides the final beam fetch.

Live mutation (the delta tier)
------------------------------
Everything above serves an *immutable* published index.  Inserts and
deletes land in :mod:`repro.index.delta`: an in-memory delta tier absorbs
writes (each inserted node wired into a private combined graph by
Online-MCGI's incremental rewire — greedy search to the vector, on-the-fly
LID, per-node alpha prune, mirrored reverse edges; deletes are tombstones)
while the :class:`BlockSlowTier` here keeps serving reads untouched.
Searches fan out: the base engine runs with tombstoned base nodes excluded
*in-graph* (the packed filter of :func:`repro.core.search.pack_filter`
pre-seeds the walk's visited bitset, so an excluded node is never expanded
— it stays navigable, which keeps the graph connected without eager
unlinking... it just can't be returned), the delta contributes its exact
top-k over the live inserted rows, and both pools merge through the normal
full-precision rerank.  A periodic merge compacts live content into a new
base generation: deterministic rebuild, packed block layout, atomic
tmp-rename store publish under a generation-numbered path, live
``update_backend`` swap (in-flight flights finish on their dispatch-time
backend snapshot — a closed tier's reads degrade to synchronous, bytes
unchanged), and an optional drift-triggered ``recalibrate``.  At a merge
boundary the live index's results are bit-identical to a freshly built
index of the same content.

Serving architecture: the functions below (:func:`search_tiered`,
:func:`search_tiered_adaptive`) are the kernel-level entry points over one
tiered index; production serving lowers through
:class:`repro.serving.SearchEngine` with a :class:`~repro.serving.TieredBackend`
— the staged pipeline (admission -> probe -> host-bucket -> continue ->
slow-tier rerank, double-buffered across batches) drives these same compiled
programs, auto-picks the continue phase's bucket family from the
granted-budget histogram, coalesces micro-batches below the admission lane
threshold, and hosts the recalibration hook for Online-MCGI index refreshes.
At billion scale the index shards across a mesh
(:mod:`repro.distributed.sharded_search`, one locally built sub-graph +
PQ codes + slow-tier rows per shard) behind the same engine API: the
distributed step runs staged at engine parity — shard walks checkpointed at
the probe horizon, per-shard budget laws (each shard's own calibrated
(lam, l_min); see :func:`repro.core.calibrate.calibrate_budget_law_per_shard`)
granting per-(query, shard) budgets in-graph, host bucket scheduling between
mesh programs, and per-bucket continues resuming into the shard-local exact
rerank + hedged global merge. ``DiskTierModel.latency_us(...,
overlapped=True)`` is the matching latency model: the rerank batch of batch
i is issued while batch i+1's walk computes, so per-batch modelled time is
the max of the two stages, not their sum.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod
from repro.core.types import GraphIndex
from repro.index.blockstore import BlockStore
from repro.pq import PqCodebook, build_lut, pq_encode, train_pq

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiskTierModel:
    """Latency model for the slow tier.

    Defaults approximate the paper's testbed (Micron 5300 PRO SATA SSD):
    ~90us random 4K read; beam-width reads issued concurrently with an
    effective queue depth. Swap-in constants for NVMe (~20us) or host-DRAM
    over PCIe (~2us) to study other deployments.
    """

    read_latency_us: float = 90.0
    queue_depth: int = 8

    def latency_us(self, reads: Array, rerank_reads: Array | int = 0,
                   *, overlapped: bool = False) -> Array:
        """Modelled wall time for ``reads`` sequential beam expansions plus an
        optional final rerank batch of ``rerank_reads`` node fetches.

        Each expansion is a dependent read (graph traversal is a pointer
        chase), so the ``reads`` term is serial. The rerank batch has no
        dependencies, so its reads are issued ``queue_depth`` at a time:
        ceil(rerank_reads / queue_depth) serialised rounds.

        ``overlapped=True`` models the staged double-buffered engine
        (``repro.serving.SearchEngine.search_batches``): reads are issued
        while compute proceeds — batch i's independent rerank reads are in
        flight during batch i+1's dependent walk chain, so in steady state a
        batch costs the *max* of the two stages instead of their sum. The
        dependent chain itself cannot be hidden (each hop's address comes
        from the previous read); only the stage overlap is modelled.
        """
        serial = reads.astype(jnp.float32) * self.read_latency_us
        rerank_reads = jnp.asarray(rerank_reads, jnp.float32)
        rounds = jnp.ceil(rerank_reads / max(self.queue_depth, 1))
        rerank_time = rounds * self.read_latency_us
        if overlapped:
            return jnp.maximum(serial, rerank_time)
        return serial + rerank_time


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TieredIndex:
    """A disk-resident MCGI/Vamana index: graph + PQ fast tier + slow tier."""

    graph: GraphIndex
    codebook: PqCodebook
    codes: Array       # (N, M) uint8 — fast tier
    vectors: Array     # (N, D) f32   — slow tier rows (in-memory mode; disk
                       # deployments serve these from a BlockSlowTier instead)

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    def fast_tier_bytes(self) -> int:
        return (
            self.codes.size
            + self.graph.adj.size * 4
            + self.codebook.centroids.size * 4
        )

    def slow_tier_bytes(self) -> int:
        return self.vectors.size * 4


def build_tiered_index(
    x: Array, graph: GraphIndex, m_pq: int = 16, seed: int = 0
) -> TieredIndex:
    # PQ needs D % M == 0; zero-pad the PQ view (T2I: 200 -> 208). L2 over
    # zero-padded dims is unchanged; the slow tier keeps the original x.
    d = x.shape[1]
    pad = (-d) % m_pq
    x_pq = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    book = train_pq(x_pq, m=m_pq, seed=seed)
    codes = pq_encode(x_pq, book)
    return TieredIndex(graph=graph, codebook=book, codes=codes, vectors=x)


def search_tiered(
    index: TieredIndex,
    queries: Array,
    beam_width: int,
    k: int = 10,
    max_hops: int = 2048,
    rerank: bool = True,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, search_mod.SearchStats]:
    """PQ-routed beam search with slow-tier rerank (the deployed path).

    ``excl`` ((Q, ceil(n/32)) words from ``search.pack_filter``) runs the
    walk attribute-filtered in-graph; the rerank consumes a pre-scrubbed
    beam, so no out-of-filter id can surface.
    """
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, beam_width=beam_width, max_hops=max_hops,
        k=k, rerank=rerank, step_kernel=step_kernel, excl=excl,
    )


def search_tiered_adaptive(
    index: TieredIndex,
    queries: Array,
    budget_cfg: search_mod.AdaptiveBeamBudget,
    k: int = 10,
    rerank: bool = True,
    num_buckets: int | None = None,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, search_mod.SearchStats, search_mod.AdaptiveStats]:
    """Per-query adaptive-beam serving path (Prop. 4.2 in the engine).

    Same tiers and cost model as :func:`search_tiered`, but each query's beam
    budget is set from its own probe-phase LID estimate — easy queries retire
    early and stop paying slow-tier reads for the hard ones. Returns
    (ids, d2, stats, adaptive_stats); ``adaptive_stats`` carries the
    per-query LID and granted budget for observability.

    ``num_buckets`` >= 2 runs the continue phase budget-bucketed (queries
    grouped by granted budget, each bucket jitted to its own ceiling) so
    converged lanes free real compute; results are identical to the
    single-program path.
    """
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq_adaptive(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, budget_cfg=budget_cfg, k=k, rerank=rerank,
        num_buckets=num_buckets, step_kernel=step_kernel, excl=excl,
    )


def _query_luts(index: TieredIndex, queries: Array) -> Array:
    """Per-query ADC LUTs, zero-padding queries to the PQ-padded dim."""
    d_book = index.codebook.m * index.codebook.dsub
    q_pq = (jnp.pad(queries, ((0, 0), (0, d_book - queries.shape[1])))
            if queries.shape[1] < d_book else queries)
    return build_lut(q_pq, index.codebook.centroids)


# --------------------------------------------------------------------------
# Pluggable slow tier: the rerank's batched node fetch, served from memory
# rows or from the block-aligned disk store.
# --------------------------------------------------------------------------


class SlowTier(Protocol):
    """What the serving rerank needs from a slow tier.

    ``fetch_beams(beam_ids (Q, L) int) -> (Q, L, D) float32`` — the batched
    node fetch of the final beam.  Rows for negative/INVALID lanes carry no
    information (the rerank masks their distances to inf before ranking):
    the in-memory tier clamps them to node 0 like the in-graph
    ``x_slow[max(ids, 0)]`` gather, while :class:`BlockSlowTier` zero-fills
    them — INVALID lanes must never count toward its cache statistics or
    trigger block I/O.  ``is_disk`` tells the engine whether the fetch is
    worth hiding behind the next batch's device programs.
    """

    is_disk: bool

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray: ...


class InMemorySlowTier:
    """The historical slow tier: full-precision rows in (host/device) memory.

    Exists so callers can treat both tiers uniformly; the serving backends
    keep their fused in-graph gather for this case (same math, no host hop).
    """

    is_disk = False

    def __init__(self, vectors: Array):
        # Held as a device array: the serving rerank passes it straight into
        # the jitted gather, so construction pays the upload once, not every
        # batch.
        self.vectors = jnp.asarray(vectors)

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray:
        safe = np.maximum(np.asarray(beam_ids, np.int64), 0)
        return np.asarray(self.vectors)[safe]


class BlockSlowTier:
    """Disk-resident slow tier over a :class:`~repro.index.blockstore.BlockStore`.

    Adds the serving policy the raw store doesn't have:

    * **hot-node cache** — a bounded LRU of recently fetched *records*
      (vector + adjacency row: the walk and the rerank share one cache)
      plus a statically *pinned* set (entry-proximal nodes: every walk
      funnels through the medoid's neighbourhood, so those blocks are the
      hottest in any trace and should never be evicted).  Hit/miss counters
      are exact — each distinct *valid* node id per fetch counts once, hit
      or miss; INVALID (-1) padding lanes are excluded from counting and
      I/O — and are surfaced per batch in the engine's
      ``BatchResult.extras``.  Over a packed store
      (``nodes_per_block > 1``) a miss pulls the whole I/O block and caches
      every co-located record, so the build-time packing turns a hop's
      co-expansions into cache hits.
    * **async prefetch** — :meth:`prefetch` (rerank beams) and
      :meth:`prefetch_adj` (walk frontiers) run the fetch on a host worker
      pool and return a future; the staged pipeline submits batch i's
      fetches right after batch i+1's device programs are dispatched, so
      the block reads and the device compute overlap.  ``io_workers`` sizes
      the pool (the out-of-core walk round-robins ``io_groups`` lane groups
      whose whole point is overlapping one group's reads with another's
      device hop — a single worker would serialise them, so
      :class:`repro.serving.OutOfCoreBackend` adopts its ``io_groups`` as
      the default via :meth:`default_io_workers`).  Each future wraps one
      deterministic fetch call, so per-future semantics are unchanged at
      any worker count: a joined prefetch future equals the direct fetch.
      The pool is created lazily and owned by the tier: :meth:`close` (also
      via ``with``) shuts it down — tiers must not leak
      ``slow-tier-prefetch`` threads per index refresh.
    * **frequency-aware hot tier** (``hot_nodes > 0``) — a
      :class:`repro.index.hot_tier.HotTier` probed between the pinned set
      and the LRU, fed by per-node EMA access scores and refilled by
      chunked asynchronous promotion ticks on its own promoter thread (see
      the module docstring's three-tier story).  :meth:`promotion_tick` is
      the engine-facing hook (non-blocking, at most one tick in flight);
      :meth:`drain_promotions` joins the pending tick — a determinism hook
      for tests and benchmarks, never called on the serving path.

    Thread safety: the cache and counters are guarded by a lock that is
    *never* held across block I/O (a separate lock serialises store reads),
    so :meth:`stats` — called at every pipeline gather — returns immediately
    even while a prefetch read is in flight; blocking there would stall the
    host loop on exactly the I/O the prefetch stage exists to hide.
    Concurrent fetches stay correct at any worker count (worst case a
    doubly-read block; hit/miss totals stay exact per call — each call
    counts its distinct valid ids once, wherever they are found).  Counters
    start at zero: the pinned-set load is construction, not serving
    traffic.
    """

    is_disk = True

    def __init__(self, store: BlockStore, cache_nodes: int = 4096,
                 pinned_ids=None, *, io_workers: int | None = None,
                 hot_nodes: int = 0, hot_chunk: int = 256,
                 freq_decay: float = 0.5, hot_device_mirror: bool = False):
        self.store = store
        self.cache_nodes = int(cache_nodes)
        # Prefetch pool width; None = unset (1, unless a consumer adopts a
        # better default via default_io_workers before the pool spins up).
        self.io_workers = io_workers
        # id -> (vector (D,) f32, adjacency (R,) i32)
        self._lru: "collections.OrderedDict[int, tuple]" = (
            collections.OrderedDict())
        self._pinned: dict[int, tuple] = {}
        self._lock = threading.Lock()       # cache + counters; no I/O under it
        self._io_lock = threading.Lock()    # block-store reads
        self._pool = None                   # lazy: many tiers never prefetch
        self._closed = False
        self.hits = 0
        self.misses = 0
        # Per-call fetch wall times (us), bounded window — percentiles via
        # fetch_latency_us(), kept out of stats() (see there).
        self._fetch_us: "collections.deque[float]" = collections.deque(
            maxlen=65536)
        if pinned_ids is not None:
            ids = np.unique(np.asarray(pinned_ids, np.int64))
            if ids.size:
                vecs, adjs = store.read_many(ids)
                self._pinned = {int(i): (vecs[j].copy(), adjs[j].copy())
                                for j, i in enumerate(ids)}
        self._hot = None
        self._hot_future = None
        if hot_nodes > 0:
            from repro.index.hot_tier import HotTier

            exclude = (np.fromiter(self._pinned, np.int64,
                                   len(self._pinned))
                       if self._pinned else None)
            # Private store handle: promotion I/O must share neither the
            # serving _io_lock nor the serving stream's I/O counters.
            self._hot = HotTier(BlockStore(store.path), store.n,
                                int(hot_nodes), chunk=hot_chunk,
                                decay=freq_decay, lock=self._lock,
                                exclude_ids=exclude,
                                device_mirror=hot_device_mirror)
        store.reset_stats()   # serving counters exclude the pinned load

    # ------------------------------------------------------------- lifecycle

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, wait: bool = True) -> None:
        """Shut down the prefetch workers and the hot tier's promoter.
        Idempotent and safe under concurrent callers — engine teardown can
        race a server drain: exactly one caller claims the pool and the hot
        tier (later/parallel closes see them already taken).  The memmapped
        store stays readable — only the owned threads are torn down, so a
        closed tier still serves synchronous fetches, and in-flight streams
        keep working: :meth:`prefetch` / :meth:`prefetch_adj` degrade to
        completed-synchronously futures instead of raising (the pipeline
        loses its overlap, never its results).  Promotion ticks become
        no-ops."""
        with self._lock:
            already, self._closed = self._closed, True
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)
        if not already and self._hot is not None:
            self._hot.close(wait=wait)

    def __enter__(self) -> "BlockSlowTier":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def default_io_workers(self, n: int) -> None:
        """Adopt ``n`` prefetch workers unless the constructor pinned a
        count or the pool already exists — how the out-of-core backend
        sizes the pool to its ``io_groups`` (one worker per round-robin
        group, so the groups' block reads actually overlap)."""
        with self._lock:
            if self.io_workers is None and self._pool is None:
                self.io_workers = max(1, int(n))

    def _submit(self, fn, *args) -> "concurrent.futures.Future":
        """Submit ``fn(*args)`` to the prefetch pool; on a closed tier (or
        one closed between the check and the submit — teardown may race an
        in-flight stream) run it synchronously into a completed future
        instead.  The store stays readable after close, so degrading costs
        the overlap, never the result."""
        with self._lock:
            pool = None
            if not self._closed:
                if self._pool is None:
                    self._pool = concurrent.futures.ThreadPoolExecutor(
                        max_workers=max(1, int(self.io_workers or 1)),
                        thread_name_prefix="slow-tier-prefetch")
                pool = self._pool
        if pool is not None:
            try:
                return pool.submit(fn, *args)
            except RuntimeError:
                pass   # pool shut down after the check; fall through
        fut: concurrent.futures.Future = concurrent.futures.Future()
        try:
            fut.set_result(fn(*args))
        except BaseException as e:
            fut.set_exception(e)
        return fut

    # ------------------------------------------------------------- promotion

    def promotion_tick(self):
        """Non-blocking: submit one hot-tier promotion round to the
        promoter thread (the engine calls this at every pipeline gather).
        At most one tick is in flight — if the previous one is still
        running, its future is returned unchanged, so a slow promotion can
        never pile up work.  Returns ``None`` without a hot tier or after
        :meth:`close`."""
        with self._lock:
            if self._hot is None or self._closed:
                return None
            fut = self._hot_future
            if fut is not None and not fut.done():
                return fut
            self._hot_future = self._hot.submit_tick()
            return self._hot_future

    def drain_promotions(self) -> None:
        """Join the in-flight promotion tick, if any — the determinism hook
        tests and benchmarks use between measured passes.  Serving never
        calls this; a promotion error would surface here."""
        fut = self._hot_future
        if fut is not None:
            fut.result()

    # ------------------------------------------------------------- fetching

    def fetch_records(
        self, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """(vectors (len, D) f32, adj (len, R) i32) for a flat array of
        *valid* node ids (duplicates fine — each distinct id counts once
        toward hits/misses, block reads, and the hot tier's frequency
        score)."""
        t0 = time.perf_counter()
        ids = np.asarray(ids, np.int64).ravel()
        uniq, inverse = np.unique(ids, return_inverse=True)
        vecs = np.empty((uniq.size, self.store.d), np.float32)
        adjs = np.empty((uniq.size, self.store.r), np.int32)
        hot = self._hot
        with self._lock:                      # probe the cache, count
            if hot is not None:
                hot.freq[uniq] += 1.0         # EMA numerator; tick decays it
            missing: list[tuple[int, int]] = []
            for j, i in enumerate(uniq.tolist()):
                rec = self._pinned.get(i)
                if rec is None and (rec := self._lru.get(i)) is not None:
                    self._lru.move_to_end(i)
                if rec is not None:
                    vecs[j], adjs[j] = rec
                    continue
                # Hot tier: O(1) membership, dense-array copy, no dict.
                if hot is not None and (s := int(hot.slot[i])) >= 0:
                    vecs[j] = hot.vectors[s]
                    adjs[j] = hot.adj[s]
                    hot.hot_hits += 1
                    continue
                missing.append((j, i))
            self.hits += uniq.size - len(missing)
            self.misses += len(missing)
        if missing:
            miss_ids = np.asarray([i for _, i in missing], np.int64)
            if self.store.nodes_per_block > 1:
                # Block-granular read: cache every co-located record, so the
                # packed layout's co-expansions become hits.
                with self._io_lock:
                    got_ids, got_v, got_a = self.store.read_blocks(
                        self.store.io_block_of(miss_ids))
                rec_of = {int(i): (got_v[j].copy(), got_a[j].copy())
                          for j, i in enumerate(got_ids)}
                with self._lock:
                    for j, i in missing:
                        vecs[j], adjs[j] = rec_of[i]
                    if self.cache_nodes > 0:
                        for i, rec in rec_of.items():
                            if i not in self._pinned:
                                self._lru[i] = rec
                                self._lru.move_to_end(i)
                        while len(self._lru) > self.cache_nodes:
                            self._lru.popitem(last=False)
            else:
                with self._io_lock:          # block reads — cache lock free
                    got_v, got_a = self.store.read_many(miss_ids)
                with self._lock:             # insert what was read
                    for (j, i), v, a in zip(missing, got_v, got_a):
                        vecs[j], adjs[j] = v, a
                        if self.cache_nodes > 0:
                            self._lru[i] = (v.copy(), a.copy())
                            while len(self._lru) > self.cache_nodes:
                                self._lru.popitem(last=False)
        dt_us = (time.perf_counter() - t0) * 1e6
        with self._lock:
            self._fetch_us.append(dt_us)
        return vecs[inverse], adjs[inverse]

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """(len(ids), D) float32 for a flat array of valid node ids."""
        return self.fetch_records(ids)[0]

    def fetch_beams(self, beam_ids: np.ndarray) -> np.ndarray:
        """Batched rerank fetch.  INVALID (-1) lanes are masked out of
        counting and I/O and their rows zero-filled — the rerank masks their
        distances to inf regardless, but padding lanes must not inflate the
        node-0 counters or trigger real block reads."""
        beam_ids = np.asarray(beam_ids, np.int64)
        out = np.zeros((*beam_ids.shape, self.store.d), np.float32)
        valid = beam_ids >= 0
        if valid.any():
            out[valid] = self.fetch(beam_ids[valid])
        return out

    def fetch_adj(self, ids: np.ndarray) -> np.ndarray:
        """Adjacency rows for the out-of-core walk's frontier: (..., R) i32,
        all-INVALID rows for INVALID lanes (inactive walk lanes issue no
        I/O and are masked out of the expand program anyway)."""
        ids = np.asarray(ids, np.int64)
        out = np.full((*ids.shape, self.store.r), search_mod.INVALID,
                      np.int32)
        valid = ids >= 0
        if valid.any():
            out[valid] = self.fetch_records(ids[valid])[1]
        return out

    def prefetch(self, beam_ids: np.ndarray) -> "concurrent.futures.Future":
        """Submit :meth:`fetch_beams` to the host worker; the caller joins
        the future at rerank time (the staged pipeline joins it one stage
        later, after the next batch's continues are on the device queue)."""
        return self._submit(self.fetch_beams, np.asarray(beam_ids))

    def prefetch_adj(self, ids: np.ndarray) -> "concurrent.futures.Future":
        """Submit :meth:`fetch_adj` to the host worker — the walk-prefetch
        stage (next hop's frontier rows) and the out-of-core walk's
        I/O-group overlap both ride this."""
        return self._submit(self.fetch_adj, np.asarray(ids))

    # ---------------------------------------------------------- observability

    def stats(self) -> dict:
        """Cumulative cache + I/O counters (exact on a replayed stream).
        With a hot tier, promotion counters ride along — promotion I/O is
        accounted on the hot tier's private store handle, so ``blocks_read``
        / ``io_blocks`` here describe the serving stream alone."""
        with self._lock:
            total = self.hits + self.misses
            out = {
                "cache_hits": self.hits,
                "cache_misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "pinned_nodes": len(self._pinned),
                "cached_nodes": len(self._lru),
                "blocks_read": self.store.stats.blocks_read,
                "io_blocks": self.store.stats.io_blocks,
                "read_time_s": self.store.stats.read_time_s,
                "measured_read_us": self.store.stats.measured_read_us(),
            }
            if self._hot is not None:
                out.update(self._hot.stats())
            return out

    def fetch_latency_us(self) -> dict:
        """Percentiles over the recent per-call fetch wall times (bounded
        window).  Kept out of :meth:`stats` — that runs at every pipeline
        gather, and percentile math over 64k samples there would put numpy
        work on the host loop for numbers only benchmarks read."""
        with self._lock:
            arr = np.asarray(self._fetch_us, np.float64)
        if arr.size == 0:
            return {"fetch_p50_us": 0.0, "fetch_p99_us": 0.0,
                    "fetch_mean_us": 0.0, "fetch_samples": 0}
        return {"fetch_p50_us": float(np.percentile(arr, 50)),
                "fetch_p99_us": float(np.percentile(arr, 99)),
                "fetch_mean_us": float(arr.mean()),
                "fetch_samples": int(arr.size)}

    def reset_stats(self) -> None:
        """Zero the counters and the latency window.  Hot-tier *state*
        (residency, the frequency EMA) survives — it is policy memory, not
        a statistic."""
        with self._lock:
            self.hits = self.misses = 0
            self._fetch_us.clear()
            self.store.reset_stats()
            if self._hot is not None:
                self._hot.reset_stats()

    def clear_cache(self) -> None:
        """Empty the LRU (cold-cache experiments); the pinned set stays —
        it is static by design."""
        with self._lock:
            self._lru.clear()


def entry_proximal_ids(adj, entry, limit: int = 256) -> np.ndarray:
    """BFS order from the entry medoid, truncated to ``limit`` nodes — the
    static pin set for the hot-node cache (every query's walk starts here)."""
    adj = np.asarray(adj)
    entry = int(np.asarray(entry))
    seen = {entry}
    order = [entry]
    frontier = [entry]
    while frontier and len(order) < limit:
        nxt = []
        for u in frontier:
            for v in adj[u].tolist():
                if v >= 0 and v not in seen:
                    seen.add(v)
                    order.append(v)
                    nxt.append(v)
                    if len(order) >= limit:
                        return np.asarray(order, np.int64)
        frontier = nxt
    return np.asarray(order, np.int64)


def open_or_build_slow_tier(path, index: TieredIndex,
                            cache_nodes: int = 4096, pin_nodes: int = 256,
                            log=None, nodes_per_block: int = 1,
                            slot_of: np.ndarray | None = None,
                            io_workers: int | None = None,
                            hot_nodes: int = 0, hot_chunk: int = 256,
                            freq_decay: float = 0.5) -> BlockSlowTier:
    """The serving bootstrap every ``--disk PATH`` consumer shares: open (or
    write — absent/unreadable/stale/re-laid-out, see
    :func:`repro.index.blockstore.ensure_block_store`) the block store for
    ``index`` and wrap it in a :class:`BlockSlowTier` with the
    entry-proximal neighbourhood pinned.  ``nodes_per_block``/``slot_of``
    select the I/O-block granularity and the packed layout (see
    :func:`repro.core.build.block_layout`); ``io_workers`` sizes the
    prefetch pool and ``hot_nodes``/``hot_chunk``/``freq_decay`` enable the
    frequency-aware hot tier (see the module docstring)."""
    from repro.index.blockstore import ensure_block_store

    store = ensure_block_store(path, np.asarray(index.vectors),
                               np.asarray(index.graph.adj), log=log,
                               nodes_per_block=nodes_per_block,
                               slot_of=slot_of)
    pinned = (entry_proximal_ids(index.graph.adj, index.graph.entry,
                                 limit=pin_nodes) if pin_nodes > 0 else None)
    return BlockSlowTier(store, cache_nodes=cache_nodes, pinned_ids=pinned,
                         io_workers=io_workers, hot_nodes=hot_nodes,
                         hot_chunk=hot_chunk, freq_decay=freq_decay)


def rerank_with_slow_tier(slow_tier, beam_ids, queries, k: int,
                          prefetched: np.ndarray | None = None):
    """Slow-tier rerank of a full beam through the pluggable tier.

    Host-gathers the beam's vectors (``prefetched`` skips the gather — the
    joined result of :meth:`BlockSlowTier.prefetch`) and runs the same
    jitted arithmetic as the fused in-memory rerank
    (:func:`repro.core.search._rerank_from_vecs`) — bit-identical results.
    """
    vecs = (prefetched if prefetched is not None
            else slow_tier.fetch_beams(np.asarray(beam_ids)))
    return search_mod._rerank_from_vecs_jit(
        jnp.asarray(beam_ids), jnp.asarray(vecs), jnp.asarray(queries), k=k)


# --------------------------------------------------------------------------
# Out-of-core walk drivers: host loops over the split-hop device programs of
# repro.core.search (ooc_select_pq / ooc_hop_pq), adjacency served from the
# block store.  See the module docstring for the architecture; bit-identity
# with the in-memory walk is argued (and spot-verified) there and pinned by
# the engine-parity matrix.
# --------------------------------------------------------------------------


def _tree_slice(state, a: int, b: int):
    return jax.tree_util.tree_map(lambda x: x[a:b], state)


def _tree_concat(states):
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *states)


def ooc_walk(codes: Array, states, ctxs: Array, budgets: Array,
             hop_limits: Array, beam_width: int, tier: BlockSlowTier,
             io_groups: int = 2):
    """Drive a batch of per-lane walk states to convergence out-of-core.

    Lanes are split into up to ``io_groups`` contiguous groups that advance
    round-robin: while one group's hop program runs on the device, another
    group's adjacency rows are being read on the tier's worker thread
    (submitted via :meth:`BlockSlowTier.prefetch_adj`).  Per-lane results
    are independent of the grouping (the bucketed scheduler already pins
    lane-slicing result-transparency), so ``io_groups`` is purely an
    I/O/compute-overlap knob.  Returns the final states.
    """
    nq = int(ctxs.shape[0])
    if nq == 0:
        return states
    budgets = jnp.asarray(budgets)
    hop_limits = jnp.asarray(hop_limits)
    n_groups = max(1, min(int(io_groups), nq))
    per = (nq + n_groups - 1) // n_groups
    bounds = [(a, min(a + per, nq)) for a in range(0, nq, per)]

    groups = []
    for a, b in bounds:
        st, u, act = search_mod.ooc_select_pq(
            _tree_slice(states, a, b), budgets[a:b], hop_limits[a:b],
            beam_width)
        groups.append({
            "st": st, "u": u, "act": act, "ctx": ctxs[a:b],
            "bud": budgets[a:b], "hl": hop_limits[a:b],
            "future": None, "done": False,
        })
    # Prime the I/O pipeline: every live group's first frontier fetch goes
    # to the worker before any hop program is dispatched.
    for g in groups:
        if np.asarray(g["act"]).any():
            g["future"] = tier.prefetch_adj(np.asarray(g["u"]))
        else:
            g["done"] = True
    while not all(g["done"] for g in groups):
        for g in groups:
            if g["done"]:
                continue
            rows = g["future"].result()     # worker I/O for *this* group
            st, u, act = search_mod.ooc_hop_pq(
                codes, g["st"], g["u"], g["act"], jnp.asarray(rows),
                g["ctx"], g["bud"], g["hl"], beam_width)
            g["st"], g["u"], g["act"] = st, u, act
            # Syncing act blocks on this group's device program; the other
            # groups' fetches are meanwhile in flight on the worker.
            if np.asarray(act).any():
                g["future"] = tier.prefetch_adj(np.asarray(u))
            else:
                g["done"] = True
    if n_groups == 1:
        return groups[0]["st"]
    return _tree_concat([g["st"] for g in groups])


def ooc_probe(codes: Array, ctxs: Array, entry, n: int,
              budget_cfg: search_mod.AdaptiveBeamBudget,
              tier: BlockSlowTier, max_hops: int | None = None,
              io_groups: int = 2, excl: Array | None = None):
    """Out-of-core probe + budget grant: the host-driven counterpart of
    ``search._probe_pq_jit`` (bit-identical outputs for the same inputs).

    ``excl`` filters the probe walk in-graph via the init-time visited
    pre-seed; the returned probe state is scrubbed of the forced entry seed
    before the budget grant, matching ``adaptive_probe_batch`` op-for-op so
    filtered budgets stay bit-identical across the in-graph and out-of-core
    drivers.

    Returns (probe_state, budgets, hop_limits, q_lid).
    """
    l_max = budget_cfg.l_max
    nq = int(ctxs.shape[0])
    states = search_mod.ooc_init_pq(codes, ctxs, jnp.asarray(entry), n,
                                    l_max, excl=excl)
    probe_state = ooc_walk(
        codes, states, ctxs,
        jnp.full((nq,), jnp.int32(budget_cfg.l_min)),
        jnp.full((nq,), jnp.int32(budget_cfg.probe_hops)),
        l_max, tier, io_groups)
    if excl is not None:
        probe_state = search_mod._scrub_state_jit(probe_state, excl)
    budgets, hop_limits, q_lid = search_mod._grant_budgets_jit(
        probe_state, budget_cfg, max_hops)
    return probe_state, budgets, hop_limits, q_lid


def ooc_continue(codes: Array, probe_state, ctxs: Array, budgets: Array,
                 hop_limits: Array, beam_width: int, tier: BlockSlowTier,
                 io_groups: int = 2):
    """Out-of-core continue: resume probe states under granted budgets —
    the host-driven counterpart of ``search._continue_pq_jit``.

    Returns (beam_ids, beam_d, hops, evals), the staged continue-program
    signature (so the engine's bucket scheduler can dispatch it unchanged).
    """
    state = ooc_walk(codes, probe_state, ctxs, budgets, hop_limits,
                     beam_width, tier, io_groups)
    return state[0], state[1], state[4], state[5]


def ooc_first_frontier(probe_state, budgets: Array, hop_limits: Array,
                       beam_width: int) -> np.ndarray:
    """The continue phase's first frontier node per lane (INVALID for lanes
    already converged) — computable as soon as the budget grant lands, which
    is what makes the engine's walk-prefetch stage possible: these nodes'
    blocks are submitted to the tier worker one stage before the continue
    runs."""
    _, u, _ = search_mod.ooc_select_pq(
        probe_state, jnp.asarray(budgets), jnp.asarray(hop_limits),
        beam_width)
    return np.asarray(u)
