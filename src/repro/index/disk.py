"""Two-tier disk-resident index model (paper §1/§5 serving architecture).

DiskANN's node layout packs (full vector + adjacency list) into SSD sectors;
the search holds PQ codes in RAM, routes on them, and pays one SSD read per
expanded node. On the TPU adaptation:

  fast tier  (HBM)   : PQ codes (N, M) uint8 + adjacency (N, R) int32
  slow tier  (host)  : full-precision vectors (N, D)

The *cost model* is preserved exactly: every node expansion is one slow-tier
"read" and the per-query hop counter of :class:`repro.core.search.SearchStats`
is the I/O metric the paper's Figures 2a/2c report. :class:`DiskTierModel`
converts counted reads into modelled latency so benchmarks can report the
paper's latency numbers under an explicit, documented hardware model rather
than a hidden one.

Serving architecture: the functions below (:func:`search_tiered`,
:func:`search_tiered_adaptive`) are the kernel-level entry points over one
tiered index; production serving lowers through
:class:`repro.serving.SearchEngine` with a :class:`~repro.serving.TieredBackend`
— the staged pipeline (admission -> probe -> host-bucket -> continue ->
slow-tier rerank, double-buffered across batches) drives these same compiled
programs, auto-picks the continue phase's bucket family from the
granted-budget histogram, coalesces micro-batches below the admission lane
threshold, and hosts the recalibration hook for Online-MCGI index refreshes.
At billion scale the index shards across a mesh
(:mod:`repro.distributed.sharded_search`, one locally built sub-graph +
PQ codes + slow-tier rows per shard) behind the same engine API: the
distributed step runs staged at engine parity — shard walks checkpointed at
the probe horizon, per-shard budget laws (each shard's own calibrated
(lam, l_min); see :func:`repro.core.calibrate.calibrate_budget_law_per_shard`)
granting per-(query, shard) budgets in-graph, host bucket scheduling between
mesh programs, and per-bucket continues resuming into the shard-local exact
rerank + hedged global merge. ``DiskTierModel.latency_us(...,
overlapped=True)`` is the matching latency model: the rerank batch of batch
i is issued while batch i+1's walk computes, so per-batch modelled time is
the max of the two stages, not their sum.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import search as search_mod
from repro.core.types import GraphIndex
from repro.pq import PqCodebook, build_lut, pq_encode, train_pq

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiskTierModel:
    """Latency model for the slow tier.

    Defaults approximate the paper's testbed (Micron 5300 PRO SATA SSD):
    ~90us random 4K read; beam-width reads issued concurrently with an
    effective queue depth. Swap-in constants for NVMe (~20us) or host-DRAM
    over PCIe (~2us) to study other deployments.
    """

    read_latency_us: float = 90.0
    queue_depth: int = 8

    def latency_us(self, reads: Array, rerank_reads: Array | int = 0,
                   *, overlapped: bool = False) -> Array:
        """Modelled wall time for ``reads`` sequential beam expansions plus an
        optional final rerank batch of ``rerank_reads`` node fetches.

        Each expansion is a dependent read (graph traversal is a pointer
        chase), so the ``reads`` term is serial. The rerank batch has no
        dependencies, so its reads are issued ``queue_depth`` at a time:
        ceil(rerank_reads / queue_depth) serialised rounds.

        ``overlapped=True`` models the staged double-buffered engine
        (``repro.serving.SearchEngine.search_batches``): reads are issued
        while compute proceeds — batch i's independent rerank reads are in
        flight during batch i+1's dependent walk chain, so in steady state a
        batch costs the *max* of the two stages instead of their sum. The
        dependent chain itself cannot be hidden (each hop's address comes
        from the previous read); only the stage overlap is modelled.
        """
        serial = reads.astype(jnp.float32) * self.read_latency_us
        rerank_reads = jnp.asarray(rerank_reads, jnp.float32)
        rounds = jnp.ceil(rerank_reads / max(self.queue_depth, 1))
        rerank_time = rounds * self.read_latency_us
        if overlapped:
            return jnp.maximum(serial, rerank_time)
        return serial + rerank_time


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TieredIndex:
    """A disk-resident MCGI/Vamana index: graph + PQ fast tier + slow tier."""

    graph: GraphIndex
    codebook: PqCodebook
    codes: Array       # (N, M) uint8 — fast tier
    vectors: Array     # (N, D) f32   — slow tier (host memory in deployment)

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    def fast_tier_bytes(self) -> int:
        return (
            self.codes.size
            + self.graph.adj.size * 4
            + self.codebook.centroids.size * 4
        )

    def slow_tier_bytes(self) -> int:
        return self.vectors.size * 4


def build_tiered_index(
    x: Array, graph: GraphIndex, m_pq: int = 16, seed: int = 0
) -> TieredIndex:
    # PQ needs D % M == 0; zero-pad the PQ view (T2I: 200 -> 208). L2 over
    # zero-padded dims is unchanged; the slow tier keeps the original x.
    d = x.shape[1]
    pad = (-d) % m_pq
    x_pq = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    book = train_pq(x_pq, m=m_pq, seed=seed)
    codes = pq_encode(x_pq, book)
    return TieredIndex(graph=graph, codebook=book, codes=codes, vectors=x)


def search_tiered(
    index: TieredIndex,
    queries: Array,
    beam_width: int,
    k: int = 10,
    max_hops: int = 2048,
    rerank: bool = True,
) -> tuple[Array, Array, search_mod.SearchStats]:
    """PQ-routed beam search with slow-tier rerank (the deployed path)."""
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, beam_width=beam_width, max_hops=max_hops,
        k=k, rerank=rerank,
    )


def search_tiered_adaptive(
    index: TieredIndex,
    queries: Array,
    budget_cfg: search_mod.AdaptiveBeamBudget,
    k: int = 10,
    rerank: bool = True,
    num_buckets: int | None = None,
) -> tuple[Array, Array, search_mod.SearchStats, search_mod.AdaptiveStats]:
    """Per-query adaptive-beam serving path (Prop. 4.2 in the engine).

    Same tiers and cost model as :func:`search_tiered`, but each query's beam
    budget is set from its own probe-phase LID estimate — easy queries retire
    early and stop paying slow-tier reads for the hard ones. Returns
    (ids, d2, stats, adaptive_stats); ``adaptive_stats`` carries the
    per-query LID and granted budget for observability.

    ``num_buckets`` >= 2 runs the continue phase budget-bucketed (queries
    grouped by granted budget, each bucket jitted to its own ceiling) so
    converged lanes free real compute; results are identical to the
    single-program path.
    """
    luts = _query_luts(index, queries)
    return search_mod.beam_search_pq_adaptive(
        index.codes, luts, index.vectors, index.graph.adj, queries,
        index.graph.entry, budget_cfg=budget_cfg, k=k, rerank=rerank,
        num_buckets=num_buckets,
    )


def _query_luts(index: TieredIndex, queries: Array) -> Array:
    """Per-query ADC LUTs, zero-padding queries to the PQ-padded dim."""
    d_book = index.codebook.m * index.codebook.dsub
    q_pq = (jnp.pad(queries, ((0, 0), (0, d_book - queries.shape[1])))
            if queries.shape[1] < d_book else queries)
    return build_lut(q_pq, index.codebook.centroids)
