from repro.index.disk import (  # noqa: F401
    DiskTierModel,
    TieredIndex,
    build_tiered_index,
    search_tiered,
    search_tiered_adaptive,
)
from repro.index.serializer import (  # noqa: F401
    load_disk_model,
    load_index,
    load_shard_laws,
    save_index,
)
