from repro.index.disk import DiskTierModel, TieredIndex, build_tiered_index  # noqa: F401
from repro.index.serializer import load_index, save_index  # noqa: F401
