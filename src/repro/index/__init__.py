from repro.index.blockstore import (  # noqa: F401
    BlockChecksumError,
    BlockStore,
    BlockStoreError,
    BlockStoreFormatError,
    BlockStoreTruncatedError,
    ensure_block_store,
    write_block_store,
)
from repro.index.disk import (  # noqa: F401
    BlockSlowTier,
    DiskTierModel,
    InMemorySlowTier,
    SlowTier,
    TieredIndex,
    build_tiered_index,
    entry_proximal_ids,
    open_or_build_slow_tier,
    search_tiered,
    search_tiered_adaptive,
)
from repro.index.hot_tier import HotTier  # noqa: F401
from repro.index.serializer import (  # noqa: F401
    load_disk_model,
    load_index,
    load_shard_laws,
    load_slow_tier,
    open_block_store,
    save_index,
)
