from repro.index.disk import (  # noqa: F401
    DiskTierModel,
    TieredIndex,
    build_tiered_index,
    search_tiered,
    search_tiered_adaptive,
)
from repro.index.serializer import load_disk_model, load_index, save_index  # noqa: F401
