"""Index (de)serialisation.

Numpy-npz container with a JSON manifest — deliberately dependency-free and
stable across hosts, the same container the training checkpointer uses
(:mod:`repro.training.checkpoint`). Billion-scale deployments shard the file
per index shard; :func:`save_index`/`load_index` handle one shard.
"""
from __future__ import annotations

import json
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core.types import GraphIndex
from repro.index.disk import TieredIndex
from repro.pq import PqCodebook


def save_index(path: str | pathlib.Path, index: TieredIndex) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        path,
        adj=np.asarray(index.graph.adj),
        entry=np.asarray(index.graph.entry),
        alpha=np.asarray(index.graph.alpha),
        lid=np.asarray(index.graph.lid),
        mu=np.asarray(index.graph.mu),
        sigma=np.asarray(index.graph.sigma),
        centroids=np.asarray(index.codebook.centroids),
        codes=np.asarray(index.codes),
        vectors=np.asarray(index.vectors),
        manifest=json.dumps(
            {
                "format": "repro.tiered_index.v1",
                "n": int(index.n),
                "degree": int(index.graph.degree_cap),
                "m_pq": int(index.codebook.m),
            }
        ),
    )


def load_index(path: str | pathlib.Path) -> TieredIndex:
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        assert manifest["format"] == "repro.tiered_index.v1", manifest
        graph = GraphIndex(
            adj=jnp.asarray(z["adj"]),
            entry=jnp.asarray(z["entry"]),
            alpha=jnp.asarray(z["alpha"]),
            lid=jnp.asarray(z["lid"]),
            mu=jnp.asarray(z["mu"]),
            sigma=jnp.asarray(z["sigma"]),
        )
        return TieredIndex(
            graph=graph,
            codebook=PqCodebook(centroids=jnp.asarray(z["centroids"])),
            codes=jnp.asarray(z["codes"]),
            vectors=jnp.asarray(z["vectors"]),
        )
