"""Index (de)serialisation.

Numpy-npz container with a JSON manifest — deliberately dependency-free and
stable across hosts, the same container the training checkpointer uses
(:mod:`repro.training.checkpoint`). Billion-scale deployments shard the file
per index shard; :func:`save_index`/`load_index` handle one shard.
"""
from __future__ import annotations

import json
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core.types import GraphIndex
from repro.index.disk import DiskTierModel, TieredIndex
from repro.pq import PqCodebook


def save_index(
    path: str | pathlib.Path,
    index: TieredIndex,
    disk_model: DiskTierModel | None = None,
    shard_laws=None,
) -> None:
    """Write one index shard; ``disk_model`` (the slow-tier latency model the
    index was benchmarked/SLO'd under) rides along in the manifest so a
    reloaded deployment reproduces the same modelled latencies.

    ``shard_laws`` — an optional (lam (S,), l_min (S,)) pair of per-shard
    calibrated budget-law arrays (``repro.core.calibrate.ShardCalibration
    .law_arrays()``) — also rides in the manifest, so a reloaded distributed
    deployment serves the same per-shard budgets it was calibrated to."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    manifest = {
        "format": "repro.tiered_index.v1",
        "n": int(index.n),
        "degree": int(index.graph.degree_cap),
        "m_pq": int(index.codebook.m),
    }
    if disk_model is not None:
        manifest["disk_model"] = {
            "read_latency_us": float(disk_model.read_latency_us),
            "queue_depth": int(disk_model.queue_depth),
        }
    if shard_laws is not None:
        lam, l_min = shard_laws
        assert len(lam) == len(l_min), (len(lam), len(l_min))
        manifest["shard_laws"] = {
            "lam": [float(v) for v in np.asarray(lam)],
            "l_min": [int(v) for v in np.asarray(l_min)],
        }
    np.savez_compressed(
        path,
        adj=np.asarray(index.graph.adj),
        entry=np.asarray(index.graph.entry),
        alpha=np.asarray(index.graph.alpha),
        lid=np.asarray(index.graph.lid),
        mu=np.asarray(index.graph.mu),
        sigma=np.asarray(index.graph.sigma),
        centroids=np.asarray(index.codebook.centroids),
        codes=np.asarray(index.codes),
        vectors=np.asarray(index.vectors),
        manifest=json.dumps(manifest),
    )


def load_disk_model(path: str | pathlib.Path) -> DiskTierModel | None:
    """The DiskTierModel stored alongside the index, or None for indexes
    saved without one (pre-v1.1 files parse fine — the key is optional)."""
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
    dm = manifest.get("disk_model")
    if dm is None:
        return None
    return DiskTierModel(
        read_latency_us=float(dm["read_latency_us"]),
        queue_depth=int(dm["queue_depth"]),
    )


def load_shard_laws(path: str | pathlib.Path):
    """The per-shard (lam, l_min) budget-law arrays stored alongside the
    index, or None when the index was saved without per-shard calibration
    (the manifest key is optional, like ``disk_model``)."""
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
    laws = manifest.get("shard_laws")
    if laws is None:
        return None
    return (np.asarray(laws["lam"], np.float32),
            np.asarray(laws["l_min"], np.int32))


def load_index(path: str | pathlib.Path) -> TieredIndex:
    with np.load(pathlib.Path(path), allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        assert manifest["format"] == "repro.tiered_index.v1", manifest
        graph = GraphIndex(
            adj=jnp.asarray(z["adj"]),
            entry=jnp.asarray(z["entry"]),
            alpha=jnp.asarray(z["alpha"]),
            lid=jnp.asarray(z["lid"]),
            mu=jnp.asarray(z["mu"]),
            sigma=jnp.asarray(z["sigma"]),
        )
        return TieredIndex(
            graph=graph,
            codebook=PqCodebook(centroids=jnp.asarray(z["centroids"])),
            codes=jnp.asarray(z["codes"]),
            vectors=jnp.asarray(z["vectors"]),
        )
