"""Index (de)serialisation.

Numpy-npz container with a JSON manifest — deliberately dependency-free and
stable across hosts, the same container the training checkpointer uses
(:mod:`repro.training.checkpoint`). Billion-scale deployments shard the file
per index shard; :func:`save_index`/`load_index` handle one shard.

Two on-disk formats:

* ``v1`` (``repro.tiered_index.v1``) — everything, slow-tier vectors
  included, in one npz.  The historical format; stays both writable
  (``version=1``, the default) and loadable forever.
* ``v2`` (``repro.tiered_index.v2``) — the out-of-core layout: the npz holds
  only the *fast tier* (graph, PQ codebook/codes, geometric profile) and the
  manifest points at a sidecar block store (``<path>.blocks``,
  :mod:`repro.index.blockstore`) holding each node's full-precision vector +
  adjacency in one checksummed aligned block.  ``load_index`` reads the
  blocks back into memory (bit-identical to v1 loading);
  :func:`load_slow_tier` instead opens the sidecar as a live
  :class:`~repro.index.disk.BlockSlowTier` so serving never materialises the
  slow tier in host memory.

The optional manifest riders (``disk_model``, ``shard_laws``, ``lineage``)
ride in both formats unchanged.
"""
from __future__ import annotations

import json
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core.types import GraphIndex
from repro.index import blockstore
from repro.index.disk import BlockSlowTier, DiskTierModel, TieredIndex
from repro.pq import PqCodebook

FORMAT_V1 = "repro.tiered_index.v1"
FORMAT_V2 = "repro.tiered_index.v2"


def blocks_path(path: str | pathlib.Path) -> pathlib.Path:
    """The v2 sidecar block-store path for an index file."""
    path = pathlib.Path(path)
    return path.with_name(path.name + ".blocks")


def save_index(
    path: str | pathlib.Path,
    index: TieredIndex,
    disk_model: DiskTierModel | None = None,
    shard_laws=None,
    version: int = 1,
    nodes_per_block: int = 1,
    slot_of=None,
    lineage: dict | None = None,
) -> None:
    """Write one index shard; ``disk_model`` (the slow-tier latency model the
    index was benchmarked/SLO'd under) rides along in the manifest so a
    reloaded deployment reproduces the same modelled latencies.

    ``shard_laws`` — an optional (lam (S,), l_min (S,)) pair of per-shard
    calibrated budget-law arrays (``repro.core.calibrate.ShardCalibration
    .law_arrays()``) — also rides in the manifest, so a reloaded distributed
    deployment serves the same per-shard budgets it was calibrated to.

    ``version=2`` writes the out-of-core layout: fast tier in the npz, slow
    tier (vector + adjacency per node, block-aligned + checksummed) in the
    ``<path>.blocks`` sidecar — what :func:`load_slow_tier` serves from
    disk.  ``version=1`` keeps the historical single-npz format.

    ``lineage`` — an optional JSON-serialisable dict recording the index's
    mutation history (generation number, merge/insert/delete counters,
    population drift — see :class:`repro.index.delta.LiveIndex`) — rides in
    the manifest so a reloaded deployment knows which live-index generation
    it is resuming from.

    ``nodes_per_block`` / ``slot_of`` (v2 only) select the sidecar's
    block-aware record layout (see
    :func:`repro.index.blockstore.write_block_store`; ``slot_of`` typically
    comes from :func:`repro.core.build.block_layout`).  The layout rides in
    the manifest's ``blocks`` entry so a reopened deployment cross-checks
    it like the store geometry.
    """
    if version not in (1, 2):
        raise ValueError(f"unknown index format version {version}")
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    manifest = {
        "format": FORMAT_V1 if version == 1 else FORMAT_V2,
        "n": int(index.n),
        "degree": int(index.graph.degree_cap),
        "m_pq": int(index.codebook.m),
    }
    if disk_model is not None:
        manifest["disk_model"] = {
            "read_latency_us": float(disk_model.read_latency_us),
            "queue_depth": int(disk_model.queue_depth),
        }
    if shard_laws is not None:
        lam, l_min = shard_laws
        assert len(lam) == len(l_min), (len(lam), len(l_min))
        manifest["shard_laws"] = {
            "lam": [float(v) for v in np.asarray(lam)],
            "l_min": [int(v) for v in np.asarray(l_min)],
        }
    if lineage is not None:
        manifest["lineage"] = json.loads(json.dumps(lineage))  # must be JSON
    arrays = dict(
        adj=np.asarray(index.graph.adj),
        entry=np.asarray(index.graph.entry),
        alpha=np.asarray(index.graph.alpha),
        lid=np.asarray(index.graph.lid),
        mu=np.asarray(index.graph.mu),
        sigma=np.asarray(index.graph.sigma),
        centroids=np.asarray(index.codebook.centroids),
        codes=np.asarray(index.codes),
    )
    if version == 1:
        arrays["vectors"] = np.asarray(index.vectors)
    else:
        bp = blockstore.write_block_store(
            blocks_path(path), np.asarray(index.vectors),
            np.asarray(index.graph.adj),
            nodes_per_block=nodes_per_block, slot_of=slot_of)
        store = blockstore.BlockStore(bp)
        manifest["blocks"] = {
            "file": bp.name,           # sibling of the npz, relocatable
            "block_size": store.block_size,
            "n": store.n, "d": store.d, "r": store.r,
            # Content fingerprint: geometry alone cannot tell two builds of
            # the same shape apart — a swapped sidecar must fail to open.
            "vectors_crc32": store.vectors_crc32,
        }
        if store.nodes_per_block != 1 or store.slot_of is not None:
            # Layout rider: how records were packed (block-aware builds).
            manifest["blocks"]["nodes_per_block"] = store.nodes_per_block
            manifest["blocks"]["layout"] = store.layout
            manifest["blocks"]["slot_table_crc32"] = store.slot_table_crc32
    np.savez_compressed(path, manifest=json.dumps(manifest), **arrays)


def _read_manifest(path: pathlib.Path) -> dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["manifest"]))


def load_disk_model(path: str | pathlib.Path) -> DiskTierModel | None:
    """The DiskTierModel stored alongside the index, or None for indexes
    saved without one (pre-v1.1 files parse fine — the key is optional)."""
    dm = _read_manifest(pathlib.Path(path)).get("disk_model")
    if dm is None:
        return None
    return DiskTierModel(
        read_latency_us=float(dm["read_latency_us"]),
        queue_depth=int(dm["queue_depth"]),
    )


def load_shard_laws(path: str | pathlib.Path):
    """The per-shard (lam, l_min) budget-law arrays stored alongside the
    index, or None when the index was saved without per-shard calibration
    (the manifest key is optional, like ``disk_model``)."""
    laws = _read_manifest(pathlib.Path(path)).get("shard_laws")
    if laws is None:
        return None
    return (np.asarray(laws["lam"], np.float32),
            np.asarray(laws["l_min"], np.int32))


def load_lineage(path: str | pathlib.Path) -> dict | None:
    """The live-index mutation lineage stored alongside the index, or None
    for indexes saved outside the delta-tier lifecycle (the manifest key is
    optional, like ``disk_model``)."""
    return _read_manifest(pathlib.Path(path)).get("lineage")


def load_index(path: str | pathlib.Path) -> TieredIndex:
    """Load either format into a fully in-memory :class:`TieredIndex`.

    v1 reads the vectors from the npz; v2 reads them back out of the sidecar
    block store (every record CRC-verified) — bit-identical arrays either
    way, so everything downstream is format-agnostic.
    """
    path = pathlib.Path(path)
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        fmt = manifest.get("format")
        if fmt not in (FORMAT_V1, FORMAT_V2):
            raise ValueError(
                f"{path}: unknown index format {fmt!r} "
                f"(expected {FORMAT_V1} or {FORMAT_V2})")
        graph = GraphIndex(
            adj=jnp.asarray(z["adj"]),
            entry=jnp.asarray(z["entry"]),
            alpha=jnp.asarray(z["alpha"]),
            lid=jnp.asarray(z["lid"]),
            mu=jnp.asarray(z["mu"]),
            sigma=jnp.asarray(z["sigma"]),
        )
        if fmt == FORMAT_V1:
            vectors = jnp.asarray(z["vectors"])
        else:
            store = open_block_store(path, manifest=manifest)
            vecs, _adj = store.read_many(np.arange(store.n))
            vectors = jnp.asarray(vecs)
        return TieredIndex(
            graph=graph,
            codebook=PqCodebook(centroids=jnp.asarray(z["centroids"])),
            codes=jnp.asarray(z["codes"]),
            vectors=vectors,
        )


def open_block_store(path: str | pathlib.Path,
                     manifest: dict | None = None) -> blockstore.BlockStore:
    """Open a v2 index's sidecar block store, cross-checking the manifest's
    recorded geometry against the store header (a swapped/stale sidecar is a
    format error, not garbage results)."""
    path = pathlib.Path(path)
    if manifest is None:
        manifest = _read_manifest(path)
    blk = manifest.get("blocks")
    if blk is None:
        raise blockstore.BlockStoreFormatError(
            f"{path}: index format {manifest.get('format')!r} has no block "
            "sidecar (saved with version=1?); re-save with "
            "save_index(..., version=2) to serve the slow tier from disk")
    store = blockstore.BlockStore(path.with_name(blk["file"]))
    keys = ("n", "d", "r", "block_size")
    if blk.get("vectors_crc32") is not None:
        keys += ("vectors_crc32",)   # content identity, not just geometry
    for key in ("nodes_per_block", "slot_table_crc32"):
        if blk.get(key) is not None:
            keys += (key,)           # layout rider (block-aware builds)
    for key in keys:
        sval = getattr(store, key)
        if sval is None or int(blk[key]) != int(sval):
            raise blockstore.BlockStoreFormatError(
                f"{store.path}: sidecar {key}={sval} does not match the "
                f"index manifest's {key}={blk[key]} (stale or swapped "
                "block file)")
    if blk.get("layout") is not None and blk["layout"] != store.layout:
        raise blockstore.BlockStoreFormatError(
            f"{store.path}: sidecar layout={store.layout!r} does not match "
            f"the index manifest's layout={blk['layout']!r} (stale or "
            "swapped block file)")
    return store


def load_slow_tier(path: str | pathlib.Path, cache_nodes: int = 4096,
                   pin_nodes: int = 256) -> BlockSlowTier:
    """Open a v2 index's slow tier for *serving*: a live
    :class:`~repro.index.disk.BlockSlowTier` over the sidecar store, with the
    entry-proximal nodes (BFS from the medoid over the npz adjacency) pinned
    in the hot cache.  Nothing slow-tier-sized is read into host memory."""
    from repro.index.disk import entry_proximal_ids

    path = pathlib.Path(path)
    store = open_block_store(path)
    pinned = None
    if pin_nodes > 0:
        with np.load(path, allow_pickle=False) as z:
            adj, entry = np.asarray(z["adj"]), np.asarray(z["entry"])
        pinned = entry_proximal_ids(adj, entry, limit=pin_nodes)
    return BlockSlowTier(store, cache_nodes=cache_nodes, pinned_ids=pinned)
