from repro.distributed.sharded_search import (  # noqa: F401
    ShardedIndexSpecs,
    distributed_search,
    sharded_index_specs,
)
