from repro.distributed.sharded_search import (  # noqa: F401
    ShardedIndexSpecs,
    distributed_search,
    make_distributed_search,
    shard_medoids,
    sharded_index_specs,
)
