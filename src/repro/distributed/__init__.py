from repro.distributed.sharded_search import (  # noqa: F401
    ShardedIndexSpecs,
    build_sharded_arrays,
    distributed_search,
    make_distributed_continue,
    make_distributed_probe,
    make_distributed_search,
    shard_medoids,
    sharded_index_specs,
)
