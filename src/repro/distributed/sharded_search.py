"""Distributed MCGI serving: sharded beam search + global top-k merge.

Layout (DESIGN.md §5): base points are sharded into n_shards = |data|x|model|
(x|pod|) partitions; every shard holds its *own locally built* MCGI sub-graph
(adjacency with shard-local ids), its PQ codes and its full-precision
vectors. A query fans out to all shards, each runs the PQ-routed beam search
+ local exact rerank on its sub-index, and the per-shard top-k are merged
into the global top-k with one all_gather + sort — the standard
scatter-gather ANN serving pattern expressed as jax collectives inside
``shard_map``.

Straggler mitigation: the merge takes a per-shard ``shard_ok`` mask; a shard
that misses its deadline (or is down) contributes +inf distances and the
merge degrades gracefully (recall loss ~ its data fraction) instead of
stalling the query — the hedged-read policy of production ANN serving. The
mask is a runtime input, so dropping shards needs no recompilation.

Memory discipline at N=10^9: per device the shard is ~3.9M points; queries
are processed in ``query_chunk`` groups under ``lax.map`` so the visited
bitmap stays at chunk x N_local bools.

Two execution shapes are built here:

* the **monolithic step** (:func:`make_distributed_search`) — probe, budget,
  continue, local rerank and hedged merge fused into one compiled program.
  This is what the dry-run prices (``launch/cells.py`` via
  ``DistributedBackend.make_step``) and what fixed-beam serving runs.
* the **staged step** (:func:`make_distributed_probe` +
  :func:`make_distributed_continue`) — the same walk split at the probe
  horizon, PR 1's init/run split lifted to the mesh: the probe program
  checkpoints every shard's frontier (beam + visited bitmap + counters,
  laid out ``(Q, n_shards, ...)`` so the host schedules on the query axis)
  and grants per-shard budgets; the continue program resumes any *subset*
  of queries with warm state, reranks locally and runs the hedged merge.
  ``repro.serving.SearchEngine`` drives the two halves from different
  pipeline stages — batch i+1's probe is dispatched before batch i's
  host-side bucket scheduling and per-bucket continues — and the split is
  result-transparent: both programs run the same per-query kernels as the
  monolithic step (property-tested in ``tests/test_engine_parity.py`` /
  the ``staged_engine`` distributed-worker scenario). The staged walk
  checkpoints the full (Q x N_local/32) visited bitmap between the stages,
  so it targets serving micro-batches; bulk scans keep the monolithic step.

Per-shard budget laws: shard sub-graphs have different geometry (a shard of
a heterogeneous collection is *not* a scaled-down copy of it), so a single
global (lam, l_min) budget law under- or over-budgets some shards. Both the
monolithic and staged builders accept ``per_shard_laws=True`` and then take
``(n_shards,)`` lam / l_min arrays as runtime inputs — one calibrated law
per shard (:func:`repro.core.calibrate.calibrate_budget_law_per_shard`),
threaded through :class:`ShardedIndexSpecs` for the dry-run and applied as
traced scalars in-graph (no recompilation when a recalibration updates
them). ``l_max`` stays global: it is the physical beam shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import search as search_mod

Array = jax.Array
INVALID = -1


@dataclasses.dataclass(frozen=True)
class ShardedIndexSpecs:
    """ShapeDtypeStructs (with shardings) of a sharded tiered index.

    ``shard_lam`` / ``shard_l_min`` are present when the index carries
    per-shard calibrated budget laws (``per_shard_laws=True``): one
    (lam, l_min) pair per shard, sharded like ``shard_ok``.
    """

    adj: jax.ShapeDtypeStruct
    codes: jax.ShapeDtypeStruct
    vectors: jax.ShapeDtypeStruct
    centroids: jax.ShapeDtypeStruct
    queries: jax.ShapeDtypeStruct
    shard_ok: jax.ShapeDtypeStruct
    entries: jax.ShapeDtypeStruct
    shard_lam: jax.ShapeDtypeStruct | None = None
    shard_l_min: jax.ShapeDtypeStruct | None = None


def _shard_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)  # points shard over every axis


def sharded_index_specs(
    mesh,
    *,
    n: int,
    d: int,
    degree: int,
    m_pq: int | None,
    n_queries: int,
    data_dtype=jnp.float32,
    per_shard_laws: bool = False,
) -> ShardedIndexSpecs:
    axes = _shard_axes(mesh)
    n_shards = mesh.devices.size
    n_pad = ((n + n_shards - 1) // n_shards) * n_shards
    row = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())
    m = m_pq or 0
    laws = {}
    if per_shard_laws:
        laws = dict(
            shard_lam=jax.ShapeDtypeStruct((n_shards,), jnp.float32, sharding=row),
            shard_l_min=jax.ShapeDtypeStruct((n_shards,), jnp.int32, sharding=row),
        )
    return ShardedIndexSpecs(
        adj=jax.ShapeDtypeStruct((n_pad, degree), jnp.int32, sharding=NamedSharding(mesh, P(axes, None))),
        codes=jax.ShapeDtypeStruct((n_pad, max(m, 1)), jnp.uint8, sharding=NamedSharding(mesh, P(axes, None))),
        vectors=jax.ShapeDtypeStruct((n_pad, d), data_dtype, sharding=NamedSharding(mesh, P(axes, None))),
        centroids=jax.ShapeDtypeStruct(
            (max(m, 1), 256, max(d // max(m, 1), 1)), jnp.float32, sharding=repl
        ),
        queries=jax.ShapeDtypeStruct((n_queries, d), jnp.float32, sharding=repl),
        shard_ok=jax.ShapeDtypeStruct((n_shards,), jnp.bool_, sharding=row),
        entries=jax.ShapeDtypeStruct((n_shards,), jnp.int32, sharding=row),
        **laws,
    )


def _shard_eval(codes, vectors, use_pq: bool):
    """The shard-local distance evaluator (PQ/ADC or exact).

    Tagged with ``kind``/``table`` like the in-memory evaluators so the fused
    beam-step kernel can route the shard's table itself (see
    :class:`repro.core.search.PallasBeamStep`).
    """
    if use_pq:
        def eval_dists(lut, ids, valid):
            c = codes[ids].astype(jnp.int32)
            m = lut.shape[0]
            gathered = jax.vmap(lambda row: lut[jnp.arange(m), row])(c)
            return gathered.sum(axis=-1)

        eval_dists.kind = "pq"
        eval_dists.table = codes
        return eval_dists

    def eval_dists(q, ids, valid):
        vecs = vectors[ids].astype(jnp.float32)
        diff = vecs - q[None, :]
        return jnp.sum(diff * diff, axis=-1)

    eval_dists.kind = "exact"
    eval_dists.table = vectors
    return eval_dists


def _shard_ctxs(centroids, queries, use_pq: bool):
    """Per-query walk contexts: ADC LUTs (PQ) or the raw queries (exact)."""
    if use_pq:
        from repro.pq.adc import build_lut

        return build_lut(queries.astype(jnp.float32), centroids)
    return queries


def _local_rerank(beam_ids, vectors, queries, k: int):
    """Local exact rerank from the shard's own full-precision rows (the
    "disk read" happens on the shard that owns the node). Returns
    (d2, local_ids), each (Q, k) ascending."""
    safe = jnp.maximum(beam_ids, 0)
    vecs = vectors[safe].astype(jnp.float32)
    diff = vecs - queries[:, None, :].astype(jnp.float32)
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(beam_ids == INVALID, jnp.inf, d2)
    order = jnp.argsort(d2, axis=-1)[:, :k]
    return (
        jnp.take_along_axis(d2, order, axis=1),
        jnp.take_along_axis(beam_ids, order, axis=1),
    )


def _hedged_merge(d2, ids, ok_l, mesh, axes, merge: str):
    """Global top-k merge of per-shard (Q, k) candidates, hedged by the
    ``shard_ok`` mask (a late/dead shard contributes +inf). Shared verbatim
    by the monolithic step and the staged continue step, so the two paths
    merge identically.

    merge:
      * "flat"          — one all_gather over every axis at once, then one
        sort (the obvious baseline; payload grows with total shard count).
      * "hierarchical"  — axis-by-axis gather+top-k reduction (model, then
        data, then pod): each stage's payload is only n_axis * Q * k rows and
        later stages ship already-reduced candidate sets (§Perf iteration on
        the mcgi serve cells; also the natural topology map — the first merge
        stays inside a chip row).
    """
    # Hedged-read mask: a late/dead shard contributes nothing.
    d2 = jnp.where(ok_l[0], d2, jnp.inf)
    q, k = d2.shape

    if merge == "flat":
        sid = jnp.int32(0)
        stride = 1
        for a in reversed(axes):
            sid = sid + jax.lax.axis_index(a).astype(jnp.int32) * stride
            stride *= mesh.shape[a]
        cat_d2 = jax.lax.all_gather(d2, axes, tiled=False)
        cat_ids = jax.lax.all_gather(ids, axes, tiled=False)
        cat_sid = jax.lax.all_gather(
            jnp.full((1,), sid, jnp.int32), axes, tiled=False
        ).reshape(-1)
        s = cat_d2.shape[0]
        flat_d2 = cat_d2.transpose(1, 0, 2).reshape(q, s * k)
        flat_ids = cat_ids.transpose(1, 0, 2).reshape(q, s * k)
        flat_sid = jnp.broadcast_to(
            cat_sid[None, :, None], (q, s, k)).reshape(q, s * k)
        order = jnp.argsort(flat_d2, axis=1)[:, :k]
        return (
            jnp.take_along_axis(flat_d2, order, axis=1),
            jnp.take_along_axis(flat_sid, order, axis=1),
            jnp.take_along_axis(flat_ids, order, axis=1),
        )

    # Hierarchical: reduce one mesh axis at a time (innermost first —
    # 'model' neighbours share the fastest links).
    planes = {"local": ids}
    for a in reversed(axes):
        n_a = mesh.shape[a]
        g_d2 = jax.lax.all_gather(d2, a, tiled=False)  # (n_a, Q, k)
        g_planes = {
            name: jax.lax.all_gather(pl, a, tiled=False)
            for name, pl in planes.items()
        }
        flat_d2 = g_d2.transpose(1, 0, 2).reshape(q, n_a * k)
        order = jnp.argsort(flat_d2, axis=1)[:, :k]
        d2 = jnp.take_along_axis(flat_d2, order, axis=1)
        new_planes = {}
        for name, pl in g_planes.items():
            flat = pl.transpose(1, 0, 2).reshape(q, n_a * k)
            new_planes[name] = jnp.take_along_axis(flat, order, axis=1)
        # Which member of this axis each winner came from.
        src = jnp.broadcast_to(
            jnp.arange(n_a, dtype=jnp.int32)[None, :, None],
            (q, n_a, k),
        ).reshape(q, n_a * k)
        new_planes[f"pos_{a}"] = jnp.take_along_axis(src, order, axis=1)
        planes = new_planes

    sid = jnp.zeros_like(planes["local"])
    stride = 1
    for a in reversed(axes):
        sid = sid + planes[f"pos_{a}"] * stride
        stride *= mesh.shape[a]
    return d2, sid, planes["local"]


def _local_search(
    adj, codes, vectors, centroids, queries, entry, *,
    beam_width: int, max_hops: int, k: int, query_chunk: int, use_pq: bool,
    beam_budget: search_mod.AdaptiveBeamBudget | None = None,
    bucket_ceilings: tuple[int, ...] | None = None,
    lam=None, l_min=None,
    step_kernel: str | None = None,
):
    """Per-shard search over the local sub-graph. Returns (d2, local_ids)
    each (Q, k).

    ``entry`` is the shard's own entry point (its local medoid, computed at
    index-build time and threaded through :class:`ShardedIndexSpecs`). With
    ``beam_budget`` set, the shard runs the adaptive engine: each query's
    budget is computed *on this shard* from its local probe beam (shard
    geometry differs, so budgets legitimately differ per shard) and the
    per-shard top-k are merged exactly as in the fixed-beam path.
    ``lam``/``l_min``, when given, are this shard's traced budget-law
    overrides (the per-shard calibration path).

    ``bucket_ceilings`` additionally quantizes each granted budget up to its
    bucket ceiling *in-graph* and derives the per-query hop limit from that
    ceiling, giving the shard a small *discrete family of hop deadlines*
    (probe + hop_factor * ceiling, always capped by ``max_hops``): a walk
    that hits its deadline stops mid-graph and still contributes its
    best-so-far beam to the merge. Note the quantization rounds *up*, so a
    query's limit is never tighter than the raw adaptive path's — the hedge
    is against unbounded straggling (deadlines are enforced mid-walk and the
    shard's completion time is governed by its top occupied bucket), not a
    tightening of the budget law. ``shard_ok`` remains the orthogonal
    mechanism for shards that are down entirely.
    """
    n_local = adj.shape[0]
    entry = entry.astype(jnp.int32)
    eval_dists = _shard_eval(codes, vectors, use_pq)
    ctxs = _shard_ctxs(centroids, queries, use_pq)

    def chunk_fn(args):
        ctx_chunk, q_chunk = args
        if beam_budget is not None:
            # max_hops still caps every per-query hop limit: enabling
            # adaptivity must not silently exceed the operator's I/O SLO.
            beam_ids, beam_d, _, _ = search_mod.adaptive_search_batch(
                ctx_chunk, adj, entry, eval_dists, n_local, beam_budget,
                max_hops=max_hops, bucket_ceilings=bucket_ceilings,
                lam=lam, l_min=l_min, step_kernel=step_kernel)
        else:
            beam_ids, beam_d, _ = search_mod.fixed_search_batch(
                ctx_chunk, adj, entry, eval_dists, n_local, beam_width,
                max_hops, step_kernel=step_kernel)
        d2, ids = _local_rerank(beam_ids, vectors, q_chunk, k)
        return d2, ids

    nq = queries.shape[0]
    assert nq % query_chunk == 0, (nq, query_chunk)
    ctx_chunks = ctxs.reshape((nq // query_chunk, query_chunk) + ctxs.shape[1:])
    q_chunks = queries.reshape(nq // query_chunk, query_chunk, -1)
    d2, ids = jax.lax.map(chunk_fn, (ctx_chunks, q_chunks))
    return d2.reshape(nq, k), ids.reshape(nq, k)


def make_distributed_search(
    mesh,
    *,
    beam_width: int,
    max_hops: int,
    k: int,
    query_chunk: int = 128,
    use_pq: bool = True,
    merge: str = "hierarchical",
    beam_budget: search_mod.AdaptiveBeamBudget | None = None,
    budget_buckets: int | None = None,
    per_shard_laws: bool = False,
    step_kernel: str | None = None,
):
    """Builds the jit-able *monolithic* sharded search step for ``mesh``.

    step(adj, codes, vectors, centroids, queries, shard_ok, entries
         [, shard_lam, shard_l_min])
      -> (d2 (Q, k), shard_id (Q, k), local_id (Q, k))

    ``entries`` is the (n_shards,) array of per-shard entry points (local
    medoids), sharded one per device like ``shard_ok``.

    Global ids are returned as (shard, local_id) pairs — billion-scale ids
    exceed int32 when flattened.

    beam_budget:
      None runs every query at the fixed ``beam_width``; an
      :class:`repro.core.search.AdaptiveBeamBudget` switches each shard to
      the per-query adaptive engine (probe -> online LID -> budget ->
      continue). Budgets are computed per shard from the shard's own probe
      beam; the global merge is unchanged.

    budget_buckets:
      with ``beam_budget`` set, quantizes each shard's granted budgets up to
      at most this many power-of-two bucket ceilings
      (:func:`repro.core.search.budget_bucket_ceilings`) and derives every
      query's hop limit from its bucket ceiling — a discrete per-shard
      deadline family (see :func:`_local_search`): straggling walks stop at
      their bucket's deadline, mid-graph, and still contribute best-so-far
      candidates to the merge. Complements (does not replace) ``shard_ok``,
      which stays the drop mechanism for dead shards; quantization rounds
      up, so recall is >= the unquantized adaptive path's at slightly more
      counted I/O.

    per_shard_laws:
      the step takes two extra trailing inputs — (n_shards,) ``shard_lam``
      float32 and ``shard_l_min`` int32 arrays, sharded like ``shard_ok`` —
      and each shard's budget law uses *its* calibrated (lam, l_min)
      instead of ``beam_budget``'s globals. Runtime inputs: recalibration
      never recompiles. The bucket-ceiling family stays derived from the
      global config's (l_min, l_max) range (ceilings are static); rounding
      up is still never tighter than any shard's law.

    For the staged split of this step (probe / continue as separate
    programs, resumable at the probe horizon) see
    :func:`make_distributed_probe` / :func:`make_distributed_continue`.
    """
    axes = _shard_axes(mesh)
    bucket_ceilings = None
    if beam_budget is not None and budget_buckets and budget_buckets > 1:
        bucket_ceilings = search_mod.budget_bucket_ceilings(
            beam_budget.l_min, beam_budget.l_max, budget_buckets)

    def step(adj, codes, vectors, centroids, queries, shard_ok, entries,
             *laws):
        def shard_fn(adj_l, codes_l, vectors_l, centroids_l, queries_l, ok_l,
                     entry_l, *laws_l):
            lam_l = laws_l[0][0] if per_shard_laws else None
            l_min_l = laws_l[1][0] if per_shard_laws else None
            d2, ids = _local_search(
                adj_l, codes_l, vectors_l, centroids_l, queries_l, entry_l[0],
                beam_width=beam_width, max_hops=max_hops, k=k,
                query_chunk=query_chunk, use_pq=use_pq,
                beam_budget=beam_budget, bucket_ceilings=bucket_ceilings,
                lam=lam_l, l_min=l_min_l, step_kernel=step_kernel,
            )
            return _hedged_merge(d2, ids, ok_l, mesh, axes, merge)

        specs_in = [
            P(axes, None),  # adj
            P(axes, None),  # codes
            P(axes, None),  # vectors
            P(),            # centroids
            P(),            # queries
            P(axes),        # shard_ok (1 flag per shard)
            P(axes),        # entries  (1 entry point per shard)
        ]
        if per_shard_laws:
            specs_in += [P(axes), P(axes)]  # shard_lam, shard_l_min
        return compat.shard_map(
            shard_fn, mesh=mesh, in_specs=tuple(specs_in),
            out_specs=(P(), P(), P()),
        )(adj, codes, vectors, centroids, queries, shard_ok, entries, *laws)

    return step


def make_distributed_probe(
    mesh,
    *,
    budget_cfg: search_mod.AdaptiveBeamBudget,
    max_hops: int,
    query_chunk: int = 128,
    use_pq: bool = True,
    budget_buckets: int | None = None,
    per_shard_laws: bool = False,
    step_kernel: str | None = None,
):
    """The probe half of the staged distributed step.

    probe(adj, codes, vectors, centroids, queries, entries
          [, shard_lam, shard_l_min])
      -> (probe_state, budgets, hop_limits, q_lid)

    Every shard walks every query ``probe_hops`` hops at its budget floor,
    estimates per-query LID from its local probe beam and grants per-shard
    budgets/hop deadlines (quantized up to the in-graph bucket ceilings when
    ``budget_buckets`` is set — exactly as the monolithic step does between
    its probe and continue phases). The walk is *checkpointed at the probe
    horizon*: ``probe_state`` is (beam_ids, beam_d, beam_exp, visited, hops,
    evals, ctx) with the per-shard leaves laid out ``(Q, n_shards, ...)``
    (shard axis second, sharded in place — no cross-device traffic), so the
    host scheduler can select any query subset on axis 0;
    ``budgets``/``hop_limits``/``q_lid`` are (Q, n_shards). ``ctx`` is the
    replicated walk context (ADC LUTs or raw queries) — carried in the
    state so the continue program resumes from the *same* buffers the probe
    used.

    Queries are probed in ``query_chunk`` groups under ``lax.map`` exactly
    like the monolithic step (so batch-mean LID centering sees the same
    chunks); a batch not divisible by the chunk runs as one chunk — staged
    serving accepts ragged *micro*-batches the monolithic step would reject
    (bounded at max(4 x query_chunk, 512) lanes, past which the single
    chunk would defeat the visited-bitmap memory discipline and the step
    refuses it at trace time).
    """
    axes = _shard_axes(mesh)
    bucket_ceilings = None
    if budget_buckets and budget_buckets > 1:
        bucket_ceilings = search_mod.budget_bucket_ceilings(
            budget_cfg.l_min, budget_cfg.l_max, budget_buckets)

    def step(adj, codes, vectors, centroids, queries, entries, *laws):
        def shard_fn(adj_l, codes_l, vectors_l, centroids_l, queries_l,
                     entry_l, *laws_l):
            n_local = adj_l.shape[0]
            entry = entry_l[0].astype(jnp.int32)
            eval_dists = _shard_eval(codes_l, vectors_l, use_pq)
            ctxs = _shard_ctxs(centroids_l, queries_l, use_pq)
            lam_l = laws_l[0][0] if per_shard_laws else None
            l_min_l = laws_l[1][0] if per_shard_laws else None
            nq = queries_l.shape[0]
            chunk = query_chunk if nq % query_chunk == 0 else nq
            # Ragged *micro*-batches run as one chunk (their visited
            # bitmaps are small); a bulk batch must land on the chunk grid
            # — refuse the silent (nq x N_local/32) visited blowup the
            # chunking exists to prevent.
            assert chunk <= max(4 * query_chunk, 512), (
                f"batch of {nq} queries is not divisible by "
                f"query_chunk={query_chunk} and too large to probe as one "
                f"chunk; align bulk batches to the chunk grid")

            def chunk_fn(ctx_chunk):
                st, budgets, hop_limits, q_lid = search_mod.adaptive_probe_batch(
                    ctx_chunk, adj_l, entry, eval_dists, n_local, budget_cfg,
                    max_hops=max_hops, lam=lam_l, l_min=l_min_l,
                    step_kernel=step_kernel)
                if bucket_ceilings is not None:
                    _, budgets = search_mod.quantize_budgets(
                        budgets, bucket_ceilings)
                    hop_limits = search_mod._bucket_hop_limits(
                        budget_cfg, budgets, max_hops)
                return st + (budgets, hop_limits, q_lid)

            ctx_chunks = ctxs.reshape((nq // chunk, chunk) + ctxs.shape[1:])
            outs = jax.lax.map(chunk_fn, ctx_chunks)
            outs = jax.tree_util.tree_map(
                lambda a: a.reshape((nq,) + a.shape[2:]), outs)
            b_ids, b_d, b_exp, visited, hops, evals, budgets, hop_limits, \
                q_lid = outs
            shard_axis = lambda a: a[:, None]  # (Q, ...) -> (Q, 1, ...)
            state = (shard_axis(b_ids), shard_axis(b_d), shard_axis(b_exp),
                     shard_axis(visited), shard_axis(hops), shard_axis(evals),
                     ctxs)
            return (state, shard_axis(budgets), shard_axis(hop_limits),
                    shard_axis(q_lid))

        specs_in = [
            P(axes, None),  # adj
            P(axes, None),  # codes
            P(axes, None),  # vectors
            P(),            # centroids
            P(),            # queries
            P(axes),        # entries
        ]
        if per_shard_laws:
            specs_in += [P(axes), P(axes)]
        state_specs = ((P(None, axes, None),) * 4     # beams + visited
                       + (P(None, axes),) * 2         # hops, evals
                       + (P(),))                      # ctx (replicated)
        out_specs = (state_specs, P(None, axes), P(None, axes),
                     P(None, axes))
        return compat.shard_map(
            shard_fn, mesh=mesh, in_specs=tuple(specs_in),
            out_specs=out_specs,
        )(adj, codes, vectors, centroids, queries, entries, *laws)

    return step


def make_distributed_continue(
    mesh,
    *,
    budget_cfg: search_mod.AdaptiveBeamBudget,
    k: int,
    use_pq: bool = True,
    merge: str = "hierarchical",
    step_kernel: str | None = None,
):
    """The continue half of the staged distributed step.

    cont(adj, codes, vectors, centroids, probe_state, queries, budgets,
         hop_limits, shard_ok)
      -> (d2 (q, k), shard_id (q, k), local_id (q, k),
          hops (q,), dist_evals (q,))

    Resumes the checkpointed shard walks (warm beam + visited set, no
    repeated hops) for *any query subset* of a probe's batch — the host
    bucket scheduler selects rows on axis 0 of every probe output — then
    reranks locally and runs the same hedged merge as the monolithic step
    (:func:`_hedged_merge`, shared code). ``shard_ok`` is consumed here, at
    merge time: flipping the mask between batches of a stream affects every
    continue dispatched after the flip, with no recompilation.

    ``hops``/``dist_evals`` are the per-query totals summed over *live*
    shards (the monolithic step reports no counters; the staged path is
    strictly more observable).
    """
    axes = _shard_axes(mesh)

    def step(adj, codes, vectors, centroids, state, queries, budgets,
             hop_limits, shard_ok):
        def shard_fn(adj_l, codes_l, vectors_l, centroids_l, state_l,
                     queries_l, budgets_l, hop_limits_l, ok_l):
            *walk, ctx = state_l
            walk = tuple(jnp.squeeze(a, axis=1) for a in walk)
            eval_dists = _shard_eval(codes_l, vectors_l, use_pq)
            beam_ids, beam_d, hops, evals = search_mod.adaptive_continue_batch(
                walk, ctx, adj_l, eval_dists, budget_cfg,
                budgets_l[:, 0], hop_limits_l[:, 0], step_kernel=step_kernel)
            d2, ids = _local_rerank(beam_ids, vectors_l, queries_l, k)
            d2, sid, lid = _hedged_merge(d2, ids, ok_l, mesh, axes, merge)
            live_hops = jax.lax.psum(jnp.where(ok_l[0], hops, 0), axes)
            live_evals = jax.lax.psum(jnp.where(ok_l[0], evals, 0), axes)
            return d2, sid, lid, live_hops, live_evals

        state_specs = ((P(None, axes, None),) * 4
                       + (P(None, axes),) * 2
                       + (P(),))
        specs_in = (
            P(axes, None),   # adj
            P(axes, None),   # codes
            P(axes, None),   # vectors
            P(),             # centroids
            state_specs,     # checkpointed walks
            P(),             # queries (replicated; local rerank targets)
            P(None, axes),   # budgets
            P(None, axes),   # hop_limits
            P(axes),         # shard_ok
        )
        return compat.shard_map(
            shard_fn, mesh=mesh, in_specs=specs_in,
            out_specs=(P(), P(), P(), P(), P()),
        )(adj, codes, vectors, centroids, state, queries, budgets,
          hop_limits, shard_ok)

    return step


def shard_medoids(vectors: Array, n_shards: int) -> Array:
    """Per-shard entry points: the local medoid of each shard's rows.

    ``vectors`` is shard-major (shard s owns rows [s*per, (s+1)*per)) —
    the layout ``distributed_search`` already requires.
    """
    per = vectors.shape[0] // n_shards
    blocks = vectors[: per * n_shards].reshape(n_shards, per, -1)
    return jax.vmap(search_mod.medoid)(blocks)


def build_sharded_arrays(
    x: Array,
    mesh,
    *,
    build_cfg,
    m_pq: int = 8,
    alpha: float = 1.2,
    pq_iters: int = 4,
    seed: int = 0,
) -> tuple[dict, int]:
    """Build a shard-major distributed index for ``mesh`` and lay it out.

    One locally built sub-graph per shard (shard-local ids, static
    ``alpha``), PQ codebook/codes over the full collection, per-shard entry
    medoids — all ``device_put`` with the shardings
    :func:`make_distributed_search` requires. ``x`` is truncated to a
    multiple of the shard count. Returns (arrays dict, rows_per_shard).

    Example/benchmark/test scale: production builds each shard's sub-graph
    on the host that owns it and ships the serializer's per-shard files;
    this helper exists so every in-process harness (examples, workers,
    benchmarks, the serve launcher's ``--distributed`` mode) shards one
    collection the same way.
    """
    from repro.core import build as build_mod
    from repro.pq import pq_encode, train_pq

    n_shards = mesh.devices.size
    x = jnp.asarray(x)
    n = (x.shape[0] // n_shards) * n_shards
    x = x[:n]
    per = n // n_shards
    adj = jnp.concatenate([
        build_mod.build_with_alpha(
            x[s * per:(s + 1) * per],
            jnp.full((per,), alpha, jnp.float32), build_cfg)
        for s in range(n_shards)
    ])
    book = train_pq(x, m=m_pq, iters=pq_iters, seed=seed)
    axes = _shard_axes(mesh)
    row = NamedSharding(mesh, P(axes, None))
    flag = NamedSharding(mesh, P(axes))
    arrays = {
        "adj": jax.device_put(adj, row),
        "codes": jax.device_put(pq_encode(x, book), row),
        "vectors": jax.device_put(x, row),
        "centroids": jax.device_put(book.centroids, NamedSharding(mesh, P())),
        "entries": jax.device_put(shard_medoids(x, n_shards), flag),
    }
    return arrays, per


def distributed_search(mesh, index_arrays, queries, shard_ok=None,
                       shard_laws=None, **kw):
    """Convenience eager entry (tests, examples): index_arrays is a dict with
    adj/codes/vectors/centroids (optionally entries) laid out shard-major.

    When ``entries`` is absent the per-shard medoids are recomputed here on
    *every call* — an O(N·D) scan. Production callers should compute them
    once at index-build time and put them in the dict. ``shard_laws`` is an
    optional (lam (S,), l_min (S,)) pair of per-shard budget-law arrays.
    """
    step = make_distributed_search(
        mesh, per_shard_laws=shard_laws is not None, **kw)
    n_shards = mesh.devices.size
    if shard_ok is None:
        shard_ok = jnp.ones((n_shards,), jnp.bool_)
    entries = index_arrays.get("entries")
    if entries is None:
        entries = shard_medoids(index_arrays["vectors"], n_shards)
    laws = ()
    if shard_laws is not None:
        laws = (jnp.asarray(shard_laws[0], jnp.float32),
                jnp.asarray(shard_laws[1], jnp.int32))
    return step(
        index_arrays["adj"], index_arrays["codes"], index_arrays["vectors"],
        index_arrays["centroids"], queries, shard_ok, entries, *laws,
    )
