"""Distributed MCGI serving: sharded beam search + global top-k merge.

Layout (DESIGN.md §5): base points are sharded into n_shards = |data|x|model|
(x|pod|) partitions; every shard holds its *own locally built* MCGI sub-graph
(adjacency with shard-local ids), its PQ codes and its full-precision
vectors. A query fans out to all shards, each runs the PQ-routed beam search
+ local exact rerank on its sub-index, and the per-shard top-k are merged
into the global top-k with one all_gather + sort — the standard
scatter-gather ANN serving pattern expressed as jax collectives inside
``shard_map``.

Straggler mitigation: the merge takes a per-shard ``shard_ok`` mask; a shard
that misses its deadline (or is down) contributes +inf distances and the
merge degrades gracefully (recall loss ~ its data fraction) instead of
stalling the query — the hedged-read policy of production ANN serving. The
mask is a runtime input, so dropping shards needs no recompilation.

Memory discipline at N=10^9: per device the shard is ~3.9M points; queries
are processed in ``query_chunk`` groups under ``lax.map`` so the visited
bitmap stays at chunk x N_local bools.

This module owns the *in-graph* distributed step only (shard walks, hedged
merge, in-graph budget buckets / hop deadlines). Serving lowers through
:class:`repro.serving.DistributedBackend` — the unified engine treats the
step as one monolithic program and pipelines batch streams at step
granularity; ``launch/cells.py`` prices the same step in the dry-run via
``DistributedBackend.make_step``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import search as search_mod

Array = jax.Array
INVALID = -1


@dataclasses.dataclass(frozen=True)
class ShardedIndexSpecs:
    """ShapeDtypeStructs (with shardings) of a sharded tiered index."""

    adj: jax.ShapeDtypeStruct
    codes: jax.ShapeDtypeStruct
    vectors: jax.ShapeDtypeStruct
    centroids: jax.ShapeDtypeStruct
    queries: jax.ShapeDtypeStruct
    shard_ok: jax.ShapeDtypeStruct
    entries: jax.ShapeDtypeStruct


def _shard_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)  # points shard over every axis


def sharded_index_specs(
    mesh,
    *,
    n: int,
    d: int,
    degree: int,
    m_pq: int | None,
    n_queries: int,
    data_dtype=jnp.float32,
) -> ShardedIndexSpecs:
    axes = _shard_axes(mesh)
    n_shards = mesh.devices.size
    n_pad = ((n + n_shards - 1) // n_shards) * n_shards
    row = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())
    m = m_pq or 0
    return ShardedIndexSpecs(
        adj=jax.ShapeDtypeStruct((n_pad, degree), jnp.int32, sharding=NamedSharding(mesh, P(axes, None))),
        codes=jax.ShapeDtypeStruct((n_pad, max(m, 1)), jnp.uint8, sharding=NamedSharding(mesh, P(axes, None))),
        vectors=jax.ShapeDtypeStruct((n_pad, d), data_dtype, sharding=NamedSharding(mesh, P(axes, None))),
        centroids=jax.ShapeDtypeStruct(
            (max(m, 1), 256, max(d // max(m, 1), 1)), jnp.float32, sharding=repl
        ),
        queries=jax.ShapeDtypeStruct((n_queries, d), jnp.float32, sharding=repl),
        shard_ok=jax.ShapeDtypeStruct((n_shards,), jnp.bool_, sharding=row),
        entries=jax.ShapeDtypeStruct((n_shards,), jnp.int32, sharding=row),
    )


def _local_search(
    adj, codes, vectors, centroids, queries, entry, *,
    beam_width: int, max_hops: int, k: int, query_chunk: int, use_pq: bool,
    beam_budget: search_mod.AdaptiveBeamBudget | None = None,
    bucket_ceilings: tuple[int, ...] | None = None,
):
    """Per-shard search over the local sub-graph. Returns (d2, local_ids)
    each (Q, k).

    ``entry`` is the shard's own entry point (its local medoid, computed at
    index-build time and threaded through :class:`ShardedIndexSpecs`). With
    ``beam_budget`` set, the shard runs the adaptive engine: each query's
    budget is computed *on this shard* from its local probe beam (shard
    geometry differs, so budgets legitimately differ per shard) and the
    per-shard top-k are merged exactly as in the fixed-beam path.

    ``bucket_ceilings`` additionally quantizes each granted budget up to its
    bucket ceiling *in-graph* and derives the per-query hop limit from that
    ceiling, giving the shard a small *discrete family of hop deadlines*
    (probe + hop_factor * ceiling, always capped by ``max_hops``): a walk
    that hits its deadline stops mid-graph and still contributes its
    best-so-far beam to the merge. Note the quantization rounds *up*, so a
    query's limit is never tighter than the raw adaptive path's — the hedge
    is against unbounded straggling (deadlines are enforced mid-walk and the
    shard's completion time is governed by its top occupied bucket), not a
    tightening of the budget law. ``shard_ok`` remains the orthogonal
    mechanism for shards that are down entirely.
    """
    n_local = adj.shape[0]
    entry = entry.astype(jnp.int32)

    if use_pq:
        from repro.pq.adc import build_lut

        luts = build_lut(queries.astype(jnp.float32), centroids)

        def eval_dists(lut, ids, valid):
            c = codes[ids].astype(jnp.int32)
            m = lut.shape[0]
            gathered = jax.vmap(lambda row: lut[jnp.arange(m), row])(c)
            return gathered.sum(axis=-1)

        ctxs = luts
    else:
        def eval_dists(q, ids, valid):
            vecs = vectors[ids].astype(jnp.float32)
            diff = vecs - q[None, :]
            return jnp.sum(diff * diff, axis=-1)

        ctxs = queries

    run = functools.partial(
        search_mod._search_one,
        adj=adj, entry=entry, eval_dists=eval_dists,
        n=n_local, beam_width=beam_width, max_hops=max_hops,
    )

    def chunk_fn(args):
        ctx_chunk, q_chunk = args
        if beam_budget is not None:
            # max_hops still caps every per-query hop limit: enabling
            # adaptivity must not silently exceed the operator's I/O SLO.
            beam_ids, beam_d, _, _ = search_mod.adaptive_search_batch(
                ctx_chunk, adj, entry, eval_dists, n_local, beam_budget,
                max_hops=max_hops, bucket_ceilings=bucket_ceilings)
        else:
            beam_ids, beam_d, _ = jax.vmap(run)(ctx_chunk)
        # Local exact rerank from the shard's own full-precision rows (the
        # "disk read" happens on the shard that owns the node).
        safe = jnp.maximum(beam_ids, 0)
        vecs = vectors[safe].astype(jnp.float32)
        diff = vecs - q_chunk[:, None, :].astype(jnp.float32)
        d2 = jnp.sum(diff * diff, axis=-1)
        d2 = jnp.where(beam_ids == INVALID, jnp.inf, d2)
        order = jnp.argsort(d2, axis=-1)[:, :k]
        return (
            jnp.take_along_axis(d2, order, axis=1),
            jnp.take_along_axis(beam_ids, order, axis=1),
        )

    nq = queries.shape[0]
    assert nq % query_chunk == 0, (nq, query_chunk)
    ctx_chunks = ctxs.reshape((nq // query_chunk, query_chunk) + ctxs.shape[1:])
    q_chunks = queries.reshape(nq // query_chunk, query_chunk, -1)
    d2, ids = jax.lax.map(chunk_fn, (ctx_chunks, q_chunks))
    return d2.reshape(nq, k), ids.reshape(nq, k)


def make_distributed_search(
    mesh,
    *,
    beam_width: int,
    max_hops: int,
    k: int,
    query_chunk: int = 128,
    use_pq: bool = True,
    merge: str = "hierarchical",
    beam_budget: search_mod.AdaptiveBeamBudget | None = None,
    budget_buckets: int | None = None,
):
    """Builds the jit-able sharded search step for ``mesh``.

    step(adj, codes, vectors, centroids, queries, shard_ok, entries)
      -> (d2 (Q, k), shard_id (Q, k), local_id (Q, k))

    ``entries`` is the (n_shards,) array of per-shard entry points (local
    medoids), sharded one per device like ``shard_ok``.

    Global ids are returned as (shard, local_id) pairs — billion-scale ids
    exceed int32 when flattened.

    beam_budget:
      None runs every query at the fixed ``beam_width``; an
      :class:`repro.core.search.AdaptiveBeamBudget` switches each shard to
      the per-query adaptive engine (probe -> online LID -> budget ->
      continue). Budgets are computed per shard from the shard's own probe
      beam; the global merge is unchanged.

    budget_buckets:
      with ``beam_budget`` set, quantizes each shard's granted budgets up to
      at most this many power-of-two bucket ceilings
      (:func:`repro.core.search.budget_bucket_ceilings`) and derives every
      query's hop limit from its bucket ceiling — a discrete per-shard
      deadline family (see :func:`_local_search`): straggling walks stop at
      their bucket's deadline, mid-graph, and still contribute best-so-far
      candidates to the merge. Complements (does not replace) ``shard_ok``,
      which stays the drop mechanism for dead shards; quantization rounds
      up, so recall is >= the unquantized adaptive path's at slightly more
      counted I/O.

    merge:
      * "flat"          — one all_gather over every axis at once, then one
        sort (the obvious baseline; payload grows with total shard count).
      * "hierarchical"  — axis-by-axis gather+top-k reduction (model, then
        data, then pod): each stage's payload is only n_axis * Q * k rows and
        later stages ship already-reduced candidate sets (§Perf iteration on
        the mcgi serve cells; also the natural topology map — the first merge
        stays inside a chip row).
    """
    axes = _shard_axes(mesh)
    bucket_ceilings = None
    if beam_budget is not None and budget_buckets and budget_buckets > 1:
        bucket_ceilings = search_mod.budget_bucket_ceilings(
            beam_budget.l_min, beam_budget.l_max, budget_buckets)

    def step(adj, codes, vectors, centroids, queries, shard_ok, entries):
        def shard_fn(adj_l, codes_l, vectors_l, centroids_l, queries_l, ok_l,
                     entry_l):
            d2, ids = _local_search(
                adj_l, codes_l, vectors_l, centroids_l, queries_l, entry_l[0],
                beam_width=beam_width, max_hops=max_hops, k=k,
                query_chunk=query_chunk, use_pq=use_pq,
                beam_budget=beam_budget, bucket_ceilings=bucket_ceilings,
            )
            # Hedged-read mask: a late/dead shard contributes nothing.
            d2 = jnp.where(ok_l[0], d2, jnp.inf)
            q = d2.shape[0]

            if merge == "flat":
                sid = jnp.int32(0)
                stride = 1
                for a in reversed(axes):
                    sid = sid + jax.lax.axis_index(a).astype(jnp.int32) * stride
                    stride *= mesh.shape[a]
                cat_d2 = jax.lax.all_gather(d2, axes, tiled=False)
                cat_ids = jax.lax.all_gather(ids, axes, tiled=False)
                cat_sid = jax.lax.all_gather(
                    jnp.full((1,), sid, jnp.int32), axes, tiled=False
                ).reshape(-1)
                s = cat_d2.shape[0]
                flat_d2 = cat_d2.transpose(1, 0, 2).reshape(q, s * k)
                flat_ids = cat_ids.transpose(1, 0, 2).reshape(q, s * k)
                flat_sid = jnp.broadcast_to(
                    cat_sid[None, :, None], (q, s, k)).reshape(q, s * k)
                order = jnp.argsort(flat_d2, axis=1)[:, :k]
                return (
                    jnp.take_along_axis(flat_d2, order, axis=1),
                    jnp.take_along_axis(flat_sid, order, axis=1),
                    jnp.take_along_axis(flat_ids, order, axis=1),
                )

            # Hierarchical: reduce one mesh axis at a time (innermost first —
            # 'model' neighbours share the fastest links).
            planes = {"local": ids}
            for a in reversed(axes):
                n_a = mesh.shape[a]
                g_d2 = jax.lax.all_gather(d2, a, tiled=False)  # (n_a, Q, k)
                g_planes = {
                    name: jax.lax.all_gather(pl, a, tiled=False)
                    for name, pl in planes.items()
                }
                flat_d2 = g_d2.transpose(1, 0, 2).reshape(q, n_a * k)
                order = jnp.argsort(flat_d2, axis=1)[:, :k]
                d2 = jnp.take_along_axis(flat_d2, order, axis=1)
                new_planes = {}
                for name, pl in g_planes.items():
                    flat = pl.transpose(1, 0, 2).reshape(q, n_a * k)
                    new_planes[name] = jnp.take_along_axis(flat, order, axis=1)
                # Which member of this axis each winner came from.
                src = jnp.broadcast_to(
                    jnp.arange(n_a, dtype=jnp.int32)[None, :, None],
                    (q, n_a, k),
                ).reshape(q, n_a * k)
                new_planes[f"pos_{a}"] = jnp.take_along_axis(src, order, axis=1)
                planes = new_planes

            sid = jnp.zeros_like(planes["local"])
            stride = 1
            for a in reversed(axes):
                sid = sid + planes[f"pos_{a}"] * stride
                stride *= mesh.shape[a]
            return d2, sid, planes["local"]

        specs_in = (
            P(axes, None),  # adj
            P(axes, None),  # codes
            P(axes, None),  # vectors
            P(),            # centroids
            P(),            # queries
            P(axes),        # shard_ok (1 flag per shard)
            P(axes),        # entries  (1 entry point per shard)
        )
        return compat.shard_map(
            shard_fn, mesh=mesh, in_specs=specs_in,
            out_specs=(P(), P(), P()),
        )(adj, codes, vectors, centroids, queries, shard_ok, entries)

    return step


def shard_medoids(vectors: Array, n_shards: int) -> Array:
    """Per-shard entry points: the local medoid of each shard's rows.

    ``vectors`` is shard-major (shard s owns rows [s*per, (s+1)*per)) —
    the layout ``distributed_search`` already requires.
    """
    per = vectors.shape[0] // n_shards
    blocks = vectors[: per * n_shards].reshape(n_shards, per, -1)
    return jax.vmap(search_mod.medoid)(blocks)


def distributed_search(mesh, index_arrays, queries, shard_ok=None, **kw):
    """Convenience eager entry (tests, examples): index_arrays is a dict with
    adj/codes/vectors/centroids (optionally entries) laid out shard-major.

    When ``entries`` is absent the per-shard medoids are recomputed here on
    *every call* — an O(N·D) scan. Production callers should compute them
    once at index-build time and put them in the dict.
    """
    step = make_distributed_search(mesh, **kw)
    n_shards = mesh.devices.size
    if shard_ok is None:
        shard_ok = jnp.ones((n_shards,), jnp.bool_)
    entries = index_arrays.get("entries")
    if entries is None:
        entries = shard_medoids(index_arrays["vectors"], n_shards)
    return step(
        index_arrays["adj"], index_arrays["codes"], index_arrays["vectors"],
        index_arrays["centroids"], queries, shard_ok, entries,
    )
