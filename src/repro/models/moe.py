"""Mixture-of-Experts FFN with scatter-based token dispatch.

Covers the two assigned MoE archs:
  * qwen3-moe-30b-a3b : 128 routed experts, top-8, expert d_ff=768, no shared
  * deepseek-v2-lite  : 64 routed experts, top-6, 2 shared experts, d_ff=1408

Dispatch design (TPU/GSPMD adaptation, see DESIGN.md §5): the GShard one-hot
dispatch einsum costs O(T·E·C·D) FLOPs of pure routing overhead and a
(G,Tg,E,Cg) tensor; this module instead computes each token's slot position
via a cumsum over the (T, E) assignment one-hot and *scatters* tokens into the
(E, C, D) expert buffers — linear memory (exactly the routed activations) and
zero matmul overhead, keeping the §Roofline "useful-FLOPs ratio" honest.
Tokens beyond an expert's capacity are dropped (capacity_factor 1.0, GShard
semantics); their residual stream passes through unchanged.

Expert buffers are sharded (E over tensor axis, C over data axis); the
scatter/gather across those shardings is GSPMD's all-to-all — the same
collective a hand-written expert-parallel dispatch would issue.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.layers import ShardCtx, constrain, dense_init

Array = jax.Array
Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_expert: int            # per-expert FFN hidden size
    n_shared: int = 0        # DeepSeek shared experts
    d_shared: int = 0        # shared-expert hidden size (d_expert if 0)
    capacity_factor: float = 1.0
    router_noise: float = 0.0

    @property
    def shared_hidden(self) -> int:
        return self.d_shared or self.d_expert


def moe_init(key: Array, cfg: MoeConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02, dtype=dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / f) ** 0.5).astype(dtype),
    }
    if cfg.n_shared:
        fs = cfg.shared_hidden * cfg.n_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, fs, dtype=dtype),
            "w_up": dense_init(k2, d, fs, dtype=dtype),
            "w_down": dense_init(k3, fs, d, dtype=dtype),
        }
    return p


def _route(p: Params, cfg: MoeConfig, x_flat: Array):
    """Token-choice top-k routing. Returns (expert_idx (T,k), probs (T,k),
    router_probs (T,E) for the aux loss)."""
    logits = (x_flat @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_e.astype(jnp.int32), top_p, probs


def load_balance_loss(router_probs: Array, expert_idx: Array, n_experts: int) -> Array:
    """Switch-Transformer aux loss: E * sum_e f_e * P_e."""
    t = router_probs.shape[0]
    onehot = jax.nn.one_hot(expert_idx[:, 0], n_experts, dtype=jnp.float32)
    f = onehot.mean(0)                      # fraction of tokens -> expert
    pmean = router_probs.mean(0)            # mean router prob
    return n_experts * jnp.sum(f * pmean)


def _dispatch_group(x_g, expert_idx_g, cap: int, n_experts: int):
    """One group's scatter-dispatch. x_g (tg, D); expert_idx_g (tg, k).

    Returns (buf (E, cap, D), dest (tg*k,), keep (tg*k,)). vmapped over the
    group axis so under GSPMD the scatter stays shard-local.
    """
    tg, d = x_g.shape
    k = expert_idx_g.shape[1]
    flat_e = expert_idx_g.reshape(tg * k)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, n_experts * cap)
    src = jnp.repeat(x_g, k, axis=0)
    buf = jnp.zeros((n_experts * cap + 1, d), x_g.dtype).at[dest].add(src)
    return buf[:-1].reshape(n_experts, cap, d), dest, keep


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= sizes[a]
    return out


def moe_apply_expert_parallel(
    p: Params, cfg: MoeConfig, x: Array, ctx: ShardCtx
) -> tuple[Array, Array]:
    """Explicit expert-parallel MoE under shard_map (§Perf iteration on the
    MoE train cells).

    Under plain GSPMD the undispatch gather over the tensor-sharded expert
    buffers lowers to per-layer *all-gathers* of the whole routed-activation
    tensor (observed: ~10 TB/device/step on qwen3-moe train_4k). This path
    pins the canonical schedule instead: local dispatch -> all_to_all(tp)
    -> local expert FFNs -> all_to_all(tp) -> local combine. The only
    cross-device traffic is the routed activations themselves, twice.

    Requirements (caller checks): B % dp == 0, S % tp == 0, E % tp == 0.
    """
    mesh = ctx.mesh
    dp, tp = ctx.dp, ctx.tp
    n_dp = _axis_size(mesh, dp)
    n_tp = _axis_size(mesh, tp)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_local = e // n_tp
    t_local = (b // n_dp) * (s // n_tp)
    cap = max(int(cfg.capacity_factor * t_local * k / e), 1)

    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    all_axes = dp_axes + (tp,)

    def fn(x_blk, router, w_gate, w_up, w_down):
        bl, sl, _ = x_blk.shape
        x_flat = x_blk.reshape(bl * sl, d)
        logits = (x_flat @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        gate = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        top_e = top_e.astype(jnp.int32)

        # Aux loss from globally psum-averaged stats (exact Switch form).
        onehot = jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32)
        f_sum = jax.lax.psum(onehot.sum(0), all_axes)
        p_sum = jax.lax.psum(probs.sum(0), all_axes)
        t_glob = t_local * n_dp * n_tp
        aux = e * jnp.sum((f_sum / t_glob) * (p_sum / t_glob))

        buf, dest, keep = _dispatch_group(x_flat, top_e, cap, e)
        # (E, cap, D) -> exchange expert ownership across tp.
        buf = buf.reshape(n_tp, e_local, cap, d)
        recv = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (n_tp, e_local, cap, D), dim0 = source peer.
        g = jax.nn.silu(jnp.einsum("pecd,edf->pecf", recv, w_gate))
        h = g * jnp.einsum("pecd,edf->pecf", recv, w_up)
        out = jnp.einsum("pecf,efd->pecd", h, w_down)
        back = jax.lax.all_to_all(out, tp, split_axis=0, concat_axis=0,
                                  tiled=False)
        flat = back.reshape(e * cap, d)
        gathered = jnp.where(
            keep[:, None], flat[jnp.minimum(dest, e * cap - 1)], 0.0
        )
        combined = (gathered.reshape(bl * sl, k, d)
                    * gate[..., None].astype(x_blk.dtype)).sum(1)
        return combined.reshape(bl, sl, d), aux

    in_specs = (
        P(dp, tp, None),        # x: batch over dp, seq over tp
        P(None, None),          # router replicated
        P(tp, None, None),      # expert weights: E over tp, gathered over dp
        P(tp, None, None),
        P(tp, None, None),
    )
    out, aux = compat.shard_map(
        fn, mesh=mesh, in_specs=in_specs,
        out_specs=(P(dp, tp, None), P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared:
        sp = p["shared"]
        x_flat = x.reshape(b * s, d)
        sg = jax.nn.silu(x_flat @ sp["w_gate"])
        shared = ((sg * (x_flat @ sp["w_up"])) @ sp["w_down"]).reshape(b, s, d)
        out = out + shared.astype(out.dtype)
    return out.astype(x.dtype), aux


def _expert_parallel_ok(cfg: MoeConfig, x: Array, ctx: ShardCtx | None) -> bool:
    if ctx is None:
        return False
    mesh = ctx.mesh
    if "model" not in mesh.axis_names:
        return False
    n_dp = _axis_size(mesh, ctx.dp)
    n_tp = _axis_size(mesh, ctx.tp)
    b, s, _ = x.shape
    return (
        n_tp > 1
        and b % n_dp == 0
        and s % n_tp == 0
        and cfg.n_experts % n_tp == 0
    )


def moe_apply(
    p: Params,
    cfg: MoeConfig,
    x: Array,
    ctx: ShardCtx | None = None,
    no_drop: bool = False,
    n_groups: int | None = None,
) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Dispatch is *group-local* (GShard semantics): tokens are split into
    ``n_groups`` groups (one per data shard on a mesh), each group scatters
    into its own (E, cap_g, D) buffer, and the expert einsum batches over
    (group, expert). Under GSPMD the group axis aligns with the data axis and
    the expert axis with the model axis, so the dispatch lowers to the
    canonical all-to-all instead of an unshardable global scatter.

    Training uses capacity dropping per group (tokens beyond cap ride the
    residual); decode passes ``no_drop=True`` (cap = group size) so serving
    is deterministic.

    On a mesh with a model axis (and compatible shapes) this dispatches to
    :func:`moe_apply_expert_parallel` — the explicit all-to-all schedule.
    """
    if not no_drop and _expert_parallel_ok(cfg, x, ctx):
        return moe_apply_expert_parallel(p, cfg, x, ctx)
    b, s, d = x.shape
    t = b * s
    if n_groups is None:
        if ctx is not None:
            n_groups = 1
            for a in (ctx.dp if isinstance(ctx.dp, tuple) else (ctx.dp,)):
                n_groups *= dict(zip(ctx.mesh.axis_names,
                                     ctx.mesh.devices.shape))[a]
        else:
            n_groups = 1
    while t % n_groups != 0:
        n_groups //= 2  # batch=1 decode etc: fall back to fewer groups
    tg = t // n_groups

    x_flat = x.reshape(t, d)
    expert_idx, gate, router_probs = _route(p, cfg, x_flat)
    aux = load_balance_loss(router_probs, expert_idx, cfg.n_experts)

    k = cfg.top_k
    if no_drop:
        cap = tg
    else:
        cap = max(int(cfg.capacity_factor * tg * k / cfg.n_experts), 1)

    x_g = x_flat.reshape(n_groups, tg, d)
    eid_g = expert_idx.reshape(n_groups, tg, k)
    if ctx is not None:
        x_g = constrain(ctx, x_g, ctx.dp, None, None)

    buf, dest, keep = jax.vmap(
        lambda xx, ee: _dispatch_group(xx, ee, cap, cfg.n_experts)
    )(x_g, eid_g)  # buf (G, E, cap, D)
    if ctx is not None:
        buf = constrain(ctx, buf, ctx.dp, ctx.tp, None, None)

    # Expert FFNs batched over (group, expert) — both axes mesh-sharded.
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]))
    h = g * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    if ctx is not None:
        out_buf = constrain(ctx, out_buf, ctx.dp, ctx.tp, None, None)

    # Gather back within each group; dropped slots contribute 0.
    def _undispatch_group(out_g, dest_g, keep_g, gate_g):
        flat = out_g.reshape(cfg.n_experts * cap, d)
        gathered = jnp.where(
            keep_g[:, None],
            flat[jnp.minimum(dest_g, cfg.n_experts * cap - 1)], 0.0,
        )
        return (gathered.reshape(tg, k, d)
                * gate_g[..., None].astype(out_g.dtype)).sum(1)

    gate_g = gate.reshape(n_groups, tg, k)
    combined = jax.vmap(_undispatch_group)(out_buf, dest, keep, gate_g)
    combined = combined.reshape(t, d)

    if cfg.n_shared:
        sp = p["shared"]
        sg = jax.nn.silu(x_flat @ sp["w_gate"])
        combined = combined + (sg * (x_flat @ sp["w_up"])) @ sp["w_down"]

    return combined.reshape(b, s, d).astype(x.dtype), aux
