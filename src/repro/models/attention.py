"""Attention variants for the LM zoo: GQA (qwen2/qwen3/deepseek-coder/minicpm)
and MLA (deepseek-v2-lite), each with a train path (full causal self-attn)
and a decode path (single token against a KV cache).

Decode paths route through :func:`repro.kernels.ops.decode_attention` (Pallas
flash-decoding on TPU, shardable jnp elsewhere). MLA ships both the naive
(expand-latent) and *absorbed* decode — the absorbed form never materialises
full K/V and is one of the framework's beyond-paper §Perf levers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.models.blockwise import blockwise_attention
from repro.models.layers import ShardCtx, constrain, dense_init

Array = jax.Array
Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GqaConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    qk_norm: bool = False       # qwen3-style per-head RMS on q/k
    rope_theta: float = 10000.0
    attn_chunk_q: int = 256
    attn_chunk_k: int = 1024
    skip_masked_blocks: bool = False  # §Perf lever: causal block skipping
    attn_unroll: bool = False         # dry-run cost accounting (scan unroll)


def gqa_init(key: Array, cfg: GqaConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), dtype)
        p["k_norm"] = jnp.ones((cfg.d_head,), dtype)
    return p


def _project_qkv(p: Params, cfg: GqaConfig, x: Array, positions: Array):
    b, s, _ = x.shape
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0.0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0.0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0.0)
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(
    p: Params, cfg: GqaConfig, x: Array, ctx: ShardCtx | None = None
) -> Array:
    """Blockwise causal self-attention. x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if ctx is not None:
        q = constrain(ctx, q, ctx.dp, None, ctx.tp, None)
        k = constrain(ctx, k, ctx.dp, None, None, None)
        v = constrain(ctx, v, ctx.dp, None, None, None)
    g = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, s, cfg.n_kv_heads, g, cfg.d_head)
    o = blockwise_attention(
        q, k, v,
        chunk_q=min(cfg.attn_chunk_q, s), chunk_k=min(cfg.attn_chunk_k, s),
        causal=True, skip_masked_blocks=cfg.skip_masked_blocks,
        unroll=cfg.attn_unroll, ctx=ctx,
    ).reshape(b, s, -1)
    return o @ p["wo"]


def gqa_init_cache(cfg: GqaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(
    p: Params,
    cfg: GqaConfig,
    x: Array,
    cache: Params,
    kv_len: Array,
    ctx: ShardCtx | None = None,
) -> tuple[Array, Params]:
    """One decode step. x: (B, 1, D); kv_len: (B,) current lengths.

    Returns (out (B, 1, D), updated cache). The new token is written at
    position kv_len[b] and attends to kv_len[b]+1 entries.
    """
    b = x.shape[0]
    positions = kv_len[:, None]  # (B, 1)
    q, k, v = _project_qkv(p, cfg, x, positions)
    # Write the new KV at each sequence's position via a masked select —
    # elementwise, so a sequence-sharded cache updates with ZERO collectives
    # (a scatter at a dynamic cross-shard index makes GSPMD all-gather the
    # whole cache; §Perf iteration on the long-context decode cells).
    s_max = cache["k"].shape[1]
    write = (jnp.arange(s_max)[None, :] == kv_len[:, None])[..., None, None]
    cache_k = jnp.where(write, k[:, 0][:, None].astype(cache["k"].dtype),
                        cache["k"])
    cache_v = jnp.where(write, v[:, 0][:, None].astype(cache["v"].dtype),
                        cache["v"])
    if ctx is not None:
        b_e, s_e = ctx.batch_seq_spec(b)
        cache_k = constrain(ctx, cache_k, b_e, s_e, None, None)
        cache_v = constrain(ctx, cache_v, b_e, s_e, None, None)
    o = ops.decode_attention(
        q[:, 0], cache_k, cache_v, kv_len + 1
    )  # (B, Hq, d)
    o = o.astype(x.dtype).reshape(b, 1, -1)
    return o @ p["wo"], {"k": cache_k, "v": cache_v}


# ----------------------------------------------------------------------- MLA

@dataclasses.dataclass(frozen=True)
class MlaConfig:
    """DeepSeek-V2 Multi-head Latent Attention (arXiv:2405.04434).

    V2-Lite: kv_lora_rank=512, no q compression, 16 heads,
    qk_nope=128, qk_rope=64, v_head=128.
    """

    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    attn_chunk_q: int = 256
    attn_chunk_k: int = 1024
    skip_masked_blocks: bool = False
    attn_unroll: bool = False         # dry-run cost accounting (scan unroll)


def mla_init(key: Array, cfg: MlaConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    return {
        # Queries (uncompressed in V2-Lite).
        "wq": dense_init(ks[0], cfg.d_model, h * (cfg.qk_nope_dim + cfg.qk_rope_dim),
                         dtype=dtype),
        # Joint KV down-projection + decoupled rope key.
        "w_dkv": dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim,
                            dtype=dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        # Up-projections from the latent.
        "w_uk": dense_init(ks[2], cfg.kv_lora_rank, h * cfg.qk_nope_dim, dtype=dtype),
        "w_uv": dense_init(ks[3], cfg.kv_lora_rank, h * cfg.v_head_dim, dtype=dtype),
        "wo": dense_init(ks[4], h * cfg.v_head_dim, cfg.d_model, dtype=dtype),
    }


def _mla_latent(p: Params, cfg: MlaConfig, x: Array, positions: Array):
    """Compressed KV path: returns (c_kv (B,S,r), k_rope (B,S,1,dr))."""
    dkv = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_kv = layers.rms_norm(c_kv, p["kv_norm"])
    k_rope = layers.apply_rope(
        k_rope[..., None, :], positions, cfg.rope_theta
    )  # (B,S,1,dr) shared across heads
    return c_kv, k_rope


def mla_train(
    p: Params, cfg: MlaConfig, x: Array, ctx: ShardCtx | None = None
) -> Array:
    b, s, _ = x.shape
    h = cfg.n_heads
    positions = jnp.arange(s)[None, :]
    q = (x @ p["wq"]).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, cfg.v_head_dim)
    if ctx is not None:
        q_nope = constrain(ctx, q_nope, ctx.dp, None, ctx.tp, None)
        k_nope = constrain(ctx, k_nope, ctx.dp, None, ctx.tp, None)
        v = constrain(ctx, v, ctx.dp, None, ctx.tp, None)

    # Fold the (nope | rope) split into one key dim and reuse the blockwise
    # machinery (its d^-0.5 scale over d = nope+rope is exactly MLA's scale);
    # the shared rope key broadcasts across heads. Here each head is its own
    # "kv head" with group size 1.
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], axis=-1
    )
    o = blockwise_attention(
        q_full, k_full, v,
        chunk_q=min(cfg.attn_chunk_q, s), chunk_k=min(cfg.attn_chunk_k, s),
        causal=True, skip_masked_blocks=cfg.skip_masked_blocks,
        unroll=cfg.attn_unroll, ctx=ctx,
    )  # (B,S,H,1,v_dim)
    o = o.reshape(b, s, -1)
    return o @ p["wo"]


def mla_init_cache(cfg: MlaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """The latent cache: (r + dr) per token — MLA's memory win."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(
    p: Params,
    cfg: MlaConfig,
    x: Array,
    cache: Params,
    kv_len: Array,
    ctx: ShardCtx | None = None,
    absorbed: bool = True,
) -> tuple[Array, Params]:
    """One MLA decode step against the latent cache.

    absorbed=True computes attention entirely in the r-dim latent space
    (W_uk folded into the query, W_uv applied after the weighted latent sum) —
    no (S, H, d) K/V ever materialises. absorbed=False expands the latent to
    full K/V (the naive baseline; kept for §Perf A/B).
    """
    b = x.shape[0]
    h = cfg.n_heads
    positions = kv_len[:, None]
    q = (x @ p["wq"]).reshape(b, 1, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv_new, k_rope_new = _mla_latent(p, cfg, x, positions)
    # Masked-select cache write (see gqa_decode) — collective-free under a
    # sequence-sharded latent cache.
    s_tot = cache["c_kv"].shape[1]
    write = (jnp.arange(s_tot)[None, :] == kv_len[:, None])[..., None]
    c_kv = jnp.where(write, c_kv_new[:, 0][:, None].astype(cache["c_kv"].dtype),
                     cache["c_kv"])
    k_rope = jnp.where(
        write, k_rope_new[:, 0, 0][:, None].astype(cache["k_rope"].dtype),
        cache["k_rope"],
    )
    if ctx is not None:
        b_e, s_e = ctx.batch_seq_spec(b)
        c_kv = constrain(ctx, c_kv, b_e, s_e, None)
        k_rope = constrain(ctx, k_rope, b_e, s_e, None)
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    s_max = c_kv.shape[1]
    mask = jnp.arange(s_max)[None, :] < (kv_len + 1)[:, None]  # (B, S)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    if absorbed:
        # q~ = W_uk^T q_nope: (B, h, r); scores in latent space.
        w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
        logits = jnp.einsum(
            "bhr,bsr->bhs", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32)
        )
        logits = logits + jnp.einsum(
            "bhd,bsd->bhs",
            q_rope[:, 0].astype(jnp.float32),
            k_rope.astype(jnp.float32),
        )
        logits = jnp.where(mask[:, None], logits * scale, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        lat = jnp.einsum("bhs,bsr->bhr", w, c_kv.astype(jnp.float32))
        w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
        o = jnp.einsum("bhr,rhd->bhd", lat, w_uv.astype(jnp.float32))
    else:
        k_nope = (c_kv.astype(x.dtype) @ p["w_uk"]).reshape(
            b, s_max, h, cfg.qk_nope_dim
        )
        v = (c_kv.astype(x.dtype) @ p["w_uv"]).reshape(b, s_max, h, cfg.v_head_dim)
        logits = jnp.einsum("bhd,bshd->bhs", q_nope[:, 0], k_nope).astype(
            jnp.float32
        ) + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], k_rope).astype(jnp.float32)
        logits = jnp.where(mask[:, None], logits * scale, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhs,bshd->bhd", w, v)

    o = o.astype(x.dtype).reshape(b, 1, -1)
    return o @ p["wo"], new_cache
