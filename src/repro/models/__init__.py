"""Architecture zoo: LM transformers (GQA/MLA, dense/MoE), GAT GNN, and the
four recsys models, all functional plain-dict params on the shared substrate
(layers.py / blockwise.py / embedding.py)."""
from repro.models.layers import ShardCtx  # noqa: F401
