"""Blockwise (memory-linear) causal attention in pure jnp.

Full S x S score materialisation is impossible at the assigned prefill_32k /
train_4k shapes (32k^2 per head). This module is the GSPMD-shardable
flash-attention equivalent: an online-softmax scan over key/value chunks, so
peak live memory is O(S * chunk_k) per head-group rather than O(S^2). On real
TPU deployments the Pallas flash kernel replaces it inside shard_map; the
dry-run and CPU tests lower this version.

Two implementations, numerically identical (tested against each other):

  * default: the query-chunk axis is a *batched* dim (reshape, no slicing),
    only the KV axis is a sequential scan. Crucial under GSPMD: q stays
    sequence-sharded over the model axis with zero resharding, while k/v are
    sequence-replicated by the caller's constraint (the standard
    Megatron-SP all-gather of the small GQA KV heads). A sequential q loop
    would dynamic-slice across the sharded axis and trigger involuntary full
    rematerialisation (XLA SPMD warning b/433785288).

  * skip_masked_blocks=True: sequential q-chunk loop that visits only
    kv-chunks j <= i — the causal-FLOPs-optimal variant (half the attention
    compute). Slicing-heavy, so it is the single-device / Pallas-kernel
    reference semantics and the §Perf A/B lever, not the GSPMD default.

FLOPs note (§Roofline): the default computes all S^2 blocks and masks —
~2x causal-optimal attention FLOPs; recorded in the useful-FLOPs ratio.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import constrain

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("chunk_q", "chunk_k", "causal",
                                              "skip_masked_blocks", "unroll",
                                              "ctx"))
def blockwise_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    chunk_q: int = 256,
    chunk_k: int = 1024,
    causal: bool = True,
    skip_masked_blocks: bool = False,
    unroll: bool = False,
    ctx=None,
) -> Array:
    """q: (B, S, Hkv, G, d); k, v: (B, S, Hkv, d) -> (B, S, Hkv, G, d_v).

    GQA group dim G folded in q; softmax in f32; output in q.dtype.
    ``unroll=True`` unrolls the KV scan — required for dry-run cost
    accounting (XLA cost_analysis counts a while-loop body once).
    """
    if skip_masked_blocks and causal:
        return _blockwise_seq_q(q, k, v, chunk_q=chunk_q, chunk_k=chunk_k)
    return _blockwise_batched_q(q, k, v, chunk_q=chunk_q, chunk_k=chunk_k,
                                causal=causal, unroll=unroll, ctx=ctx)


def _blockwise_batched_q(q, k, v, *, chunk_q, chunk_k, causal, unroll=False,
                         ctx=None):
    b, s, hkv, g, d = q.shape
    dv = v.shape[-1]
    assert s % chunk_q == 0 and s % chunk_k == 0, (s, chunk_q, chunk_k)
    nq, nk = s // chunk_q, s // chunk_k
    scale = d ** -0.5

    qc = q.reshape(b, nq, chunk_q, hkv, g, d)
    kc = k.reshape(b, nk, chunk_k, hkv, d)
    vc = v.reshape(b, nk, chunk_k, hkv, dv)
    q_pos = jnp.arange(s).reshape(nq, chunk_q)
    k_pos = jnp.arange(s).reshape(nk, chunk_k)

    # The q-chunk axis nq carries the sequence sharding (callers pick
    # chunk_q <= S/|tp| so nq tiles the model axis). Constraints inside the
    # scan pin both the forward intermediates AND their bwd cotangents
    # (with_sharding_constraint transposes to itself) — without them the
    # backward pass replicates every (B, nq, cq, H, G, ck) f32 tensor across
    # the mesh (observed: ~9.4e12 B/device of all-gathers on train_4k).
    def _pin(t):
        if ctx is None:
            return t
        spec = (ctx.dp, ctx.tp) + (None,) * (t.ndim - 2)
        return constrain(ctx, t, *spec)

    acc0 = _pin(jnp.zeros((b, nq, chunk_q, hkv, g, dv), jnp.float32))
    m0 = _pin(jnp.full((b, nq, chunk_q, hkv, g), -jnp.inf, jnp.float32))
    l0 = _pin(jnp.zeros((b, nq, chunk_q, hkv, g), jnp.float32))

    def kv_step(carry, kj):
        acc, m, l = carry
        kt = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
        logits = jnp.einsum(
            "bnqhgd,bkhd->bnqhgk", qc.astype(jnp.float32),
            kt.astype(jnp.float32),
        ) * scale  # (B, nq, cq, H, G, ck)
        logits = _pin(logits)
        if causal:
            kp = jax.lax.dynamic_index_in_dim(k_pos, kj, 0, keepdims=False)
            mask = q_pos[:, :, None] >= kp[None, None, :]  # (nq, cq, ck)
            logits = jnp.where(
                mask[None, :, :, None, None, :], logits, -jnp.inf
            )
        m_new = jnp.maximum(m, logits.max(axis=-1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - safe_m[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bnqhgk,bkhd->bnqhgd", p, vt.astype(jnp.float32)
        )
        return (_pin(acc), _pin(m_new), _pin(l)), None

    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk),
                                  unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, hkv, g, dv).astype(q.dtype)


def _blockwise_seq_q(q, k, v, *, chunk_q, chunk_k):
    """Causal-optimal sequential-q variant (j <= i kv chunks only)."""
    b, s, hkv, g, d = q.shape
    dv = v.shape[-1]
    assert s % chunk_q == 0 and s % chunk_k == 0, (s, chunk_q, chunk_k)
    nq, nk = s // chunk_q, s // chunk_k
    scale = d ** -0.5

    qc = q.reshape(b, nq, chunk_q, hkv, g, d)
    kc = k.reshape(b, nk, chunk_k, hkv, d)
    vc = v.reshape(b, nk, chunk_k, hkv, dv)
    q_pos = jnp.arange(s).reshape(nq, chunk_q)
    k_pos = jnp.arange(s).reshape(nk, chunk_k)

    def q_block(qi, q_tile):
        acc0 = jnp.zeros((b, chunk_q, hkv, g, dv), jnp.float32)
        m0 = jnp.full((b, chunk_q, hkv, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, chunk_q, hkv, g), jnp.float32)

        def kv_block(carry, kj):
            acc, m, l = carry
            kt = kc[:, kj]
            vt = vc[:, kj]
            logits = jnp.einsum(
                "bqhgd,bkhd->bqhgk", q_tile.astype(jnp.float32),
                kt.astype(jnp.float32),
            ) * scale
            mask = q_pos[qi][:, None] >= k_pos[kj][None, :]
            logits = jnp.where(mask[None, :, None, None, :], logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(logits - safe_m[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vt.astype(jnp.float32)
            )
            return acc, m_new, l

        # Only kv chunks whose start precedes this q chunk's end contribute.
        hi = jnp.minimum((qi * chunk_q + chunk_q + chunk_k - 1) // chunk_k, nk)

        def body(j, carry):
            return kv_block(carry, j)

        acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda qi: q_block(qi, qc[:, qi]), jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, hkv, g, dv)
    return out.astype(q.dtype)
