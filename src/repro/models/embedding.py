"""EmbeddingBag and fused multi-table embedding for the recsys archs.

JAX has no nn.EmbeddingBag; per kernel_taxonomy §RecSys it is built from
``jnp.take`` + ``jax.ops.segment_sum``. The multi-table variant fuses all
categorical tables into one row-sharded array with per-field offsets — the
FBGEMM table-batched-embedding layout, which is also the natural layout for
row-sharding a ~100 GB DLRM table over (data x model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
Params = dict[str, Any]


def embedding_bag(
    table: Array,
    indices: Array,
    weights: Array | None = None,
    mask: Array | None = None,
    mode: str = "sum",
) -> Array:
    """Bagged lookup. table (V, D); indices (B, L) -> (B, D).

    mask (B, L) marks valid entries (ragged bags padded to L).
    """
    vecs = jnp.take(table, indices, axis=0)  # (B, L, D)
    if weights is not None:
        vecs = vecs * weights[..., None]
    if mask is not None:
        vecs = vecs * mask[..., None].astype(vecs.dtype)
    if mode == "sum":
        return vecs.sum(axis=1)
    if mode == "mean":
        denom = (
            mask.sum(axis=1, keepdims=True).astype(vecs.dtype)
            if mask is not None
            else jnp.float32(indices.shape[1])
        )
        return vecs.sum(axis=1) / jnp.maximum(denom, 1.0)
    if mode == "max":
        if mask is not None:
            vecs = jnp.where(mask[..., None], vecs, -jnp.inf)
        return vecs.max(axis=1)
    raise ValueError(mode)


# Embedding tables are row-padded to this multiple so they tile exactly over
# any production mesh (512 = 2 pods x 16 x 16); ghost rows are never indexed.
ROW_MULTIPLE = 512


def pad_rows(n: int, multiple: int = ROW_MULTIPLE) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class FusedTableSpec:
    """Static description of the fused categorical tables."""

    vocab_sizes: tuple[int, ...]
    dim: int

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return sum(self.vocab_sizes)

    @property
    def padded_rows(self) -> int:
        return pad_rows(self.total_rows)

    @property
    def offsets(self) -> tuple[int, ...]:
        out, acc = [], 0
        for v in self.vocab_sizes:
            out.append(acc)
            acc += v
        return tuple(out)


def fused_table_init(key: Array, spec: FusedTableSpec, scale: float = 0.01) -> Array:
    # Uniform(-1/sqrt(dim)) rows, the DLRM reference init.
    return jax.random.uniform(
        key, (spec.padded_rows, spec.dim), minval=-scale, maxval=scale
    )


def fused_lookup(table: Array, spec: FusedTableSpec, sparse_ids: Array) -> Array:
    """sparse_ids (B, n_fields) per-field local ids -> (B, n_fields, dim).

    Single fused gather over the row-sharded table; GSPMD turns it into the
    all-to-all embedding exchange of a sharded embedding server.
    """
    offs = jnp.asarray(spec.offsets, jnp.int32)[None, :]
    flat = sparse_ids.astype(jnp.int32) + offs
    return jnp.take(table, flat, axis=0)
