"""Graph attention network (GAT, arXiv:1710.10903) on the segment-op
substrate, plus a real fanout neighbour sampler for the minibatch shape.

JAX has no sparse SpMM beyond BCOO, so message passing is expressed the
TPU-idiomatic way (kernel_taxonomy §GNN): edge-index gathers +
``jax.ops.segment_sum`` / ``segment_max`` scatters. Edge arrays are padded to
a static E_max with a sentinel (src = dst = n_nodes), which lands in a ghost
row that is sliced off — fixed shapes for jit/pjit, zero effect on results.

Edge-parallel distribution: edges shard over the data axis; the segment ops
become per-shard partial reductions + cross-shard scatter-adds (GSPMD emits
the collective), which is the standard large-graph regime of the ogb_products
and minibatch_lg cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ShardCtx, constrain, dense_init

Array = jax.Array
Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GatConfig:
    d_in: int
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    n_layers: int = 2
    negative_slope: float = 0.2


def gat_init(key: Array, cfg: GatConfig) -> Params:
    """Layer 1: n_heads x d_hidden (concat); layer 2: 1 head -> n_classes
    (the Cora configuration of the paper; deeper variants stack middles)."""
    layers = []
    d_prev = cfg.d_in
    for li in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        last = li == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append(
            {
                "w": dense_init(k1, d_prev, heads * d_out),
                "a_src": jax.random.normal(k2, (heads, d_out)) * 0.1,
                "a_dst": jax.random.normal(k3, (heads, d_out)) * 0.1,
            }
        )
        d_prev = heads * d_out if not last else d_out
    return {"layers": layers}


def _gat_layer(
    p: Params,
    x: Array,
    src: Array,
    dst: Array,
    n_nodes: int,
    heads: int,
    d_out: int,
    negative_slope: float,
    concat: bool,
    ctx: ShardCtx | None,
) -> Array:
    """One GAT layer via SDDMM-style edge scores + segment softmax + scatter.

    src/dst: (E,) int32 edge endpoints; padded edges point at the ghost row
    ``n_nodes`` and are annihilated by the segment ops.
    """
    h = (x @ p["w"]).reshape(-1, heads, d_out)  # (N, H, F)
    # Edge attention logits: a_src . h[src] + a_dst . h[dst]  (SDDMM)
    alpha_src = jnp.einsum("nhf,hf->nh", h, p["a_src"])  # (N, H)
    alpha_dst = jnp.einsum("nhf,hf->nh", h, p["a_dst"])
    e = alpha_src[src] + alpha_dst[dst]  # (E, H)
    e = jax.nn.leaky_relu(e, negative_slope)
    if ctx is not None:
        e = constrain(ctx, e, ctx.dp, None)

    # Segment softmax over incoming edges of each dst node.
    n_seg = n_nodes + 1  # ghost row for padded edges
    e_max = jax.ops.segment_max(e, dst, num_segments=n_seg)
    e_max = jnp.where(jnp.isfinite(e_max), e_max, 0.0)
    e_exp = jnp.exp(e - e_max[dst])
    denom = jax.ops.segment_sum(e_exp, dst, num_segments=n_seg)
    att = e_exp / jnp.maximum(denom[dst], 1e-9)  # (E, H)

    msg = h[src] * att[:, :, None]  # (E, H, F)
    out = jax.ops.segment_sum(msg, dst, num_segments=n_seg)[:n_nodes]
    if concat:
        return jax.nn.elu(out.reshape(n_nodes, heads * d_out))
    return out.mean(axis=1)  # average heads on the output layer


def gat_forward(
    cfg: GatConfig,
    params: Params,
    x: Array,
    edge_index: Array,
    ctx: ShardCtx | None = None,
) -> Array:
    """x: (N, d_in); edge_index: (2, E) int32 (padded with n_nodes).
    Returns (N, n_classes) logits."""
    n_nodes = x.shape[0]
    src, dst = edge_index[0], edge_index[1]
    for li, p in enumerate(params["layers"]):
        last = li == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        x = _gat_layer(
            p, x, src, dst, n_nodes, heads, d_out,
            cfg.negative_slope, concat=not last, ctx=ctx,
        )
    return x


def gat_loss(
    cfg: GatConfig,
    params: Params,
    batch: dict[str, Array],
    ctx: ShardCtx | None = None,
) -> tuple[Array, dict[str, Array]]:
    """batch: features (N, F), edge_index (2, E), labels (N,), mask (N,)."""
    logits = gat_forward(cfg, params, batch["features"], batch["edge_index"], ctx)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(batch["labels"], 0)[:, None], axis=1
    )[:, 0]
    nll = lse - gold
    mask = batch["mask"].astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    acc = (
        (logits.argmax(-1) == batch["labels"]).astype(jnp.float32) * mask
    ).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss, "acc": acc}


def gat_graph_loss(
    cfg: GatConfig,
    params: Params,
    batch: dict[str, Array],
    ctx: ShardCtx | None = None,
) -> tuple[Array, dict[str, Array]]:
    """Graph-level task (molecule shape): block-diagonal batch of graphs,
    mean-pooled node logits per graph.

    batch: features (N, F), edge_index (2, E), graph_ids (N,) int32 in
    [0, G), labels (G,).
    """
    logits_node = gat_forward(cfg, params, batch["features"],
                              batch["edge_index"], ctx)
    g = batch["labels"].shape[0]
    gid = batch["graph_ids"]
    sums = jax.ops.segment_sum(logits_node, gid, num_segments=g)
    cnts = jax.ops.segment_sum(
        jnp.ones((logits_node.shape[0],), jnp.float32), gid, num_segments=g
    )
    logits = (sums / jnp.maximum(cnts, 1.0)[:, None]).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((logits.argmax(-1) == batch["labels"]).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}


# ------------------------------------------------------------- sampler (host)

class NeighborSampler:
    """Fanout neighbour sampler over a host-side CSR graph (GraphSAGE-style,
    the minibatch_lg regime: batch_nodes=1024, fanout 15-10).

    Produces fixed-shape padded blocks the jitted GNN consumes; sampling is
    host work in every production GNN system (DGL/PyG dataloaders), so numpy
    here is the honest architecture, not a shortcut.
    """

    def __init__(self, edge_index: np.ndarray, n_nodes: int, seed: int = 0):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.src_sorted = src[order].astype(np.int32)
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        counts = np.bincount(dst, minlength=n_nodes)
        self.indptr[1:] = np.cumsum(counts)
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample_block(
        self, seed_nodes: np.ndarray, fanouts: tuple[int, ...]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Multi-hop sample. Returns (node_ids, edge_src_local, edge_dst_local)
        where edges are indices into node_ids and padded with len(node_ids).
        """
        nodes = list(seed_nodes.astype(np.int64))
        node_pos = {int(n): i for i, n in enumerate(nodes)}
        edges_s, edges_d = [], []
        frontier = seed_nodes.astype(np.int64)
        for f in fanouts:
            next_frontier = []
            for u in frontier:
                lo, hi = self.indptr[u], self.indptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = self.rng.choice(deg, size=take, replace=False) + lo
                for e in picks:
                    v = int(self.src_sorted[e])
                    if v not in node_pos:
                        node_pos[v] = len(nodes)
                        nodes.append(v)
                        next_frontier.append(v)
                    edges_s.append(node_pos[v])
                    edges_d.append(node_pos[int(u)])
            frontier = np.asarray(next_frontier, np.int64)
        return (
            np.asarray(nodes, np.int32),
            np.asarray(edges_s, np.int32),
            np.asarray(edges_d, np.int32),
        )


def pad_edges(
    src: np.ndarray, dst: np.ndarray, e_max: int, ghost: int
) -> np.ndarray:
    """Pad an edge list to (2, e_max) with the ghost sentinel."""
    e = len(src)
    assert e <= e_max, (e, e_max)
    out = np.full((2, e_max), ghost, np.int32)
    out[0, :e] = src
    out[1, :e] = dst
    return out
