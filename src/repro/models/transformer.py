"""Decoder-only transformer covering all five assigned LM architectures.

One config dataclass expresses dense (deepseek-coder-33b, qwen2-7b,
minicpm-2b) and MoE (qwen3-moe-30b-a3b, deepseek-v2-lite) variants with GQA
or MLA attention. Layer parameters are *stacked* (leading n_layers axis) and
the forward pass is a rematerialised ``lax.scan`` — compile time and HLO size
stay constant in depth, which is what makes 62-layer dry-runs on 512 host
devices tractable.

Three entry points per model (matching the assigned shape kinds):
  * :func:`lm_loss`        — train_* shapes (causal LM, f32 CE)
  * :func:`prefill`        — prefill_* shapes (populate KV cache, last logits)
  * :func:`decode_step`    — decode_* / long_* shapes (one token vs cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers
from repro.models import moe as moe_mod
from repro.models.layers import ShardCtx, constrain

Array = jax.Array
Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attention: str = "gqa"              # "gqa" | "mla"
    qkv_bias: bool = False
    qk_norm: bool = False
    moe: moe_mod.MoeConfig | None = None
    first_k_dense: int = 0              # deepseek: leading dense layers in MoE nets
    mla: attn_mod.MlaConfig | None = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # MiniCPM (mup-style) scaling knobs [arXiv:2404.06395].
    scale_emb: float = 1.0
    scale_depth: float = 0.0            # 0 => residual scale 1
    dim_model_base: int = 0             # 0 => logit scale 1
    dtype: Any = jnp.bfloat16           # activation/compute dtype
    remat: bool = True
    attn_chunk_q: int = 256
    attn_chunk_k: int = 1024
    skip_masked_blocks: bool = False
    attn_unroll: bool = False
    # Scan-unroll factor for the layer loop: 1 = rolled (deployment),
    # int k = partial unroll, True = full unroll. The dry-run prices the
    # loop body via two partial-unroll compiles (XLA cost_analysis counts
    # a while body exactly once).
    unroll_layers: int | bool = 1
    aux_loss_weight: float = 0.01

    @property
    def gqa(self) -> attn_mod.GqaConfig:
        return attn_mod.GqaConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta,
            attn_chunk_q=self.attn_chunk_q, attn_chunk_k=self.attn_chunk_k,
            skip_masked_blocks=self.skip_masked_blocks,
            attn_unroll=self.attn_unroll,
        )

    @property
    def residual_scale(self) -> float:
        if self.scale_depth:
            return self.scale_depth / (self.n_layers ** 0.5)
        return 1.0

    @property
    def logit_scale(self) -> float:
        if self.dim_model_base:
            return self.dim_model_base / self.d_model
        return 1.0

    def n_params(self) -> int:
        """Total parameter count (for 6ND roofline accounting)."""
        import math

        shapes = jax.eval_shape(lambda k: init_lm(self, k), jax.random.PRNGKey(0))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        shapes = jax.eval_shape(lambda k: init_lm(self, k), jax.random.PRNGKey(0))
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            import math
            size = math.prod(leaf.shape)
            keys = "/".join(str(p) for p in path)
            if any(w in keys for w in ("w_gate", "w_up", "w_down")) and "moe" in keys \
               and "shared" not in keys:
                size = size * self.moe.top_k // self.moe.n_experts
            total += size
        return total


def _layer_init(cfg: TransformerConfig, key: Array, dense_ffn: bool) -> Params:
    """Init one layer; vmapped over stacked layer keys.

    ``dense_ffn`` selects the FFN kind — MoE archs with first_k_dense > 0
    keep those leading dense layers in a *separate* stacked group
    ("dense_layers"), so the MoE scan stays homogeneous and no layer carries
    (or computes) both FFN kinds.
    """
    k_attn, k_ffn, k_moe = jax.random.split(key, 3)
    dt = jnp.float32
    p: Params = {
        "ln_attn": jnp.ones((cfg.d_model,), dt),
        "ln_ffn": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.attention == "mla":
        p["attn"] = attn_mod.mla_init(k_attn, cfg.mla, dtype=dt)
    else:
        p["attn"] = attn_mod.gqa_init(k_attn, cfg.gqa, dtype=dt)
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = moe_mod.moe_init(k_moe, cfg.moe, dtype=dt)
    else:
        p["ffn"] = layers.swiglu_init(k_ffn, cfg.d_model, cfg.d_ff, dtype=dt)
    return p


def init_lm(cfg: TransformerConfig, key: Array) -> Params:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    kd = cfg.first_k_dense if cfg.moe is not None else 0
    stacked = jax.vmap(lambda k: _layer_init(cfg, k, False))(layer_keys[kd:])
    p = {
        "embed": layers.embed_init(k_embed, cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_final": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if kd:
        p["dense_layers"] = jax.vmap(lambda k: _layer_init(cfg, k, True))(
            layer_keys[:kd]
        )
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab, scale=0.02)
    return p


def _block(
    cfg: TransformerConfig,
    p: Params,
    x: Array,
    ctx: ShardCtx | None,
) -> tuple[Array, Array]:
    """One transformer block (train path). Returns (x, aux_loss).

    The FFN kind is determined by which params the layer carries ("moe" vs
    "ffn") — see _layer_init."""
    rs = cfg.residual_scale
    h = layers.rms_norm(x, p["ln_attn"].astype(x.dtype))
    if cfg.attention == "mla":
        a = attn_mod.mla_train(p["attn"], cfg.mla, h, ctx)
    else:
        a = attn_mod.gqa_train(p["attn"], cfg.gqa, h, ctx)
    x = x + a * rs
    if ctx is not None:
        x = constrain(ctx, x, ctx.dp, ctx.tp, None)

    h = layers.rms_norm(x, p["ln_ffn"].astype(x.dtype))
    aux = jnp.float32(0.0)
    if "moe" in p:
        out, aux = moe_mod.moe_apply(p["moe"], cfg.moe, h, ctx)
    else:
        out = layers.swiglu(p["ffn"], h)
    x = x + out * rs
    if ctx is not None:
        x = constrain(ctx, x, ctx.dp, ctx.tp, None)
    return x, aux


def forward(
    cfg: TransformerConfig,
    params: Params,
    tokens: Array,
    ctx: ShardCtx | None = None,
) -> tuple[Array, Array]:
    """tokens (B, S) -> (hidden (B, S, D), aux_loss)."""
    x = params["embed"][tokens].astype(cfg.dtype) * cfg.scale_emb
    if ctx is not None:
        x = constrain(ctx, x, ctx.dp, ctx.tp, None)

    def body(carry, p_layer):
        h, aux = carry
        p_layer = jax.tree.map(lambda a: a.astype(cfg.dtype), p_layer)
        h, a = _block(cfg, p_layer, h, ctx)
        return (h, aux + a), None

    aux = jnp.float32(0.0)
    if "dense_layers" in params:
        kd = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        for i in range(kd):
            p_layer = jax.tree.map(lambda a: a[i], params["dense_layers"])
            (x, aux), _ = (jax.checkpoint(body) if cfg.remat else body)(
                (x, aux), p_layer)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(
        body_fn, (x, aux), params["layers"], unroll=cfg.unroll_layers,
    )
    x = layers.rms_norm(x, params["ln_final"].astype(x.dtype))
    return x, aux


def logits_from_hidden(
    cfg: TransformerConfig, params: Params, x: Array, ctx: ShardCtx | None
) -> Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype) * cfg.logit_scale
    if ctx is not None:
        logits = constrain(ctx, logits, ctx.dp, None, ctx.tp)
    return logits


def lm_loss(
    cfg: TransformerConfig,
    params: Params,
    batch: dict[str, Array],
    ctx: ShardCtx | None = None,
) -> tuple[Array, dict[str, Array]]:
    """Causal LM loss. batch: tokens (B, S) int32, labels (B, S) int32
    (-100 = ignore)."""
    x, aux = forward(cfg, params, batch["tokens"], ctx)
    logits = logits_from_hidden(cfg, params, x, ctx)
    mask = batch["labels"] >= 0
    loss = layers.cross_entropy(logits, jnp.maximum(batch["labels"], 0), mask)
    total = loss + cfg.aux_loss_weight * aux
    return total, {"ce": loss, "aux": aux}


# ------------------------------------------------------------------ serving

def init_cache(
    cfg: TransformerConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Params:
    """Stacked per-layer KV cache (leading n_layers axis); MoE archs with a
    dense prefix carry {"dense": (kd, ...), "scanned": (L-kd, ...)}."""
    if cfg.attention == "mla":
        one = attn_mod.mla_init_cache(cfg.mla, batch, max_len, dtype)
    else:
        one = attn_mod.gqa_init_cache(cfg.gqa, batch, max_len, dtype)

    def stack(n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one
        )

    kd = cfg.first_k_dense if cfg.moe is not None else 0
    if kd:
        return {"dense": stack(kd), "scanned": stack(cfg.n_layers - kd)}
    return stack(cfg.n_layers)


def decode_step(
    cfg: TransformerConfig,
    params: Params,
    cache: Params,
    tokens: Array,
    kv_len: Array,
    ctx: ShardCtx | None = None,
    mla_absorbed: bool = True,
) -> tuple[Array, Params]:
    """One decode step. tokens (B, 1); kv_len (B,) -> (logits (B, V), cache)."""
    x = params["embed"][tokens].astype(cfg.dtype) * cfg.scale_emb

    def one_layer(h, p_layer, cache_layer):
        p_layer = jax.tree.map(lambda a: a.astype(cfg.dtype), p_layer)
        rs = cfg.residual_scale
        hn = layers.rms_norm(h, p_layer["ln_attn"].astype(h.dtype))
        if cfg.attention == "mla":
            a, new_cache = attn_mod.mla_decode(
                p_layer["attn"], cfg.mla, hn, cache_layer, kv_len, ctx,
                absorbed=mla_absorbed,
            )
        else:
            a, new_cache = attn_mod.gqa_decode(
                p_layer["attn"], cfg.gqa, hn, cache_layer, kv_len, ctx
            )
        h = h + a * rs
        hn = layers.rms_norm(h, p_layer["ln_ffn"].astype(h.dtype))
        if "moe" in p_layer:
            out, _ = moe_mod.moe_apply(p_layer["moe"], cfg.moe, hn, ctx,
                                       no_drop=True)
        else:
            out = layers.swiglu(p_layer["ffn"], hn)
        h = h + out * rs
        return h, new_cache

    kd = cfg.first_k_dense if "dense_layers" in params else 0
    dense_caches = []
    for i in range(kd):
        p_layer = jax.tree.map(lambda a: a[i], params["dense_layers"])
        cache_layer = jax.tree.map(lambda a: a[i], cache["dense"])
        x, nc = one_layer(x, p_layer, cache_layer)
        dense_caches.append(nc)

    def body(h, scanned):
        p_layer, cache_layer = scanned
        return one_layer(h, p_layer, cache_layer)

    moe_cache = cache["scanned"] if kd else cache
    x, new_scanned = jax.lax.scan(
        body, x, (params["layers"], moe_cache), unroll=cfg.unroll_layers,
    )
    if kd:
        new_cache = {
            "dense": jax.tree.map(
                lambda *ls: jnp.stack(ls), *dense_caches
            ) if kd > 1 else jax.tree.map(lambda l: l[None], dense_caches[0]),
            "scanned": new_scanned,
        }
    else:
        new_cache = new_scanned
    x = layers.rms_norm(x, params["ln_final"].astype(x.dtype))
    logits = logits_from_hidden(cfg, params, x, ctx)
    return logits[:, 0], new_cache


def prefill(
    cfg: TransformerConfig,
    params: Params,
    tokens: Array,
    ctx: ShardCtx | None = None,
) -> Array:
    """Prefill pass for the prefill_* shapes: full forward, last-position
    logits. (Cache write-out is a gather away; the compute/memory profile —
    what the dry-run measures — is the forward itself.)"""
    x, _ = forward(cfg, params, tokens, ctx)
    logits = logits_from_hidden(cfg, params, x[:, -1:, :], ctx)
    return logits[:, 0]
