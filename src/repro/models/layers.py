"""Shared neural-net building blocks (functional, plain-dict params).

Everything is ``jax.eval_shape``-compatible: init functions only use the PRNG
key and config, so the dry-run can materialise parameter *shapes* with
shardings and never allocate 30B-parameter trees on the host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Array = jax.Array
Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries the active mesh so models can pin activation shardings.

    ``dp`` is the data-parallel axis spec (('pod','data') on the multi-pod
    mesh), ``tp`` the tensor axis name. With ctx=None every constraint is an
    identity — the same model code runs single-host tests and 512-chip
    dry-runs.

    Constraints are *divisibility-safe*: a spec whose sharded dims don't tile
    the mesh axes is skipped (returns x unchanged) rather than failing — so
    e.g. a batch=1 long-context decode simply doesn't batch-shard, and a
    28-head model doesn't head-shard over 16, without per-arch special cases.
    GSPMD then propagates whatever neighbouring constraints remain.
    """

    mesh: Any
    dp: tuple[str, ...] | str = ("data",)
    tp: str = "model"

    def _filter_spec(self, x: Array, spec: tuple) -> tuple:
        """Drop (entry-by-entry) the spec parts whose axes don't tile the
        dim; e.g. batch=1 keeps the sequence sharding instead of losing the
        whole constraint."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = []
        for dim, entry in enumerate(spec):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            out.append(entry if x.shape[dim] % total == 0 else None)
        return tuple(out)

    def constrain(self, x: Array, *spec) -> Array:
        if len(spec) != x.ndim:
            return x
        spec = self._filter_spec(x, spec)
        if all(e is None for e in spec):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )

    def batch_seq_spec(self, batch: int) -> tuple:
        """(batch_entry, seq_entry) for KV-cache-like (B, S, ...) tensors:
        batch over dp + seq over tp when the batch tiles dp; otherwise all
        axes go to the sequence dim (long-context batch=1 layout)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        dp_axes = self.dp if isinstance(self.dp, tuple) else (self.dp,)
        dp_size = 1
        for a in dp_axes:
            dp_size *= sizes[a]
        if batch % dp_size == 0:
            return self.dp, self.tp
        return None, tuple(dp_axes) + (self.tp,)


def constrain(ctx: ShardCtx | None, x: Array, *spec) -> Array:
    if ctx is None:
        return x
    return ctx.constrain(x, *spec)


# ---------------------------------------------------------------- init utils

def dense_init(key: Array, d_in: int, d_out: int, scale: float | None = None,
               dtype=jnp.float32) -> Array:
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# -------------------------------------------------------------------- norms

def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


# --------------------------------------------------------------------- RoPE

def rope_freqs(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- MLP

def swiglu_init(key: Array, d: int, f: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype=dtype),
        "w_up": dense_init(k2, d, f, dtype=dtype),
        "w_down": dense_init(k3, f, d, dtype=dtype),
    }


def swiglu(p: Params, x: Array) -> Array:
    g = jax.nn.silu(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


def mlp_init(key: Array, sizes: tuple[int, ...], dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        f"w{i}": dense_init(keys[i], sizes[i], sizes[i + 1], dtype=dtype)
        for i in range(len(sizes) - 1)
    } | {
        f"b{i}": jnp.zeros((sizes[i + 1],), dtype)
        for i in range(len(sizes) - 1)
    }


def mlp_apply(p: Params, x: Array, act: Callable = jax.nn.relu,
              final_act: bool = False) -> Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def cross_entropy(logits: Array, labels: Array, mask: Array | None = None) -> Array:
    """Token-level CE, numerically stable, f32 accumulation.

    logits: (..., V); labels: (...); mask broadcastable to labels (1 = keep).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
