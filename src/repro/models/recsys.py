"""The four assigned recsys architectures.

  * DLRM (MLPerf config, arXiv:1906.00091) — dense MLP + 26 fused embedding
    tables + dot interaction + top MLP.
  * DeepFM (arXiv:1703.04247) — first-order + FM second-order + deep MLP.
  * MIND (arXiv:1904.08030) — multi-interest capsule routing retrieval.
  * BERT4Rec (arXiv:1904.06690) — bidirectional transformer, cloze training.

Every model exposes loss(params, batch) for train_batch, score(params, batch)
for serve_p99 / serve_bulk, and retrieval(params, batch) for retrieval_cand
(1 user vs n_candidates). Retrieval has two paths: the exact full-model scan
and, for the embedding-dot models (MIND/BERT4Rec and the two-tower readout),
the MCGI/ANN integration used by the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.embedding import (
    FusedTableSpec,
    embedding_bag,
    fused_lookup,
    fused_table_init,
)
from repro.models.layers import ShardCtx, constrain, dense_init, mlp_apply, mlp_init

Array = jax.Array
Params = dict[str, Any]


def bce_with_logits(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ------------------------------------------------------------------- DLRM

# Criteo-1TB per-field cardinalities used by the MLPerf DLRM benchmark.
CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class DlrmConfig:
    n_dense: int = 13
    vocab_sizes: tuple[int, ...] = CRITEO_1TB_VOCABS
    embed_dim: int = 128
    bot_mlp: tuple[int, ...] = (512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)

    @property
    def table(self) -> FusedTableSpec:
        return FusedTableSpec(self.vocab_sizes, self.embed_dim)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def dlrm_init(key: Array, cfg: DlrmConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "table": fused_table_init(k1, cfg.table),
        "bot": mlp_init(k2, (cfg.n_dense,) + cfg.bot_mlp),
        "top": mlp_init(k3, (cfg.n_interact + cfg.bot_mlp[-1],) + cfg.top_mlp),
    }


def _dot_interaction(vecs: Array) -> Array:
    """(B, F, D) -> (B, F(F-1)/2) strictly-lower-triangle pairwise dots."""
    f = vecs.shape[1]
    gram = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    ii, jj = jnp.tril_indices(f, k=-1)
    return gram[:, ii, jj]


def dlrm_forward(
    cfg: DlrmConfig, p: Params, dense: Array, sparse: Array,
    ctx: ShardCtx | None = None,
) -> Array:
    """dense (B, 13) f32; sparse (B, 26) int32 -> (B,) logits."""
    z = mlp_apply(p["bot"], dense, final_act=True)  # (B, 128)
    emb = fused_lookup(p["table"], cfg.table, sparse)  # (B, 26, 128)
    if ctx is not None:
        emb = constrain(ctx, emb, ctx.dp, None, None)
    vecs = jnp.concatenate([z[:, None, :], emb], axis=1)  # (B, 27, 128)
    inter = _dot_interaction(vecs)
    top_in = jnp.concatenate([z, inter], axis=1)
    return mlp_apply(p["top"], top_in)[:, 0]


def dlrm_loss(cfg: DlrmConfig, p: Params, batch: dict, ctx=None):
    logits = dlrm_forward(cfg, p, batch["dense"], batch["sparse"], ctx)
    loss = bce_with_logits(logits, batch["labels"])
    return loss, {"bce": loss}


def dlrm_retrieval(
    cfg: DlrmConfig, p: Params, batch: dict, ctx=None
) -> Array:
    """retrieval_cand: one user, (C,) candidate ids substituted into sparse
    field 0; full-model scoring of every candidate (exact baseline path)."""
    dense = batch["dense"]          # (1, 13)
    sparse = batch["sparse"]        # (1, 26)
    cands = batch["candidates"]     # (C,)
    c = cands.shape[0]
    sparse_rep = jnp.broadcast_to(sparse, (c, cfg.n_sparse)).at[:, 0].set(cands)
    dense_rep = jnp.broadcast_to(dense, (c, cfg.n_dense))
    return dlrm_forward(cfg, p, dense_rep, sparse_rep, ctx)  # (C,) scores


# ----------------------------------------------------------------- DeepFM

@dataclasses.dataclass(frozen=True)
class DeepFmConfig:
    n_fields: int = 39
    vocab_per_field: int = 871264    # ~34M total / 39 fields (Criteo-scale)
    embed_dim: int = 10
    mlp: tuple[int, ...] = (400, 400, 400)

    @property
    def table(self) -> FusedTableSpec:
        return FusedTableSpec((self.vocab_per_field,) * self.n_fields,
                              self.embed_dim)


def deepfm_init(key: Array, cfg: DeepFmConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "table": fused_table_init(k1, cfg.table),
        "first_order": fused_table_init(k2, FusedTableSpec(cfg.table.vocab_sizes, 1)),
        "b0": jnp.zeros((), jnp.float32),
        "mlp": mlp_init(k3, (cfg.n_fields * cfg.embed_dim,) + cfg.mlp + (1,)),
    }


def deepfm_forward(
    cfg: DeepFmConfig, p: Params, sparse: Array, ctx: ShardCtx | None = None
) -> Array:
    emb = fused_lookup(p["table"], cfg.table, sparse)  # (B, F, D)
    if ctx is not None:
        emb = constrain(ctx, emb, ctx.dp, None, None)
    # FM second order: 1/2 ((sum v)^2 - sum v^2), summed over dim.
    s = emb.sum(axis=1)
    fm2 = 0.5 * (s * s - (emb * emb).sum(axis=1)).sum(axis=-1)
    first = fused_lookup(p["first_order"], FusedTableSpec(cfg.table.vocab_sizes, 1),
                         sparse)[..., 0].sum(axis=1)
    deep = mlp_apply(p["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
    return p["b0"] + first + fm2 + deep


def deepfm_loss(cfg: DeepFmConfig, p: Params, batch: dict, ctx=None):
    logits = deepfm_forward(cfg, p, batch["sparse"], ctx)
    loss = bce_with_logits(logits, batch["labels"])
    return loss, {"bce": loss}


def deepfm_retrieval(cfg: DeepFmConfig, p: Params, batch: dict, ctx=None) -> Array:
    sparse = batch["sparse"]
    cands = batch["candidates"]
    c = cands.shape[0]
    rep = jnp.broadcast_to(sparse, (c, cfg.n_fields)).at[:, 0].set(cands)
    return deepfm_forward(cfg, p, rep, ctx)


# ------------------------------------------------------------------- MIND

@dataclasses.dataclass(frozen=True)
class MindConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    pow_p: float = 2.0           # label-aware attention sharpness


def mind_init(key: Array, cfg: MindConfig) -> Params:
    from repro.models.embedding import pad_rows

    k1, k2 = jax.random.split(key)
    return {
        "items": layers.embed_init(k1, pad_rows(cfg.n_items), cfg.embed_dim),
        "s": dense_init(k2, cfg.embed_dim, cfg.embed_dim),  # shared bilinear map
    }


def mind_interests(
    cfg: MindConfig, p: Params, hist: Array, mask: Array
) -> Array:
    """B2I dynamic routing: (B, L) history -> (B, K, D) interest capsules."""
    e = jnp.take(p["items"], hist, axis=0)  # (B, L, D)
    e_hat = e @ p["s"]  # (B, L, D)
    b, l, d = e_hat.shape
    k = cfg.n_interests
    # Fixed (shared) logit init, per MIND's randomly-initialised routing.
    logits0 = jnp.broadcast_to(
        jnp.linspace(-1.0, 1.0, k)[None, None, :], (b, l, k)
    )

    def squash(u):
        n2 = jnp.sum(u * u, axis=-1, keepdims=True)
        return (n2 / (1.0 + n2)) * u / jnp.sqrt(n2 + 1e-9)

    def routing_iter(logits, _):
        w = jax.nn.softmax(logits, axis=-1)  # (B, L, K) over capsules
        w = w * mask[..., None].astype(w.dtype)
        u = jnp.einsum("blk,bld->bkd", w, e_hat)
        u = squash(u)
        delta = jnp.einsum("bkd,bld->blk", u, e_hat)
        return logits + delta, u

    logits, us = jax.lax.scan(
        routing_iter, logits0, None, length=cfg.capsule_iters,
        unroll=True,  # 3 iters; unrolled so dry-run cost analysis counts them
    )
    return us[-1]  # (B, K, D)


def mind_loss(cfg: MindConfig, p: Params, batch: dict, ctx=None):
    """Sampled-softmax with in-batch negatives; label-aware attention."""
    hist, mask, target = batch["hist"], batch["hist_mask"], batch["target"]
    interests = mind_interests(cfg, p, hist, mask)  # (B, K, D)
    tgt = jnp.take(p["items"], target, axis=0)      # (B, D)
    att = jax.nn.softmax(
        cfg.pow_p * jnp.einsum("bkd,bd->bk", interests, tgt), axis=-1
    )
    user = jnp.einsum("bk,bkd->bd", att, interests)  # (B, D)
    # In-batch sampled softmax.
    logits = user @ tgt.T  # (B, B)
    labels = jnp.arange(logits.shape[0])
    loss = layers.cross_entropy(logits, labels)
    return loss, {"sampled_ce": loss}


def mind_retrieval(cfg: MindConfig, p: Params, batch: dict, ctx=None) -> Array:
    """Max-over-interests dot scores for (C,) candidates — the ANN-friendly
    readout MCGI indexes in examples/recsys_retrieval.py."""
    interests = mind_interests(cfg, p, batch["hist"], batch["hist_mask"])
    cand = jnp.take(p["items"], batch["candidates"], axis=0)  # (C, D)
    scores = jnp.einsum("bkd,cd->bkc", interests, cand)
    return scores.max(axis=1)  # (B, C)


# --------------------------------------------------------------- BERT4Rec

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff_mult: int = 4

    @property
    def mask_token(self) -> int:
        return self.n_items  # vocab rows = n_items + 1


def bert4rec_init(key: Array, cfg: Bert4RecConfig) -> Params:
    keys = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        k1, k2, k3, k4 = jax.random.split(keys[2 + i], 4)
        blocks.append(
            {
                "wqkv": dense_init(k1, d, 3 * d),
                "wo": dense_init(k2, d, d),
                "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
                "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
                "ffn": mlp_init(k3, (d, cfg.d_ff_mult * d, d)),
            }
        )
    from repro.models.embedding import pad_rows

    return {
        "items": layers.embed_init(keys[0], pad_rows(cfg.n_items + 1), d),
        "pos": layers.embed_init(keys[1], cfg.seq_len, d),
        "blocks": blocks,
        "ln_f_g": jnp.ones((d,)), "ln_f_b": jnp.zeros((d,)),
    }


def bert4rec_encode(
    cfg: Bert4RecConfig, p: Params, seq: Array, mask: Array,
    ctx: ShardCtx | None = None,
) -> Array:
    """seq (B, S) item ids; mask (B, S) validity -> (B, S, D) hidden."""
    b, s = seq.shape
    h = jnp.take(p["items"], seq, axis=0) + p["pos"][None, :s]
    if ctx is not None:
        h = constrain(ctx, h, ctx.dp, None, None)
    attn_mask = mask[:, None, None, :]  # (B, 1, 1, S) keys validity
    for blk in p["blocks"]:
        hn = layers.layer_norm(h, blk["ln1_g"], blk["ln1_b"])
        qkv = hn @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        dh = cfg.embed_dim // cfg.n_heads
        q = q.reshape(b, s, cfg.n_heads, dh)
        k = k.reshape(b, s, cfg.n_heads, dh)
        v = v.reshape(b, s, cfg.n_heads, dh)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (dh ** -0.5)
        logits = jnp.where(attn_mask, logits, -jnp.inf)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(h.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, -1)
        h = h + o @ blk["wo"]
        hn = layers.layer_norm(h, blk["ln2_g"], blk["ln2_b"])
        h = h + mlp_apply(blk["ffn"], hn, act=jax.nn.gelu)
    return layers.layer_norm(h, p["ln_f_g"], p["ln_f_b"])


def bert4rec_loss(cfg: Bert4RecConfig, p: Params, batch: dict, ctx=None):
    """Cloze objective: predict items at masked positions.

    batch: seq (B,S) with mask_token at cloze slots, seq_mask (B,S) validity,
    mlm_positions (B, P) int32, mlm_labels (B, P) (-1 pad).
    """
    h = bert4rec_encode(cfg, p, batch["seq"], batch["seq_mask"], ctx)
    pos = batch["mlm_positions"]
    gathered = jnp.take_along_axis(
        h, pos[..., None].astype(jnp.int32), axis=1
    )  # (B, P, D)
    logits = gathered @ p["items"].T  # tied output embedding
    if ctx is not None:
        logits = constrain(ctx, logits, ctx.dp, None, ctx.tp)
    valid = batch["mlm_labels"] >= 0
    loss = layers.cross_entropy(
        logits, jnp.maximum(batch["mlm_labels"], 0), valid
    )
    return loss, {"cloze_ce": loss}


def bert4rec_retrieval(cfg: Bert4RecConfig, p: Params, batch: dict, ctx=None):
    """Score candidates for the next item: hidden at the final (mask) slot
    dotted with candidate embeddings."""
    h = bert4rec_encode(cfg, p, batch["seq"], batch["seq_mask"], ctx)
    last = h[:, -1, :]  # (B, D) — pipeline places the mask token last
    cand = jnp.take(p["items"], batch["candidates"], axis=0)
    return last @ cand.T  # (B, C)
