"""Executable oracles for the paper's §4.2 topology claims (Prop. 4.3).

For small point sets these compute the exact Relative Neighborhood Graph and
Euclidean Minimum Spanning Tree, letting tests assert the inclusion chain

    E_EMST ⊆ E_RNG ⊆ E_MCGI(alpha >= 1, complete candidate pool)

and global connectivity. The chain holds for pruning from *complete*
candidate pools (that is the statement's regime); the practical builder prunes
from greedy-search pools, so the tests exercise :func:`repro.core.prune`
directly on complete pools, plus graph-level connectivity of built indices.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import connected_components, minimum_spanning_tree

from repro.core import prune as prune_mod


def pairwise_np(x: np.ndarray) -> np.ndarray:
    sq = (x * x).sum(axis=1)
    d2 = sq[:, None] - 2.0 * (x @ x.T) + sq[None, :]
    np.fill_diagonal(d2, 0.0)
    return np.maximum(d2, 0.0)


def rng_edges(x: np.ndarray) -> set[tuple[int, int]]:
    """Relative Neighborhood Graph: edge (u,v) iff no witness n has
    max(d(u,n), d(v,n)) < d(u,v).  O(N^3) — test scale only."""
    n = x.shape[0]
    d2 = pairwise_np(x)
    edges = set()
    for u in range(n):
        for v in range(u + 1, n):
            duv = d2[u, v]
            witnesses = np.maximum(d2[u], d2[v]) < duv
            witnesses[u] = witnesses[v] = False
            if not witnesses.any():
                edges.add((u, v))
    return edges


def emst_edges(x: np.ndarray) -> set[tuple[int, int]]:
    d = np.sqrt(pairwise_np(x))
    t = minimum_spanning_tree(csr_matrix(d)).tocoo()
    return {(min(i, j), max(i, j)) for i, j in zip(t.row, t.col)}


def mcgi_complete_pool_edges(
    x: np.ndarray, alpha: np.ndarray, degree: int | None = None
) -> set[tuple[int, int]]:
    """Directed MCGI pruning applied to the *complete* candidate pool of every
    node (the regime of Prop. 4.3), returned as an undirected edge set.

    With degree=None the cap is N-1 (no truncation), which is the pure
    occlusion-rule graph the proposition reasons about.
    """
    n = x.shape[0]
    degree = n - 1 if degree is None else degree
    xj = jnp.asarray(x, dtype=jnp.float32)
    node_ids = jnp.arange(n, dtype=jnp.int32)
    cand = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (n, n))
    rows, _ = prune_mod.robust_prune_batch(
        xj, node_ids, cand, jnp.asarray(alpha, jnp.float32), degree
    )
    rows = np.asarray(rows)
    edges = set()
    for u in range(n):
        for v in rows[u]:
            if v >= 0:
                edges.add((min(u, int(v)), max(u, int(v))))
    return edges


def is_connected(n: int, edges: set[tuple[int, int]]) -> bool:
    if not edges:
        return n <= 1
    rows = np.array([e[0] for e in edges] + [e[1] for e in edges])
    cols = np.array([e[1] for e in edges] + [e[0] for e in edges])
    m = csr_matrix((np.ones_like(rows), (rows, cols)), shape=(n, n))
    ncomp, _ = connected_components(m, directed=False)
    return ncomp == 1


def reachable_from(adj: np.ndarray, entry: int) -> np.ndarray:
    """BFS reachability over a directed padded adjacency (navigability check)."""
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    seen[entry] = True
    frontier = [entry]
    while frontier:
        nxt = []
        for u in frontier:
            for v in adj[u]:
                if v >= 0 and not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    return seen
