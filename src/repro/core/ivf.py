"""IVF-Flat baseline (Faiss-style inverted file, exact in-list distances).

The paper uses memory-mapped Faiss IVF-Flat as the in-memory throughput
roofline (RQ1/RQ2). Structure: a k-means coarse quantiser over ``nlist``
centroids; each base point assigned to its nearest centroid's inverted list;
a query probes the ``nprobe`` closest lists and scans them exactly.

JAX-native layout: inverted lists are padded to the max list length into a
dense (nlist, max_len) id matrix — scans are fixed-shape gathers + one fused
distance matmul, which is also precisely how an MXU wants to consume them.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distance as dist_mod

Array = jax.Array
INVALID = -1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IvfIndex:
    centroids: Array  # (nlist, D)
    lists: Array      # (nlist, max_len) int32, INVALID padded
    list_len: Array   # (nlist,)


def kmeans(
    x: Array, k: int, iters: int = 10, key: Array | None = None, chunk: int = 65536
) -> Array:
    """Batched Lloyd's algorithm (shared with the PQ codebook trainer)."""
    key = jax.random.PRNGKey(0) if key is None else key
    n = x.shape[0]
    init = jax.random.choice(key, n, shape=(k,), replace=False)
    centroids = x[init]

    @jax.jit
    def assign(c, xs):
        return jnp.argmin(dist_mod.squared_l2(xs, c), axis=1)

    for _ in range(iters):
        parts = [assign(centroids, x[s : s + chunk]) for s in range(0, n, chunk)]
        a = jnp.concatenate(parts)
        sums = jax.ops.segment_sum(x, a, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), a, num_segments=k)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Re-seed empty clusters at the points farthest from their centroid.
        empty = counts == 0
        centroids = jnp.where(empty[:, None], centroids, new)
    return centroids


def build_ivf(x: Array, nlist: int = 256, iters: int = 10, seed: int = 0) -> IvfIndex:
    centroids = kmeans(x, nlist, iters=iters, key=jax.random.PRNGKey(seed))
    assign = jnp.argmin(dist_mod.squared_l2(x, centroids), axis=1)
    a = np.asarray(assign)
    n = x.shape[0]
    order = np.argsort(a, kind="stable")
    sorted_ids = np.arange(n, dtype=np.int32)[order]
    counts = np.bincount(a, minlength=nlist)
    max_len = int(counts.max())
    lists = np.full((nlist, max_len), INVALID, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for c in range(nlist):
        lists[c, : counts[c]] = sorted_ids[starts[c] : starts[c] + counts[c]]
    return IvfIndex(
        centroids=centroids,
        lists=jnp.asarray(lists),
        list_len=jnp.asarray(counts.astype(np.int32)),
    )


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def search_ivf(
    index: IvfIndex, x: Array, queries: Array, nprobe: int = 8, k: int = 10
) -> tuple[Array, Array, Array]:
    """Probe ``nprobe`` lists per query, exact scan, top-k.

    Returns (ids, d2, scanned): (Q, k), (Q, k), (Q,) #points scanned.
    """
    cd = dist_mod.squared_l2(queries, index.centroids)  # (Q, nlist)
    probes = jnp.argsort(cd, axis=1)[:, :nprobe]  # (Q, nprobe)

    def per_query(q, probe):
        ids = index.lists[probe].reshape(-1)  # (nprobe * max_len,)
        valid = ids != INVALID
        vecs = x[jnp.maximum(ids, 0)]
        diff = vecs - q[None, :]
        d2 = jnp.where(valid, jnp.sum(diff * diff, axis=-1), jnp.inf)
        order = jnp.argsort(d2)[:k]
        return ids[order], d2[order], valid.sum()

    return jax.vmap(per_query)(queries, probes)
