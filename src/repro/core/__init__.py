"""MCGI core — the paper's primary contribution plus its baselines.

Public surface:
  * LID estimation + calibration      — :mod:`repro.core.lid`
  * Phi mapping (LID -> alpha)        — :mod:`repro.core.mapping`
  * Adaptive robust prune             — :mod:`repro.core.prune`
  * Offline build (Algorithm 1)       — :mod:`repro.core.build`
  * Online build  (Algorithm 2)       — :mod:`repro.core.online`
  * Batched beam search (exact / PQ)  — :mod:`repro.core.search`
  * Budget-law calibration (lam fit)  — :mod:`repro.core.calibrate`
  * Baselines: Vamana / IVF / HNSW    — build.py / ivf.py / hnsw.py
  * Theory oracles (Prop. 4.3)        — :mod:`repro.core.theory`

NOTE: ``repro.core.calibrate`` is the calibration *module*; the LID
population-stats helper formerly re-exported here under that name lives at
:func:`repro.core.lid.calibrate`.
"""
from repro.core.build import (  # noqa: F401
    BuildConfig,
    block_layout,
    build_mcgi,
    build_vamana,
)
from repro.core.distance import brute_force_topk, knn_graph, recall_at_k  # noqa: F401
from repro.core.lid import LidProfile, estimate_dataset_lid, lid_from_dists  # noqa: F401
from repro.core.mapping import ALPHA_MAX, ALPHA_MIN, AlphaMapping, phi  # noqa: F401
from repro.core.online import build_online_mcgi  # noqa: F401
from repro.core.search import (  # noqa: F401
    AdaptiveBeamBudget,
    AdaptiveStats,
    SearchStats,
    beam_search_exact,
    beam_search_exact_adaptive,
    beam_search_pq,
    beam_search_pq_adaptive,
    budget_bucket_ceilings,
    medoid,
)
from repro.core.types import GraphIndex  # noqa: F401
from repro.core.calibrate import (  # noqa: F401
    CalibrationResult,
    calibrate_budget_law,
    calibrate_budget_law_joint,
    exact_recall_eval,
    tiered_recall_eval,
)
