"""The mapping function Phi: LID -> pruning parameter alpha (paper §3.2).

    z(u)   = (LID_hat(u) - mu) / sigma                       (Eq. 7)
    Phi(u) = alpha_min + (alpha_max - alpha_min) / (1 + e^z)  (Eq. 8)

Monotonicity (Prop. 3.5) and boundedness (Prop. 3.6) hold by construction and
are property-tested in ``tests/test_mapping.py``.

The same module also hosts the *routing-side* budget law of Prop. 4.2
(L(q) ∝ exp(lambda * LID(q))), which the paper derives but deliberately does
not deploy per-query (fixed L at serve time, §4.1); we expose it for the
beyond-paper adaptive-beam experiments.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

# Defaults from the paper's evaluation (§3.2 / Table 2).
ALPHA_MIN = 1.0
ALPHA_MAX = 1.5


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AlphaMapping:
    """Frozen Phi parameters: population stats + operational range."""

    mu: Array
    sigma: Array
    alpha_min: float = dataclasses.field(metadata=dict(static=True), default=ALPHA_MIN)
    alpha_max: float = dataclasses.field(metadata=dict(static=True), default=ALPHA_MAX)

    def __call__(self, lid: Array) -> Array:
        return phi(lid, self.mu, self.sigma, self.alpha_min, self.alpha_max)


def phi(
    lid: Array,
    mu: Array,
    sigma: Array,
    alpha_min: float = ALPHA_MIN,
    alpha_max: float = ALPHA_MAX,
) -> Array:
    """Eq. 8. Vectorised over ``lid``.

    ``sigma`` is clamped away from zero: a dataset with no geometric variance
    degenerates to the constant mapping alpha = (alpha_min + alpha_max) / 2
    at z = 0, matching the paper's "average complexity" behaviour.
    """
    z = (lid - mu) / jnp.maximum(sigma, 1e-6)
    # Clip z for float safety; exp(±40) already saturates the logistic in f32.
    z = jnp.clip(z, -40.0, 40.0)
    return alpha_min + (alpha_max - alpha_min) / (1.0 + jnp.exp(z))


def constant_alpha(n: int, alpha: float) -> Array:
    """Static-alpha per-node array — the DiskANN/Vamana baseline (alpha=1.2
    conventionally).  MCGI with this mapping *is* Vamana, which is how the
    framework isolates the paper's contribution."""
    return jnp.full((n,), alpha, dtype=jnp.float32)


def adaptive_beam_budget(
    lid: Array,
    lam: float | Array,
    l_min: int | Array,
    l_max: int,
    mu: Array | None = None,
) -> Array:
    """Prop. 4.2's iso-recall budget  L(q) = C * exp(lambda * LID(q)).

    Normalised so a query of average complexity gets the geometric mean of
    [l_min, l_max]; clipped to the operational range. Integer-valued.

    ``lam`` and ``l_min`` may be traced scalars: the distributed serving path
    threads *per-shard* calibrated budget laws through as runtime arrays
    (shard geometry differs), so neither knob may be baked into the compiled
    program as a python constant. ``l_max`` stays static — it is the physical
    beam shape.

    This is the beyond-paper knob (the paper fixes L for SIMD alignment and
    compensates in the topology); on TPU a *grouped* adaptive beam is feasible
    because queries are batched — see ``repro/core/search.py`` early-exit.
    """
    center = jnp.mean(lid) if mu is None else mu
    l_mid = jnp.sqrt(jnp.asarray(l_min, jnp.float32)
                     * jnp.asarray(l_max, jnp.float32))
    budget = l_mid * jnp.exp(lam * (lid - center))
    return jnp.clip(jnp.round(budget), l_min, l_max).astype(jnp.int32)
