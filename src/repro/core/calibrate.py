"""Budget-law calibration: fit ``lam`` (and optionally ``hop_factor``) to a
recall target on a held-out query sample.

MCGI's Prop. 4.2 gives the *shape* of the per-query budget law
(L(q) ∝ exp(lam * LID(q))) but not its strength: ``lam`` trades mean I/O for
recall, and the right value is dataset geometry dependent. Following NSG's
treatment of its search parameter, the single knob is made transferable by
calibrating it against an operational recall target instead of hand-tuning
per dataset.

Monotonicity makes this a bisection, not a grid search: with the budget
center at the batch-mean LID, ``lam = 0`` serves every query at the
geometric-mean budget, and raising ``lam`` spreads budgets apart —
below-average-LID queries shrink toward ``l_min`` (that's where the I/O
savings come from) while above-average ones grow toward ``l_max``. Measured
recall on a fixed sample is (noisily but reliably) monotone *non-increasing*
in ``lam``: the saturated hard queries gain little from the extra headroom,
while the shrunk easy lanes are where recall pressure appears. The
calibrated value is therefore the **largest** ``lam`` that still meets the
target — maximum budget-law savings subject to the recall SLO — found in
O(log(range/tol)) search evaluations. If even ``lam = lam_lo`` misses the
target, the hop budget (not the beam law) is the binding constraint:
``hop_factor`` is escalated and the bisection re-run.

Everything is deterministic under a fixed seed: the held-out sample, the
search engine, and the bisection path.

Beyond the single-knob fit, :func:`calibrate_budget_law_joint` fits
(lam, l_min) *jointly*: the budget floor l_min sets the law's geometric mid
(the real mean-I/O lever) and is exactly the recall pressure point the lam
bisection works around, so the joint pass scans candidate floors ascending
(max savings first) and runs the lam bisection at each until one meets the
target. The serving engine exposes both passes live via
``repro.serving.SearchEngine.recalibrate`` (the Online-MCGI refresh hook).

The distributed path goes one step further:
:func:`calibrate_budget_law_per_shard` runs the joint fit once *per shard*
on shard-local held-out queries (each shard's sub-graph has its own
geometry — one global law under-budgets the hard shards and over-budgets the
easy ones) and returns per-shard (lam, l_min) arrays that thread through
``ShardedIndexSpecs`` into the distributed step as runtime inputs — a
recalibration updates the arrays without recompiling anything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import distance as distance_mod
from repro.core import search as search_mod


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Outcome of a budget-law calibration run.

    Attributes:
      lam:        fitted budget-law exponent — the largest value whose
                  measured recall still meets the target (max I/O savings
                  subject to the recall SLO).
      hop_factor: hop budget multiplier the fit succeeded at.
      recall:     measured recall at (lam, hop_factor) on the held-out sample.
      target:     the recall target that was requested.
      achieved:   whether ``recall >= target`` was reached inside the ranges.
      history:    every (lam, hop_factor, recall) evaluation, in order — the
                  measured recall curve the bisection walked.
      l_min:      fitted budget floor when the joint (lam, l_min) pass ran
                  (:func:`calibrate_budget_law_joint`); None for the plain
                  lam-only fit.
      joint_history: per-l_min-candidate summary of the joint pass —
                  (l_min, lam, hop_factor, recall, achieved) in evaluation
                  order; empty for the plain fit.
    """

    lam: float       # fitted exponent: largest value still meeting target
    hop_factor: int
    recall: float
    target: float
    achieved: bool
    history: tuple[tuple[float, int, float], ...]
    l_min: int | None = None
    joint_history: tuple[tuple[int, float, int, float, bool], ...] = ()

    def budget_cfg(
        self, base: search_mod.AdaptiveBeamBudget
    ) -> search_mod.AdaptiveBeamBudget:
        """The base config with the fitted knobs substituted in."""
        out = dataclasses.replace(
            base, lam=self.lam, hop_factor=self.hop_factor)
        if self.l_min is not None:
            out = dataclasses.replace(out, l_min=self.l_min)
        return out


def bisect_lam(
    eval_recall: Callable[[float], float],
    target: float,
    lam_lo: float = 0.0,
    lam_hi: float = 1.0,
    tol: float = 0.02,
    max_iters: int = 8,
) -> tuple[float, float, list[tuple[float, float]]]:
    """Largest ``lam`` in [lam_lo, lam_hi] with ``eval_recall(lam) >= target``.

    Assumes ``eval_recall`` is monotone non-increasing in ``lam`` (see module
    docstring): the bisection keeps a feasible lower end and pushes it up.
    Returns (lam, recall_at_lam, [(lam, recall) evaluations]). When even
    ``lam_lo`` misses the target, returns (lam_lo, recall_at_lo, history) —
    the caller decides whether to escalate another knob (hop_factor).
    """
    history: list[tuple[float, float]] = []

    def f(lam: float) -> float:
        r = float(eval_recall(float(lam)))
        history.append((float(lam), r))
        return r

    r_lo = f(lam_lo)
    if r_lo < target:
        return lam_lo, r_lo, history
    r_hi = f(lam_hi)
    if r_hi >= target:
        return lam_hi, r_hi, history
    lo, hi, r_at_lo = lam_lo, lam_hi, r_lo
    for _ in range(max_iters):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        r_mid = f(mid)
        if r_mid >= target:
            lo, r_at_lo = mid, r_mid
        else:
            hi = mid
    return lo, r_at_lo, history


def holdout_sample(
    n_queries: int, sample: int, seed: int = 0
) -> np.ndarray:
    """Deterministic held-out query subset (sorted indices for stable
    gather order — bit-reproducible recall measurements)."""
    sample = min(sample, n_queries)
    rng = np.random.default_rng(seed)
    sel = rng.choice(n_queries, size=sample, replace=False)
    return np.sort(sel)


def calibrate_budget_law(
    eval_recall: Callable[[search_mod.AdaptiveBeamBudget], float],
    base_cfg: search_mod.AdaptiveBeamBudget,
    recall_target: float,
    *,
    lam_range: tuple[float, float] = (0.0, 1.0),
    max_hop_factor: int = 16,
    tol: float = 0.02,
    max_iters: int = 8,
) -> CalibrationResult:
    """Fit ``lam`` (escalating ``hop_factor`` when needed) to ``recall_target``.

    ``eval_recall`` measures recall of one candidate config on the held-out
    sample (see :func:`exact_recall_eval` / :func:`tiered_recall_eval`).
    ``hop_factor`` doubles from ``base_cfg.hop_factor`` up to
    ``max_hop_factor`` whenever even ``lam = lam_range[0]`` misses the
    target (the hop budget, not the beam law, is binding there).
    """
    history: list[tuple[float, int, float]] = []
    hop_factor = base_cfg.hop_factor
    while True:
        cfg_at = dataclasses.replace(base_cfg, hop_factor=hop_factor)

        def eval_lam(lam: float, _cfg=cfg_at) -> float:
            return eval_recall(dataclasses.replace(_cfg, lam=lam))

        lam, recall, lam_hist = bisect_lam(
            eval_lam, recall_target, lam_range[0], lam_range[1],
            tol=tol, max_iters=max_iters)
        history.extend((lm, hop_factor, r) for lm, r in lam_hist)
        if recall >= recall_target or hop_factor * 2 > max_hop_factor:
            return CalibrationResult(
                lam=float(lam), hop_factor=int(hop_factor),
                recall=float(recall), target=float(recall_target),
                achieved=bool(recall >= recall_target),
                history=tuple(history))
        hop_factor *= 2


def joint_l_min_candidates(
    base_cfg: search_mod.AdaptiveBeamBudget, floor: int = 2
) -> tuple[int, ...]:
    """Default l_min grid for the joint fit: halving down from the base
    config's floor to ``floor``, returned ascending (max-savings first)."""
    cands = [int(base_cfg.l_min)]
    while cands[-1] // 2 >= max(1, floor):
        cands.append(cands[-1] // 2)
    return tuple(sorted(set(cands)))


def calibrate_budget_law_joint(
    make_eval: Callable[
        [search_mod.AdaptiveBeamBudget],
        Callable[[search_mod.AdaptiveBeamBudget], float]],
    base_cfg: search_mod.AdaptiveBeamBudget,
    recall_target: float,
    *,
    l_min_candidates: tuple[int, ...] | None = None,
    lam_range: tuple[float, float] = (0.0, 1.0),
    max_hop_factor: int = 16,
    tol: float = 0.02,
    max_iters: int = 8,
) -> CalibrationResult:
    """Joint (lam, l_min) fit: the smallest feasible budget floor, then the
    largest feasible lam at that floor.

    ``l_min`` is the recall pressure point the lam bisection works around:
    the budget law centers at the geometric mid ``sqrt(l_min * l_max)``, so
    lowering ``l_min`` lowers *every* query's expected budget (the real I/O
    lever), while recall pressure concentrates on the easy lanes shrunk
    toward the floor. Feasibility is monotone in ``l_min`` (raising the floor
    only widens frontiers), so the joint fit scans the candidate floors
    *ascending* and returns the first whose lam bisection
    (:func:`calibrate_budget_law`, hop_factor escalation included) meets the
    target — maximum savings subject to the recall SLO. If no floor is
    feasible the largest candidate's (best-recall) fit is returned with
    ``achieved=False``.

    ``make_eval`` builds a recall evaluator *specialised to one candidate's
    shape knobs* — the shared-probe evaluators
    (:func:`exact_recall_eval` / :func:`tiered_recall_eval` with
    ``base_cfg=``) compile one probe per l_min candidate and reuse it across
    that candidate's whole lam bisection. Deterministic end to end under a
    fixed seed, like the plain fit.
    """
    if l_min_candidates is None:
        l_min_candidates = joint_l_min_candidates(base_cfg)
    cands = sorted({int(c) for c in l_min_candidates})
    assert cands and 0 < cands[0] and cands[-1] <= base_cfg.l_max, cands
    joint_hist: list[tuple[int, float, int, float, bool]] = []
    last: CalibrationResult | None = None
    for lm in cands:
        cfg_lm = dataclasses.replace(base_cfg, l_min=lm)
        result = calibrate_budget_law(
            make_eval(cfg_lm), cfg_lm, recall_target, lam_range=lam_range,
            max_hop_factor=max_hop_factor, tol=tol, max_iters=max_iters)
        joint_hist.append((lm, result.lam, result.hop_factor, result.recall,
                           result.achieved))
        last = result
        if result.achieved:
            return dataclasses.replace(
                result, l_min=lm, joint_history=tuple(joint_hist))
    assert last is not None
    return dataclasses.replace(
        last, l_min=cands[-1], joint_history=tuple(joint_hist))


@dataclasses.dataclass(frozen=True)
class ShardCalibration:
    """Per-shard budget laws fitted by :func:`calibrate_budget_law_per_shard`.

    Attributes:
      lam / l_min / hop_factor: the fitted knobs, one entry per shard.
      results: each shard's full :class:`CalibrationResult` (histories,
        achieved flags) in shard order.
    """

    lam: tuple[float, ...]
    l_min: tuple[int, ...]
    hop_factor: tuple[int, ...]
    results: tuple[CalibrationResult, ...]

    @property
    def achieved(self) -> bool:
        return all(r.achieved for r in self.results)

    def law_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """The (lam (S,) f32, l_min (S,) i32) runtime arrays the distributed
        step consumes (``shard_laws=`` on the backend / ``per_shard_laws``
        steps; serialized via ``repro.index.save_index(shard_laws=)``).

        Deploy together with :meth:`serving_budget` — ``hop_factor`` is not
        a per-shard runtime array, so a fit that escalated it on any shard
        must raise the serving config's global value to match."""
        return (np.asarray(self.lam, np.float32),
                np.asarray(self.l_min, np.int32))

    def serving_budget(
        self, base: search_mod.AdaptiveBeamBudget
    ) -> search_mod.AdaptiveBeamBudget:
        """``base`` with ``hop_factor`` escalated to the per-shard max.

        The distributed step derives hop deadlines from the (global) budget
        config's ``hop_factor``; a shard whose fit only met the target after
        hop-factor escalation would silently serve under a tighter deadline
        than it was calibrated to. Hop limits are *caps*, so serving the
        largest fitted escalation everywhere never tightens any shard's fit
        (easy shards still retire when their frontier closes)."""
        return dataclasses.replace(base, hop_factor=max(self.hop_factor))


def calibrate_budget_law_per_shard(
    make_shard_eval: Callable[[int], Callable],
    base_cfg: search_mod.AdaptiveBeamBudget,
    recall_target: float,
    n_shards: int,
    *,
    joint: bool = True,
    **fit_kw,
) -> ShardCalibration:
    """Fit one budget law per shard of a distributed index.

    ``make_shard_eval(s)`` returns shard ``s``'s evaluator *factory* (the
    ``make_eval`` shape of :func:`calibrate_budget_law_joint`: config ->
    recall evaluator on shard-local held-out queries — see
    :func:`shard_exact_recall_evals`). Each shard runs the joint
    (lam, l_min) fit (or the plain lam fit with ``joint=False``) against the
    same ``recall_target``: the global merge only ever *adds* candidates
    across shards, so per-shard recall at the target is a sound (mildly
    conservative) surrogate for global recall at the target.

    Deterministic end to end under a fixed seed, shard by shard. Returns a
    :class:`ShardCalibration`; its :meth:`~ShardCalibration.law_arrays` feed
    ``DistributedBackend(shard_laws=)`` directly.
    """
    results = []
    for s in range(n_shards):
        factory = make_shard_eval(s)
        if joint:
            r = calibrate_budget_law_joint(
                factory, base_cfg, recall_target, **fit_kw)
        else:
            r = calibrate_budget_law(
                factory(base_cfg), base_cfg, recall_target, **fit_kw)
        results.append(r)
    return ShardCalibration(
        lam=tuple(float(r.lam) for r in results),
        l_min=tuple(int(r.l_min if r.l_min is not None else base_cfg.l_min)
                    for r in results),
        hop_factor=tuple(int(r.hop_factor) for r in results),
        results=tuple(results),
    )


def calibrate_budget_law_per_class(
    make_eval: Callable[
        [search_mod.AdaptiveBeamBudget],
        Callable[[search_mod.AdaptiveBeamBudget], float]],
    base_cfg: search_mod.AdaptiveBeamBudget,
    recall_targets: "dict[str, float]",
    *,
    joint: bool = True,
    **fit_kw,
) -> "dict[str, CalibrationResult]":
    """Fit one budget law per QoS class — the serving front door's knob.

    ``recall_targets`` maps class name -> recall target (e.g.
    ``{"interactive": 0.85, "batch": 0.97}``); each class runs the joint
    (lam, l_min) fit (or the plain lam fit with ``joint=False``) against
    *its own* target over the same ``make_eval`` factory and the same
    held-out sample.  The result is the per-class (lam, l_min) split the
    paper's budget law makes free: a looser target fits a higher lam and a
    lower floor — fewer slow-tier reads — while a stricter class keeps its
    recall SLO, on the same index and the same backend.

    Deploy via :func:`class_budget_cfgs`: one
    :class:`~repro.serving.engine.SearchEngine` per class over one shared
    backend, handed to ``repro.serving.server.FrontDoor`` keyed by class
    name.  Deterministic end to end under a fixed seed, class by class
    (dict order is preserved).
    """
    out: dict[str, CalibrationResult] = {}
    for name, target in recall_targets.items():
        if joint:
            out[name] = calibrate_budget_law_joint(
                make_eval, base_cfg, float(target), **fit_kw)
        else:
            out[name] = calibrate_budget_law(
                make_eval(base_cfg), base_cfg, float(target), **fit_kw)
    return out


def class_budget_cfgs(
    results: "dict[str, CalibrationResult]",
    base_cfg: search_mod.AdaptiveBeamBudget,
) -> "dict[str, search_mod.AdaptiveBeamBudget]":
    """Per-class serving configs from a :func:`calibrate_budget_law_per_class`
    fit — each class's base config with its fitted knobs substituted in."""
    return {name: r.budget_cfg(base_cfg) for name, r in results.items()}


def shard_exact_recall_evals(
    vectors, adj, entries, queries, n_shards: int, *,
    k: int = 10, sample: int = 256, seed: int = 0,
) -> Callable[[int], Callable]:
    """``make_shard_eval`` over a shard-major distributed layout.

    ``vectors``/``adj`` are the concatenated shard-major arrays (shard s owns
    rows [s*per, (s+1)*per) with shard-local adjacency ids — the layout
    ``make_distributed_search`` requires, *before* device_put); ``entries``
    the per-shard local medoids. Shard recall is measured against the
    shard's own brute-force top-k: the budget law governs the shard-local
    walk, and the global merge sits outside it. The held-out sample is drawn
    once per shard from the same seed, so every shard calibrates against the
    same queries.
    """
    per = adj.shape[0] // n_shards

    def make_shard_eval(s: int) -> Callable:
        x_s = vectors[s * per:(s + 1) * per]
        adj_s = adj[s * per:(s + 1) * per]
        entry_s = jnp.asarray(entries)[s]
        _, gt_s = distance_mod.brute_force_topk(
            jnp.asarray(queries), jnp.asarray(x_s), k=k)

        def factory(cfg: search_mod.AdaptiveBeamBudget) -> Callable:
            return exact_recall_eval(
                x_s, adj_s, entry_s, queries, gt_s, k=k, sample=sample,
                seed=seed, base_cfg=cfg)

        return factory

    return make_shard_eval


def _candidate_grants(cfg: search_mod.AdaptiveBeamBudget, q_lid):
    """Budgets + hop limits for one candidate config, from a shared probe's
    LID estimates — plain traced arithmetic, no recompilation per candidate
    (the jitted probe/continue programs are keyed on the *base* config only;
    lam / hop_factor / center never enter a static argument here)."""
    from repro.core import mapping as mapping_mod

    center = (jnp.float32(cfg.center) if cfg.center is not None
              else jnp.mean(q_lid))
    budgets = mapping_mod.adaptive_beam_budget(
        q_lid, cfg.lam, cfg.l_min, cfg.l_max, mu=center)
    return budgets, search_mod._bucket_hop_limits(cfg, budgets, None)


def _check_shape_knobs(cfg, base):
    """The shared probe state is only valid while the shape knobs match —
    the calibration loop varies lam/hop_factor/center exclusively."""
    same = (cfg.l_min == base.l_min and cfg.l_max == base.l_max
            and cfg.probe_hops == base.probe_hops
            and cfg.lid_k == base.lid_k)
    if not same:
        raise ValueError(
            f"calibration evaluator is specialised to probe knobs of {base}; "
            f"got {cfg}")


def exact_recall_eval(
    x, adj, entry, queries, gt_ids, *, k: int = 10,
    sample: int = 256, seed: int = 0,
    base_cfg: search_mod.AdaptiveBeamBudget | None = None,
) -> Callable[[search_mod.AdaptiveBeamBudget], float]:
    """Recall evaluator over the exact-distance adaptive engine.

    Draws a deterministic held-out sample of ``queries`` (with matching
    ground-truth rows) once. The probe walk depends only on the shape knobs
    (l_min/l_max/probe_hops/lid_k), never on lam or hop_factor, so it runs
    *once*, lazily, at the first evaluation; each candidate then re-runs only
    the continue phase with its own (traced) budgets and hop limits — the
    whole bisection shares two compiled programs.
    """
    sel = holdout_sample(queries.shape[0], sample, seed)
    q_s, gt_s = queries[sel], gt_ids[sel][:, :k]
    probe = {}

    def eval_recall(cfg: search_mod.AdaptiveBeamBudget) -> float:
        if not probe:
            probe["base"] = base_cfg or cfg
            probe["state"], _, _, probe["q_lid"] = search_mod._probe_exact_jit(
                x, adj, q_s, entry, probe["base"])
        _check_shape_knobs(cfg, probe["base"])
        budgets, hop_limits = _candidate_grants(cfg, probe["q_lid"])
        beam_ids, _, _, _ = search_mod._continue_exact_jit(
            x, adj, probe["state"], q_s, budgets, hop_limits,
            budget_cfg=probe["base"])
        return float(distance_mod.recall_at_k(beam_ids[:, :k], gt_s))

    return eval_recall


def tiered_recall_eval(
    index, queries, gt_ids, *, k: int = 10, sample: int = 256, seed: int = 0,
    base_cfg: search_mod.AdaptiveBeamBudget | None = None,
) -> Callable[[search_mod.AdaptiveBeamBudget], float]:
    """Recall evaluator over the deployed two-tier path: PQ-routed walk +
    slow-tier rerank, so the fitted lam reflects ADC distance noise too.
    Same shared-probe structure as :func:`exact_recall_eval` — one probe, one
    continue program, no per-candidate recompilation."""
    from repro.index.disk import _query_luts

    sel = holdout_sample(queries.shape[0], sample, seed)
    q_s, gt_s = queries[sel], gt_ids[sel][:, :k]
    luts = _query_luts(index, q_s)
    probe = {}

    def eval_recall(cfg: search_mod.AdaptiveBeamBudget) -> float:
        if not probe:
            probe["base"] = base_cfg or cfg
            probe["state"], _, _, probe["q_lid"] = search_mod._probe_pq_jit(
                index.codes, index.graph.adj, luts, index.graph.entry,
                probe["base"])
        _check_shape_knobs(cfg, probe["base"])
        budgets, hop_limits = _candidate_grants(cfg, probe["q_lid"])
        beam_ids, _, _, _ = search_mod._continue_pq_jit(
            index.codes, index.graph.adj, probe["state"], luts, budgets,
            hop_limits, budget_cfg=probe["base"])
        ids, _ = search_mod._rerank_slow_tier_jit(
            beam_ids, index.vectors, q_s, k=k)
        return float(distance_mod.recall_at_k(ids, gt_s))

    return eval_recall
