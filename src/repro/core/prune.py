"""Adaptive robust pruning — the "dynamic occlusion criterion" (paper §3.2/§3.3).

An edge (u, v) is pruned when a previously selected witness n satisfies

    alpha(u) * d(n, v) <= d(u, v)            (paper §4.2)

with the *per-node* alpha(u) produced by the mapping function Phi. With
alpha(u) = const this is exactly Vamana's RobustPrune, which is how the
DiskANN baseline is expressed in this framework.

All distances in this module are squared-L2; the criterion is applied as
``alpha^2 * d2(n, v) <= d2(u, v)`` which is equivalent on true distances.

The selection loop is sequential in the candidate rank (each selected witness
can occlude later candidates) — implemented as a ``lax.fori_loop`` over the
(small, O(L+R)) candidate list with vectorised occlusion updates, vmapped over
the node batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

INVALID = -1


def _dedup_mask(ids: Array) -> Array:
    """True for the first occurrence of each id (ids sorted by priority)."""
    c = ids.shape[0]
    same = ids[None, :] == ids[:, None]  # (C, C)
    earlier = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)
    dup = (same & earlier).any(axis=1)
    return ~dup


def robust_prune_one(
    cand_ids: Array,
    cand_d2: Array,
    cand_pd2: Array,
    alpha: Array,
    degree: int,
) -> tuple[Array, Array]:
    """Prune one node's candidate pool to <= ``degree`` neighbours.

    Args:
      cand_ids: (C,) candidate ids, INVALID-padded; may contain duplicates.
      cand_d2:  (C,) squared distance of each candidate to the node u
        (``inf`` for invalid entries).
      cand_pd2: (C, C) pairwise squared distances among candidates.
      alpha:    scalar pruning parameter alpha(u) >= 1 (on true distances).
      degree:   max out-degree R.

    Returns:
      (nbr_ids, nbr_d2): each (degree,), selected neighbours sorted ascending
      by distance, INVALID/inf padded.
    """
    c = cand_ids.shape[0]
    valid = (cand_ids != INVALID) & jnp.isfinite(cand_d2)

    order = jnp.argsort(jnp.where(valid, cand_d2, jnp.inf))
    ids = cand_ids[order]
    d2 = jnp.where(valid[order], cand_d2[order], jnp.inf)
    pd2 = cand_pd2[order][:, order]
    valid = valid[order] & _dedup_mask(ids)

    alpha_sq = alpha * alpha

    def body(i, state):
        pruned, selected, count = state
        active = valid[i] & (~pruned[i]) & (count < degree)
        selected = selected.at[i].set(active)
        count = count + active.astype(jnp.int32)
        # Occlude later candidates j: alpha^2 * d2(c_i, c_j) <= d2(u, c_j).
        later = jnp.arange(c) > i
        occluded = later & (alpha_sq * pd2[i, :] <= d2)
        pruned = jnp.where(active, pruned | occluded, pruned)
        return pruned, selected, count

    pruned0 = jnp.zeros((c,), dtype=bool)
    selected0 = jnp.zeros((c,), dtype=bool)
    _, selected, _ = jax.lax.fori_loop(0, c, body, (pruned0, selected0, 0))

    # Compact the selected entries (already distance-sorted) into (degree,).
    rank = jnp.where(selected, jnp.arange(c), c)
    take = jnp.argsort(rank)[:degree]
    out_ids = jnp.where(selected[take], ids[take], INVALID)
    out_d2 = jnp.where(selected[take], d2[take], jnp.inf)
    return out_ids.astype(jnp.int32), out_d2


@functools.partial(jax.jit, static_argnames=("degree",))
def robust_prune_batch(
    x: Array,
    node_ids: Array,
    cand_ids: Array,
    alpha: Array,
    degree: int,
) -> tuple[Array, Array]:
    """Vectorised prune for a batch of nodes.

    Args:
      x:        (N, D) base vectors (distance oracle for the occlusion checks —
        on the real two-tier system these reads come from the fast tier's PQ
        codes during build, full precision here).
      node_ids: (B,) nodes being re-wired.
      cand_ids: (B, C) candidate pools (INVALID-padded, duplicates allowed).
      alpha:    (B,) per-node alpha(u).
      degree:   max out-degree R.

    Returns:
      (adj_rows, adj_d2): (B, degree) pruned neighbour lists + distances.
    """
    safe = jnp.maximum(cand_ids, 0)
    cvecs = x[safe]  # (B, C, D)
    uvecs = x[node_ids]  # (B, D)

    diff = cvecs - uvecs[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # (B, C)
    # Self-edges and invalid slots are never eligible.
    bad = (cand_ids == INVALID) | (cand_ids == node_ids[:, None])
    d2 = jnp.where(bad, jnp.inf, d2)

    # Pairwise candidate distances for occlusion tests.
    sq = jnp.sum(cvecs * cvecs, axis=-1)  # (B, C)
    pd2 = sq[:, :, None] - 2.0 * jnp.einsum("bcd,bed->bce", cvecs, cvecs) + sq[:, None, :]
    pd2 = jnp.maximum(pd2, 0.0)

    ids = jnp.where(bad, INVALID, cand_ids)
    return jax.vmap(robust_prune_one, in_axes=(0, 0, 0, 0, None))(
        ids, d2, pd2, alpha, degree
    )


def greedy_block_pack(adj, entry: int, nodes_per_block: int):
    """Block-aware slot assignment (the BAMG layout lever): co-locate each
    node's record with its nearest pruned out-neighbours so one I/O-block
    read covers a hop's expansions.

    Nodes are visited in BFS order from the entry point — the order a beam
    walk first touches records — and every still-unassigned node opens a
    *group*: itself plus its nearest unassigned out-neighbours (adjacency
    rows come distance-ascending out of the robust prune, so row order *is*
    nearness order).  Groups fill consecutive record slots and are capped at
    the current I/O block's remaining capacity, so a seed node and the
    neighbours packed with it always share one block — when the walk expands
    the seed, the block read that fetched its adjacency has already paid for
    the neighbours it is most likely to hop to next.  Unreachable nodes are
    appended in id order.

    Host-side numpy (build-time layout, not a kernel).  Returns
    ``slot_of``: (N,) int64 permutation mapping node id -> record slot,
    the form :func:`repro.index.blockstore.write_block_store` takes.
    """
    import numpy as np

    adj = np.asarray(adj)
    n = adj.shape[0]
    npb = int(nodes_per_block)
    if npb <= 1:
        return np.arange(n, dtype=np.int64)

    # BFS from the entry over out-edges; unreached nodes follow in id order.
    order = np.empty(n, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    order[0] = int(entry)
    seen[int(entry)] = True
    head, tail = 0, 1
    while head < tail:
        u = order[head]
        head += 1
        for v in adj[u]:
            if v >= 0 and not seen[v]:
                seen[v] = True
                order[tail] = v
                tail += 1
    if tail < n:
        rest = np.flatnonzero(~seen)
        order[tail:] = rest
        seen[rest] = True

    slot_of = np.empty(n, dtype=np.int64)
    assigned = np.zeros(n, dtype=bool)
    next_slot = 0
    for u in order:
        if assigned[u]:
            continue
        group = [int(u)]
        assigned[u] = True
        # Fill only to the end of the current I/O block: the group never
        # straddles a block boundary.
        capacity = npb - (next_slot % npb)
        for v in adj[u]:
            if len(group) >= capacity:
                break
            if v >= 0 and not assigned[v]:
                group.append(int(v))
                assigned[v] = True
        for g in group:
            slot_of[g] = next_slot
            next_slot += 1
    assert next_slot == n
    return slot_of
