"""Online-MCGI — Algorithm 2 of the paper.

Differences from the offline Algorithm 1:
  * Phase 1 only *bootstraps* the population statistics (mu, sigma) from a
    random sample instead of estimating LID for every point (negligible
    pre-processing cost at billion scale, §3.3);
  * during refinement, each node's LID is estimated *on the fly* from its
    current greedy-search candidate pool C, and alpha_u recomputed each round —
    noisy early, converging as neighbour quality improves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import lid as lid_mod
from repro.core import mapping as mapping_mod
from repro.core import prune as prune_mod
from repro.core import search as search_mod
from repro.core.types import GraphIndex

Array = jax.Array
INVALID = build_mod.INVALID


def _rewire_batch_online(
    x: Array,
    adj: Array,
    mu: Array,
    sigma: Array,
    entry: Array,
    node_ids: Array,
    cfg: build_mod.BuildConfig,
) -> tuple[Array, Array, Array, Array]:
    """One online refinement step: search -> online LID -> alpha_u -> prune.

    Returns (new_rows, new_d2, alpha_u, lid_u) for the batch.
    """
    queries = x[node_ids]
    beam_ids, beam_d2, _ = search_mod.beam_search_exact(
        x, adj, queries, entry,
        beam_width=cfg.beam_width, max_hops=cfg.max_hops, k=cfg.beam_width,
    )
    # Exclude the node itself from its own LID neighbourhood.
    self_mask = beam_ids == node_ids[:, None]
    d2 = jnp.where(self_mask | (beam_ids == INVALID), jnp.inf, beam_d2)
    lid_u = lid_mod.online_lid(d2, k=min(cfg.lid_k, cfg.beam_width))
    alpha_u = mapping_mod.phi(lid_u, mu, sigma, cfg.alpha_min, cfg.alpha_max)

    pool = jnp.concatenate([beam_ids, adj[node_ids]], axis=1)
    rows, rows_d2 = prune_mod.robust_prune_batch(
        x, node_ids, pool, alpha_u, cfg.degree
    )
    return rows, rows_d2, alpha_u, lid_u


def build_online_mcgi(
    x: Array, cfg: build_mod.BuildConfig = build_mod.BuildConfig(),
    sample: int = 2048, progress=None,
) -> GraphIndex:
    """Algorithm 2 — bootstrap stats + on-the-fly LID adaptation."""
    n = x.shape[0]
    key = jax.random.PRNGKey(cfg.seed)
    mu, sigma = lid_mod.bootstrap_stats(
        x, jax.random.fold_in(key, 17), sample=sample, k=cfg.lid_k
    )
    if progress:
        progress(f"bootstrap: mu={float(mu):.2f} sigma={float(sigma):.2f}")

    adj = build_mod.random_graph(n, cfg.degree, key)
    entry = search_mod.medoid(x)
    alpha_final = jnp.full((n,), 0.5 * (cfg.alpha_min + cfg.alpha_max), jnp.float32)
    # Seeded at mu so un-refined nodes are consistent with alpha_final's
    # midpoint; overwritten per batch with the online estimate each alpha was
    # actually computed from.
    lid_final = jnp.full((n,), mu, jnp.float32)

    rewire = jax.jit(
        _rewire_batch_online, static_argnames=("cfg",)
    )

    for it in range(cfg.iters):
        perm = np.asarray(jax.random.permutation(jax.random.fold_in(key, it + 1), n))
        for start in range(0, n, cfg.batch):
            ids_np = perm[start : start + cfg.batch]
            real = ids_np.size
            if real < cfg.batch:
                # Wrap-around pad keeps the jitted rewire shape fixed; the pad
                # lanes recompute nodes already refined earlier this round, so
                # everything below scatters only the real prefix — otherwise
                # the padded scatter would carry duplicate ids (and for small
                # n, duplicate ids with rows from different adj snapshots),
                # making the build depend on the scatter's unspecified
                # duplicate-index winner.
                ids_np = np.concatenate([ids_np, perm[: cfg.batch - real]])
            node_ids = jnp.asarray(ids_np)
            rows, _, alpha_u, lid_u = rewire(x, adj, mu, sigma, entry, node_ids, cfg)
            keep = node_ids[:real]
            adj = adj.at[keep].set(rows[:real])
            alpha_final = alpha_final.at[keep].set(alpha_u[:real])
            lid_final = lid_final.at[keep].set(lid_u[:real])
            dest, cand = build_mod._reverse_pairs(
                ids_np[:real], np.asarray(rows)[:real], cfg.reverse_cap
            )
            for ds in range(0, dest.shape[0], cfg.batch):
                dslice = dest[ds : ds + cfg.batch]
                cslice = cand[ds : ds + cfg.batch]
                dvalid = None
                if dslice.size < cfg.batch:
                    pad = cfg.batch - dslice.size
                    # Pad destinations repeat a live node; mark them so the
                    # insert drops their scatter lanes (their re-pruned rows
                    # come from an all-INVALID pool and would race the real
                    # lane's row under a duplicate index).
                    dvalid = jnp.asarray(np.arange(cfg.batch) < dslice.size)
                    dslice = np.concatenate([dslice, dslice[:1].repeat(pad)])
                    cslice = np.concatenate(
                        [cslice, np.full((pad, cfg.reverse_cap), INVALID, np.int32)]
                    )
                adj = build_mod._insert_reverse(
                    x, adj, alpha_final, jnp.asarray(dslice), jnp.asarray(cslice),
                    cfg, valid=dvalid,
                )
        if progress:
            progress(f"online refinement round {it + 1}/{cfg.iters} done")

    return GraphIndex(
        adj=adj, entry=entry, alpha=alpha_final,
        lid=lid_final, mu=mu, sigma=sigma,
    )
