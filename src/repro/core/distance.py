"""Distance primitives shared by every index in the framework.

All graph algorithms in :mod:`repro.core` work on *squared* L2 distances (the
monotone transform preserves every comparison the algorithms make and saves a
sqrt per pair).  The LID estimator needs true distances and applies the sqrt
itself (see :mod:`repro.core.lid`).

The pure-jnp implementations here are the reference path; the Pallas kernels in
:mod:`repro.kernels` provide the TPU-optimised drop-ins and are validated
against these functions.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# Metric names accepted across the framework.
L2 = "l2"
IP = "ip"  # inner-product (maximum inner product search, negated to a "distance")
COSINE = "cosine"


def squared_l2(q: Array, x: Array) -> Array:
    """Pairwise squared L2 distances.

    Args:
      q: (Q, D) queries.
      x: (N, D) base points.
    Returns:
      (Q, N) squared distances, computed via the expansion
      ``|q|^2 - 2 q.x + |x|^2`` so the contraction hits the MXU.
    """
    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # (Q, 1)
    xn = jnp.sum(x * x, axis=-1)  # (N,)
    dot = q @ x.T  # (Q, N)
    d2 = qn - 2.0 * dot + xn[None, :]
    return jnp.maximum(d2, 0.0)


def neg_inner_product(q: Array, x: Array) -> Array:
    """Negated inner product as a distance (smaller = more similar)."""
    return -(q @ x.T)


def pairwise(q: Array, x: Array, metric: str = L2) -> Array:
    if metric == L2:
        return squared_l2(q, x)
    if metric == IP:
        return neg_inner_product(q, x)
    if metric == COSINE:
        qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
        xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        return neg_inner_product(qn, xn)
    raise ValueError(f"unknown metric {metric!r}")


def point_to_points(q: Array, x: Array, metric: str = L2) -> Array:
    """(D,) query vs (M, D) points -> (M,) distances."""
    return pairwise(q[None, :], x, metric)[0]


@functools.partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def brute_force_topk(
    q: Array, x: Array, k: int, metric: str = L2, chunk: int = 4096
) -> tuple[Array, Array]:
    """Exact top-k nearest neighbours by chunked scan over the base set.

    Chunking bounds the (Q, chunk) score buffer so ground-truth computation for
    10^5-point benchmark sets fits comfortably in host memory.

    Returns:
      (dists, ids): each (Q, k), ascending by distance.
    """
    n = x.shape[0]
    nq = q.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    n_chunks = xp.shape[0] // chunk

    init_d = jnp.full((nq, k), jnp.inf, dtype=jnp.float32)
    init_i = jnp.full((nq, k), -1, dtype=jnp.int32)

    def body(carry, ci):
        best_d, best_i = carry
        xs = jax.lax.dynamic_slice_in_dim(xp, ci * chunk, chunk, axis=0)
        d = pairwise(q, xs, metric)  # (Q, chunk)
        ids = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        valid = ids < n
        d = jnp.where(valid[None, :], d, jnp.inf)
        cat_d = jnp.concatenate([best_d, d], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, (nq, chunk))], axis=1)
        order = jnp.argsort(cat_d, axis=1)[:, :k]
        return (
            jnp.take_along_axis(cat_d, order, axis=1),
            jnp.take_along_axis(cat_i, order, axis=1),
        ), None

    (best_d, best_i), _ = jax.lax.scan(
        body, (init_d, init_i), jnp.arange(n_chunks, dtype=jnp.int32)
    )
    return best_d, best_i


def knn_graph(
    x: Array, k: int, metric: str = L2, chunk_q: int = 1024
) -> tuple[Array, Array]:
    """Exact k-NN of every point against the dataset (excluding self).

    Used by the calibration phase (Phase 1 of Algorithm 1) and by the theory
    oracles.  Runs in query chunks to bound memory.

    Returns:
      (dists, ids): each (N, k), ascending; ``dists`` are squared-L2 for the
      l2 metric (callers needing true distances take a sqrt).
    """
    n = x.shape[0]
    outs_d, outs_i = [], []
    topk = jax.jit(
        functools.partial(brute_force_topk, k=k + 1, metric=metric)
    )
    for start in range(0, n, chunk_q):
        qs = x[start : start + chunk_q]
        d, i = topk(qs, x)
        # Drop self-matches: the nearest hit at distance 0 with id == row.
        rows = jnp.arange(start, start + qs.shape[0])[:, None]
        is_self = i == rows
        # Push self to the end, then take first k.
        d = jnp.where(is_self, jnp.inf, d)
        order = jnp.argsort(d, axis=1)[:, :k]
        outs_d.append(jnp.take_along_axis(d, order, axis=1))
        outs_i.append(jnp.take_along_axis(i, order, axis=1))
    return jnp.concatenate(outs_d, axis=0), jnp.concatenate(outs_i, axis=0)


def recall_at_k(pred_ids: Array, true_ids: Array) -> Array:
    """Mean Recall@k between predicted and ground-truth id sets (both (Q, k))."""
    hits = (pred_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return hits.mean()


DistanceFn = Callable[[Array, Array], Array]
