"""Batched greedy beam search over a proximity graph (paper §3.3 / §4.1).

TPU-native adaptation of DiskANN's pointer-chasing loop: the beam is a dense
fixed-shape (L,) state, the visited set a bitmask, and the hop loop a
``lax.while_loop`` with masked convergence — vmapped over the query batch.

Two distance regimes:
  * exact       — full-precision vectors (in-memory benchmark mode);
  * PQ-routed   — LUT/ADC distances steer the walk, the final beam is
    re-ranked with full-precision vectors; each node *expansion* counts as one
    slow-tier I/O (DiskANN's SSD read), which is the quantity Figures 2a/2c
    are about.

I/O accounting is carried in :class:`SearchStats` and surfaced by every
benchmark.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array
INVALID = -1

# eval_dists(query_ctx, ids, valid_mask) -> (len(ids),) squared distances.
DistEval = Callable[[Array, Array, Array], Array]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Per-query work counters (the paper's resource-efficiency metrics)."""

    hops: Array        # nodes expanded == slow-tier reads (DiskANN I/O model)
    dist_evals: Array  # distance computations (compute-side cost, RQ4/T2I)

    def mean(self) -> "SearchStats":
        return SearchStats(hops=self.hops.mean(), dist_evals=self.dist_evals.mean())


def _beam_merge(
    beam_ids, beam_d, beam_exp, new_ids, new_d, beam_width
):
    """Merge R freshly evaluated candidates into the beam; keep best L."""
    cat_ids = jnp.concatenate([beam_ids, new_ids])
    cat_d = jnp.concatenate([beam_d, new_d])
    cat_exp = jnp.concatenate([beam_exp, jnp.zeros(new_ids.shape, dtype=bool)])
    order = jnp.argsort(cat_d)[:beam_width]
    return cat_ids[order], cat_d[order], cat_exp[order]


def _search_one(
    query_ctx: Array,
    adj: Array,
    entry: Array,
    eval_dists: DistEval,
    n: int,
    beam_width: int,
    max_hops: int,
) -> tuple[Array, Array, SearchStats]:
    """Beam search for a single query context; vmap over the batch.

    The visited set is a *bit-packed* uint32 array (n/32 words): 8x less
    working-set memory and HBM traffic than a bool mask — at billion-scale
    shards (3.9M points/device, 128-query chunks) this is the difference
    between a 500 MB and a 62 MB visited buffer (§Perf, mcgi serve cells).
    Requires duplicate-free adjacency rows (the pruner dedups; random init
    graphs are dedup'd at construction).
    """
    r = adj.shape[1]
    nw = (n + 31) // 32

    entry_d = eval_dists(query_ctx, entry[None], jnp.ones((1,), dtype=bool))[0]
    beam_ids = jnp.full((beam_width,), INVALID, dtype=jnp.int32).at[0].set(entry)
    beam_d = jnp.full((beam_width,), jnp.inf, dtype=jnp.float32).at[0].set(entry_d)
    beam_exp = jnp.zeros((beam_width,), dtype=bool)
    visited = jnp.zeros((nw,), dtype=jnp.uint32).at[entry >> 5].set(
        jnp.uint32(1) << (entry.astype(jnp.uint32) & 31)
    )

    def cond(state):
        _, _, beam_exp, _, hops, _ = state
        frontier_open = jnp.any((~beam_exp) & (state[0] != INVALID))
        return (hops < max_hops) & frontier_open

    def body(state):
        beam_ids, beam_d, beam_exp, visited, hops, evals = state
        # Closest unexpanded beam entry.
        cand_d = jnp.where(beam_exp | (beam_ids == INVALID), jnp.inf, beam_d)
        j = jnp.argmin(cand_d)
        u = beam_ids[j]
        beam_exp = beam_exp.at[j].set(True)

        nbrs = adj[jnp.maximum(u, 0)]  # (R,)
        valid = (nbrs != INVALID) & (u != INVALID)
        safe = jnp.maximum(nbrs, 0)
        word_idx = safe >> 5
        bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
        seen = (visited[word_idx] & bit) != 0
        valid = valid & (~seen)
        d = eval_dists(query_ctx, safe, valid)
        d = jnp.where(valid, d, jnp.inf)
        # Distinct ids set distinct bits, so scatter-add implements the OR.
        visited = visited.at[word_idx].add(jnp.where(valid, bit, 0))

        nbr_ids = jnp.where(valid, nbrs, INVALID)
        beam_ids, beam_d, beam_exp = _beam_merge(
            beam_ids, beam_d, beam_exp, nbr_ids, d, beam_width
        )
        return beam_ids, beam_d, beam_exp, visited, hops + 1, evals + valid.sum()

    state = (beam_ids, beam_d, beam_exp, visited, jnp.int32(0), jnp.int32(0))
    beam_ids, beam_d, beam_exp, visited, hops, evals = jax.lax.while_loop(
        cond, body, state
    )
    return beam_ids, beam_d, SearchStats(hops=hops, dist_evals=evals)


@functools.partial(
    jax.jit, static_argnames=("beam_width", "max_hops", "k")
)
def beam_search_exact(
    x: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    beam_width: int,
    max_hops: int = 2048,
    k: int = 10,
) -> tuple[Array, Array, SearchStats]:
    """Exact-distance beam search, batched over (Q, D) queries.

    Returns (ids, d2, stats): (Q, k) ascending results + per-query counters.
    """
    n = x.shape[0]

    def eval_dists(q, ids, valid):
        vecs = x[ids]
        diff = vecs - q[None, :]
        return jnp.sum(diff * diff, axis=-1)

    run = functools.partial(
        _search_one,
        adj=adj,
        entry=entry,
        eval_dists=eval_dists,
        n=n,
        beam_width=beam_width,
        max_hops=max_hops,
    )
    beam_ids, beam_d, stats = jax.vmap(run)(queries)
    return beam_ids[:, :k], beam_d[:, :k], stats


@functools.partial(
    jax.jit, static_argnames=("beam_width", "max_hops", "k", "rerank")
)
def beam_search_pq(
    codes: Array,
    luts: Array,
    x_slow: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    beam_width: int,
    max_hops: int = 2048,
    k: int = 10,
    rerank: bool = True,
) -> tuple[Array, Array, SearchStats]:
    """PQ-routed beam search + optional full-precision re-rank.

    Args:
      codes:  (N, M) uint8 PQ codes — the fast-tier (HBM) representation.
      luts:   (Q, M, K) per-query ADC lookup tables
        (``repro.pq.adc.build_lut``).
      x_slow: (N, D) full-precision vectors — the slow tier; touched only for
        the final beam re-rank (one batched read of ``beam_width`` nodes,
        mirroring DiskANN's read-along-the-path + rerank).
      adj:    (N, R) graph.
    """
    n = codes.shape[0]

    def eval_dists(lut, ids, valid):
        # lut: (M, K); codes[ids]: (R, M) -> sum_m lut[m, code[r, m]]
        c = codes[ids].astype(jnp.int32)
        m = lut.shape[0]
        gathered = jax.vmap(lambda row: lut[jnp.arange(m), row])(c)
        return gathered.sum(axis=-1)

    run = functools.partial(
        _search_one,
        adj=adj,
        entry=entry,
        eval_dists=eval_dists,
        n=n,
        beam_width=beam_width,
        max_hops=max_hops,
    )
    beam_ids, beam_d, stats = jax.vmap(run)(luts)

    if rerank:
        safe = jnp.maximum(beam_ids, 0)
        vecs = x_slow[safe]  # (Q, L, D) — the batched slow-tier read
        diff = vecs - queries[:, None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        d2 = jnp.where(beam_ids == INVALID, jnp.inf, d2)
        order = jnp.argsort(d2, axis=-1)[:, :k]
        return (
            jnp.take_along_axis(beam_ids, order, axis=1),
            jnp.take_along_axis(d2, order, axis=1),
            stats,
        )
    return beam_ids[:, :k], beam_d[:, :k], stats


def medoid(x: Array) -> Array:
    """Entry point: the point closest to the dataset centroid (DiskANN's
    choice; O(N·D) instead of the O(N^2) true medoid)."""
    c = jnp.mean(x, axis=0, keepdims=True)
    diff = x - c
    return jnp.argmin(jnp.sum(diff * diff, axis=-1)).astype(jnp.int32)
