"""Batched greedy beam search over a proximity graph (paper §3.3 / §4.1).

TPU-native adaptation of DiskANN's pointer-chasing loop: the beam is a dense
fixed-shape (L,) state, the visited set a bitmask, and the hop loop a
``lax.while_loop`` with masked convergence — vmapped over the query batch.

Two distance regimes:
  * exact       — full-precision vectors (in-memory benchmark mode);
  * PQ-routed   — LUT/ADC distances steer the walk, the final beam is
    re-ranked with full-precision vectors; each node *expansion* counts as one
    slow-tier I/O (DiskANN's SSD read), which is the quantity Figures 2a/2c
    are about.

I/O accounting is carried in :class:`SearchStats` and surfaced by every
benchmark.

This module holds the *pure search kernels* only — fixed-beam and adaptive
probe/continue programs plus their jit wrappers. Serve-time control flow
(host-side bucket scheduling, batch pipelining, recalibration) lives in
:mod:`repro.serving`; the ``num_buckets=`` convenience on the adaptive entry
points below delegates to that scheduler.

The per-hop body (frontier select -> adjacency gather -> distance eval ->
beam merge -> visited update) is *pluggable*: :class:`BeamStepKernel` is the
reference implementation (the historical inline body, factored verbatim),
and :class:`PallasBeamStep` swaps the whole batched hop for one fused
``repro.kernels.beam_step`` launch per hop (beam state in VMEM, one kernel
instead of a chain of HLOs). Every walk entry point — fixed-beam, probe and
continue — takes ``step_kernel=`` (``None``/"reference" | "pallas" |
"auto"), threaded from the serving engines as a static jit key.
"reference" is the default everywhere (bit-stable, no dispatch-policy
dependence); "pallas" forces the fused kernel (compiled on TPU, interpret
elsewhere — bit-identical to the reference, see
:mod:`repro.kernels.beam_step`); "auto" consults the
:func:`repro.kernels.ops.resolve_impl` policy and falls back to the
reference off-TPU unless interpret mode is requested.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
INVALID = -1

# eval_dists(query_ctx, ids, valid_mask) -> (len(ids),) squared distances.
DistEval = Callable[[Array, Array, Array], Array]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Per-query work counters (the paper's resource-efficiency metrics)."""

    hops: Array        # nodes expanded == slow-tier reads (DiskANN I/O model)
    dist_evals: Array  # distance computations (compute-side cost, RQ4/T2I)

    def mean(self) -> "SearchStats":
        return SearchStats(hops=self.hops.mean(), dist_evals=self.dist_evals.mean())


@dataclasses.dataclass(frozen=True)
class AdaptiveBeamBudget:
    """Serve-time configuration of Prop. 4.2's per-query budget law.

    The engine runs a short *probe* phase at ``l_min`` width, estimates each
    query's LID from the probe beam's own candidate distances
    (:func:`repro.core.lid.online_lid` — no brute-force k-NN pre-pass), maps
    it to a budget ``L(q) = C * exp(lam * (LID(q) - center))`` clipped to
    [l_min, l_max], and *continues* the same search (warm state, no repeated
    hops) with a per-query frontier budget and hop limit.

    Attributes:
      l_min / l_max: operational beam range; the physical beam is ``l_max``
        wide (fixed shape — one compiled program for every budget).
      lam:         budget-law exponent (0 disables adaptivity at l_mid).
      lid_k:       neighbourhood size for the online LID estimate.
      probe_hops:  hops spent in the probe phase before budgets are set.
      hop_factor:  per-query hop limit = probe_hops + hop_factor * budget.
      center:      LID normalisation center; None -> batch mean (self
        normalising — robust to the ADC-vs-exact distance scale difference).
    """

    l_min: int
    l_max: int
    lam: float = 0.15
    lid_k: int = 16
    probe_hops: int = 8
    hop_factor: int = 4
    center: float | None = None

    def __post_init__(self):
        assert 0 < self.l_min <= self.l_max, (self.l_min, self.l_max)
        assert self.probe_hops >= 1 and self.hop_factor >= 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdaptiveStats:
    """Per-query adaptivity diagnostics returned by the adaptive engine."""

    q_lid: Array    # (Q,) online LID estimate from the probe beam
    budget: Array   # (Q,) int32 beam budget actually granted


def _beam_merge(
    beam_ids, beam_d, beam_exp, new_ids, new_d, beam_width
):
    """Merge R freshly evaluated candidates into the beam; keep best L."""
    cat_ids = jnp.concatenate([beam_ids, new_ids])
    cat_d = jnp.concatenate([beam_d, new_d])
    cat_exp = jnp.concatenate([beam_exp, jnp.zeros(new_ids.shape, dtype=bool)])
    order = jnp.argsort(cat_d)[:beam_width]
    return cat_ids[order], cat_d[order], cat_exp[order]


def _init_state(query_ctx: Array, entry: Array, eval_dists: DistEval,
                n: int, beam_width: int, excl_words: Array | None = None):
    """Fresh search state for one query: entry node in the beam, visited set
    seeded. State tuple: (beam_ids, beam_d, beam_exp, visited, hops, evals).

    ``excl_words`` (optional, (ceil(n/32),) uint32) is a per-query attribute
    filter: set bits mark *excluded* nodes.  Seeding the visited set with it
    makes the filter an in-graph lane mask — excluded neighbours fail the
    seen-check in :func:`_expand_frontier` exactly like INVALID lanes, so
    they never enter the beam and the walk only ever ranks in-filter nodes.
    The hop kernels (reference and fused Pallas alike) consume the state
    unchanged.  The entry node is force-seeded to start the walk; when it is
    itself excluded its beam distance is set to inf so it can only be
    traversed *through*, and :func:`scrub_excluded` drops it from the beam
    at walk exit.  Without a filter the code path is byte-identical to the
    historical one.
    """
    nw = (n + 31) // 32
    entry_d = eval_dists(query_ctx, entry[None], jnp.ones((1,), dtype=bool))[0]
    word = entry >> 5
    bit = jnp.uint32(1) << (entry.astype(jnp.uint32) & 31)
    if excl_words is None:
        visited = jnp.zeros((nw,), dtype=jnp.uint32).at[word].set(bit)
    else:
        entry_d = jnp.where((excl_words[word] & bit) != 0, jnp.inf, entry_d)
        visited = excl_words.at[word].set(excl_words[word] | bit)
    beam_ids = jnp.full((beam_width,), INVALID, dtype=jnp.int32).at[0].set(entry)
    beam_d = jnp.full((beam_width,), jnp.inf, dtype=jnp.float32).at[0].set(entry_d)
    beam_exp = jnp.zeros((beam_width,), dtype=bool)
    return beam_ids, beam_d, beam_exp, visited, jnp.int32(0), jnp.int32(0)


def pack_filter(allowed, n: int) -> Array:
    """Pack a boolean *allowed* mask into per-query exclusion bitset words.

    ``allowed`` is (n,) or (Q, n) bool — True for nodes the query may return
    (a tenant namespace, an attribute predicate, live non-tombstoned rows).
    Returns (Q, ceil(n/32)) uint32 words whose set bits mark *excluded*
    nodes, the form :func:`_init_state` seeds the visited bitset with (bit
    ``j`` of word ``w`` is node ``w * 32 + j``, matching the walk's packing).
    Host-side numpy; a (n,) mask packs once and broadcasts over queries.
    """
    allowed = np.atleast_2d(np.asarray(allowed, dtype=bool))
    q, n_mask = allowed.shape
    assert n_mask == n, (n_mask, n)
    nw = (n + 31) // 32
    padded = np.zeros((q, nw * 32), dtype=bool)
    padded[:, :n] = ~allowed
    bits = padded.reshape(q, nw, 32).astype(np.uint32)
    words = (bits << np.arange(32, dtype=np.uint32)).sum(
        axis=2, dtype=np.uint32)
    return jnp.asarray(words)


def scrub_excluded(beam_ids: Array, beam_d: Array, excl_words: Array):
    """Drop excluded ids from final beams: (Q, L) ids/d2 + (Q, nw) words.

    The walk's visited pre-seed keeps excluded nodes out of the beam, with
    one exception — the force-seeded entry node (inf distance, so it sits
    behind every real candidate).  Scrubbing it to INVALID/inf at walk exit
    means every downstream consumer (top-k slice, slow-tier rerank, partial
    results) sees the standard empty-lane convention and can never surface
    an out-of-filter id.  Beams stay distance-sorted (the scrubbed lane was
    already at inf).
    """
    safe = jnp.maximum(beam_ids, 0)
    bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
    words = jnp.take_along_axis(excl_words, safe >> 5, axis=1)
    blocked = (beam_ids != INVALID) & ((words & bit) != 0)
    return (jnp.where(blocked, INVALID, beam_ids),
            jnp.where(blocked, jnp.inf, beam_d))


_scrub_excluded_jit = jax.jit(scrub_excluded)


def _scrub_state(probe_state, excl_words: Array):
    """Apply :func:`scrub_excluded` to a full search-state tuple."""
    ids, d = scrub_excluded(probe_state[0], probe_state[1], excl_words)
    return (ids, d) + tuple(probe_state[2:])


_scrub_state_jit = jax.jit(_scrub_state)


def _select_frontier(state, in_budget: Array):
    """First half of the hop: pick the closest unexpanded in-budget beam
    entry, mark it expanded, and return its node id.

    This is the point where the walk's next adjacency read becomes known —
    the out-of-core driver runs this half on device, yields ``u`` to the
    host for the block fetch, then resumes with :func:`_expand_frontier`.
    """
    beam_ids, beam_d, beam_exp, visited, hops, evals = state
    cand_d = jnp.where(
        beam_exp | (beam_ids == INVALID) | (~in_budget), jnp.inf, beam_d)
    j = jnp.argmin(cand_d)
    u = beam_ids[j]
    beam_exp = beam_exp.at[j].set(True)
    return (beam_ids, beam_d, beam_exp, visited, hops, evals), u


def _expand_frontier(state, u: Array, nbrs: Array, query_ctx: Array,
                     eval_dists: DistEval, beam_width: int):
    """Second half of the hop: evaluate ``u``'s adjacency row and merge.

    ``nbrs`` is ``adj[u]`` however it was obtained — an in-graph gather
    (:meth:`BeamStepKernel.step`) or a host-side block-store read (the
    out-of-core walk). Identical ops on identical values either way, which
    is what keeps the two walks bit-identical.
    """
    beam_ids, beam_d, beam_exp, visited, hops, evals = state
    valid = (nbrs != INVALID) & (u != INVALID)
    safe = jnp.maximum(nbrs, 0)
    word_idx = safe >> 5
    bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
    seen = (visited[word_idx] & bit) != 0
    valid = valid & (~seen)
    d = eval_dists(query_ctx, safe, valid)
    d = jnp.where(valid, d, jnp.inf)
    # Distinct ids set distinct bits, so scatter-add implements the OR.
    visited = visited.at[word_idx].add(jnp.where(valid, bit, 0))

    nbr_ids = jnp.where(valid, nbrs, INVALID)
    beam_ids, beam_d, beam_exp = _beam_merge(
        beam_ids, beam_d, beam_exp, nbr_ids, d, beam_width
    )
    return beam_ids, beam_d, beam_exp, visited, hops + 1, evals + valid.sum()


class BeamStepKernel:
    """The pluggable per-hop kernel of the beam walk (reference impl).

    ``step`` advances ONE query's state by one hop — the body factored
    verbatim out of the historical inline ``_run_search`` loop (now split
    into :func:`_select_frontier` + :func:`_expand_frontier` so the
    out-of-core walk can interpose a host-side block read between the two
    halves), so fixed-beam, probe and continue all execute the same code.
    ``run_batch`` drives a batch of lanes to convergence (here: a vmap of
    per-lane while loops, the historical execution shape).  Subclasses
    override ``run_batch`` to change *how* hops execute without touching
    *what* a hop computes; :class:`PallasBeamStep` swaps in the fused
    single-launch hop.
    """

    name = "reference"

    def step(self, state, query_ctx: Array, adj: Array,
             eval_dists: DistEval, beam_width: int, in_budget: Array):
        """One hop of one query's walk (the reference hop body, verbatim)."""
        state, u = _select_frontier(state, in_budget)
        nbrs = adj[jnp.maximum(u, 0)]  # (R,)
        return _expand_frontier(state, u, nbrs, query_ctx, eval_dists,
                                beam_width)

    def run_batch(self, states, ctxs: Array, adj: Array,
                  eval_dists: DistEval, beam_width: int, hop_limits: Array,
                  budgets: Array | None = None):
        """Run a batch of lanes to convergence; leaves of ``states`` are
        (Q, ...) with per-lane ``hop_limits`` and optional ``budgets``."""
        if budgets is None:
            def one(state, c, h):
                return _run_search(state, c, adj, eval_dists, beam_width,
                                   hop_limit=h, step_kernel=self)

            return jax.vmap(one)(states, ctxs, hop_limits)

        def one(state, c, h, b):
            return _run_search(state, c, adj, eval_dists, beam_width,
                               hop_limit=h, budget=b, step_kernel=self)

        return jax.vmap(one)(states, ctxs, hop_limits, budgets)


class PallasBeamStep(BeamStepKernel):
    """Fused-hop execution: one ``repro.kernels.ops.beam_step`` launch per
    hop of the whole batch, beam state resident in VMEM.

    The per-lane ``step`` body is inherited unchanged (it *is* the hop's
    semantics); ``run_batch`` replaces the vmap-of-while shape with one
    batch-level while whose body is the fused kernel.  Both shapes freeze
    converged lanes identically (XLA lowers a vmapped while to exactly this
    any-cond + select-masking form), so results are bit-identical — the
    engine-parity kernel axis asserts it per backend.

    The fused kernel sees through the two standard evaluators via their
    ``kind``/``table`` tags (:func:`_exact_eval`, :func:`_pq_eval`, and the
    distributed shard evaluator); an untagged custom evaluator falls back to
    the reference execution shape.
    """

    name = "pallas"
    request = "pallas"   # ops-layer dispatch: interpret off-TPU, never oracle

    def run_batch(self, states, ctxs: Array, adj: Array,
                  eval_dists: DistEval, beam_width: int, hop_limits: Array,
                  budgets: Array | None = None):
        kind = getattr(eval_dists, "kind", None)
        table = getattr(eval_dists, "table", None)
        if kind not in ("exact", "pq") or table is None:
            return super().run_batch(states, ctxs, adj, eval_dists,
                                     beam_width, hop_limits, budgets)
        from repro.kernels import ops

        q = hop_limits.shape[0]
        b = (jnp.full((q,), beam_width, jnp.int32) if budgets is None
             else jnp.broadcast_to(budgets, (q,)).astype(jnp.int32))
        hl = jnp.broadcast_to(hop_limits, (q,)).astype(jnp.int32)

        def cond(st):
            beam_ids, _, beam_exp, _, hops, _ = st
            in_b = jax.lax.broadcasted_iota(
                jnp.int32, beam_ids.shape, 1) < b[:, None]
            frontier = jnp.any(
                (~beam_exp) & (beam_ids != INVALID) & in_b, axis=1)
            return jnp.any((hops < hl) & frontier)

        def body(st):
            return ops.beam_step(st, ctxs, adj, table, b, hl, kind=kind,
                                 request=self.request)

        return jax.lax.while_loop(cond, body, states)


REFERENCE_STEP = BeamStepKernel()
PALLAS_STEP = PallasBeamStep()


def resolve_step_kernel(
    spec: "str | BeamStepKernel | None" = None,
) -> BeamStepKernel:
    """Resolve a ``step_kernel=`` knob to a kernel object.

    ``None``/"reference" -> the reference hop; "pallas" -> the fused kernel
    (compiled on TPU, interpret-mode elsewhere — bit-identical either way);
    "auto" -> whatever :func:`repro.kernels.ops.resolve_impl` picks for this
    process (the fused kernel on TPU or under ``REPRO_PALLAS_INTERPRET=1``,
    the reference otherwise).  Kernel instances pass through, so tests can
    inject custom execution shapes.
    """
    if spec is None or spec == "reference":
        return REFERENCE_STEP
    if isinstance(spec, BeamStepKernel):
        return spec
    if spec == "pallas":
        return PALLAS_STEP
    if spec == "auto":
        from repro.kernels import ops

        return PALLAS_STEP if ops.resolve_impl() != "ref" else REFERENCE_STEP
    raise ValueError(
        f"unknown step_kernel {spec!r}; expected 'reference' | 'pallas' | "
        "'auto' (or a BeamStepKernel instance)")


def _run_search(
    state,
    query_ctx: Array,
    adj: Array,
    eval_dists: DistEval,
    beam_width: int,
    hop_limit: Array,
    budget: Array | None = None,
    step_kernel: BeamStepKernel | None = None,
):
    """Advance one query's beam search until its frontier closes.

    The physical beam is fixed-shape ``(beam_width,)``; ``budget`` (a traced
    per-query scalar) restricts the *active frontier* to the best ``budget``
    slots — the per-query knob of the adaptive engine. Because the beam is
    kept sorted by the merge, budget-b convergence is exactly beam-width-b
    search (with a slightly richer candidate pool retained for the final
    top-k). ``hop_limit`` is likewise a traced scalar, so vmapped batches
    retire work lane-by-lane as queries converge: a converged lane's cond is
    False, its state freezes, and its hop counter (== slow-tier I/O) stops —
    easy queries stop paying for hard ones.

    The hop body itself lives on ``step_kernel`` (default: the reference
    :class:`BeamStepKernel`) — this function owns only the convergence loop.
    """
    kernel = step_kernel if step_kernel is not None else REFERENCE_STEP
    slot = jnp.arange(beam_width)
    in_budget = (slot < budget) if budget is not None else jnp.ones(
        (beam_width,), dtype=bool)

    def cond(state):
        beam_ids, _, beam_exp, _, hops, _ = state
        frontier_open = jnp.any((~beam_exp) & (beam_ids != INVALID) & in_budget)
        return (hops < hop_limit) & frontier_open

    def body(state):
        return kernel.step(state, query_ctx, adj, eval_dists, beam_width,
                           in_budget)

    return jax.lax.while_loop(cond, body, state)


def _search_one(
    query_ctx: Array,
    adj: Array,
    entry: Array,
    eval_dists: DistEval,
    n: int,
    beam_width: int,
    max_hops: int,
) -> tuple[Array, Array, SearchStats]:
    """Beam search for a single query context; vmap over the batch.

    The visited set is a *bit-packed* uint32 array (n/32 words): 8x less
    working-set memory and HBM traffic than a bool mask — at billion-scale
    shards (3.9M points/device, 128-query chunks) this is the difference
    between a 500 MB and a 62 MB visited buffer (§Perf, mcgi serve cells).
    Requires duplicate-free adjacency rows (the pruner dedups; random init
    graphs are dedup'd at construction).
    """
    state = _init_state(query_ctx, entry, eval_dists, n, beam_width)
    beam_ids, beam_d, _, _, hops, evals = _run_search(
        state, query_ctx, adj, eval_dists, beam_width,
        hop_limit=jnp.int32(max_hops),
    )
    return beam_ids, beam_d, SearchStats(hops=hops, dist_evals=evals)


def fixed_search_batch(
    ctxs: Array,
    adj: Array,
    entry: Array,
    eval_dists: DistEval,
    n: int,
    beam_width: int,
    max_hops: int,
    step_kernel: "str | BeamStepKernel | None" = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats]:
    """Batched fixed-beam walk through the pluggable step kernel.

    The batch-level counterpart of ``vmap(_search_one)`` (same math, same
    results): init every lane, then hand the batch to the step kernel's
    ``run_batch`` — which is exactly the historical vmapped loop for the
    reference kernel, or one fused launch per hop for the Pallas one.

    ``excl`` ((Q, ceil(n/32)) uint32 from :func:`pack_filter`) runs the walk
    filtered in-graph: excluded nodes never enter the beam (visited
    pre-seed) and the exit beam is scrubbed of the forced entry seed.
    """
    kernel = resolve_step_kernel(step_kernel)
    if excl is None:
        states = jax.vmap(
            lambda c: _init_state(c, entry, eval_dists, n, beam_width))(ctxs)
    else:
        states = jax.vmap(
            lambda c, e: _init_state(c, entry, eval_dists, n, beam_width,
                                     excl_words=e))(ctxs, excl)
    hop_limits = jnp.full((ctxs.shape[0],), jnp.int32(max_hops))
    beam_ids, beam_d, _, _, hops, evals = kernel.run_batch(
        states, ctxs, adj, eval_dists, beam_width, hop_limits)
    if excl is not None:
        beam_ids, beam_d = scrub_excluded(beam_ids, beam_d, excl)
    return beam_ids, beam_d, SearchStats(hops=hops, dist_evals=evals)


# --------------------------------------------------------------------------
# Out-of-core walk programs.
#
# The reference walk is a vmapped ``lax.while_loop`` whose body gathers
# ``adj[u]`` in-graph — which requires the whole adjacency in device memory.
# The out-of-core walk runs the *same* per-lane ops as a host-driven loop of
# two device programs, yielding each hop's frontier ids to the host so the
# adjacency rows can come from the block store instead:
#
#     select:  (state)            -> (state', u, active)     [device]
#     fetch:   rows = adj[u]      via BlockSlowTier          [host  ]
#     hop:     (state', u, rows)  -> expand, then next select [device]
#
# Bit-identity with the in-graph walk rests on two properties the codebase
# already pins elsewhere: (a) XLA lowers a vmapped while_loop to an any-cond
# loop whose body select-masks converged lanes — ``_lane_active`` +
# ``_freeze_inactive`` below replicate exactly that form, so each lane's
# state sequence is identical; (b) per-lane ops are batch-shape-invariant
# (the bucketed scheduler already slices lanes into differently-shaped
# programs and asserts bitwise equality against the full-batch program).


def _lane_active(state, in_budget: Array, hop_limit: Array) -> Array:
    """One lane's while-loop condition (verbatim from ``_run_search``)."""
    beam_ids, _, beam_exp, _, hops, _ = state
    frontier_open = jnp.any((~beam_exp) & (beam_ids != INVALID) & in_budget)
    return (hops < hop_limit) & frontier_open


def _freeze_inactive(active: Array, new, old):
    """Per-lane select-masking: inactive lanes keep their old state leaves —
    the exact form XLA lowers a vmapped ``while_loop`` body to."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(active, n, o), new, old)


def ooc_select_batch(states, budgets: Array, hop_limits: Array,
                     beam_width: int):
    """First frontier selection of an out-of-core walk segment.

    Returns ``(states, u, active)``: per-lane frontier node ids (INVALID for
    lanes whose loop condition is already False — no I/O is issued for them)
    and the lanes' activity mask. The beam_exp mark of the selection is
    applied only to active lanes.
    """
    def one(state, b, h):
        in_budget = jnp.arange(beam_width) < b
        active = _lane_active(state, in_budget, h)
        sel, u = _select_frontier(state, in_budget)
        return (_freeze_inactive(active, sel, state),
                jnp.where(active, u, jnp.int32(INVALID)), active)

    return jax.vmap(one)(states, budgets, hop_limits)


def ooc_hop_batch(states, u: Array, active: Array, rows: Array, ctxs: Array,
                  eval_dists: DistEval, budgets: Array, hop_limits: Array,
                  beam_width: int):
    """One out-of-core hop: expand the previously selected frontier with its
    host-fetched adjacency rows, then select the next frontier.

    ``rows[i]`` must equal ``adj[u[i]]`` for active lanes (INVALID lanes in
    ``rows`` are ignored — ``_expand_frontier`` masks on ``u``). Returns
    ``(states, u_next, active_next)`` with the same conventions as
    :func:`ooc_select_batch`.
    """
    def one(state, u1, a1, nbrs, c, b, h):
        in_budget = jnp.arange(beam_width) < b
        expanded = _expand_frontier(state, u1, nbrs, c, eval_dists,
                                    beam_width)
        state = _freeze_inactive(a1, expanded, state)
        a2 = _lane_active(state, in_budget, h)
        sel, u2 = _select_frontier(state, in_budget)
        return (_freeze_inactive(a2, sel, state),
                jnp.where(a2, u2, jnp.int32(INVALID)), a2)

    return jax.vmap(one)(states, u, active, rows, ctxs, budgets, hop_limits)


@functools.partial(jax.jit, static_argnames=("n", "beam_width"))
def ooc_init_pq(codes: Array, ctxs: Array, entry: Array, n: int,
                beam_width: int, excl: Array | None = None):
    """Fresh per-lane states for a PQ-steered out-of-core walk (entry node's
    ADC distance comes from the device-resident codes).  ``excl`` seeds the
    per-lane visited bitsets with the filter, exactly as in
    :func:`fixed_search_batch`."""
    if excl is None:
        return jax.vmap(
            lambda c: _init_state(c, entry, _pq_eval(codes), n,
                                  beam_width))(ctxs)
    return jax.vmap(
        lambda c, e: _init_state(c, entry, _pq_eval(codes), n, beam_width,
                                 excl_words=e))(ctxs, excl)


@functools.partial(jax.jit, static_argnames=("beam_width",))
def ooc_select_pq(states, budgets, hop_limits, beam_width: int):
    return ooc_select_batch(states, budgets, hop_limits, beam_width)


@functools.partial(jax.jit, static_argnames=("beam_width",))
def ooc_hop_pq(codes, states, u, active, rows, ctxs, budgets, hop_limits,
               beam_width: int):
    return ooc_hop_batch(states, u, active, rows, ctxs, _pq_eval(codes),
                         budgets, hop_limits, beam_width)


def budget_bucket_ceilings(
    l_min: int, l_max: int, max_buckets: int = 4
) -> tuple[int, ...]:
    """Power-of-two-style budget ceilings covering [l_min, l_max], ascending.

    Halving down from ``l_max`` gives at most ``max_buckets`` ceilings whose
    last element is always ``l_max`` (so every granted budget has a bucket).
    E.g. (16, 96, 4) -> (16, 24, 48, 96). The small, geometric family keeps
    host-side bucket scheduling to a handful of padded batch shapes.
    """
    assert max_buckets >= 1 and 0 < l_min <= l_max
    cs = [int(l_max)]
    while len(cs) < max_buckets and cs[-1] > int(l_min):
        cs.append(max(int(l_min), cs[-1] // 2))
    return tuple(sorted(set(cs)))


def quantize_budgets(
    budgets: Array, ceilings: tuple[int, ...]
) -> tuple[Array, Array]:
    """Round each granted budget *up* to its bucket ceiling (jit-safe).

    Returns (bucket_index, quantized_budget); ``ceilings`` must be ascending
    with ``ceilings[-1] >= budgets.max()``. Used in-graph by the distributed
    path, where the bucket ceiling doubles as the hedged per-query hop
    deadline, and on the host by the bucket scheduler.
    """
    ceil_arr = jnp.asarray(ceilings, dtype=jnp.int32)
    idx = jnp.searchsorted(ceil_arr, budgets.astype(jnp.int32), side="left")
    idx = jnp.minimum(idx, len(ceilings) - 1)
    return idx, ceil_arr[idx]


def _bucket_hop_limits(
    budget_cfg: AdaptiveBeamBudget, budgets: Array, max_hops: int | None
) -> Array:
    """Per-query hop limit = probe + hop_factor * budget, SLO-capped."""
    hop_limits = (jnp.int32(budget_cfg.probe_hops)
                  + jnp.int32(budget_cfg.hop_factor) * budgets)
    if max_hops is not None:
        hop_limits = jnp.minimum(hop_limits, jnp.int32(max_hops))
    return hop_limits


def grant_budgets(
    probe_state,
    budget_cfg: AdaptiveBeamBudget,
    max_hops: int | None = None,
    *,
    lam: Array | None = None,
    l_min: Array | None = None,
):
    """Phase 2 of the adaptive engine: LID estimate + budget grant from a
    finished probe state.

    Factored out of :func:`adaptive_probe_batch` so the out-of-core walk's
    host-driven probe grants budgets through the *same* ops (bit-identical
    LID/budget/hop-limit values for the same probe state). Returns
    ``(budgets, hop_limits, q_lid)``.
    """
    from repro.core import lid as lid_mod
    from repro.core import mapping as mapping_mod

    lam_ = budget_cfg.lam if lam is None else lam
    l_min_ = budget_cfg.l_min if l_min is None else l_min
    p_ids, p_d = probe_state[0], probe_state[1]
    d_pool = jnp.where(p_ids == INVALID, jnp.inf, p_d)
    q_lid = lid_mod.online_lid(d_pool, k=min(budget_cfg.lid_k,
                                             budget_cfg.l_max))
    center = (jnp.float32(budget_cfg.center)
              if budget_cfg.center is not None else jnp.mean(q_lid))
    budgets = mapping_mod.adaptive_beam_budget(
        q_lid, lam_, l_min_, budget_cfg.l_max, mu=center)
    hop_limits = _bucket_hop_limits(budget_cfg, budgets, max_hops)
    return budgets, hop_limits, q_lid


_grant_budgets_jit = jax.jit(
    grant_budgets, static_argnames=("budget_cfg", "max_hops"))


def adaptive_probe_batch(
    ctxs: Array,
    adj: Array,
    entry: Array,
    eval_dists: DistEval,
    n: int,
    budget_cfg: AdaptiveBeamBudget,
    max_hops: int | None = None,
    *,
    lam: Array | None = None,
    l_min: Array | None = None,
    step_kernel: "str | BeamStepKernel | None" = None,
    excl: Array | None = None,
):
    """Phases 1-2 of the adaptive engine: probe walk + budget grant.

    Every query walks ``probe_hops`` hops at ``l_min`` frontier budget into a
    fixed-shape ``l_max``-wide beam; its LID is estimated from the probe
    beam's own candidate distances (``lid.online_lid`` — no brute-force k-NN
    pre-pass) and mapped to ``L(q)`` by ``mapping.adaptive_beam_budget``.

    ``lam``/``l_min`` override the config's values with *traced scalars* —
    the per-shard budget-law path of the distributed engine, where each
    shard's calibrated (lam, l_min) arrives as a runtime array and must not
    recompile the program. Shape knobs (``l_max``, ``probe_hops``,
    ``lid_k``) always come from ``budget_cfg``.

    Returns (probe_state, budgets, hop_limits, q_lid); ``probe_state`` is the
    warm per-query search state the continue phase resumes from.

    ``excl`` ((Q, ceil(n/32)) uint32 from :func:`pack_filter`) makes the
    probe walk filtered in-graph; the returned probe state is already
    scrubbed of the forced entry seed, so the continue phase (which only
    ever admits nodes past the pre-seeded visited set) and every partial
    rerank of the probe beam need no filter awareness of their own.
    """
    l_max = budget_cfg.l_max
    l_min_ = budget_cfg.l_min if l_min is None else l_min

    kernel = resolve_step_kernel(step_kernel)
    if excl is None:
        states = jax.vmap(
            lambda c: _init_state(c, entry, eval_dists, n, l_max))(ctxs)
    else:
        states = jax.vmap(
            lambda c, e: _init_state(c, entry, eval_dists, n, l_max,
                                     excl_words=e))(ctxs, excl)
    nq = ctxs.shape[0]
    probe_state = kernel.run_batch(
        states, ctxs, adj, eval_dists, l_max,
        hop_limits=jnp.full((nq,), jnp.int32(budget_cfg.probe_hops)),
        budgets=jnp.broadcast_to(jnp.int32(l_min_), (nq,)))
    if excl is not None:
        probe_state = _scrub_state(probe_state, excl)
    budgets, hop_limits, q_lid = grant_budgets(
        probe_state, budget_cfg, max_hops, lam=lam, l_min=l_min)
    return probe_state, budgets, hop_limits, q_lid


def adaptive_continue_batch(
    probe_state,
    ctxs: Array,
    adj: Array,
    eval_dists: DistEval,
    budget_cfg: AdaptiveBeamBudget,
    budgets: Array,
    hop_limits: Array,
    step_kernel: "str | BeamStepKernel | None" = None,
):
    """Phase 3: resume the probe states (warm beam + visited set, no repeated
    hops) with per-query frontier budgets and hop limits.

    Returns (beam_ids, beam_d, hops, evals); the counters include the probe
    phase (the continue loop resumes them).
    """
    kernel = resolve_step_kernel(step_kernel)
    beam_ids, beam_d, _, _, hops, evals = kernel.run_batch(
        probe_state, ctxs, adj, eval_dists, budget_cfg.l_max,
        hop_limits=hop_limits, budgets=budgets)
    return beam_ids, beam_d, hops, evals


def adaptive_search_batch(
    ctxs: Array,
    adj: Array,
    entry: Array,
    eval_dists: DistEval,
    n: int,
    budget_cfg: AdaptiveBeamBudget,
    max_hops: int | None = None,
    bucket_ceilings: tuple[int, ...] | None = None,
    *,
    lam: Array | None = None,
    l_min: Array | None = None,
    step_kernel: "str | BeamStepKernel | None" = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats, AdaptiveStats]:
    """The per-query adaptive-beam engine (Prop. 4.2 deployed in-graph).

    Three phases, one compiled program, no host round-trip:
      1. *probe*   — every query walks ``probe_hops`` hops at ``l_min``
         frontier budget, filling the (fixed-shape, ``l_max``-wide) beam;
      2. *budget*  — each query's LID is estimated from the probe beam's own
         candidate distances and mapped to ``L(q)``;
      3. *continue* — the same search states resume (warm state, no repeated
         hops) with per-query frontier budgets and hop limits.

    Returns (beam_ids, beam_d, stats, adaptive_stats); hops in ``stats``
    count probe + continuation. ``max_hops``, when given, caps every
    per-query hop limit — an operator's latency SLO outranks the budget law.

    ``bucket_ceilings`` (an ascending static tuple from
    :func:`budget_bucket_ceilings`) quantizes each granted budget *up* to its
    bucket ceiling in-graph and derives the hop limit from the ceiling — the
    hedged per-shard hop deadline of the distributed path: a straggler
    query's walk is cut off at its bucket's deadline instead of the shard
    dropping its whole contribution. For host-side bucket *scheduling* (which
    keeps results bit-identical to this unbucketed path) see
    :func:`beam_search_exact_adaptive` / :func:`beam_search_pq_adaptive` with
    ``num_buckets``.

    ``lam``/``l_min``, when given, are traced per-shard budget-law overrides
    forwarded to :func:`adaptive_probe_batch` (the distributed path's
    per-shard calibration).
    """
    probe_state, budgets, hop_limits, q_lid = adaptive_probe_batch(
        ctxs, adj, entry, eval_dists, n, budget_cfg, max_hops,
        lam=lam, l_min=l_min, step_kernel=step_kernel, excl=excl)
    if bucket_ceilings is not None:
        _, budgets = quantize_budgets(budgets, bucket_ceilings)
        hop_limits = _bucket_hop_limits(budget_cfg, budgets, max_hops)
    beam_ids, beam_d, hops, evals = adaptive_continue_batch(
        probe_state, ctxs, adj, eval_dists, budget_cfg, budgets, hop_limits,
        step_kernel=step_kernel)
    return (beam_ids, beam_d, SearchStats(hops=hops, dist_evals=evals),
            AdaptiveStats(q_lid=q_lid, budget=budgets))


def _exact_eval(x: Array) -> DistEval:
    """Full-precision squared-L2 distance evaluator (in-memory mode)."""
    def eval_dists(q, ids, valid):
        vecs = x[ids]
        diff = vecs - q[None, :]
        return jnp.sum(diff * diff, axis=-1)

    # Tags let the fused Pallas step route this evaluator's table itself
    # (the kernel gathers rows by DMA instead of calling the closure).
    eval_dists.kind = "exact"
    eval_dists.table = x
    return eval_dists


def _pq_eval(codes: Array) -> DistEval:
    """ADC distance evaluator over PQ codes; the query ctx is its LUT."""
    def eval_dists(lut, ids, valid):
        # lut: (M, K); codes[ids]: (R, M) -> sum_m lut[m, code[r, m]]
        c = codes[ids].astype(jnp.int32)
        m = lut.shape[0]
        gathered = jax.vmap(lambda row: lut[jnp.arange(m), row])(c)
        return gathered.sum(axis=-1)

    eval_dists.kind = "pq"
    eval_dists.table = codes
    return eval_dists


@functools.partial(
    jax.jit, static_argnames=("beam_width", "max_hops", "k", "step_kernel")
)
def beam_search_exact(
    x: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    beam_width: int,
    max_hops: int = 2048,
    k: int = 10,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats]:
    """Exact-distance beam search, batched over (Q, D) queries.

    Returns (ids, d2, stats): (Q, k) ascending results + per-query counters.
    ``excl`` (from :func:`pack_filter`) runs the walk attribute-filtered
    in-graph; out-of-filter results come back INVALID/inf, never ids.
    """
    n = x.shape[0]
    eval_dists = _exact_eval(x)
    beam_ids, beam_d, stats = fixed_search_batch(
        queries, adj, entry, eval_dists, n, beam_width, max_hops,
        step_kernel=step_kernel, excl=excl)
    return beam_ids[:, :k], beam_d[:, :k], stats


@functools.partial(
    jax.jit,
    static_argnames=("beam_width", "max_hops", "k", "rerank", "step_kernel"),
)
def beam_search_pq(
    codes: Array,
    luts: Array,
    x_slow: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    beam_width: int,
    max_hops: int = 2048,
    k: int = 10,
    rerank: bool = True,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats]:
    """PQ-routed beam search + optional full-precision re-rank.

    Args:
      codes:  (N, M) uint8 PQ codes — the fast-tier (HBM) representation.
      luts:   (Q, M, K) per-query ADC lookup tables
        (``repro.pq.adc.build_lut``).
      x_slow: (N, D) full-precision vectors — the slow tier; touched only for
        the final beam re-rank (one batched read of ``beam_width`` nodes,
        mirroring DiskANN's read-along-the-path + rerank).
      adj:    (N, R) graph.
      excl:   optional (Q, ceil(n/32)) filter words from :func:`pack_filter`;
        the walk runs filtered in-graph and the rerank sees a pre-scrubbed
        beam (INVALID lanes rank at inf), so it needs no filter awareness.
    """
    n = codes.shape[0]
    eval_dists = _pq_eval(codes)
    beam_ids, beam_d, stats = fixed_search_batch(
        luts, adj, entry, eval_dists, n, beam_width, max_hops,
        step_kernel=step_kernel, excl=excl)

    if rerank:
        ids, d2 = _rerank_slow_tier(beam_ids, x_slow, queries, k)
        return ids, d2, stats
    return beam_ids[:, :k], beam_d[:, :k], stats


def _rerank_slow_tier(beam_ids, x_slow, queries, k):
    """Full-precision re-rank of the final beam (one batched slow-tier read)."""
    safe = jnp.maximum(beam_ids, 0)
    vecs = x_slow[safe]  # (Q, L, D) — the batched slow-tier read
    return _rerank_from_vecs(beam_ids, vecs, queries, k)


def _rerank_from_vecs(beam_ids, vecs, queries, k):
    """Re-rank from pre-gathered beam vectors (Q, L, D).

    The arithmetic tail of :func:`_rerank_slow_tier`, shared with the
    disk-backed slow tier (:class:`repro.index.disk.BlockSlowTier`), whose
    gather happens on the host out of block reads instead of an in-graph
    index — the two paths run identical ops on identical values, so results
    are bit-identical.
    """
    diff = vecs - queries[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(beam_ids == INVALID, jnp.inf, d2)
    order = jnp.argsort(d2, axis=-1)[:, :k]
    return (
        jnp.take_along_axis(beam_ids, order, axis=1),
        jnp.take_along_axis(d2, order, axis=1),
    )


@functools.partial(jax.jit, static_argnames=("budget_cfg", "k", "step_kernel"))
def _beam_search_exact_adaptive_jit(
    x, adj, queries, entry, budget_cfg: AdaptiveBeamBudget, k: int = 10,
    step_kernel: str | None = None, excl: Array | None = None,
):
    """Single-program adaptive path: probe + continue in one compiled call."""
    beam_ids, beam_d, stats, astats = adaptive_search_batch(
        queries, adj, entry, _exact_eval(x), x.shape[0], budget_cfg,
        step_kernel=step_kernel, excl=excl)
    return beam_ids[:, :k], beam_d[:, :k], stats, astats


@functools.partial(jax.jit, static_argnames=("budget_cfg", "step_kernel"))
def _probe_exact_jit(x, adj, queries, entry, budget_cfg: AdaptiveBeamBudget,
                     step_kernel: str | None = None,
                     excl: Array | None = None):
    return adaptive_probe_batch(
        queries, adj, entry, _exact_eval(x), x.shape[0], budget_cfg,
        step_kernel=step_kernel, excl=excl)


@functools.partial(jax.jit, static_argnames=("budget_cfg", "step_kernel"))
def _continue_exact_jit(x, adj, probe_state, ctxs, budgets, hop_limits,
                        budget_cfg: AdaptiveBeamBudget,
                        step_kernel: str | None = None):
    return adaptive_continue_batch(
        probe_state, ctxs, adj, _exact_eval(x), budget_cfg, budgets,
        hop_limits, step_kernel=step_kernel)


@functools.partial(jax.jit, static_argnames=("budget_cfg", "step_kernel"))
def _probe_pq_jit(codes, adj, luts, entry, budget_cfg: AdaptiveBeamBudget,
                  step_kernel: str | None = None,
                  excl: Array | None = None):
    return adaptive_probe_batch(
        luts, adj, entry, _pq_eval(codes), codes.shape[0], budget_cfg,
        step_kernel=step_kernel, excl=excl)


@functools.partial(jax.jit, static_argnames=("budget_cfg", "step_kernel"))
def _continue_pq_jit(codes, adj, probe_state, luts, budgets, hop_limits,
                     budget_cfg: AdaptiveBeamBudget,
                     step_kernel: str | None = None):
    return adaptive_continue_batch(
        probe_state, luts, adj, _pq_eval(codes), budget_cfg, budgets,
        hop_limits, step_kernel=step_kernel)


def _bucketed_continue(
    continue_fn,
    probe_state,
    ctxs: Array,
    budgets: Array,
    hop_limits: Array,
    ceilings: tuple[int, ...],
):
    """Host-side budget-bucketed continue phase, via the serving scheduler.

    The scheduling itself lives in :mod:`repro.serving.pipeline` (this module
    keeps only the device-side search kernels); the eager per-bucket gather
    discipline here is the historical behaviour of the ``num_buckets=`` entry
    points.  The staged engine (:class:`repro.serving.engine.SearchEngine`)
    drives the same scheduler with deferred gathers and double buffering.
    Returns (beam_ids, beam_d, hops, evals) in the original query order.
    """
    from repro.serving import pipeline as pipe

    out_ids, out_d, out_hops, out_evals = pipe.bucketed_continue(
        continue_fn, probe_state, ctxs, budgets, hop_limits, ceilings)
    return (jnp.asarray(out_ids), jnp.asarray(out_d),
            jnp.asarray(out_hops), jnp.asarray(out_evals))


def beam_search_exact_adaptive(
    x: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    budget_cfg: AdaptiveBeamBudget,
    k: int = 10,
    num_buckets: int | None = None,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats, AdaptiveStats]:
    """Exact-distance adaptive-beam search (probe -> budget -> continue).

    Per-query counterpart of :func:`beam_search_exact`: the frontier budget is
    ``L(q)`` from the probe-phase LID estimate instead of a fixed
    ``beam_width``. Returns (ids, d2, stats, adaptive_stats).

    ``num_buckets`` >= 2 switches the continue phase to budget-bucketed
    execution (:func:`_bucketed_continue`): queries are grouped by granted
    budget and each bucket runs to its own ceiling, so converged lanes free
    real compute. Results are identical to the single-program path.

    ``excl`` filters the walk in-graph (see :func:`pack_filter`); only the
    probe needs it — the continue phase resumes a scrubbed probe state whose
    visited bitset already carries the filter.
    """
    if num_buckets is None or num_buckets <= 1:
        return _beam_search_exact_adaptive_jit(
            x, adj, queries, entry, budget_cfg, k=k, step_kernel=step_kernel,
            excl=excl)
    probe_state, budgets, hop_limits, q_lid = _probe_exact_jit(
        x, adj, queries, entry, budget_cfg, step_kernel=step_kernel,
        excl=excl)
    ceilings = budget_bucket_ceilings(
        budget_cfg.l_min, budget_cfg.l_max, num_buckets)
    cont = functools.partial(_continue_exact_jit, x, adj,
                             budget_cfg=budget_cfg, step_kernel=step_kernel)
    beam_ids, beam_d, hops, evals = _bucketed_continue(
        cont, probe_state, queries, budgets, hop_limits, ceilings)
    return (beam_ids[:, :k], beam_d[:, :k],
            SearchStats(hops=hops, dist_evals=evals),
            AdaptiveStats(q_lid=q_lid, budget=budgets))


@functools.partial(
    jax.jit, static_argnames=("budget_cfg", "k", "rerank", "step_kernel"))
def _beam_search_pq_adaptive_jit(
    codes, luts, x_slow, adj, queries, entry,
    budget_cfg: AdaptiveBeamBudget, k: int = 10, rerank: bool = True,
    step_kernel: str | None = None, excl: Array | None = None,
):
    beam_ids, beam_d, stats, astats = adaptive_search_batch(
        luts, adj, entry, _pq_eval(codes), codes.shape[0], budget_cfg,
        step_kernel=step_kernel, excl=excl)
    if rerank:
        ids, d2 = _rerank_slow_tier(beam_ids, x_slow, queries, k)
        return ids, d2, stats, astats
    return beam_ids[:, :k], beam_d[:, :k], stats, astats


_rerank_slow_tier_jit = jax.jit(_rerank_slow_tier, static_argnames=("k",))
_rerank_from_vecs_jit = jax.jit(_rerank_from_vecs, static_argnames=("k",))


def beam_search_pq_adaptive(
    codes: Array,
    luts: Array,
    x_slow: Array,
    adj: Array,
    queries: Array,
    entry: Array,
    budget_cfg: AdaptiveBeamBudget,
    k: int = 10,
    rerank: bool = True,
    num_buckets: int | None = None,
    step_kernel: str | None = None,
    excl: Array | None = None,
) -> tuple[Array, Array, SearchStats, AdaptiveStats]:
    """PQ-routed adaptive-beam search + optional full-precision re-rank.

    The probe-phase LID is estimated from ADC distances — the same values
    that steer the walk — so the budget decision adds zero extra slow-tier
    reads. Shapes as in :func:`beam_search_pq`. ``num_buckets`` >= 2 enables
    budget-bucketed continue execution (see
    :func:`beam_search_exact_adaptive`); the final rerank stays one batched
    slow-tier read over the whole batch.  ``excl`` filters the walk in-graph
    (probe only — the continue phase inherits the filter via the visited
    bitset, see :func:`beam_search_exact_adaptive`).
    """
    if num_buckets is None or num_buckets <= 1:
        return _beam_search_pq_adaptive_jit(
            codes, luts, x_slow, adj, queries, entry, budget_cfg,
            k=k, rerank=rerank, step_kernel=step_kernel, excl=excl)
    probe_state, budgets, hop_limits, q_lid = _probe_pq_jit(
        codes, adj, luts, entry, budget_cfg, step_kernel=step_kernel,
        excl=excl)
    ceilings = budget_bucket_ceilings(
        budget_cfg.l_min, budget_cfg.l_max, num_buckets)
    cont = functools.partial(_continue_pq_jit, codes, adj,
                             budget_cfg=budget_cfg, step_kernel=step_kernel)
    beam_ids, beam_d, hops, evals = _bucketed_continue(
        cont, probe_state, luts, budgets, hop_limits, ceilings)
    stats = SearchStats(hops=hops, dist_evals=evals)
    astats = AdaptiveStats(q_lid=q_lid, budget=budgets)
    if rerank:
        ids, d2 = _rerank_slow_tier_jit(beam_ids, x_slow, queries, k=k)
        return ids, d2, stats, astats
    return beam_ids[:, :k], beam_d[:, :k], stats, astats


def medoid(x: Array) -> Array:
    """Entry point: the point closest to the dataset centroid (DiskANN's
    choice; O(N·D) instead of the O(N^2) true medoid)."""
    c = jnp.mean(x, axis=0, keepdims=True)
    diff = x - c
    return jnp.argmin(jnp.sum(diff * diff, axis=-1)).astype(jnp.int32)
