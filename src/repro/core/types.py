"""Index datastructures shared across builders, searchers and the disk tier."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphIndex:
    """A built proximity-graph index.

    Attributes:
      adj:   (N, R) int32 out-neighbour lists, -1 padded.
      entry: scalar int32 entry point (medoid).
      alpha: (N,) per-node pruning parameter actually used at build time
             (constant array for the Vamana baseline).
      lid:   (N,) LID estimates from calibration (zeros when not calibrated,
             e.g. Vamana / Online-MCGI bootstrap-only).
      mu, sigma: population LID statistics (Eq. 7).
    """

    adj: Array
    entry: Array
    alpha: Array
    lid: Array
    mu: Array
    sigma: Array

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    @property
    def degree_cap(self) -> int:
        return self.adj.shape[1]

    def out_degrees(self) -> Array:
        return (self.adj != -1).sum(axis=1)

    def undirected_edge_set(self) -> set[tuple[int, int]]:
        """Host-side edge set (small graphs only — theory oracles/tests)."""
        import numpy as np

        adj = np.asarray(self.adj)
        edges = set()
        for u in range(adj.shape[0]):
            for v in adj[u]:
                if v >= 0:
                    edges.add((min(u, int(v)), max(u, int(v))))
        return edges
