"""Local Intrinsic Dimensionality estimation (paper §3.1).

Implements the MLE / Hill estimator of Definition 3.3 (Eq. 5):

    LID_hat(x) = - ( (1/k) * sum_i ln(r_i / r_k) )^{-1}

over the k nearest-neighbour distances r_1 <= ... <= r_k of x, plus the
population calibration (mu, sigma) of §3.2 used by the mapping function.

The estimator is exposed in three granularities:
  * :func:`lid_from_sorted_dists` — one neighbourhood, the literal Eq. 5;
  * :func:`lid_from_dists`        — batched, unsorted inputs (sorts internally);
  * :func:`estimate_dataset_lid`  — Phase 1 of Algorithm 1: exact k-NN over the
    dataset then batched estimation.

A Pallas-kernel version of the batched estimator lives in
``repro.kernels.lid_kernel`` and is validated against this module.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distance as dist_mod

Array = jax.Array

# Numerical guards: zero/duplicate distances would send ln(r_i/r_k) to -inf.
_EPS = 1e-12
# Clamp of the estimate range; LID estimates beyond ambient dimensionality of
# typical data (<= 2048 here) are estimator noise and would destabilise the
# z-score calibration.
_LID_MAX = 4096.0


def lid_from_sorted_dists(r: Array) -> Array:
    """Eq. 5 on one ascending distance vector ``r`` of shape (k,).

    Accepts *true* (not squared) distances. Returns a scalar LID estimate.
    """
    r = jnp.maximum(r, _EPS)
    rk = r[-1]
    log_ratio = jnp.log(r / rk)  # <= 0
    mean = jnp.mean(log_ratio)
    # mean == 0 happens when all k distances are identical (degenerate
    # neighbourhood, e.g. duplicated points): treat as maximally complex.
    lid = -1.0 / jnp.minimum(mean, -1.0 / _LID_MAX)
    return lid


def lid_from_dists(dists: Array, *, squared: bool = True) -> Array:
    """Batched Eq. 5.

    Args:
      dists: (B, k) neighbour distances per point, any order, possibly squared.
      squared: if True, inputs are squared-L2 (the native output of
        :mod:`repro.core.distance`); sqrt is applied to recover r_i.
    Returns:
      (B,) LID estimates.
    """
    d = jnp.sort(dists, axis=-1)
    if squared:
        d = jnp.sqrt(jnp.maximum(d, 0.0))
    return jax.vmap(lid_from_sorted_dists)(d)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LidProfile:
    """The "frozen geometric profile" of Phase 1 (paper §3.3).

    Attributes:
      lid:   (N,) per-point LID estimates.
      mu:    scalar population mean (Eq. 7).
      sigma: scalar population std (Eq. 7).
    """

    lid: Array
    mu: Array
    sigma: Array

    def zscore(self, lid: Array) -> Array:
        return (lid - self.mu) / jnp.maximum(self.sigma, 1e-6)


def calibrate(lid: Array) -> LidProfile:
    """Aggregate population statistics over per-point LID estimates."""
    mu = jnp.mean(lid)
    sigma = jnp.std(lid)
    return LidProfile(lid=lid, mu=mu, sigma=sigma)


def estimate_dataset_lid(
    x: Array, k: int = 16, chunk_q: int = 1024, metric: str = dist_mod.L2
) -> LidProfile:
    """Phase 1 (Geometric Calibration) of Algorithm 1.

    Exact k-NN for every point (O(N^2 / chunk) scan, the paper's O(N log N)
    bound assumes an auxiliary index; the framework also supports sampled
    calibration via :func:`bootstrap_stats` for large N) followed by batched
    MLE estimation and population aggregation.
    """
    d, _ = dist_mod.knn_graph(x, k=k, metric=metric, chunk_q=chunk_q)
    lid = lid_from_dists(d, squared=(metric == dist_mod.L2))
    return calibrate(lid)


def bootstrap_stats(
    x: Array, key: Array, sample: int = 2048, k: int = 16, metric: str = dist_mod.L2
) -> tuple[Array, Array]:
    """Online-MCGI Phase 1 (Algorithm 2): bootstrap (mu, sigma) from a sample.

    The sampled points are queried against the *full* dataset so the
    neighbourhood radii (and thus the statistics) are unbiased; only the set of
    reference points is subsampled.
    """
    n = x.shape[0]
    sample = min(sample, n)
    idx = jax.random.choice(key, n, shape=(sample,), replace=False)
    q = x[idx]
    d, ids = dist_mod.brute_force_topk(q, x, k=k + 1, metric=metric)
    # Drop self matches.
    is_self = ids == idx[:, None]
    d = jnp.where(is_self, jnp.inf, d)
    d = jnp.sort(d, axis=1)[:, :k]
    lid = lid_from_dists(d, squared=(metric == dist_mod.L2))
    return jnp.mean(lid), jnp.std(lid)


@functools.partial(jax.jit, static_argnames=("k",))
def online_lid(cand_dists: Array, k: int) -> Array:
    """On-the-fly LID from a greedy-search candidate pool (Algorithm 2).

    Args:
      cand_dists: (B, C) squared distances of each node's candidate pool;
        invalid entries padded with +inf.
      k: neighbourhood size to use (<= C).
    Returns:
      (B,) LID estimates from the k closest valid candidates.
    """
    d = jnp.sort(cand_dists, axis=-1)[:, :k]
    # Neighbourhoods with fewer than k valid candidates: replace inf tail with
    # the largest finite value so ln(r_i/r_k) stays finite (conservative:
    # repeats shrink the estimate's denominator -> higher LID -> stricter
    # alpha, which is the safe direction per §3.2).
    finite = jnp.isfinite(d)
    max_finite = jnp.max(jnp.where(finite, d, -jnp.inf), axis=-1, keepdims=True)
    d = jnp.where(finite, d, max_finite)
    return lid_from_dists(d, squared=True)
