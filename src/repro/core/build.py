"""MCGI index construction — Algorithm 1 (offline) of the paper.

Phase 1 (Geometric Calibration): estimate LID for every point, freeze the
population statistics (mu, sigma), map to per-node alpha(u) via Phi.

Phase 2 (Manifold-Consistent Refinement): Vamana-style synchronous rounds —
each round re-wires every node u from the candidate pool found by a greedy
search towards x_u on the current graph, pruned with the *node-specific*
alpha(u); newly created edges are mirrored (reverse-edge insertion with
re-pruning of overfull destinations), which is what makes the graph navigable
from the medoid.

The loop is host-orchestrated over jitted batch steps (search + prune are
fixed-shape jitted kernels); batch size trades host round-trips against the
(B, C, D) candidate-gather footprint.

``build_vamana`` (the DiskANN baseline) is the same procedure with the
constant-alpha mapping — the framework's way of isolating the paper's single
moving part.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lid as lid_mod
from repro.core import mapping as mapping_mod
from repro.core import prune as prune_mod
from repro.core import search as search_mod
from repro.core.types import GraphIndex

Array = jax.Array
INVALID = -1


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Construction hyper-parameters (paper Table 2 naming)."""

    degree: int = 32            # R — max out-degree
    beam_width: int = 64        # L_build — construction beam
    iters: int = 2              # T — refinement rounds
    lid_k: int = 16             # k-NN size for the LID estimator
    alpha_min: float = mapping_mod.ALPHA_MIN
    alpha_max: float = mapping_mod.ALPHA_MAX
    batch: int = 256            # nodes re-wired per jitted step
    max_hops: int = 256         # search budget during construction
    reverse_cap: int = 16       # reverse-edge candidates accepted per node/step
    seed: int = 0


def random_graph(n: int, degree: int, key: Array) -> Array:
    """R-regular random initial graph (Algorithm 1's RandomGraph).

    Rows are duplicate-free (the bit-packed visited set in the searcher
    scatter-adds one bit per neighbour, so a repeated id within a row would
    corrupt the mask)."""
    keys = jax.random.split(key, n)

    def row(k, u):
        ids = jax.random.randint(k, (degree,), 0, n, dtype=jnp.int32)
        ids = jnp.where(ids == u, (ids + 1) % n, ids)  # no self-loops
        # Mark duplicate ids INVALID (order-preserving dedup).
        srt = jnp.sort(ids)
        dup_sorted = jnp.concatenate(
            [jnp.zeros((1,), bool), srt[1:] == srt[:-1]]
        )
        # An id is a duplicate occurrence if an earlier slot holds the same id.
        earlier_same = (ids[None, :] == ids[:, None]) & (
            jnp.arange(degree)[None, :] < jnp.arange(degree)[:, None]
        )
        del dup_sorted
        return jnp.where(earlier_same.any(axis=1), INVALID, ids)

    return jax.vmap(row)(keys, jnp.arange(n, dtype=jnp.int32))


def _rewire_batch(
    x: Array,
    adj: Array,
    alpha: Array,
    entry: Array,
    node_ids: Array,
    cfg: BuildConfig,
) -> tuple[Array, Array]:
    """One jitted refinement step for a batch of nodes.

    Greedy-search each node's own vector on the current graph, pool the beam
    with the node's current neighbours, robust-prune with alpha(u).
    Returns (new_rows, new_d2): (B, R) each.
    """
    queries = x[node_ids]
    beam_ids, _, _ = search_mod.beam_search_exact(
        x, adj, queries, entry,
        beam_width=cfg.beam_width, max_hops=cfg.max_hops, k=cfg.beam_width,
    )
    pool = jnp.concatenate([beam_ids, adj[node_ids]], axis=1)  # (B, L+R)
    return prune_mod.robust_prune_batch(
        x, node_ids, pool, alpha[node_ids], cfg.degree
    )


def _reverse_pairs(
    node_ids: np.ndarray, new_rows: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side grouping of mirrored edges.

    Every new edge (u -> v) proposes the reverse candidate (v -> u). Groups by
    destination v and pads each group to ``cap`` (overflow is dropped — those
    edges get another chance in the next round, matching batched-Vamana
    practice).

    Returns (dest_ids (V,), cand (V, cap)) as numpy (INVALID padded).
    """
    us = np.repeat(node_ids, new_rows.shape[1])
    vs = new_rows.reshape(-1)
    keep = vs >= 0
    us, vs = us[keep], vs[keep]
    if vs.size == 0:
        return np.empty((0,), np.int32), np.empty((0, cap), np.int32)
    order = np.argsort(vs, kind="stable")
    us, vs = us[order], vs[order]
    dest, start = np.unique(vs, return_index=True)
    cand = np.full((dest.size, cap), INVALID, dtype=np.int32)
    bounds = np.append(start, vs.size)
    for i in range(dest.size):
        grp = us[bounds[i] : bounds[i + 1]][:cap]
        cand[i, : grp.size] = grp
    return dest.astype(np.int32), cand


def _insert_reverse(
    x: Array, adj: Array, alpha: Array, dest: Array, cand: Array, cfg: BuildConfig,
    valid: Array | None = None,
) -> Array:
    """Merge reverse candidates into destination adjacency lists, re-pruning
    overfull nodes with their own alpha(v).

    ``valid`` (optional, (B,) bool) marks real lanes in a shape-padded batch.
    Pad lanes repeat a live destination id (keeping jit shapes fixed), so
    without the mask their re-pruned rows — computed from an all-INVALID
    candidate pool, hence generally *different* from the real lane's row —
    would reach the scatter under a duplicate index, where the winner is
    unspecified.  Masked lanes scatter to row N instead, which ``mode="drop"``
    discards.
    """
    pool = jnp.concatenate([adj[dest], cand], axis=1)
    rows, _ = prune_mod.robust_prune_batch(x, dest, pool, alpha[dest], cfg.degree)
    if valid is None:
        return adj.at[dest].set(rows)
    dest = jnp.where(valid, dest, adj.shape[0])
    return adj.at[dest].set(rows, mode="drop")


def build_with_alpha(
    x: Array,
    alpha: Array,
    cfg: BuildConfig,
    progress: Callable[[str], None] | None = None,
    init_adj: Array | None = None,
) -> Array:
    """Phase 2 (Manifold-Consistent Refinement) given frozen per-node alpha."""
    n = x.shape[0]
    key = jax.random.PRNGKey(cfg.seed)
    adj = random_graph(n, cfg.degree, key) if init_adj is None else init_adj
    entry = search_mod.medoid(x)

    for it in range(cfg.iters):
        perm = np.asarray(
            jax.random.permutation(jax.random.fold_in(key, it + 1), n)
        )
        for start in range(0, n, cfg.batch):
            ids_np = perm[start : start + cfg.batch]
            if ids_np.size < cfg.batch:  # keep jit shapes fixed: wrap-around pad
                ids_np = np.concatenate([ids_np, perm[: cfg.batch - ids_np.size]])
            node_ids = jnp.asarray(ids_np)
            new_rows, _ = _rewire_batch(x, adj, alpha, entry, node_ids, cfg)
            adj = adj.at[node_ids].set(new_rows)
            dest, cand = _reverse_pairs(
                ids_np, np.asarray(new_rows), cfg.reverse_cap
            )
            for ds in range(0, dest.shape[0], cfg.batch):
                dslice = dest[ds : ds + cfg.batch]
                cslice = cand[ds : ds + cfg.batch]
                if dslice.size < cfg.batch:
                    pad = cfg.batch - dslice.size
                    dslice = np.concatenate([dslice, dslice[:1].repeat(pad)])
                    cslice = np.concatenate(
                        [cslice, np.full((pad, cfg.reverse_cap), INVALID, np.int32)]
                    )
                adj = _insert_reverse(
                    x, adj, alpha, jnp.asarray(dslice), jnp.asarray(cslice), cfg
                )
        if progress:
            progress(f"refinement round {it + 1}/{cfg.iters} done")
    return adj


def build_mcgi(
    x: Array, cfg: BuildConfig = BuildConfig(), progress=None
) -> GraphIndex:
    """Algorithm 1 — full offline MCGI build (calibration + refinement)."""
    profile = lid_mod.estimate_dataset_lid(x, k=cfg.lid_k)
    mapping = mapping_mod.AlphaMapping(
        mu=profile.mu, sigma=profile.sigma,
        alpha_min=cfg.alpha_min, alpha_max=cfg.alpha_max,
    )
    alpha = mapping(profile.lid)
    if progress:
        progress(
            f"calibration: mu={float(profile.mu):.2f} sigma={float(profile.sigma):.2f}"
        )
    adj = build_with_alpha(x, alpha, cfg, progress)
    return GraphIndex(
        adj=adj, entry=search_mod.medoid(x), alpha=alpha,
        lid=profile.lid, mu=profile.mu, sigma=profile.sigma,
    )


def build_vamana(
    x: Array, alpha: float = 1.2, cfg: BuildConfig = BuildConfig(), progress=None
) -> GraphIndex:
    """DiskANN/Vamana baseline: identical pipeline, constant alpha.

    DiskANN builds in two passes (alpha=1 then alpha=target); we reproduce
    that with iters>=2 by using alpha=1 in the first round.
    """
    n = x.shape[0]
    alpha_arr = mapping_mod.constant_alpha(n, alpha)
    if cfg.iters >= 2:
        # DiskANN's first pass runs with alpha=1, the second with the target.
        adj = build_with_alpha(
            x, mapping_mod.constant_alpha(n, 1.0),
            dataclasses.replace(cfg, iters=1), progress,
        )
        adj = build_with_alpha(
            x, alpha_arr, dataclasses.replace(cfg, iters=cfg.iters - 1),
            progress, init_adj=adj,
        )
    else:
        adj = build_with_alpha(x, alpha_arr, cfg, progress)
    return GraphIndex(
        adj=adj, entry=search_mod.medoid(x), alpha=alpha_arr,
        lid=jnp.zeros((n,), jnp.float32), mu=jnp.float32(0), sigma=jnp.float32(0),
    )


def block_layout(graph: GraphIndex, nodes_per_block: int) -> np.ndarray:
    """Build-time block-aware record layout for the on-disk store.

    Thin entry point over :func:`repro.core.prune.greedy_block_pack` taking
    the built :class:`GraphIndex` directly; the returned ``slot_of``
    permutation feeds ``write_block_store(..., nodes_per_block=,
    slot_of=)`` and is recorded in the store manifest (the serializer's
    layout rider), so a reopened store knows how its records were packed.
    """
    return prune_mod.greedy_block_pack(
        np.asarray(graph.adj), int(graph.entry), nodes_per_block)
