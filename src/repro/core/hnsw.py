"""HNSW baseline (Malkov & Yashunin) — the in-memory graph-index ceiling.

Build is the inherently sequential insertion procedure; it runs on the host in
numpy (index construction is offline — what the paper benchmarks online is
*search*). Search runs in JAX over the flattened per-layer adjacency arrays:
greedy descent (beam 1) through the upper layers, then the standard ef-width
beam on layer 0, reusing the framework's batched beam-search machinery.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod

Array = jax.Array
INVALID = -1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HnswIndex:
    layers: Array      # (n_layers, N, M) int32 adjacency per layer, INVALID pad
    entry: Array       # scalar int32 — top-layer entry point
    n_layers: int = dataclasses.field(metadata=dict(static=True), default=1)


def _select_heuristic(
    cand: list[int], dists: dict[int, float], x: np.ndarray, m: int
) -> list[int]:
    """HNSW Algorithm 4 neighbour-selection heuristic (keep diverse set)."""
    out: list[int] = []
    for c in sorted(cand, key=lambda i: dists[i]):
        if len(out) >= m:
            break
        d_cq = dists[c]
        ok = True
        for s in out:
            diff = x[c] - x[s]
            if float(diff @ diff) < d_cq:
                ok = False
                break
        if ok:
            out.append(c)
    return out


def _search_layer_np(
    x: np.ndarray, adj: np.ndarray, q: np.ndarray, entry: int, ef: int
) -> dict[int, float]:
    """Host-side ef-search on one layer during construction."""
    import heapq

    def d(i):
        diff = x[i] - q
        return float(diff @ diff)

    visited = {entry}
    d0 = d(entry)
    cand = [(d0, entry)]       # min-heap of frontier
    best = [(-d0, entry)]      # max-heap of result set
    while cand:
        dc, c = heapq.heappop(cand)
        if dc > -best[0][0] and len(best) >= ef:
            break
        for nb in adj[c]:
            if nb < 0 or nb in visited:
                continue
            visited.add(int(nb))
            dn = d(int(nb))
            if len(best) < ef or dn < -best[0][0]:
                heapq.heappush(cand, (dn, int(nb)))
                heapq.heappush(best, (-dn, int(nb)))
                if len(best) > ef:
                    heapq.heappop(best)
    return {i: -nd for nd, i in best}


def build_hnsw(
    x_jax: Array, m: int = 16, ef_construction: int = 100, seed: int = 0
) -> HnswIndex:
    """Sequential HNSW insertion (paper's [27]); numpy host build."""
    x = np.asarray(x_jax)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    ml = 1.0 / math.log(m)
    levels = np.minimum(
        (-np.log(rng.uniform(size=n, low=1e-12, high=1.0)) * ml).astype(np.int64), 8
    )
    n_layers = int(levels.max()) + 1
    m0 = 2 * m  # layer-0 degree, per the paper
    adj = [
        np.full((n, m0 if l == 0 else m), INVALID, dtype=np.int32)
        for l in range(n_layers)
    ]
    entry, entry_level = 0, int(levels[0])

    for i in range(1, n):
        li = int(levels[i])
        ep = entry
        # Greedy descent through layers above li.
        for l in range(entry_level, li, -1):
            if l >= n_layers:
                continue
            improved = True
            while improved:
                improved = False
                for nb in adj[l][ep]:
                    if nb < 0:
                        continue
                    if float((x[nb] - x[i]) @ (x[nb] - x[i])) < float(
                        (x[ep] - x[i]) @ (x[ep] - x[i])
                    ):
                        ep = int(nb)
                        improved = True
        # Insert on layers min(li, entry_level) .. 0.
        for l in range(min(li, entry_level), -1, -1):
            found = _search_layer_np(x, adj[l], x[i], ep, ef_construction)
            cap = m0 if l == 0 else m
            nbrs = _select_heuristic(list(found), found, x, cap)
            adj[l][i, : len(nbrs)] = nbrs
            for nb in nbrs:
                row = adj[l][nb]
                slot = np.argmax(row == INVALID) if (row == INVALID).any() else -1
                if row[slot] == INVALID and slot != -1:
                    row[slot] = i
                else:
                    # Overfull: re-select among existing + new.
                    cand = [int(v) for v in row if v >= 0] + [i]
                    dists = {
                        c: float((x[c] - x[nb]) @ (x[c] - x[nb])) for c in cand
                    }
                    sel = _select_heuristic(cand, dists, x, cap)
                    row[:] = INVALID
                    row[: len(sel)] = sel
            ep = nbrs[0] if nbrs else ep
        if li > entry_level:
            entry, entry_level = i, li

    # Pad every layer to the layer-0 width for a single stacked array.
    width = m0
    stacked = np.full((n_layers, n, width), INVALID, dtype=np.int32)
    for l in range(n_layers):
        stacked[l, :, : adj[l].shape[1]] = adj[l]
    return HnswIndex(
        layers=jnp.asarray(stacked), entry=jnp.int32(entry), n_layers=n_layers
    )


def search_hnsw(
    index: HnswIndex, x: Array, queries: Array, ef: int, k: int = 10
) -> tuple[Array, Array, search_mod.SearchStats]:
    """Layered search: greedy on upper layers, beam ef on layer 0."""

    def descend(q, entry):
        def layer_step(ep, l):
            # One full greedy walk on layer l (bounded hops).
            def body(state):
                ep, improved = state
                nbrs = index.layers[l, ep]
                valid = nbrs != INVALID
                vecs = x[jnp.maximum(nbrs, 0)]
                d = jnp.where(
                    valid, jnp.sum((vecs - q[None, :]) ** 2, axis=-1), jnp.inf
                )
                j = jnp.argmin(d)
                d_ep = jnp.sum((x[ep] - q) ** 2)
                better = d[j] < d_ep
                return (jnp.where(better, nbrs[j], ep), better)

            def cond(state):
                return state[1]

            ep, _ = jax.lax.while_loop(cond, body, (ep, jnp.bool_(True)))
            return ep, None

        eps, _ = jax.lax.scan(
            layer_step, entry, jnp.arange(index.n_layers - 1, 0, -1)
        )
        return eps

    entries = jax.vmap(lambda q: descend(q, index.entry))(queries)
    # Layer-0 beam search re-uses the shared machinery with per-query entries.
    layer0 = index.layers[0]

    def one(q, e):
        def eval_dists(qq, ids, valid):
            vecs = x[ids]
            return jnp.sum((vecs - qq[None, :]) ** 2, axis=-1)

        return search_mod._search_one(
            q, adj=layer0, entry=e, eval_dists=eval_dists,
            n=x.shape[0], beam_width=ef, max_hops=4 * ef,
        )

    beam_ids, beam_d, stats = jax.vmap(one)(queries, entries)
    return beam_ids[:, :k], beam_d[:, :k], stats
