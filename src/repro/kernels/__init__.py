"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ref.py the pure-jnp oracle, ops.py the jit'd dispatch wrapper. Dispatch is
one shared policy (``ops.resolve_impl``): ``REPRO_PALLAS_INTERPRET=1`` wins
everywhere (interpret mode, bit-faithful to the kernel body, TPU included),
else TPU runs the compiled kernel, else the oracle. Validated by shape/dtype
sweeps in tests/test_kernels.py.

``beam_step.py`` is the fused graph-walk hop (neighbor gather + ADC/exact
distances + beam top-k merge + visited update in one launch, beam state in
VMEM); the step-kernel layer in :mod:`repro.core.search` plugs it into
fixed-beam, probe, and continue via ``ops.beam_step``, and its "pallas"
request never falls back to the oracle — off-TPU it runs interpret-mode so
the fused arithmetic is always what executes.
"""
from repro.kernels import ops  # noqa: F401
