"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ref.py the pure-jnp oracle, ops.py the jit'd dispatch wrapper (TPU: compiled
kernel; elsewhere: interpret mode or oracle). Validated by shape/dtype sweeps
in tests/test_kernels.py.
"""
from repro.kernels import ops  # noqa: F401
