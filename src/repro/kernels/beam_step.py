"""Fused beam-step Pallas kernel — one launch per hop of the graph walk.

The serving hot loop's per-hop body (frontier select -> adjacency-row fetch ->
neighbor distance evaluation -> beam top-k merge -> visited-bitmap
test/update) otherwise lowers to a chain of separate XLA HLOs per hop; this
kernel fuses the whole hop into one ``pallas_call`` with the per-query beam
state resident in VMEM.  Grid = one program per query lane; the graph
adjacency and the distance table (full-precision rows or PQ codes) stay in
``ANY`` memory (HBM at scale) and are pulled row-by-row with explicit async
copies — the TPU expression of DiskANN's pointer-chasing gather, and exactly
the per-distance-call launch overhead CRouting identifies as the dominant
cost of graph walks.

Two static distance variants (the same two evaluators the reference walk
closes over):

* ``kind="exact"`` — ``table`` is (N, D) vectors; squared L2 against the
  query context (1, D).
* ``kind="pq"``    — ``table`` is (N, M) uint8 codes; ADC lookup against the
  per-query LUT context (1, M, K).

Bit-exactness contract: every arithmetic expression below is copied from the
reference hop body (``repro.core.search``) and runs on identical values, so
interpret-mode results are bit-identical to the reference walk — the
engine-parity kernel axis asserts this end to end.  The one structural
substitution is the beam merge: the reference's stable
``argsort(cat_d)[:L]`` becomes an L-round masked-argmin selection loop
(argsort does not lower on the TPU vector unit).  The two are bitwise equal
under the walk's state invariant — a beam/candidate entry has ``d == inf``
iff its id is INVALID (payload (INVALID, inf, False)) — because finite keys
tie-break lowest-index-first in both, and once only inf keys remain the
emitted payload is forced to the shared (INVALID, inf, False).

Lane freezing: a converged/hop-capped lane writes its state back unchanged
(the same select-masking XLA applies to a vmapped ``while_loop``), so a
batch-level while over fused steps retires lanes exactly like the reference's
per-lane loops.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
INVALID = -1


def _select_merge(cat_ids, cat_d, cat_exp, beam_width: int):
    """Keep-best-L merge as a selection loop (TPU-lowerable argsort stand-in).

    Bitwise equal to ``argsort(cat_d, stable)[:beam_width]`` gathers under
    the invariant that every inf-keyed entry carries the identical payload
    (INVALID, inf, False): finite keys pick lowest-index-first in both, and
    the all-inf tail emits that shared payload explicitly.
    """
    total = cat_d.shape[0]

    def select(i, carry):
        out_ids, out_d, out_exp, taken = carry
        key = jnp.where(taken, jnp.inf, cat_d)
        p = jnp.argmin(key)
        exhausted = jnp.isinf(key[p])
        out_ids = out_ids.at[i].set(
            jnp.where(exhausted, INVALID, cat_ids[p]))
        out_d = out_d.at[i].set(jnp.where(exhausted, jnp.inf, cat_d[p]))
        out_exp = out_exp.at[i].set(cat_exp[p] & (~exhausted))
        return out_ids, out_d, out_exp, taken.at[p].set(True)

    init = (jnp.zeros((beam_width,), jnp.int32),
            jnp.zeros((beam_width,), jnp.float32),
            jnp.zeros((beam_width,), bool),
            jnp.zeros((total,), bool))
    out_ids, out_d, out_exp, _ = jax.lax.fori_loop(
        0, beam_width, select, init)
    return out_ids, out_d, out_exp


def _beam_step_kernel(
    # per-query inputs (VMEM blocks / SMEM scalars)
    ids_ref, d_ref, exp_ref, vis_ref, hops_ref, evals_ref, bud_ref, hl_ref,
    ctx_ref,
    # whole-array inputs (ANY memory; fetched by DMA)
    adj_ref, table_ref,
    # outputs (same per-query layout as the inputs)
    o_ids, o_d, o_exp, o_vis, o_hops, o_evals,
    # scratch
    nbrs_s, rows_s, adj_sem, row_sem,
    *, kind: str, beam_width: int, degree: int,
):
    beam_ids = ids_ref[...]      # (1, L)
    beam_d = d_ref[...]          # (1, L)
    beam_exp = exp_ref[...]      # (1, L)
    visited = vis_ref[...]       # (1, NW)
    hops = hops_ref[0]
    evals = evals_ref[0]
    budget = bud_ref[0]
    hop_limit = hl_ref[0]

    slot = jax.lax.broadcasted_iota(jnp.int32, (1, beam_width), 1)
    in_budget = slot < budget
    frontier_open = jnp.any(
        (~beam_exp) & (beam_ids != INVALID) & in_budget)
    # Lane-freeze predicate: identical to the reference loop's cond, so an
    # inactive lane writes its state back unchanged.
    active = (hops < hop_limit) & frontier_open

    # --- frontier select (reference expressions, verbatim) ----------------
    cand_d = jnp.where(
        beam_exp | (beam_ids == INVALID) | (~in_budget), jnp.inf, beam_d)
    j = jnp.argmin(cand_d[0])
    u = beam_ids[0, j]
    new_exp = beam_exp.at[0, j].set(True)

    # --- adjacency row fetch (one DMA; inactive lanes fetch row 0) --------
    u_safe = jnp.maximum(u, 0)
    adj_cp = pltpu.make_async_copy(adj_ref.at[u_safe], nbrs_s, adj_sem)
    adj_cp.start()
    adj_cp.wait()
    nbrs = nbrs_s[...][None, :]                    # (1, R)

    valid = (nbrs != INVALID) & (u != INVALID)
    safe = jnp.maximum(nbrs, 0)
    word_idx = safe >> 5
    bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
    seen = (visited[0][word_idx[0]] & bit[0]) != 0
    valid = valid & (~seen)[None, :]

    # --- neighbor row gather (R row DMAs into VMEM scratch) ---------------
    def fetch(r, carry):
        row_cp = pltpu.make_async_copy(
            table_ref.at[safe[0, r]], rows_s.at[r], row_sem)
        row_cp.start()
        row_cp.wait()
        return carry

    jax.lax.fori_loop(0, degree, fetch, 0)
    rows = rows_s[...]                             # (R, D) or (R, M)

    # --- distance evaluation (the reference evaluators' expressions) ------
    if kind == "pq":
        lut = ctx_ref[...][0]                      # (M, K)
        c = rows.astype(jnp.int32)                 # (R, M)
        m = lut.shape[0]
        gathered = jax.vmap(lambda row: lut[jnp.arange(m), row])(c)
        d = gathered.sum(axis=-1)                  # (R,)
    else:
        qv = ctx_ref[...][0]                       # (D,)
        vecs = rows.astype(jnp.float32)
        diff = vecs - qv[None, :]
        d = jnp.sum(diff * diff, axis=-1)          # (R,)
    d = jnp.where(valid[0], d, jnp.inf)

    # Distinct ids set distinct bits, so scatter-add implements the OR.
    new_visited = visited[0].at[word_idx[0]].add(
        jnp.where(valid[0], bit[0], jnp.uint32(0)))[None, :]

    nbr_ids = jnp.where(valid[0], nbrs[0], INVALID)

    # --- beam top-k merge --------------------------------------------------
    cat_ids = jnp.concatenate([beam_ids[0], nbr_ids])
    cat_d = jnp.concatenate([beam_d[0], d])
    cat_exp = jnp.concatenate(
        [new_exp[0], jnp.zeros((degree,), dtype=bool)])
    m_ids, m_d, m_exp = _select_merge(cat_ids, cat_d, cat_exp, beam_width)

    # --- write-back with lane freezing ------------------------------------
    o_ids[...] = jnp.where(active, m_ids[None, :], beam_ids)
    o_d[...] = jnp.where(active, m_d[None, :], beam_d)
    o_exp[...] = jnp.where(active, m_exp[None, :], beam_exp)
    o_vis[...] = jnp.where(active, new_visited, visited)
    o_hops[0] = jnp.where(active, hops + 1, hops)
    o_evals[0] = jnp.where(active, evals + valid[0].sum(), evals)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def beam_step(
    state,
    ctxs: Array,
    adj: Array,
    table: Array,
    budgets: Array,
    hop_limits: Array,
    *,
    kind: str,
    interpret: bool = False,
):
    """Advance every lane of a batched walk state by one fused hop.

    state: (beam_ids (Q, L) i32, beam_d (Q, L) f32, beam_exp (Q, L) bool,
    visited (Q, ceil(N/32)) u32, hops (Q,) i32, evals (Q,) i32) — the walk
    state of :mod:`repro.core.search`.  ``ctxs`` is (Q, D) queries
    (``kind="exact"``) or (Q, M, K) ADC LUTs (``kind="pq"``); ``table`` the
    matching (N, D) vectors / (N, M) uint8 codes; ``budgets``/``hop_limits``
    (Q,) i32.  Returns the post-hop state; lanes whose frontier is closed or
    hop limit reached pass through unchanged.
    """
    assert kind in ("exact", "pq"), kind
    beam_ids, beam_d, beam_exp, visited, hops, evals = state
    q, beam_width = beam_ids.shape
    nw = visited.shape[1]
    degree = adj.shape[1]

    if kind == "pq":
        ctx_spec = pl.BlockSpec((1,) + ctxs.shape[1:], lambda i: (i, 0, 0))
    else:
        ctx_spec = pl.BlockSpec((1, ctxs.shape[1]), lambda i: (i, 0))
    lane = lambda i: (i, 0)
    scalar = lambda i: (i,)
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)

    out = pl.pallas_call(
        functools.partial(_beam_step_kernel, kind=kind,
                          beam_width=beam_width, degree=degree),
        grid=(q,),
        in_specs=[
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, nw), lane),
            smem((1,), scalar),        # hops
            smem((1,), scalar),        # evals
            smem((1,), scalar),        # budgets
            smem((1,), scalar),        # hop_limits
            ctx_spec,
            pl.BlockSpec(memory_space=pltpu.ANY),   # adj
            pl.BlockSpec(memory_space=pltpu.ANY),   # table
        ],
        out_specs=[
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, beam_width), lane),
            pl.BlockSpec((1, nw), lane),
            smem((1,), scalar),
            smem((1,), scalar),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, beam_width), jnp.int32),
            jax.ShapeDtypeStruct((q, beam_width), jnp.float32),
            jax.ShapeDtypeStruct((q, beam_width), jnp.bool_),
            jax.ShapeDtypeStruct((q, nw), jnp.uint32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((degree,), jnp.int32),
            pltpu.VMEM((degree,) + table.shape[1:], table.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(beam_ids, beam_d, beam_exp, visited, hops, evals,
      budgets.astype(jnp.int32), hop_limits.astype(jnp.int32), ctxs,
      adj, table)
    return tuple(out)
