"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` is the semantic ground truth the kernel sweep tests
(``tests/test_kernels.py``) assert against, and the CPU fallback that
``ops.py`` dispatches to off-TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_distance_ref(q: Array, x: Array) -> Array:
    """(Q, D), (N, D) -> (Q, N) squared L2."""
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    dot = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    return jnp.maximum(qn - 2.0 * dot + xn[None, :], 0.0)


def pq_scan_ref(lut: Array, codes: Array) -> Array:
    """(M, K) f32 LUT, (N, M) uint8 codes -> (N,) ADC distances."""
    m = lut.shape[0]
    c = codes.astype(jnp.int32)
    return lut[jnp.arange(m)[None, :], c].sum(axis=-1)


def topk_ref(d: Array, k: int) -> tuple[Array, Array]:
    """(Q, N) -> ((Q, k) ascending dists, (Q, k) ids)."""
    vals, ids = jax.lax.top_k(-d, k)
    return -vals, ids.astype(jnp.int32)


def lid_ref(knn_d2: Array) -> Array:
    """(B, k) ascending squared k-NN distances -> (B,) Hill LID estimates."""
    r = jnp.sqrt(jnp.maximum(knn_d2, 1e-24))
    rk = r[:, -1:]
    mean_log = jnp.mean(jnp.log(r / rk), axis=-1)
    return -1.0 / jnp.minimum(mean_log, -1.0 / 4096.0)


def beam_step_ref(state, ctxs, adj, table, budgets, hop_limits, *, kind):
    """One fused beam-walk hop over a batch of lanes (pure-jnp oracle).

    Semantic ground truth for ``kernels/beam_step.py``: advances every lane
    of the walk state (beam_ids, beam_d, beam_exp, visited, hops, evals) by
    one hop — frontier select, adjacency gather, distance eval
    (``kind="exact"``: ``table`` is (N, D) vectors, ``ctxs`` (Q, D) queries;
    ``kind="pq"``: ``table`` is (N, M) codes, ``ctxs`` (Q, M, K) ADC LUTs),
    stable-argsort beam merge, visited-bitmap update — freezing lanes whose
    frontier is closed or hop limit reached.  Mirrors the reference hop body
    in :mod:`repro.core.search` expression-for-expression (kept standalone so
    the kernels package has no core dependency).
    """
    assert kind in ("exact", "pq"), kind
    INVALID = -1

    def one(beam_ids, beam_d, beam_exp, visited, hops, evals, ctx,
            budget, hop_limit):
        beam_width = beam_ids.shape[0]
        in_budget = jnp.arange(beam_width) < budget
        frontier_open = jnp.any((~beam_exp) & (beam_ids != INVALID) & in_budget)
        active = (hops < hop_limit) & frontier_open

        cand_d = jnp.where(
            beam_exp | (beam_ids == INVALID) | (~in_budget), jnp.inf, beam_d)
        j = jnp.argmin(cand_d)
        u = beam_ids[j]
        new_exp = beam_exp.at[j].set(True)

        nbrs = adj[jnp.maximum(u, 0)]
        valid = (nbrs != INVALID) & (u != INVALID)
        safe = jnp.maximum(nbrs, 0)
        word_idx = safe >> 5
        bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
        seen = (visited[word_idx] & bit) != 0
        valid = valid & (~seen)

        if kind == "pq":
            c = table[safe].astype(jnp.int32)
            m = ctx.shape[0]
            gathered = jax.vmap(lambda row: ctx[jnp.arange(m), row])(c)
            d = gathered.sum(axis=-1)
        else:
            vecs = table[safe].astype(jnp.float32)
            diff = vecs - ctx[None, :]
            d = jnp.sum(diff * diff, axis=-1)
        d = jnp.where(valid, d, jnp.inf)
        new_visited = visited.at[word_idx].add(jnp.where(valid, bit, 0))

        nbr_ids = jnp.where(valid, nbrs, INVALID)
        cat_ids = jnp.concatenate([beam_ids, nbr_ids])
        cat_d = jnp.concatenate([beam_d, d])
        cat_exp = jnp.concatenate([new_exp, jnp.zeros(nbrs.shape, dtype=bool)])
        order = jnp.argsort(cat_d)[:beam_width]
        m_ids, m_d, m_exp = cat_ids[order], cat_d[order], cat_exp[order]

        return (jnp.where(active, m_ids, beam_ids),
                jnp.where(active, m_d, beam_d),
                jnp.where(active, m_exp, beam_exp),
                jnp.where(active, new_visited, visited),
                jnp.where(active, hops + 1, hops),
                jnp.where(active, evals + valid.sum(), evals))

    q = state[0].shape[0]
    budgets = jnp.broadcast_to(budgets, (q,)).astype(jnp.int32)
    hop_limits = jnp.broadcast_to(hop_limits, (q,)).astype(jnp.int32)
    return jax.vmap(one)(*state, ctxs, budgets, hop_limits)


def decode_attention_gqa_ref(
    q: Array, k: Array, v: Array, kv_len: Array | None = None
) -> Array:
    """GQA decode attention *without* expanding KV across the group dim.

    q: (B, Hq, d); k, v: (B, S, Hkv, d) with Hq = G * Hkv. The grouped
    einsum keeps the (possibly sequence-sharded) cache unexpanded — a
    ``jnp.repeat`` here makes GSPMD all-gather the whole cache (observed:
    2 x 1 GB per layer on the long_500k cells).
    """
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    if kv_len is not None:
        s = k.shape[1]
        mask = jnp.arange(s)[None, None, None, :] < kv_len[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, d)


def decode_attention_ref(
    q: Array, k: Array, v: Array, kv_len: Array | None = None
) -> Array:
    """Single-token decode attention (the serving hot loop).

    q: (B, H, d); k, v: (B, S, H, d) — H is kv-head count after GQA groups
    are folded into B·H by the caller. kv_len: (B,) valid prefix lengths.
    Returns (B, H, d).
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if kv_len is not None:
        s = k.shape[1]
        mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
