"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` is the semantic ground truth the kernel sweep tests
(``tests/test_kernels.py``) assert against, and the CPU fallback that
``ops.py`` dispatches to off-TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_distance_ref(q: Array, x: Array) -> Array:
    """(Q, D), (N, D) -> (Q, N) squared L2."""
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    dot = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    return jnp.maximum(qn - 2.0 * dot + xn[None, :], 0.0)


def pq_scan_ref(lut: Array, codes: Array) -> Array:
    """(M, K) f32 LUT, (N, M) uint8 codes -> (N,) ADC distances."""
    m = lut.shape[0]
    c = codes.astype(jnp.int32)
    return lut[jnp.arange(m)[None, :], c].sum(axis=-1)


def topk_ref(d: Array, k: int) -> tuple[Array, Array]:
    """(Q, N) -> ((Q, k) ascending dists, (Q, k) ids)."""
    vals, ids = jax.lax.top_k(-d, k)
    return -vals, ids.astype(jnp.int32)


def lid_ref(knn_d2: Array) -> Array:
    """(B, k) ascending squared k-NN distances -> (B,) Hill LID estimates."""
    r = jnp.sqrt(jnp.maximum(knn_d2, 1e-24))
    rk = r[:, -1:]
    mean_log = jnp.mean(jnp.log(r / rk), axis=-1)
    return -1.0 / jnp.minimum(mean_log, -1.0 / 4096.0)


def decode_attention_gqa_ref(
    q: Array, k: Array, v: Array, kv_len: Array | None = None
) -> Array:
    """GQA decode attention *without* expanding KV across the group dim.

    q: (B, Hq, d); k, v: (B, S, Hkv, d) with Hq = G * Hkv. The grouped
    einsum keeps the (possibly sequence-sharded) cache unexpanded — a
    ``jnp.repeat`` here makes GSPMD all-gather the whole cache (observed:
    2 x 1 GB per layer on the long_500k cells).
    """
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    if kv_len is not None:
        s = k.shape[1]
        mask = jnp.arange(s)[None, None, None, :] < kv_len[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, d)


def decode_attention_ref(
    q: Array, k: Array, v: Array, kv_len: Array | None = None
) -> Array:
    """Single-token decode attention (the serving hot loop).

    q: (B, H, d); k, v: (B, S, H, d) — H is kv-head count after GQA groups
    are folded into B·H by the caller. kv_len: (B,) valid prefix lengths.
    Returns (B, H, d).
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if kv_len is not None:
        s = k.shape[1]
        mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
