"""PQ ADC scan Pallas kernel — the fast-tier distance hot-spot.

CPU DiskANN does M byte-gathers per point (AVX2 shuffle loops). Gathers are
VPU-serial on TPU, so the kernel re-expresses the scan as an MXU matmul:

    global_code[n, m] = code[n, m] + m*K          (flat LUT index)
    onehot(global_code) : (TN, M*K)  — built in-register from iota compares
    dist[n] = onehot(global_code[n]) @ lut_flat   (TN, M*K) x (M*K,)

With M=16, K=256 the one-hot tile is (128, 4096) f32 = 2 MB VMEM and the
matmul is MXU-shaped. The LUT block (one query's full table, M*K f32 = 16 KB)
stays resident across the base sweep.

Grid: (queries, base tiles). Output (Q, N) approximate distances.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp

Array = jax.Array

TILE_N = 128


def _pq_scan_kernel(lut_ref, codes_ref, o_ref, *, m: int, k: int):
    lut = lut_ref[...].reshape(1, m * k).astype(jnp.float32)   # (1, M*K)
    codes = codes_ref[...].astype(jnp.int32)                   # (TN, M)
    offsets = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1) * k
    flat = codes + offsets                                     # (TN, M)
    onehot = _onehot(flat, m, k)                               # (TN, M*K)
    dist = jax.lax.dot_general(
        onehot, lut.T, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TN, 1)
    o_ref[...] = dist.reshape(1, TILE_N)


def _onehot(flat: Array, m: int, k: int) -> Array:
    """(TN, M) flat indices -> (TN, M*K) sum-of-onehots (in-register)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (TILE_N, m, k), 2)
    sub = jax.lax.broadcasted_iota(jnp.int32, (TILE_N, m, k), 1)
    target = flat[:, :, None]
    hits = (cols + sub * k) == target
    return hits.astype(jnp.float32).reshape(TILE_N, m * k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq_scan(luts: Array, codes: Array, *, interpret: bool = False) -> Array:
    """(Q, M, K) LUTs x (N, M) uint8 codes -> (Q, N) ADC distances."""
    q, m, k = luts.shape
    n = codes.shape[0]
    pad = (-n) % TILE_N
    cp = jnp.pad(codes, ((0, pad), (0, 0)))
    grid = (q, cp.shape[0] // TILE_N)
    out = pl.pallas_call(
        functools.partial(_pq_scan_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, k), lambda qi, nj: (qi, 0, 0)),
            pl.BlockSpec((TILE_N, m), lambda qi, nj: (nj, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_N), lambda qi, nj: (qi, nj)),
        out_shape=jax.ShapeDtypeStruct((q, cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(luts, cp)
    return out[:, :n]
