"""Flash-decoding attention Pallas kernel — the LM serving hot-spot.

One new token attends to a long KV cache: q (B, Hq, d) vs k/v (B, S, Hkv, d)
with GQA group g = Hq/Hkv. The sequence axis is streamed in TS-sized tiles
with the online-softmax recurrence (running max m, normaliser l, accumulator
acc in VMEM scratch), so the (B, Hq, S) logits matrix never materialises —
the kernel is HBM-bound at exactly (k+v bytes), which is the roofline for
decode.

Grid: (B, Hq, S/TS); TPU grid steps run sequentially with the last axis
fastest, which is what makes the scratch-carried recurrence valid.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

Array = jax.Array

TILE_S = 512


def _decode_attn_kernel(
    q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].reshape(-1).astype(jnp.float32)          # (d,)
    k = k_ref[...].reshape(TILE_S, -1).astype(jnp.float32)  # (TS, d)
    v = v_ref[...].reshape(TILE_S, -1).astype(jnp.float32)  # (TS, d)
    kv_len = len_ref[0, 0]

    logits = jax.lax.dot_general(
        k, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * scale                                          # (TS,)
    pos = j * TILE_S + jax.lax.broadcasted_iota(jnp.int32, (TILE_S,), 0)
    logits = jnp.where(pos < kv_len, logits, -jnp.inf)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    # All-masked tiles keep m at -inf; guard the exp against nan.
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(logits - safe_m)                             # (TS,)
    correction = jnp.where(
        jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0
    )
    l_new = l_ref[0, 0] * correction + jnp.sum(p)
    acc = acc_ref[...].reshape(-1) * correction + jax.lax.dot_general(
        p[None, :], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0]
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    acc_ref[...] = acc.reshape(acc_ref.shape)
    o_ref[...] = (acc / jnp.maximum(l_new, 1e-30)).reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(
    q: Array, k: Array, v: Array, kv_len: Array, *, interpret: bool = False
) -> Array:
    """q (B, Hq, d); k, v (B, S, Hkv, d); kv_len (B,) -> (B, Hq, d)."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    pad = (-s) % TILE_S
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_tiles = kp.shape[1] // TILE_S
    lens = kv_len.astype(jnp.int32).reshape(b, 1)
    scale = 1.0 / (d ** 0.5)

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, scale=scale),
        grid=(b, hq, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
            pl.BlockSpec((1, TILE_S, 1, d), lambda bi, hi, j: (bi, j, hi // g, 0)),
            pl.BlockSpec((1, TILE_S, 1, d), lambda bi, hi, j: (bi, j, hi // g, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, j: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max m
            pltpu.VMEM((1, 1), jnp.float32),   # running normaliser l
            pltpu.VMEM((1, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, kp, vp, lens)
    return out
