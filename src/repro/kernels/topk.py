"""Two-phase k-selection Pallas kernel (beam merge / bulk-scan top-k).

Phase 1 (this kernel): per (query, base-tile) block, select the local top-k
by k rounds of masked row-min — k is small (<= 64) so the rounds stay in
registers; distances live in VMEM once.

Phase 2 (jnp, negligible): merge the (Q, n_tiles·k) partials with one sort.
This mirrors how TPU top-k is implemented in practice (tile-local selection +
log-merge) while keeping the kernel simple enough to verify in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp

Array = jax.Array

TILE_N = 1024


def _topk_tile_kernel(d_ref, vals_ref, ids_ref, *, k: int, tile: int):
    d = d_ref[...].reshape(tile).astype(jnp.float32)
    base = pl.program_id(1) * tile
    ids = jax.lax.broadcasted_iota(jnp.int32, (tile,), 0) + base

    def round_(i, state):
        d_masked, vals, out_ids = state
        j = jnp.argmin(d_masked)
        vals = vals.at[i].set(d_masked[j])
        out_ids = out_ids.at[i].set(ids[j])
        d_masked = d_masked.at[j].set(jnp.inf)
        return d_masked, vals, out_ids

    vals0 = jnp.full((k,), jnp.inf, jnp.float32)
    ids0 = jnp.full((k,), -1, jnp.int32)
    _, vals, out_ids = jax.lax.fori_loop(0, k, round_, (d, vals0, ids0))
    vals_ref[...] = vals.reshape(1, 1, k)
    ids_ref[...] = out_ids.reshape(1, 1, k)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk(d: Array, k: int, *, interpret: bool = False) -> tuple[Array, Array]:
    """(Q, N) distances -> ((Q, k) ascending, (Q, k) int32 ids)."""
    q, n = d.shape
    pad = (-n) % TILE_N
    dp = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
    n_tiles = dp.shape[1] // TILE_N
    grid = (q, n_tiles)
    vals, ids = pl.pallas_call(
        functools.partial(_topk_tile_kernel, k=k, tile=TILE_N),
        grid=grid,
        in_specs=[pl.BlockSpec((1, TILE_N), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((q, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(dp)
    # Phase 2: merge partials.
    flat_v = vals.reshape(q, n_tiles * k)
    flat_i = ids.reshape(q, n_tiles * k)
    order = jnp.argsort(flat_v, axis=1)[:, :k]
    return (
        jnp.take_along_axis(flat_v, order, axis=1),
        jnp.take_along_axis(flat_i, order, axis=1),
    )
