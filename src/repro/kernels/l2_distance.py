"""Tiled squared-L2 distance Pallas kernel — the bulk distance hot-spot.

Replaces the paper's AVX2 inner loop. Tiling: (TQ, D) query tile x (TN, D)
base tile -> (TQ, TN) output block; the cross term is one MXU matmul per
block, norms are VPU row reductions fused in the same kernel. TQ = TN = 128
keeps every matmul dimension MXU-aligned and the working set
(2·128·D + 128·128) f32 within VMEM for D up to ~8k.

Grid iterates base tiles fastest so each query tile's norms are reused across
the whole base sweep from VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp

Array = jax.Array

TILE_Q = 128
TILE_N = 128


def _l2_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (TQ, D)
    x = x_ref[...].astype(jnp.float32)  # (TN, D)
    qn = jnp.sum(q * q, axis=1, keepdims=True)        # (TQ, 1)
    xn = jnp.sum(x * x, axis=1, keepdims=True).T      # (1, TN)
    dot = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TN) on the MXU
    o_ref[...] = jnp.maximum(qn - 2.0 * dot + xn, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_distance(q: Array, x: Array, *, interpret: bool = False) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2. Q, N padded to tile multiples."""
    nq, d = q.shape
    n = x.shape[0]
    pq_pad = (-nq) % TILE_Q
    pn_pad = (-n) % TILE_N
    qp = jnp.pad(q, ((0, pq_pad), (0, 0)))
    xp = jnp.pad(x, ((0, pn_pad), (0, 0)))

    grid = (qp.shape[0] // TILE_Q, xp.shape[0] // TILE_N)
    out = pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_Q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_Q, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:nq, :n]
